//! Property-based tests (RNG-driven, in the proptest spirit — the offline
//! build has no proptest crate) over the coordinator-side invariants:
//! quantization, optimizers, spike detection, the data pipeline.

use switchback::optim::{clip_global_norm, AdamW, AdamWConfig, Optimizer, ParamMeta};
use switchback::quant;
use switchback::telemetry::{detect_loss_spikes, lead_lag_from_events, SpikeConfig};
use switchback::tensor::{Matrix, Rng};

fn meta(n: usize) -> Vec<ParamMeta> {
    (0..n)
        .map(|i| ParamMeta { name: format!("p{i}"), decay: false, kind: "w".into() })
        .collect()
}

/// Quantization invariants over 200 random matrices:
/// codes in range, absmax maps to ±127, dequant error ≤ half a step,
/// quantization is idempotent on its own grid.
#[test]
fn prop_rowwise_quant_invariants() {
    let mut rng = Rng::seed(101);
    for trial in 0..200 {
        let rows = 1 + rng.below(40);
        let cols = 1 + rng.below(60);
        let scale = [1e-4f32, 1.0, 1e4][rng.below(3)];
        let x = Matrix::randn(rows, cols, scale, &mut rng);
        let q = quant::rowwise_quant(&x);
        for r in 0..rows {
            let row = x.row(r);
            let absmax = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            if absmax > 0.0 {
                assert_eq!(q.state[r], absmax, "trial {trial}");
                let has_extreme = q.codes.row(r).iter().any(|&c| c == 127 || c == -127);
                assert!(has_extreme, "absmax element must map to ±127");
            }
            let step = q.state[r] / 127.0;
            for (&v, &c) in row.iter().zip(q.codes.row(r)) {
                assert!((c as f32 * step - v).abs() <= 0.5 * step * 1.0001 + 1e-12);
            }
        }
        // idempotence: dequantized values re-quantize to the same codes
        let back = quant::dequant_rowwise(&q);
        let q2 = quant::rowwise_quant(&back);
        assert_eq!(q.codes.data, q2.codes.data, "trial {trial}: not idempotent");
    }
}

/// fp8 invariants over random values: result is on the fp8 grid (its own
/// round-trip fixed point), monotone, sign-symmetric, magnitude-bounded.
#[test]
fn prop_fp8_round_invariants() {
    let mut rng = Rng::seed(102);
    for fmt in [quant::E4M3, quant::E5M2] {
        for _ in 0..5000 {
            let v = rng.normal() * [1e-6f32, 1e-2, 1.0, 1e3][rng.below(4)];
            let r = quant::fp8_round(v, fmt);
            assert_eq!(quant::fp8_round(r, fmt), r, "fixed point: {v} {r}");
            assert_eq!(quant::fp8_round(-v, fmt), -r, "odd symmetry");
            assert!(r.abs() <= fmt.max_value);
            // relative error bound for normals: half ULP = 2^-(m+1)
            if v.abs() >= (2.0f32).powi(fmt.min_normal_exp) && v.abs() <= fmt.max_value {
                let tol = v.abs() * (2.0f32).powi(-(fmt.mantissa_bits + 1)) * 1.0001;
                assert!((r - v).abs() <= tol, "{v} -> {r} (fmt {})", fmt.name);
            }
        }
    }
}

/// Gradient clipping: post-clip norm never exceeds the max, direction is
/// preserved, and no-op when already inside the ball.
#[test]
fn prop_clip_global_norm() {
    let mut rng = Rng::seed(103);
    for _ in 0..100 {
        let n_tensors = 1 + rng.below(5);
        let mut grads: Vec<Vec<f32>> = (0..n_tensors)
            .map(|_| {
                let n = 1 + rng.below(50);
                let mut v = vec![0.0; n];
                rng.fill_normal(&mut v, 10.0);
                v
            })
            .collect();
        let orig = grads.clone();
        let max = 0.5 + rng.uniform() * 5.0;
        let pre = clip_global_norm(&mut grads, max);
        let post: f32 = grads
            .iter()
            .flat_map(|g| g.iter().map(|v| v * v))
            .sum::<f32>()
            .sqrt();
        assert!(post <= max * 1.0001, "post {post} max {max}");
        if pre <= max {
            assert_eq!(grads, orig, "no-op inside the ball");
        } else {
            // direction preserved: ratios constant
            let k = post / pre;
            for (g, o) in grads.iter().flatten().zip(orig.iter().flatten()) {
                assert!((g - o * k).abs() < 1e-4);
            }
        }
    }
}

/// StableAdamW invariant: the applied lr multiplier is always ≤ 1 and
/// equals 1/max(1, RMS); plain AdamW always reports multiplier 1.
#[test]
fn prop_update_clipping_multiplier() {
    let mut rng = Rng::seed(104);
    for clip in [false, true] {
        let mut opt = AdamW::new(
            AdamWConfig { update_clipping: clip, ..AdamWConfig::plain(0.995) },
            &meta(3),
            &[8, 8, 8],
        );
        let mut params = vec![vec![0.0f32; 8]; 3];
        for _ in 0..50 {
            let grads: Vec<Vec<f32>> = (0..3)
                .map(|_| {
                    let mut g = vec![0.0f32; 8];
                    let scale = (10.0f32).powi(rng.below(5) as i32 - 2);
                    rng.fill_normal(&mut g, scale);
                    g
                })
                .collect();
            let stats = opt.step(&mut params, &grads, 1e-3, None);
            for (rms, mult) in stats.rms.iter().zip(&stats.lr_mult) {
                if clip {
                    assert!((mult - 1.0 / rms.max(1.0)).abs() < 1e-6);
                    assert!(*mult <= 1.0 + 1e-6);
                } else {
                    assert_eq!(*mult, 1.0);
                }
            }
            for p in params.iter().flatten() {
                assert!(p.is_finite());
            }
        }
    }
}

/// Spike detector sanity under random walks: a flat-noise trace produces
/// (almost) no confirmed spikes; injected plateaus are always found.
#[test]
fn prop_spike_detector_false_positive_rate() {
    let mut rng = Rng::seed(105);
    let cfg = SpikeConfig { burn_in: 20, ..Default::default() };
    let mut total_fp = 0;
    for _ in 0..20 {
        let trace: Vec<f32> = (0..500).map(|_| 2.0 + 0.05 * rng.normal()).collect();
        total_fp += detect_loss_spikes(&trace, &cfg).len();
    }
    assert!(total_fp <= 2, "too many false positives on pure noise: {total_fp}");

    for trial in 0..20 {
        let mut trace: Vec<f32> = (0..500).map(|_| 2.0 + 0.05 * rng.normal()).collect();
        let at = 100 + rng.below(300);
        for i in at..at + 4 {
            trace[i] = 6.0;
        }
        let spikes = detect_loss_spikes(&trace, &cfg);
        assert!(
            spikes.iter().any(|&t| t.abs_diff(at as u64) <= 2),
            "trial {trial}: missed injected spike at {at}: {spikes:?}"
        );
    }
}

/// Lead–lag analyzer: under random (unrelated) spike trains, the predicted
/// fraction should be close to the chance fraction — no spurious causality.
#[test]
fn prop_lead_lag_no_spurious_causality() {
    let mut rng = Rng::seed(106);
    let len = 20000u64;
    let mut total_pred = 0usize;
    let mut total_expected = 0.0f64;
    let mut total_spikes = 0usize;
    for _ in 0..30 {
        let loss_spikes: Vec<u64> = {
            let mut v: Vec<u64> = (0..30).map(|_| rng.below(len as usize) as u64).collect();
            v.sort();
            v.dedup();
            v
        };
        let rms_spikes: Vec<u64> = {
            let mut v: Vec<u64> = (0..60).map(|_| rng.below(len as usize) as u64).collect();
            v.sort();
            v.dedup();
            v
        };
        let rep = lead_lag_from_events(&loss_spikes, &rms_spikes, len);
        total_pred += rep.predicted;
        total_expected += rep.chance_fraction * rep.total_loss_spikes as f64;
        total_spikes += rep.total_loss_spikes;
    }
    let rate = total_pred as f64 / total_spikes as f64;
    let expected = total_expected / total_spikes as f64;
    assert!(
        (rate - expected).abs() < 0.03,
        "random spikes predicted at {rate:.3} vs chance {expected:.3}"
    );
}

/// Data pipeline: batches are finite, labelled, and learnable-by-construction
/// (same-concept images are closer to each other than to other concepts).
#[test]
fn prop_data_concept_structure() {
    use switchback::data::{DataConfig, SyntheticClip};
    let mut d = SyntheticClip::new(DataConfig::for_model(16, 48, 16, 512, 3));
    let b = d.next_batch(64);
    assert!(b.images.iter().all(|v| v.is_finite()));
    let dim = 16 * 48;
    // mean intra-concept distance < mean inter-concept distance
    let img = |i: usize| &b.images[i * dim..(i + 1) * dim];
    let dist = |a: &[f32], c: &[f32]| -> f32 {
        a.iter().zip(c).map(|(x, y)| (x - y) * (x - y)).sum::<f32>()
    };
    let (mut intra, mut inter, mut ni, mut nx) = (0.0f32, 0.0f32, 0, 0);
    for i in 0..64 {
        for j in (i + 1)..64 {
            let e = dist(img(i), img(j));
            if b.concepts[i] == b.concepts[j] {
                intra += e;
                ni += 1;
            } else {
                inter += e;
                nx += 1;
            }
        }
    }
    if ni > 0 && nx > 0 {
        assert!(
            intra / ni as f32 * 1.5 < inter / nx as f32,
            "concepts not separable: intra {} inter {}",
            intra / ni as f32,
            inter / nx as f32
        );
    }
}

/// Quant round-trip (`rowwise_quant` → `dequant_rowwise`): the max-abs
/// reconstruction error over a whole matrix is bounded by half a quant
/// step of its worst row, across benign and adversarial distributions
/// (outlier rows, near-zero rows, extreme scales).
#[test]
fn prop_quant_roundtrip_max_abs_error_bound() {
    let mut rng = Rng::seed(404);
    for trial in 0..50 {
        let rows = 1 + rng.below(24);
        let cols = 1 + rng.below(48);
        let scale = [1e-6f32, 1e-2, 1.0, 1e4][rng.below(4)];
        let mut x = Matrix::randn(rows, cols, scale, &mut rng);
        // adversarial structure: one outlier row, one all-zero row
        if rows >= 2 {
            let c = rng.below(cols);
            x.row_mut(0)[c] = 1e6;
            for v in x.row_mut(rows - 1) {
                *v = 0.0;
            }
        }
        let q = quant::rowwise_quant(&x);
        let back = quant::dequant_rowwise(&q);
        let max_err = x.max_abs_diff(&back);
        let worst_half_step =
            q.state.iter().fold(0.0f32, |m, &s| m.max(s)) / quant::INT8_MAX / 2.0;
        assert!(
            max_err <= worst_half_step * 1.0001 + 1e-12,
            "trial {trial}: max-abs err {max_err} exceeds half-step {worst_half_step}"
        );
        // the all-zero row must reconstruct exactly
        if rows >= 2 {
            assert!(back.row(rows - 1).iter().all(|&v| v == 0.0));
        }
    }
}

/// `LinearCache::retained_bytes`: SwitchBackM's int8 activation cache is
/// ≈4× smaller than the f32 cache every other kind keeps (Algorithm 3's
/// selling point), and both report exact byte counts.
#[test]
fn prop_linear_cache_retained_bytes() {
    use switchback::nn::{Linear, LinearKind};
    let mut rng = Rng::seed(405);
    for &(rows, cols) in &[(8usize, 256usize), (64, 64), (3, 1024)] {
        let x = Matrix::randn(rows, cols, 1.0, &mut rng);
        let full = Linear::new(16, cols, LinearKind::SwitchBack, &mut rng);
        let mem = Linear::new(16, cols, LinearKind::SwitchBackM, &mut rng);
        let (_, c_full) = full.forward(&x);
        let (_, c_mem) = mem.forward(&x);
        // exact accounting: f32 = 4 bytes/elt; int8 = 1 byte/elt + 4/row
        assert_eq!(c_full.retained_bytes(), rows * cols * 4);
        assert_eq!(c_mem.retained_bytes(), rows * cols + rows * 4);
        let ratio = c_full.retained_bytes() as f64 / c_mem.retained_bytes() as f64;
        assert!(
            ratio > 3.5 && ratio <= 4.0,
            "{rows}x{cols}: expected ≈4× cache saving, got {ratio:.2}×"
        );
    }
}
