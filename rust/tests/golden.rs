//! Cross-language golden tests: the rust `quant` module must agree
//! bit-for-bit with the jnp oracles (`python/compile/kernels/ref.py`),
//! via golden vectors emitted by `aot.py` into artifacts/quant_golden.json.

use std::path::Path;
use switchback::quant::{self, E4M3, E5M2};
use switchback::tensor::Matrix;
use switchback::util::json::{parse, Value};

fn load_golden() -> Option<Value> {
    let p = Path::new("artifacts/quant_golden.json");
    if !p.exists() {
        eprintln!("skipping: artifacts/quant_golden.json not built");
        return None;
    }
    Some(parse(&std::fs::read_to_string(p).unwrap()).unwrap())
}

fn f32s(v: &Value, key: &str) -> Vec<f32> {
    v.get(key)
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as f32)
        .collect()
}

fn i8s(v: &Value, key: &str) -> Vec<i8> {
    v.get(key)
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as i8)
        .collect()
}

#[test]
fn rowwise_quant_bit_exact_vs_jax() {
    let Some(g) = load_golden() else { return };
    let rows = g.get("rows").unwrap().as_usize().unwrap();
    let cols = g.get("cols").unwrap().as_usize().unwrap();
    let x = Matrix::from_vec(rows, cols, f32s(&g, "x"));
    let q = quant::rowwise_quant(&x);
    assert_eq!(q.codes.data, i8s(&g, "row_codes"), "row codes differ from jax");
    let want_state = f32s(&g, "row_state");
    for (a, b) in q.state.iter().zip(&want_state) {
        assert!((a - b).abs() <= f32::EPSILON * a.abs(), "{a} vs {b}");
    }
}

#[test]
fn tensorwise_quant_bit_exact_vs_jax() {
    let Some(g) = load_golden() else { return };
    let rows = g.get("rows").unwrap().as_usize().unwrap();
    let cols = g.get("cols").unwrap().as_usize().unwrap();
    let x = Matrix::from_vec(rows, cols, f32s(&g, "x"));
    let q = quant::tensorwise_quant(&x);
    assert_eq!(q.codes.data, i8s(&g, "tensor_codes"));
    let want = g.get("tensor_state").unwrap().as_f64().unwrap() as f32;
    assert!((q.state - want).abs() <= f32::EPSILON * want.abs());
}

#[test]
fn fp8_rounding_bit_exact_vs_jax() {
    let Some(g) = load_golden() else { return };
    let x = f32s(&g, "x");
    let want_e4 = f32s(&g, "fp8_e4m3");
    for (i, want) in want_e4.iter().enumerate() {
        let got = quant::fp8_round(x[i], E4M3);
        assert_eq!(got, *want, "e4m3 idx {i}: input {}", x[i]);
    }
    let want_e5 = f32s(&g, "fp8_e5m2_x100");
    for (i, want) in want_e5.iter().enumerate() {
        let got = quant::fp8_round(x[i] * 100.0, E5M2);
        assert_eq!(got, *want, "e5m2 idx {i}: input {}", x[i] * 100.0);
    }
}
