//! Integration tests for `switchback lint` over the committed fixture
//! corpus (tests/fixtures/lint/) and over the real tree itself.
//!
//! - `fire/` must produce at least one ACTIVE finding per rule, one lock
//!   cycle, and one held-across-blocking finding;
//! - `clean/` must produce zero active findings (its string/comment
//!   traps and `lint:allow` site are the interesting part);
//! - `src/` (the shipped tree) must lint clean with a cycle-free,
//!   non-empty lock graph — the same gate CI enforces.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use switchback::analysis::{lint_root, Level, LintReport, RULES};
use switchback::util::json;

fn fixture(tree: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/lint")
        .join(tree)
}

fn lint_fixture(tree: &str) -> LintReport {
    lint_root(&fixture(tree)).expect("fixture tree readable")
}

#[test]
fn fire_tree_triggers_every_rule() {
    let r = lint_fixture("fire");
    let fired: BTreeSet<&str> = r.active().map(|f| f.rule).collect();
    for rule in RULES {
        assert!(fired.contains(rule), "rule {rule} did not fire on fire/");
    }
    // `--deny warn` must fail on this tree.
    assert!(r.worst() >= Some(Level::Warn));
    assert_eq!(r.suppressed_total(), 0, "fire/ has no lint:allow sites");
}

#[test]
fn fire_tree_findings_land_in_the_expected_files() {
    let r = lint_fixture("fire");
    let expect = [
        ("no-panic-path", "serve/panic_path.rs"),
        ("safety-comment", "gemm/unsafe_nosafety.rs"),
        ("checked-narrowing", "ckpt/narrowing.rs"),
        ("epoch-clock", "util/clock.rs"),
        ("metrics-naming", "serve/metrics_name.rs"),
        ("joined-spawn", "util/spawn_discard.rs"),
        ("lock-order", "serve/lock_cycle.rs"),
    ];
    for (rule, rel) in expect {
        assert!(
            r.active().any(|f| f.rule == rule && f.rel == rel),
            "expected {rule} finding in {rel}"
        );
    }
}

#[test]
fn fire_tree_lock_graph_has_the_synthetic_cycle() {
    let r = lint_fixture("fire");
    assert!(!r.graph.cycles.is_empty(), "two-lock cycle not detected");
    let cycle = &r.graph.cycles[0];
    assert!(cycle.iter().any(|n| n.ends_with("::alpha")), "cycle: {cycle:?}");
    assert!(cycle.iter().any(|n| n.ends_with("::beta")), "cycle: {cycle:?}");
    assert!(r.graph.blocking_holds() >= 1, "held-across-join not detected");
    // Lock findings are errors: a cycle must fail even `--deny error`.
    assert_eq!(r.worst(), Some(Level::Error));
}

#[test]
fn clean_tree_has_zero_active_findings() {
    let r = lint_fixture("clean");
    let leaked: Vec<String> = r
        .active()
        .map(|f| format!("{}:{} {} {}", f.rel, f.line, f.rule, f.message))
        .collect();
    assert!(leaked.is_empty(), "clean/ fired: {leaked:?}");
    // The one `lint:allow(no-panic-path)` site is counted, not dropped.
    assert_eq!(r.suppressed_total(), 1);
    assert!(r.graph.cycles.is_empty());
    assert_eq!(r.graph.blocking_holds(), 0);
    // Consistent-order nesting still shows up as a graph edge.
    assert!(!r.graph.edges.is_empty(), "alpha->beta edge expected");
}

#[test]
fn shipped_tree_lints_clean() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let r = lint_root(&src).expect("src tree readable");
    let leaked: Vec<String> = r
        .active()
        .map(|f| format!("{}:{} {} {}", f.rel, f.line, f.rule, f.message))
        .collect();
    assert!(leaked.is_empty(), "shipped tree fired: {leaked:?}");
    assert!(r.graph.cycles.is_empty(), "real lock graph has a cycle");
    assert_eq!(r.graph.blocking_holds(), 0);
    assert!(!r.graph.nodes.is_empty(), "lock graph saw no locks at all");
    assert!(r.graph.functions > 0);
}

#[test]
fn ledger_json_round_trips_for_both_trees() {
    for (tree, active_min) in [("fire", 1usize), ("clean", 0usize)] {
        let r = lint_fixture(tree);
        let v = json::parse(&r.ledger_json()).expect("ledger parses");
        assert_eq!(v.get("schema").and_then(json::Value::as_str), Some("lint_ledger_v1"));
        let total = v.get("findings_total").and_then(json::Value::as_usize).unwrap();
        if active_min == 0 {
            assert_eq!(total, 0, "{tree} ledger");
        } else {
            assert!(total >= active_min, "{tree} ledger: {total}");
        }
        for rule in RULES {
            let key = rule.replace('-', "_");
            assert!(v.get(&format!("rule_{key}")).is_some(), "{tree}: rule_{key}");
            assert!(v.get(&format!("sup_{key}")).is_some(), "{tree}: sup_{key}");
        }
        for key in ["lock_nodes", "lock_edges", "lock_cycles", "blocking_holds"] {
            assert!(v.get(key).is_some(), "{tree}: {key} missing");
        }
    }
}
