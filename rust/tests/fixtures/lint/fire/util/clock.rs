//! Should-fire fixture: raw `Instant::now()` outside `trace/`.

use std::time::Instant;

pub fn stamp() -> Instant {
    Instant::now()
}

pub fn stamp_qualified() -> std::time::Instant {
    std::time::Instant::now()
}
