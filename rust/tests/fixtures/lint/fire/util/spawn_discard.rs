//! Should-fire fixture: a spawn whose `JoinHandle` is discarded — the
//! thread can never be joined.

pub fn fire_and_forget() {
    std::thread::spawn(|| {
        println!("orphan");
    });
}

pub fn discarded_via_let_underscore() {
    let _ = std::thread::spawn(|| 42);
}
