//! Should-fire fixture: `unsafe` with no adjacent justification comment.

pub fn caller(p: *const u8) -> u8 {
    unsafe { *p }
}
