//! Should-fire fixture: every `no-panic-path` shape the rule must catch
//! inside a panic-free directory (`serve/`).

pub fn unwrap_on_request_path(v: Option<u32>) -> u32 {
    v.unwrap()
}

pub fn expect_on_request_path(v: Option<u32>) -> u32 {
    v.expect("present")
}

pub fn panics(flag: bool) {
    if flag {
        panic!("boom");
    }
    unreachable!()
}

pub fn variable_indexing(xs: &[u32], idx: usize) -> u32 {
    xs[idx]
}
