//! Should-fire fixture: a synthetic two-lock cycle (`alpha` before
//! `beta` in one function, `beta` before `alpha` in another) plus a lock
//! held across a blocking `join()`.

use std::sync::Mutex;
use std::thread::JoinHandle;

pub struct Pair {
    pub alpha: Mutex<u32>,
    pub beta: Mutex<u32>,
}

pub fn alpha_then_beta(p: &Pair) -> u32 {
    let a = p.alpha.lock();
    let b = p.beta.lock();
    let out = *b.unwrap_or_else(|e| e.into_inner()) + *a.unwrap_or_else(|e| e.into_inner());
    out
}

pub fn beta_then_alpha(p: &Pair) -> u32 {
    let b = p.beta.lock();
    let a = p.alpha.lock();
    let out = *a.unwrap_or_else(|e| e.into_inner()) + *b.unwrap_or_else(|e| e.into_inner());
    out
}

pub fn held_across_join(m: &Mutex<u32>, h: JoinHandle<()>) {
    let guard = m.lock();
    let _ = h.join();
    drop(guard);
}
