//! Should-fire fixture: counter names that violate the exposition
//! contract — the registry appends `_total` at exposition time, so a
//! literal already ending in `_total` double-suffixes, and names must be
//! lowercase dotted.

pub fn bad_counter_names() {
    crate::trace::global().counter("serve.requests_total").inc();
    crate::trace::global().counter("Serve.Requests").inc();
}
