//! Should-fire fixture: bare `as` integer narrowing on a parse path
//! (`ckpt/` is a parser directory).

pub fn parse_crc(raw: u64) -> u32 {
    raw as u32
}

pub fn parse_len(raw: u64) -> u16 {
    raw as u16
}
