//! Should-NOT-fire fixture for `no-panic-path`: every shape that looks
//! like a violation to a naive scanner but is not one.
//!
//! A comment saying panic!("never") or xs.unwrap() must not fire.

pub fn string_and_comment_traps() -> &'static str {
    // .unwrap() inside a string literal is data, not code:
    let msg = "please don't .unwrap() or panic!(...) here";
    let raw = r#"indexing like xs[idx] inside a raw string"#;
    let _ = raw;
    msg
}

pub fn allowed_index_shapes(xs: &[u32]) -> u32 {
    let first = xs[0]; // literal index — allowed
    let head = &xs[..2]; // range — slicing, not the Index panic shape
    let tail = &xs[1..]; // range again
    let v = vec![1, 2, 3]; // vec![ — macro bracket, not indexing
    let arr: [u8; 4] = [0; 4]; // type and repeat-literal brackets
    first + head.len() as u32 + tail.len() as u32 + v.len() as u32 + arr.len() as u32
}

pub fn fail_closed(v: Option<u32>) -> Result<u32, String> {
    v.ok_or_else(|| "missing".to_string())
}

pub fn suppressed_with_reason(v: Option<u32>) -> u32 {
    // lint:allow(no-panic-path): fixture exercising the suppression path
    v.unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_in_tests() {
        let v: Option<u32> = Some(3);
        assert_eq!(v.unwrap(), 3);
        let xs = [1u32, 2, 3];
        let i = 1usize;
        assert_eq!(xs[i], 2);
    }
}
