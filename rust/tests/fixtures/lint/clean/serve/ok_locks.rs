//! Should-NOT-fire fixture for the lock-order analyzer: consistent
//! acquisition order, sequential (non-nested) holds, and an explicit
//! `drop` before the blocking call.

use std::sync::Mutex;
use std::thread::JoinHandle;

pub struct Pair {
    pub alpha: Mutex<u32>,
    pub beta: Mutex<u32>,
}

pub fn consistent_order_one(p: &Pair) -> u32 {
    let a = p.alpha.lock();
    let b = p.beta.lock();
    let out = *a.unwrap_or_else(|e| e.into_inner()) + *b.unwrap_or_else(|e| e.into_inner());
    out
}

pub fn consistent_order_two(p: &Pair) -> u32 {
    let a = p.alpha.lock();
    let b = p.beta.lock();
    let out = *b.unwrap_or_else(|e| e.into_inner()) - *a.unwrap_or_else(|e| e.into_inner());
    out
}

pub fn sequential_holds(p: &Pair) {
    p.alpha.lock().unwrap_or_else(|e| e.into_inner());
    p.beta.lock().unwrap_or_else(|e| e.into_inner());
}

pub fn dropped_before_join(m: &Mutex<u32>, h: JoinHandle<()>) {
    let guard = m.lock();
    drop(guard);
    let _ = h.join();
}

pub fn metrics_ok() {
    crate::trace::global().counter("serve.fixture.requests").inc();
}
