//! Should-NOT-fire fixture for `checked-narrowing`: widening and checked
//! conversions are fine even in a parser directory; `as` in comments,
//! strings and test code must not fire.
//!
//! Beware: a doc mentioning `raw as u32` is prose, not a cast.

pub fn widening_is_fine(x: u16) -> u64 {
    x as u64
}

pub fn usize_cast_is_fine(x: u32) -> usize {
    x as usize
}

pub fn checked_narrowing(x: u64) -> Result<u32, String> {
    u32::try_from(x).map_err(|_| "out of range".to_string())
}

pub fn string_trap() -> &'static str {
    "casting raw as u32 here is just a sentence"
}

#[cfg(test)]
mod tests {
    #[test]
    fn narrowing_in_tests_is_fine() {
        let x = 300u64;
        assert_eq!(x as u32, 300);
    }
}
