//! Should-NOT-fire fixture for `joined-spawn`: handles that are bound,
//! collected or returned are all joinable — only discarding fires.

use std::thread::JoinHandle;

pub fn bound_and_joined() {
    let h = std::thread::spawn(|| 1);
    let _ = h.join();
}

pub fn collected(handles: &mut Vec<JoinHandle<i32>>) {
    handles.push(std::thread::spawn(|| 2));
}

pub fn returned() -> JoinHandle<i32> {
    std::thread::spawn(|| 3)
}

pub fn spawn_in_string() -> &'static str {
    "std::thread::spawn(|| 4); — prose, not code"
}
