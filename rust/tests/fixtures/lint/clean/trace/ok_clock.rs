//! Should-NOT-fire fixture for `epoch-clock`: `trace/` is the one place
//! raw `Instant::now()` is legal (it implements the epoch).

use std::time::Instant;

pub fn epoch_impl() -> Instant {
    Instant::now()
}
