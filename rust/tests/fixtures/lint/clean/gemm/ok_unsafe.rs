//! Should-NOT-fire fixture for `safety-comment`: documented unsafe.

pub fn caller(p: *const u8) -> u8 {
    // SAFETY: `p` is non-null and points at one readable byte — the only
    // caller derives it from a live slice.
    unsafe { *p }
}
