//! Integration tests over the real AOT artifacts (require `make artifacts`).
//!
//! These prove the full L1→L2→L3 composition: the HLO text that jax lowered
//! loads, compiles, and reproduces jax's own numbers (golden check), and a
//! short end-to-end training run learns.

// The whole suite needs the PJRT runtime (gated `pjrt` feature).
#![cfg(feature = "pjrt")]

use std::path::Path;
use switchback::config::{OptimizerKind, TrainConfig};
use switchback::coordinator::Trainer;
use switchback::runtime::Runtime;
use switchback::util::json;

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("highprec_micro_b32.manifest.json").exists() {
        Some(p)
    } else {
        None
    }
}

macro_rules! need_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn golden_step_matches_jax() {
    let dir = need_artifacts!();
    let golden_path = dir.join("highprec_micro_b32.golden.json");
    let golden = json::parse(&std::fs::read_to_string(golden_path).unwrap()).unwrap();
    let runtime = Runtime::cpu().unwrap();
    let art = runtime.load(dir, "highprec_micro_b32").unwrap();
    let m = &art.manifest;
    let params = art.initial_params(0, false).unwrap();
    // the deterministic batch aot.py used for the golden record
    let b = m.batch;
    let n_img = b * m.config.patches * m.config.patch_dim;
    let images: Vec<f32> = (0..n_img).map(|i| (i as f32).sin()).collect();
    let tokens: Vec<i32> =
        (0..(b * m.config.seq) as i32).map(|i| i % m.config.vocab as i32).collect();
    let out = art.train_step(&params, &images, &tokens).unwrap();

    let want_loss = golden.get("loss").unwrap().as_f64().unwrap() as f32;
    assert!(
        (out.loss - want_loss).abs() < 1e-4,
        "loss {} vs jax golden {}",
        out.loss,
        want_loss
    );
    let want_mags: Vec<f32> = golden
        .get("mags")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect();
    assert_eq!(out.mags.len(), want_mags.len());
    for (a, b) in out.mags.iter().zip(&want_mags) {
        assert!((a - b).abs() < 1e-4, "mags {a} vs {b}");
    }
    let g0_l2: f32 = out.grads[0].iter().map(|v| v * v).sum::<f32>().sqrt();
    let want_g0 = golden.get("grad0_l2").unwrap().as_f64().unwrap() as f32;
    assert!(
        (g0_l2 - want_g0).abs() / want_g0.max(1e-9) < 1e-3,
        "grad0 l2 {g0_l2} vs {want_g0}"
    );
}

#[test]
fn params_bin_matches_manifest_layout() {
    let dir = need_artifacts!();
    let runtime = Runtime::cpu().unwrap();
    let art = runtime.load(dir, "highprec_micro_b32").unwrap();
    let params = art.initial_params(0, false).unwrap();
    assert_eq!(params.len(), art.manifest.n_tensors);
    let total: usize = params.iter().map(|p| p.len()).sum();
    assert_eq!(total, art.manifest.n_params);
    for (p, t) in params.iter().zip(&art.manifest.tensors) {
        assert_eq!(p.len(), t.numel, "tensor {}", t.name);
    }
    // logit_scale is ln(1/0.07)
    let ls = art
        .manifest
        .tensors
        .iter()
        .position(|t| t.kind == "logit_scale")
        .unwrap();
    assert!((params[ls][0] - (1.0f32 / 0.07).ln()).abs() < 1e-4);
}

#[test]
fn reinit_respects_init_specs() {
    let dir = need_artifacts!();
    let runtime = Runtime::cpu().unwrap();
    let art = runtime.load(dir, "highprec_micro_b32").unwrap();
    let params = art.initial_params(7, true).unwrap();
    for (p, t) in params.iter().zip(&art.manifest.tensors) {
        match t.init.as_str() {
            "zeros" => assert!(p.iter().all(|&v| v == 0.0), "{}", t.name),
            "ones" => assert!(p.iter().all(|&v| v == 1.0), "{}", t.name),
            s if s.starts_with("normal:") => {
                let std: f32 = s[7..].parse().unwrap();
                if p.len() > 500 {
                    let mean: f32 = p.iter().sum::<f32>() / p.len() as f32;
                    let var: f32 =
                        p.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>()
                            / p.len() as f32;
                    assert!(
                        (var.sqrt() - std).abs() < 0.25 * std,
                        "{}: std {} vs {}",
                        t.name,
                        var.sqrt(),
                        std
                    );
                }
            }
            _ => {}
        }
    }
    // different seeds give different params
    let params2 = art.initial_params(8, true).unwrap();
    let pe = art.probe_indices().0;
    assert_ne!(params[pe], params2[pe]);
}

#[test]
fn micro_training_learns_and_evaluates() {
    let dir = need_artifacts!();
    let runtime = Runtime::cpu().unwrap();
    let mut cfg = TrainConfig::preset("highprec_micro_b32", 60)
        .with_optimizer(OptimizerKind::StableAdamw, 0.99);
    cfg.artifact_dir = dir.to_str().unwrap().to_string();
    cfg.lr = 3e-3;
    let mut trainer = Trainer::new(&runtime, cfg).unwrap();
    let res = trainer.run(false).unwrap();
    let loss = res.loss_trace();
    assert!(!res.diverged);
    assert!(
        res.tail_loss < loss[0] - 0.3,
        "should learn: first {} tail {}",
        loss[0],
        res.tail_loss
    );
    // zero-shot accuracy should beat chance (1/32) clearly after training
    let acc = res.zero_shot_acc.unwrap();
    assert!(acc > 0.10, "acc {acc} not above chance");
}

#[test]
fn pallas_artifact_composes_end_to_end() {
    let dir = need_artifacts!();
    if !dir.join("switchback_int8_pallas_micro_b8.manifest.json").exists() {
        eprintln!("skipping: pallas artifact missing");
        return;
    }
    let runtime = Runtime::cpu().unwrap();
    let art = runtime.load(dir, "switchback_int8_pallas_micro_b8").unwrap();
    let m = &art.manifest;
    let params = art.initial_params(0, false).unwrap();
    let b = m.batch;
    let images = vec![0.5f32; b * m.config.patches * m.config.patch_dim];
    let tokens = vec![1i32; b * m.config.seq];
    let out = art.train_step(&params, &images, &tokens).unwrap();
    assert!(out.loss.is_finite());
    assert_eq!(out.grads.len(), m.n_tensors);
    // compare against the jnp-path artifact with identical params/batch:
    // the pallas kernels and the jnp reference implement the same math.
    // (they share init because both were built from seed 0 at batch 8? the
    // jnp artifact is b32, so just sanity-check magnitudes here.)
    assert!(out.mags.iter().all(|v| v.is_finite() && *v > 0.0));
}

#[test]
fn switchback_artifact_close_to_highprec_on_same_batch() {
    let dir = need_artifacts!();
    let runtime = Runtime::cpu().unwrap();
    let hp = runtime.load(dir, "highprec_micro_b32").unwrap();
    let sb = runtime.load(dir, "switchback_int8_micro_b32").unwrap();
    let params = hp.initial_params(0, false).unwrap();
    let m = &hp.manifest;
    let b = m.batch;
    let n_img = b * m.config.patches * m.config.patch_dim;
    let images: Vec<f32> = (0..n_img).map(|i| (i as f32 * 0.37).cos()).collect();
    let tokens: Vec<i32> =
        (0..(b * m.config.seq) as i32).map(|i| (i * 7) % m.config.vocab as i32).collect();
    let o1 = hp.train_step(&params, &images, &tokens).unwrap();
    let o2 = sb.train_step(&params, &images, &tokens).unwrap();
    // same init, same batch: int8 loss within quantization noise of f32 loss
    assert!(
        (o1.loss - o2.loss).abs() < 0.05,
        "losses diverge: {} vs {}",
        o1.loss,
        o2.loss
    );
}
