//! Row-major f32 matrix used by the native GEMM / nn substrate.

use super::Rng;

/// Dense row-major matrix of f32.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        let mut m = Self::zeros(rows, cols);
        rng.fill_normal(&mut m.data, std);
        m
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Naive reference matmul `self [m,k] @ other [k,n]` — the oracle the
    /// blocked/parallel GEMMs are tested against.
    pub fn matmul_naive(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows);
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[p * n..(p + 1) * n];
                let orow = &mut out.data[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()))
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt() as f32
    }
}

/// Dense row-major int8 matrix (quantized codes).
#[derive(Debug, Clone, PartialEq)]
pub struct MatrixI8 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i8>,
}

impl MatrixI8 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0; rows * cols] }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[i8] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_naive_identity() {
        let mut eye = Matrix::zeros(3, 3);
        for i in 0..3 {
            eye.data[i * 3 + i] = 1.0;
        }
        let a = Matrix::from_vec(3, 3, (0..9).map(|v| v as f32).collect());
        assert_eq!(a.matmul_naive(&eye), a);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::seed(1);
        let a = Matrix::randn(5, 7, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn matmul_known_values() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul_naive(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }
}
