//! Deterministic RNG for initialization and synthetic data.
//!
//! A small xoshiro256++ implementation: reproducible across platforms and
//! fast enough to generate batches on the training path without showing up
//! in profiles.  (We deliberately do not use `rand`'s `StdRng` here so that
//! seeds recorded in EXPERIMENTS.md stay stable across crate upgrades.)

/// xoshiro256++ with splitmix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    spare: Option<f32>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create an RNG from a seed (any value, including 0, is fine).
    pub fn seed(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// Derive an independent stream (for per-tensor / per-example seeding).
    pub fn fork(&self, stream: u64) -> Self {
        let mut sm = self.s[0] ^ stream.wrapping_mul(0xA24BAED4963EE407);
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let res = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        res
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        // 24 high bits -> exactly representable f32 in [0,1)
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f32::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f32::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Fill a slice with standard normals scaled by `std`.
    pub fn fill_normal(&mut self, buf: &mut [f32], std: f32) {
        for v in buf.iter_mut() {
            *v = self.normal() * std;
        }
    }

    /// Full generator state for checkpointing: the four xoshiro words plus
    /// the cached Box–Muller spare (dropping the spare would shift every
    /// subsequent normal draw, breaking bit-identical resume).
    pub fn state(&self) -> ([u64; 4], Option<f32>) {
        (self.s, self.spare)
    }

    /// Rebuild a generator from [`Rng::state`] output.
    pub fn from_state(s: [u64; 4], spare: Option<f32>) -> Self {
        Self { s, spare }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed(42);
        let mut b = Rng::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_decorrelates() {
        let base = Rng::seed(42);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut r = Rng::seed(3);
        let mut sum = 0.0f64;
        for _ in 0..10000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u as f64;
        }
        assert!((sum / 10000.0 - 0.5).abs() < 0.02);
    }

    /// State round-trip resumes the exact stream — including mid-pair,
    /// when Box–Muller has a spare normal cached.
    #[test]
    fn state_roundtrip_is_bit_identical() {
        let mut a = Rng::seed(17);
        let _ = a.normal(); // leaves a spare cached
        let (s, spare) = a.state();
        assert!(spare.is_some(), "odd normal draw must cache a spare");
        let mut b = Rng::from_state(s, spare);
        for _ in 0..64 {
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed(9);
        let n = 50000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean: f64 = xs.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
        let var: f64 =
            xs.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
