//! Minimal host-side tensor utilities.
//!
//! The training path keeps parameters, gradients and optimizer state as flat
//! `Vec<f32>` buffers (one per named tensor, described by the artifact
//! manifest); this module provides the shape bookkeeping, deterministic
//! initialization, and a tiny RNG-backed `Matrix` used by the native
//! [`crate::nn`] / [`crate::gemm`] substrate.

mod matrix;
mod rng;

pub use matrix::{Matrix, MatrixI8};
pub use rng::Rng;

/// A named, shaped, flat f32 buffer (a parameter or gradient tensor).
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn zeros(name: impl Into<String>, shape: &[usize]) -> Self {
        let numel = shape.iter().product();
        Self { name: name.into(), shape: shape.to_vec(), data: vec![0.0; numel] }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Root-mean-square of the entries (used by telemetry probes).
    pub fn rms(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        let ss: f64 = self.data.iter().map(|&v| (v as f64) * (v as f64)).sum();
        (ss / self.data.len() as f64).sqrt() as f32
    }

    /// Largest absolute entry.
    pub fn absmax(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// True if any entry is non-finite (the loss-scaler Inf/NaN check, §3.6).
    pub fn has_nonfinite(&self) -> bool {
        self.data.iter().any(|v| !v.is_finite())
    }
}

/// Initialization specs mirrored from the manifest (`aot.py::_init_spec`):
/// `zeros`, `ones`, `const:<v>`, `normal:<std>`.
#[derive(Debug, Clone, PartialEq)]
pub enum InitSpec {
    Zeros,
    Ones,
    Const(f32),
    Normal(f32),
}

impl InitSpec {
    pub fn parse(s: &str) -> Option<Self> {
        if s == "zeros" {
            Some(Self::Zeros)
        } else if s == "ones" {
            Some(Self::Ones)
        } else if let Some(v) = s.strip_prefix("const:") {
            v.parse().ok().map(Self::Const)
        } else if let Some(v) = s.strip_prefix("normal:") {
            v.parse().ok().map(Self::Normal)
        } else {
            None
        }
    }

    /// Fill `buf` according to the spec with the given RNG.
    pub fn fill(&self, buf: &mut [f32], rng: &mut Rng) {
        match self {
            Self::Zeros => buf.fill(0.0),
            Self::Ones => buf.fill(1.0),
            Self::Const(v) => buf.fill(*v),
            Self::Normal(std) => {
                for v in buf.iter_mut() {
                    *v = rng.normal() * std;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_spec_roundtrip() {
        assert_eq!(InitSpec::parse("zeros"), Some(InitSpec::Zeros));
        assert_eq!(InitSpec::parse("ones"), Some(InitSpec::Ones));
        assert_eq!(InitSpec::parse("const:2.5"), Some(InitSpec::Const(2.5)));
        assert_eq!(InitSpec::parse("normal:0.02"), Some(InitSpec::Normal(0.02)));
        assert_eq!(InitSpec::parse("bogus"), None);
    }

    #[test]
    fn normal_fill_has_requested_std() {
        let mut rng = Rng::seed(7);
        let mut buf = vec![0.0f32; 20000];
        InitSpec::Normal(0.5).fill(&mut buf, &mut rng);
        let mean: f32 = buf.iter().sum::<f32>() / buf.len() as f32;
        let var: f32 =
            buf.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / buf.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var.sqrt() - 0.5).abs() < 0.02, "std {}", var.sqrt());
    }

    #[test]
    fn tensor_stats() {
        let t = HostTensor {
            name: "t".into(),
            shape: vec![2, 2],
            data: vec![1.0, -3.0, 0.0, 2.0],
        };
        assert_eq!(t.absmax(), 3.0);
        assert!((t.rms() - (14.0f32 / 4.0).sqrt()).abs() < 1e-6);
        assert!(!t.has_nonfinite());
        let t2 = HostTensor { data: vec![f32::NAN], ..t };
        assert!(t2.has_nonfinite());
    }
}
