//! Lock-order analyzer: per-function acquisition sequences → one
//! inter-procedural acquisition graph → cycle + held-across-blocking
//! findings.
//!
//! Scope is `serve/`, `trace/`, `ckpt/` — the directories where request
//! threads, the standby watcher, the background saver, and the metrics
//! registry interleave.  The model is lexical and deliberately simple:
//!
//! * An **acquisition** is a `.lock()` / `.read()` / `.write()` call with
//!   empty parens (argument-taking `io::Read::read` etc. never match).
//!   The lock's identity is `(file, receiver)` — the last identifier of
//!   the dotted receiver chain, so `self.shared.encoder.read()` is node
//!   `serve/engine.rs::encoder`.
//! * The **hold range** of a guard runs to the end of the enclosing
//!   brace block for `let`-bound guards (or to an explicit `drop(name)`),
//!   to the end of the `if let`/`while let`/`match` block for
//!   condition-bound guards, and to the end of the statement for
//!   temporaries.  The model is positional: it does not follow loop
//!   back-edges.
//! * An **edge** `A → B` means B was acquired (directly, or transitively
//!   through a resolvable call) while A was held.  Calls resolve by name:
//!   same-file definitions win; otherwise a globally unique definition;
//!   method calls additionally skip std-colliding names (`push`, `get`,
//!   …) so `Vec::push` never aliases a lock-taking method.  Unresolvable
//!   calls contribute nothing — the graph under-approximates rather than
//!   inventing edges.
//! * A cycle in the graph is a potential deadlock ([`Level::Error`]), as
//!   is holding any lock across `join()` / `recv()` / `recv_timeout()` /
//!   `accept()` / `thread::sleep` — directly or through a resolvable
//!   call.  `Condvar::wait*` is exempt: it releases the guard it takes
//!   (that is the condvar idiom the batcher uses).
//!
//! `// lint:allow(lock-order)` on an acquisition line removes that site
//! from the graph (counted as a suppression); on a blocking call's line
//! it suppresses the held-across finding.

use std::collections::{BTreeMap, BTreeSet};

use super::rules::{in_dirs, Finding, Level};
use super::scan::{
    find_word, is_ident_byte, matching_close, next_nonspace, prev_nonspace,
    word_ending_at, ScannedFile,
};

/// Directories whose locks participate in the graph.
const LOCK_DIRS: &[&str] = &["serve", "trace", "ckpt"];
/// Receivers that look like locks but are std stream handles.
const STREAM_RECEIVERS: &[&str] = &["stdout", "stderr", "stdin"];
/// Method-call names too std-common to resolve against our definitions.
const METHOD_CALL_DENY: &[&str] = &[
    "clear", "clone", "drop", "flush", "get", "insert", "is_empty", "join",
    "len", "new", "next", "pop", "push", "read", "recv", "remove", "send",
    "take", "wait", "write",
];

/// One acquisition edge: `to` was acquired while `from` was held, at
/// `rel:line`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Edge {
    pub from: String,
    pub to: String,
    pub rel: String,
    pub line: usize,
}

/// The inter-procedural lock graph plus the findings derived from it.
#[derive(Debug, Default)]
pub struct LockGraph {
    /// All acquisition nodes (`rel::receiver`), sorted.
    pub nodes: Vec<String>,
    /// Deduplicated edges (first witnessing site kept).
    pub edges: Vec<Edge>,
    /// Each cycle as the node ring that forms it.
    pub cycles: Vec<Vec<String>>,
    /// `lock-order` findings: one per cycle, one per held-across-blocking
    /// site (suppressed ones carry `suppressed: true`).
    pub findings: Vec<Finding>,
    /// Functions whose bodies were analyzed.
    pub functions: usize,
}

impl LockGraph {
    /// Unsuppressed held-across-blocking findings.
    pub fn blocking_holds(&self) -> usize {
        self.findings
            .iter()
            .filter(|f| !f.suppressed && f.message.contains("held across"))
            .count()
    }
}

struct Acq {
    node: usize,
    pos: usize,
    end: usize,
    line: usize,
}

struct CallSite {
    pos: usize,
    name: String,
    method: bool,
}

struct FnDef {
    file: usize,
    body: (usize, usize),
    acqs: Vec<Acq>,
    calls: Vec<CallSite>,
    /// (pos, what) direct blocking operations.
    blocking: Vec<(usize, String)>,
}

/// Matching opener for the closer at `close`, scanning backwards.
fn matching_open(b: &[u8], close: usize) -> Option<usize> {
    let (o, c) = match b[close] {
        b')' => (b'(', b')'),
        b']' => (b'[', b']'),
        _ => return None,
    };
    let mut depth = 1i32;
    let mut j = close;
    while j > 0 {
        j -= 1;
        if b[j] == c {
            depth += 1;
        } else if b[j] == o {
            depth -= 1;
            if depth == 0 {
                return Some(j);
            }
        }
    }
    None
}

/// Last identifier of the receiver chain ending at the `.` at `dot`.
fn receiver_name(f: &ScannedFile, dot: usize) -> Option<String> {
    let b = f.masked.as_bytes();
    let mut j = prev_nonspace(b, dot)?;
    loop {
        if is_ident_byte(b[j]) {
            let w = word_ending_at(&f.masked, j + 1);
            if w.is_empty() || w.as_bytes()[0].is_ascii_digit() {
                return None;
            }
            return Some(w.to_string());
        }
        if b[j] == b')' || b[j] == b']' {
            let open = matching_open(b, j)?;
            j = prev_nonspace(b, open)?;
            continue;
        }
        return None;
    }
}

/// End of the brace block enclosing `from`.
fn enclosing_block_end(b: &[u8], from: usize) -> usize {
    let mut d = 0i32;
    let mut j = from;
    while j < b.len() {
        match b[j] {
            b'{' => d += 1,
            b'}' => {
                if d == 0 {
                    return j;
                }
                d -= 1;
            }
            _ => {}
        }
        j += 1;
    }
    b.len()
}

/// End of the statement containing `from` (a `;` outside nested groups,
/// or the enclosing `}`).
fn stmt_end(b: &[u8], from: usize) -> usize {
    let mut pd = 0i32;
    let mut bd = 0i32;
    let mut j = from;
    while j < b.len() {
        match b[j] {
            b'(' | b'[' => pd += 1,
            b')' | b']' => pd -= 1,
            b'{' => bd += 1,
            b'}' => {
                if bd == 0 {
                    return j;
                }
                bd -= 1;
            }
            b';' if pd <= 0 && bd <= 0 => return j,
            _ => {}
        }
        j += 1;
    }
    b.len()
}

/// End of the first brace block after `from` — the body of the
/// `if let`/`while let`/`match` whose condition holds the guard.
fn first_block_end(b: &[u8], from: usize) -> usize {
    let mut pd = 0i32;
    let mut j = from;
    while j < b.len() {
        match b[j] {
            b'(' | b'[' => pd += 1,
            b')' | b']' => pd -= 1,
            b'{' if pd <= 0 => return matching_close(b, j),
            b';' if pd <= 0 => return j,
            _ => {}
        }
        j += 1;
    }
    b.len()
}

/// Where the guard acquired at `dot` stops being held.
fn hold_end(f: &ScannedFile, dot: usize) -> usize {
    let b = f.masked.as_bytes();
    // statement start: nearest `;`/`{`/`}` before the acquisition
    let mut s = dot;
    while s > 0 {
        let c = b[s - 1];
        if c == b';' || c == b'{' || c == b'}' {
            break;
        }
        s -= 1;
    }
    let Some(w0) = next_nonspace(b, s) else { return stmt_end(b, dot) };
    let mut e0 = w0;
    while e0 < b.len() && is_ident_byte(b[e0]) {
        e0 += 1;
    }
    match &f.masked[w0..e0] {
        "let" => {
            let mut w = next_nonspace(b, e0).unwrap_or(e0);
            let mut we = w;
            while we < b.len() && is_ident_byte(b[we]) {
                we += 1;
            }
            if &f.masked[w..we] == "mut" {
                w = next_nonspace(b, we).unwrap_or(we);
                we = w;
                while we < b.len() && is_ident_byte(b[we]) {
                    we += 1;
                }
            }
            let bind = &f.masked[w..we];
            if bind == "_" {
                // `let _ = ..` drops the guard immediately
                return stmt_end(b, dot);
            }
            let simple = !bind.is_empty()
                && next_nonspace(b, we).map(|p| b[p] == b'=' || b[p] == b':')
                    == Some(true);
            let end = enclosing_block_end(b, dot);
            if simple {
                // an explicit drop(bind) releases early
                let bind = bind.to_string();
                for at in find_word(&f.masked[dot..end.min(f.masked.len())], "drop") {
                    let at = dot + at;
                    let Some(op) = next_nonspace(b, at + 4) else { continue };
                    if b[op] != b'(' {
                        continue;
                    }
                    let Some(aw) = next_nonspace(b, op + 1) else { continue };
                    let mut ae = aw;
                    while ae < b.len() && is_ident_byte(b[ae]) {
                        ae += 1;
                    }
                    if f.masked[aw..ae] == bind
                        && next_nonspace(b, ae).map(|p| b[p]) == Some(b')')
                    {
                        return at;
                    }
                }
            }
            end
        }
        "if" | "while" | "match" => first_block_end(b, dot),
        _ => stmt_end(b, dot),
    }
}

/// Collect every function body in `f` as `(name, (open, end))`.
fn fn_bodies(f: &ScannedFile) -> Vec<(String, (usize, usize))> {
    let b = f.masked.as_bytes();
    let mut out = Vec::new();
    for at in find_word(&f.masked, "fn") {
        let Some(ns) = next_nonspace(b, at + 2) else { continue };
        if !is_ident_byte(b[ns]) || b[ns].is_ascii_digit() {
            continue;
        }
        let mut e = ns;
        while e < b.len() && is_ident_byte(b[e]) {
            e += 1;
        }
        // find the body `{`, or bail at a bodyless `;` declaration
        let mut j = e;
        let mut depth = 0i32;
        let mut body = None;
        while j < b.len() {
            match b[j] {
                b'(' | b'[' => depth += 1,
                b')' | b']' => depth -= 1,
                b';' if depth <= 0 => break,
                b'{' if depth <= 0 => {
                    body = Some((j, matching_close(b, j) + 1));
                    break;
                }
                _ => {}
            }
            j += 1;
        }
        if let Some(range) = body {
            out.push((f.masked[ns..e].to_string(), range));
        }
    }
    out
}

/// Build the lock graph over `files` (only `serve/`/`trace/`/`ckpt/`
/// files participate).
pub fn analyze(files: &[ScannedFile]) -> LockGraph {
    let scoped: Vec<&ScannedFile> = files
        .iter()
        .filter(|f| in_dirs(&f.rel, LOCK_DIRS))
        .collect();

    // ---- function table ----------------------------------------------
    let mut fns: Vec<FnDef> = Vec::new();
    let mut fn_names: Vec<String> = Vec::new();
    let mut by_name: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    for (fi, f) in scoped.iter().enumerate() {
        for (name, body) in fn_bodies(f) {
            let idx = fns.len();
            by_name.entry(name.clone()).or_default().push(idx);
            fn_names.push(name);
            fns.push(FnDef {
                file: fi,
                body,
                acqs: Vec::new(),
                calls: Vec::new(),
                blocking: Vec::new(),
            });
        }
    }

    // innermost function whose body contains `pos` in file `fi`
    let owner = |fns: &[FnDef], fi: usize, pos: usize| -> Option<usize> {
        fns.iter()
            .enumerate()
            .filter(|(_, d)| d.file == fi && d.body.0 <= pos && pos < d.body.1)
            .max_by_key(|(_, d)| d.body.0)
            .map(|(i, _)| i)
    };

    // ---- events ------------------------------------------------------
    let mut nodes: Vec<String> = Vec::new();
    let mut node_ids: BTreeMap<String, usize> = BTreeMap::new();
    let mut findings: Vec<Finding> = Vec::new();

    for (fi, f) in scoped.iter().enumerate() {
        let b = f.masked.as_bytes();

        // acquisitions
        for m in ["lock", "read", "write"] {
            for at in find_word(&f.masked, m) {
                let Some(p) = prev_nonspace(b, at) else { continue };
                if b[p] != b'.' {
                    continue;
                }
                let Some(op) = next_nonspace(b, at + m.len()) else { continue };
                if b[op] != b'(' {
                    continue;
                }
                if next_nonspace(b, op + 1).map(|q| b[q]) != Some(b')') {
                    continue;
                }
                if f.in_test(at) {
                    continue;
                }
                let Some(name) = receiver_name(f, p) else { continue };
                if STREAM_RECEIVERS.contains(&name.as_str()) {
                    continue;
                }
                let line = f.line_of(at);
                if f.allow_on(line, "lock-order") {
                    findings.push(Finding {
                        rule: "lock-order",
                        level: Level::Error,
                        rel: f.rel.clone(),
                        line,
                        message: format!("acquisition of `{name}` excluded from graph"),
                        suppressed: true,
                    });
                    continue;
                }
                let key = format!("{}::{}", f.rel, name);
                let node = *node_ids.entry(key.clone()).or_insert_with(|| {
                    nodes.push(key);
                    nodes.len() - 1
                });
                if let Some(fx) = owner(&fns, fi, at) {
                    let end = hold_end(f, p).min(fns[fx].body.1);
                    fns[fx].acqs.push(Acq { node, pos: at, end, line });
                }
            }
        }

        // direct blocking operations
        let mut push_blocking = |fns: &mut Vec<FnDef>, at: usize, what: String| {
            if f.in_test(at) {
                return;
            }
            if let Some(fx) = owner(fns, fi, at) {
                fns[fx].blocking.push((at, what));
            }
        };
        for (m, empty) in [("join", true), ("recv", true), ("accept", true), ("recv_timeout", false)]
        {
            for at in find_word(&f.masked, m) {
                let Some(p) = prev_nonspace(b, at) else { continue };
                if b[p] != b'.' {
                    continue;
                }
                let Some(op) = next_nonspace(b, at + m.len()) else { continue };
                if b[op] != b'(' {
                    continue;
                }
                if empty && next_nonspace(b, op + 1).map(|q| b[q]) != Some(b')') {
                    continue;
                }
                push_blocking(&mut fns, at, format!(".{m}()"));
            }
        }
        for at in find_word(&f.masked, "sleep") {
            let Some(c) = prev_nonspace(b, at) else { continue };
            if b[c] != b':' || c == 0 || b[c - 1] != b':' {
                continue;
            }
            let Some(tw) = prev_nonspace(b, c - 1) else { continue };
            if word_ending_at(&f.masked, tw + 1) != "thread" {
                continue;
            }
            if next_nonspace(b, at + 5).map(|p| b[p]) != Some(b'(') {
                continue;
            }
            push_blocking(&mut fns, at, "thread::sleep".into());
        }

        // calls to functions we know.  `drop` never resolves: explicit
        // `drop(x)` is always `std::mem::drop` (calling `Drop::drop` is
        // E0040), so linking it to our `Drop` impls would invent edges.
        for (name, defs) in &by_name {
            if name == "drop" {
                continue;
            }
            let same_file = defs.iter().any(|&d| fns[d].file == fi);
            let unique = defs.len() == 1;
            for at in find_word(&f.masked, name) {
                let Some(op) = next_nonspace(b, at + name.len()) else { continue };
                if b[op] != b'(' {
                    continue;
                }
                let prev = prev_nonspace(b, at);
                let method = prev.map(|p| b[p]) == Some(b'.');
                if method && METHOD_CALL_DENY.contains(&name.as_str()) {
                    continue;
                }
                if let Some(p) = prev {
                    // skip the definition itself and type constructors
                    if is_ident_byte(b[p]) {
                        let w = word_ending_at(&f.masked, p + 1);
                        if w == "fn" || w == "struct" {
                            continue;
                        }
                    }
                }
                if !same_file && !unique {
                    continue; // ambiguous cross-file name
                }
                if f.in_test(at) {
                    continue;
                }
                if let Some(fx) = owner(&fns, fi, at) {
                    fns[fx].calls.push(CallSite { pos: at, name: name.clone(), method });
                }
            }
        }
    }

    // ---- call resolution + transitive closure ------------------------
    let resolve = |caller_file: usize, name: &str| -> Vec<usize> {
        let Some(defs) = by_name.get(name) else { return vec![] };
        let local: Vec<usize> = defs
            .iter()
            .copied()
            .filter(|&d| fns[d].file == caller_file)
            .collect();
        if !local.is_empty() {
            return local;
        }
        if defs.len() == 1 {
            return defs.clone();
        }
        vec![]
    };

    let mut acq_sets: Vec<BTreeSet<usize>> = fns
        .iter()
        .map(|d| d.acqs.iter().map(|a| a.node).collect())
        .collect();
    let mut blocks: Vec<Option<String>> = fns
        .iter()
        .map(|d| d.blocking.first().map(|(_, w)| w.clone()))
        .collect();
    loop {
        let mut changed = false;
        for i in 0..fns.len() {
            for c in &fns[i].calls {
                for callee in resolve(fns[i].file, &c.name) {
                    if callee == i {
                        continue;
                    }
                    let add: Vec<usize> = acq_sets[callee]
                        .iter()
                        .copied()
                        .filter(|n| !acq_sets[i].contains(n))
                        .collect();
                    if !add.is_empty() {
                        acq_sets[i].extend(add);
                        changed = true;
                    }
                    if blocks[i].is_none() {
                        if let Some(w) = blocks[callee].clone() {
                            blocks[i] = Some(format!("{}() -> {w}", c.name));
                            changed = true;
                        }
                    }
                }
            }
        }
        if !changed {
            break;
        }
    }

    // ---- edges + held-across findings --------------------------------
    let mut edges: Vec<Edge> = Vec::new();
    let mut seen: BTreeSet<(usize, usize)> = BTreeSet::new();
    let mut adj: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); nodes.len()];
    for (i, d) in fns.iter().enumerate() {
        let f = scoped[d.file];
        for a in &d.acqs {
            let held = |pos: usize| a.pos < pos && pos < a.end;
            let mut add_edge = |to: usize, line: usize, edges: &mut Vec<Edge>| {
                if to == a.node {
                    return;
                }
                adj[a.node].insert(to);
                if seen.insert((a.node, to)) {
                    edges.push(Edge {
                        from: nodes[a.node].clone(),
                        to: nodes[to].clone(),
                        rel: f.rel.clone(),
                        line,
                    });
                }
            };
            for b2 in &d.acqs {
                if held(b2.pos) {
                    add_edge(b2.node, b2.line, &mut edges);
                }
            }
            for c in &d.calls {
                if !held(c.pos) {
                    continue;
                }
                let line = f.line_of(c.pos);
                for callee in resolve(d.file, &c.name) {
                    if callee == i {
                        continue;
                    }
                    for &n in acq_sets[callee].iter() {
                        add_edge(n, line, &mut edges);
                    }
                    if let Some(w) = &blocks[callee] {
                        let suppressed = f.allow_on(line, "lock-order");
                        findings.push(Finding {
                            rule: "lock-order",
                            level: Level::Error,
                            rel: f.rel.clone(),
                            line,
                            message: format!(
                                "lock `{}` held across blocking {w}",
                                nodes[a.node]
                            ),
                            suppressed,
                        });
                    }
                }
            }
            for (pos, what) in &d.blocking {
                if !held(*pos) {
                    continue;
                }
                let line = f.line_of(*pos);
                let suppressed = f.allow_on(line, "lock-order");
                findings.push(Finding {
                    rule: "lock-order",
                    level: Level::Error,
                    rel: f.rel.clone(),
                    line,
                    message: format!("lock `{}` held across blocking {what}", nodes[a.node]),
                    suppressed,
                });
            }
        }
    }

    // ---- cycles (Tarjan SCC; self-edges were never added) ------------
    let cycles = sccs(&adj)
        .into_iter()
        .filter(|c| c.len() > 1)
        .map(|c| {
            let mut ring: Vec<String> = c.iter().map(|&n| nodes[n].clone()).collect();
            ring.sort();
            ring
        })
        .collect::<Vec<_>>();
    for ring in &cycles {
        let site = edges
            .iter()
            .find(|e| ring.contains(&e.from) && ring.contains(&e.to));
        findings.push(Finding {
            rule: "lock-order",
            level: Level::Error,
            rel: site.map(|e| e.rel.clone()).unwrap_or_default(),
            line: site.map(|e| e.line).unwrap_or(0),
            message: format!("lock-order cycle: {}", ring.join(" -> ")),
            suppressed: false,
        });
    }

    let mut sorted_nodes = nodes.clone();
    sorted_nodes.sort();
    edges.sort_by(|a, b| (&a.from, &a.to).cmp(&(&b.from, &b.to)));
    LockGraph {
        nodes: sorted_nodes,
        edges,
        cycles,
        findings,
        functions: fns.len(),
    }
}

/// Strongly connected components (iterative Tarjan).
fn sccs(adj: &[BTreeSet<usize>]) -> Vec<Vec<usize>> {
    let n = adj.len();
    let mut index = vec![usize::MAX; n];
    let mut low = vec![0usize; n];
    let mut on_stack = vec![false; n];
    let mut stack: Vec<usize> = Vec::new();
    let mut next = 0usize;
    let mut out: Vec<Vec<usize>> = Vec::new();

    // explicit DFS stack: (node, iterator position over neighbors)
    for root in 0..n {
        if index[root] != usize::MAX {
            continue;
        }
        let mut work: Vec<(usize, Vec<usize>, usize)> =
            vec![(root, adj[root].iter().copied().collect(), 0)];
        index[root] = next;
        low[root] = next;
        next += 1;
        stack.push(root);
        on_stack[root] = true;
        while let Some((v, nbrs, mut i)) = work.pop() {
            let mut descended = false;
            while i < nbrs.len() {
                let w = nbrs[i];
                i += 1;
                if index[w] == usize::MAX {
                    work.push((v, nbrs.clone(), i));
                    index[w] = next;
                    low[w] = next;
                    next += 1;
                    stack.push(w);
                    on_stack[w] = true;
                    work.push((w, adj[w].iter().copied().collect(), 0));
                    descended = true;
                    break;
                } else if on_stack[w] {
                    low[v] = low[v].min(index[w]);
                }
            }
            if descended {
                continue;
            }
            if low[v] == index[v] {
                let mut comp = Vec::new();
                while let Some(w) = stack.pop() {
                    on_stack[w] = false;
                    comp.push(w);
                    if w == v {
                        break;
                    }
                }
                out.push(comp);
            }
            if let Some(frame) = work.last() {
                let p = frame.0;
                low[p] = low[p].min(low[v]);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn graph(files: &[(&str, &str)]) -> LockGraph {
        let scanned: Vec<ScannedFile> =
            files.iter().map(|(rel, src)| ScannedFile::new(rel, src)).collect();
        analyze(&scanned)
    }

    #[test]
    fn nested_acquisition_makes_an_edge() {
        let g = graph(&[(
            "serve/a.rs",
            "fn f(x: &M, y: &M) {\n    let a = x.alpha.lock().unwrap();\n    let b = y.beta.lock().unwrap();\n}\n",
        )]);
        assert_eq!(g.nodes, vec!["serve/a.rs::alpha", "serve/a.rs::beta"]);
        assert_eq!(g.edges.len(), 1);
        assert_eq!(g.edges[0].from, "serve/a.rs::alpha");
        assert_eq!(g.edges[0].to, "serve/a.rs::beta");
        assert!(g.cycles.is_empty());
    }

    #[test]
    fn two_lock_cycle_across_functions_is_detected() {
        let src = "\
fn ab(s: &S) {
    let a = s.alpha.lock().unwrap();
    take_beta(s);
}
fn take_beta(s: &S) {
    let b = s.beta.lock().unwrap();
}
fn ba(s: &S) {
    let b = s.beta.lock().unwrap();
    let a = s.alpha.lock().unwrap();
}
";
        let g = graph(&[("serve/cycle.rs", src)]);
        assert_eq!(g.cycles.len(), 1, "edges: {:?}", g.edges);
        assert!(g.findings.iter().any(|f| f.message.contains("cycle")));
    }

    #[test]
    fn sequential_temporaries_do_not_make_edges() {
        let src = "\
fn f(x: &M, y: &M) -> usize {
    let a = x.alpha.lock().unwrap().len();
    let n = compute(a);
    y.beta.lock().unwrap().push(n);
    x.alpha.lock().unwrap().clear();
    n
}
";
        // `a` here is a usize, not a guard — but the model treats the
        // alpha guard as block-held, so alpha->beta is reported.  The
        // second, temporary beta/alpha acquisitions add nothing new.
        let g = graph(&[("serve/a.rs", src)]);
        assert!(g.cycles.is_empty(), "edges: {:?}", g.edges);
    }

    #[test]
    fn drop_releases_a_block_bound_guard() {
        let src = "\
fn f(x: &M, y: &M) {
    let a = x.alpha.lock().unwrap();
    drop(a);
    let b = y.beta.lock().unwrap();
}
fn g(x: &M, y: &M) {
    let b = y.beta.lock().unwrap();
    let a = x.alpha.lock().unwrap();
}
";
        // without the drop() this would be an alpha<->beta cycle
        let g = graph(&[("serve/a.rs", src)]);
        assert!(g.cycles.is_empty(), "edges: {:?}", g.edges);
        assert_eq!(g.edges.len(), 1);
        assert_eq!(g.edges[0].from, "serve/a.rs::beta");
    }

    #[test]
    fn join_under_lock_is_flagged_condvar_wait_is_not() {
        let src = "\
fn bad(s: &S) {
    let g = s.state.lock().unwrap();
    s.handle.join();
}
fn fine(s: &S) {
    let g = s.state.lock().unwrap();
    let g = s.cv.wait(g).unwrap();
}
";
        let g = graph(&[("serve/a.rs", src)]);
        let holds: Vec<&Finding> = g
            .findings
            .iter()
            .filter(|f| f.message.contains("held across"))
            .collect();
        assert_eq!(holds.len(), 1, "findings: {:?}", g.findings);
        assert!(holds[0].message.contains(".join()"));
    }

    #[test]
    fn transitive_blocking_through_a_call_is_flagged() {
        let src = "\
fn outer(s: &S) {
    let g = s.state.lock().unwrap();
    drain(s);
}
fn drain(s: &S) {
    s.rx.recv();
}
";
        let g = graph(&[("ckpt/a.rs", src)]);
        assert_eq!(g.blocking_holds(), 1, "findings: {:?}", g.findings);
    }

    #[test]
    fn allow_comment_removes_acquisition_and_counts_suppression() {
        let src = "\
fn f(x: &M, y: &M) {
    let a = x.alpha.lock().unwrap(); // lint:allow(lock-order): leaf lock
    let b = y.beta.lock().unwrap();
}
";
        let g = graph(&[("serve/a.rs", src)]);
        assert!(g.edges.is_empty());
        assert_eq!(g.findings.iter().filter(|f| f.suppressed).count(), 1);
    }

    #[test]
    fn io_read_write_with_args_are_not_acquisitions() {
        let src = "\
fn f(r: &mut R, w: &mut W, buf: &mut [u8]) {
    r.read(buf);
    w.write(buf);
    r.stream.read_exact(buf);
}
";
        let g = graph(&[("serve/a.rs", src)]);
        assert!(g.nodes.is_empty(), "nodes: {:?}", g.nodes);
    }

    #[test]
    fn out_of_scope_dirs_do_not_participate() {
        let g = graph(&[(
            "gemm/a.rs",
            "fn f(x: &M) { let a = x.alpha.lock().unwrap(); x.h.join(); }\n",
        )]);
        assert!(g.nodes.is_empty());
        assert!(g.findings.is_empty());
    }

    #[test]
    fn test_code_is_ignored() {
        let src = "\
#[cfg(test)]
mod tests {
    fn f(x: &M, y: &M) {
        let a = x.alpha.lock().unwrap();
        let b = y.beta.lock().unwrap();
    }
}
";
        let g = graph(&[("serve/a.rs", src)]);
        assert!(g.nodes.is_empty());
    }
}
