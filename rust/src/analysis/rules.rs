//! The invariant rules the linter enforces, over [`ScannedFile`]s.
//!
//! Every rule is lexical (it reads the masked source, so strings and
//! comments never fire), test-aware (findings inside `#[test]`/
//! `#[cfg(test)]` items are dropped), and suppressible with
//! `// lint:allow(rule): reason` on the finding's line or the line
//! above.  Rule semantics are specified in DESIGN.md §Static analysis;
//! the should-fire / should-not-fire corpus lives in
//! `tests/fixtures/lint*/`.

use super::scan::{
    find_word, is_ident_byte, matching_close, next_nonspace, prev_nonspace,
    word_ending_at, ScannedFile,
};

/// Finding severity. `--deny LEVEL` fails the run when any unsuppressed
/// finding reaches `LEVEL`; rule findings are [`Level::Warn`], lock-order
/// hazards are [`Level::Error`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Info,
    Warn,
    Error,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s {
            "info" => Some(Level::Info),
            "warn" => Some(Level::Warn),
            "error" => Some(Level::Error),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Info => "info",
            Level::Warn => "warn",
            Level::Error => "error",
        }
    }
}

/// One lint finding, suppressed or not (suppressed findings are kept so
/// the ledger can count suppressions per rule).
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub level: Level,
    pub rel: String,
    pub line: usize,
    pub message: String,
    pub suppressed: bool,
}

/// Every rule the engine knows, in report order.  `lock-order` findings
/// come from [`super::locks`], the rest from [`check_file`].
pub const RULES: &[&str] = &[
    "no-panic-path",
    "safety-comment",
    "checked-narrowing",
    "epoch-clock",
    "metrics-naming",
    "joined-spawn",
    "lock-order",
];

/// Directories whose non-test code must not panic.
const PANIC_FREE_DIRS: &[&str] = &["serve", "net", "ckpt"];
/// Directories whose parsers must not narrow with bare `as`.
const PARSER_DIRS: &[&str] = &["ckpt", "net"];

/// Does `rel`'s directory path contain one of `dirs` as a component?
pub(crate) fn in_dirs(rel: &str, dirs: &[&str]) -> bool {
    let mut parts: Vec<&str> = rel.split('/').collect();
    parts.pop(); // file name
    parts.iter().any(|p| dirs.contains(p))
}

fn emit(
    f: &ScannedFile,
    out: &mut Vec<Finding>,
    rule: &'static str,
    off: usize,
    message: String,
) {
    if f.in_test(off) {
        return;
    }
    let line = f.line_of(off);
    let suppressed = f.allow_on(line, rule);
    out.push(Finding {
        rule,
        level: Level::Warn,
        rel: f.rel.clone(),
        line,
        message,
        suppressed,
    });
}

/// Run every file-local rule over `f`, appending findings to `out`.
pub fn check_file(f: &ScannedFile, out: &mut Vec<Finding>) {
    no_panic_path(f, out);
    safety_comment(f, out);
    checked_narrowing(f, out);
    epoch_clock(f, out);
    metrics_naming(f, out);
    joined_spawn(f, out);
}

/// Offsets of `.name(` method calls (whitespace-tolerant) in `masked`.
fn method_calls(masked: &str, name: &str) -> Vec<usize> {
    let b = masked.as_bytes();
    let mut out = Vec::new();
    for at in find_word(masked, name) {
        let Some(p) = prev_nonspace(b, at) else { continue };
        if b[p] != b'.' {
            continue;
        }
        let Some(q) = next_nonspace(b, at + name.len()) else { continue };
        if b[q] == b'(' {
            out.push(at);
        }
    }
    out
}

/// Keywords that turn `word [` into a type/pattern position, not an
/// index expression.
const NON_EXPR_KEYWORDS: &[&str] = &[
    "as", "async", "await", "box", "break", "const", "continue", "crate",
    "dyn", "else", "enum", "fn", "for", "if", "impl", "in", "let", "loop",
    "match", "move", "mut", "pub", "ref", "return", "static", "struct",
    "trait", "type", "union", "unsafe", "use", "where", "while", "yield",
];

fn short(inner: &str) -> String {
    let s: String = inner.chars().take(24).collect();
    if s.len() < inner.len() {
        format!("{s}...")
    } else {
        s
    }
}

/// `no-panic-path`: under `serve/`, `net/`, `ckpt/`, non-test code may
/// not `.unwrap()`, `.expect(..)`, hit a panicking macro, or index with a
/// non-trivial subscript (integer literals and `..` ranges are exempt —
/// they are either obviously bounded or slice-typed, and slicing is
/// checked by the same length guards the parsers already assert).
fn no_panic_path(f: &ScannedFile, out: &mut Vec<Finding>) {
    if !in_dirs(&f.rel, PANIC_FREE_DIRS) {
        return;
    }
    for name in ["unwrap", "expect"] {
        for at in method_calls(&f.masked, name) {
            emit(
                f,
                out,
                "no-panic-path",
                at,
                format!(".{name}() can panic — return an error instead"),
            );
        }
    }
    let b = f.masked.as_bytes();
    for mac in ["panic", "unreachable", "todo", "unimplemented"] {
        for at in find_word(&f.masked, mac) {
            if next_nonspace(b, at + mac.len()).map(|p| b[p]) == Some(b'!') {
                emit(
                    f,
                    out,
                    "no-panic-path",
                    at,
                    format!("{mac}! aborts the thread — fail closed instead"),
                );
            }
        }
    }
    let mut i = 0usize;
    while i < b.len() {
        if b[i] != b'[' {
            i += 1;
            continue;
        }
        let open = i;
        i += 1;
        let Some(p) = prev_nonspace(b, open) else { continue };
        let candidate = if is_ident_byte(b[p]) {
            let w = word_ending_at(&f.masked, p + 1);
            !w.is_empty()
                && !w.as_bytes()[0].is_ascii_digit()
                && !NON_EXPR_KEYWORDS.contains(&w)
        } else {
            b[p] == b')' || b[p] == b']'
        };
        if !candidate {
            continue;
        }
        let close = matching_close(b, open);
        let inner = f.masked[open + 1..close.min(f.masked.len())].trim();
        if inner.is_empty()
            || inner.bytes().all(|c| c.is_ascii_digit() || c == b'_')
            || inner.contains("..")
        {
            continue;
        }
        emit(
            f,
            out,
            "no-panic-path",
            open,
            format!("indexing `[{}]` can panic — use .get()", short(inner)),
        );
    }
}

/// `safety-comment`: every `unsafe` needs `// SAFETY:` on its line or
/// within the three lines above (one comment covers all `unsafe` tokens
/// on a line).
fn safety_comment(f: &ScannedFile, out: &mut Vec<Finding>) {
    let mut last_line = 0usize;
    for at in find_word(&f.masked, "unsafe") {
        let line = f.line_of(at);
        if line == last_line {
            continue;
        }
        last_line = line;
        if !f.safety_near(line) {
            emit(
                f,
                out,
                "safety-comment",
                at,
                "unsafe without an adjacent // SAFETY: justification".into(),
            );
        }
    }
}

/// `checked-narrowing`: wire/ckpt parsers must not narrow integers with
/// bare `as` — use `try_from` and fail closed on overflow.
fn checked_narrowing(f: &ScannedFile, out: &mut Vec<Finding>) {
    if !in_dirs(&f.rel, PARSER_DIRS) {
        return;
    }
    let b = f.masked.as_bytes();
    const NARROW: &[&str] = &["u8", "u16", "u32", "i8", "i16", "i32"];
    for at in find_word(&f.masked, "as") {
        let Some(q) = next_nonspace(b, at + 2) else { continue };
        if !is_ident_byte(b[q]) {
            continue;
        }
        let mut e = q;
        while e < b.len() && is_ident_byte(b[e]) {
            e += 1;
        }
        let ty = &f.masked[q..e];
        if NARROW.contains(&ty) {
            emit(
                f,
                out,
                "checked-narrowing",
                at,
                format!("bare `as {ty}` truncates silently — use {ty}::try_from"),
            );
        }
    }
}

/// `epoch-clock`: outside `trace/`, time comes from `trace::clock()` so
/// every timestamp is anchored to the one process trace epoch.
fn epoch_clock(f: &ScannedFile, out: &mut Vec<Finding>) {
    if in_dirs(&f.rel, &["trace"]) {
        return;
    }
    let b = f.masked.as_bytes();
    for at in find_word(&f.masked, "Instant") {
        let Some(c) = next_nonspace(b, at + "Instant".len()) else { continue };
        if b[c] != b':' || b.get(c + 1) != Some(&b':') {
            continue;
        }
        let Some(w) = next_nonspace(b, c + 2) else { continue };
        let mut e = w;
        while e < b.len() && is_ident_byte(b[e]) {
            e += 1;
        }
        if &f.masked[w..e] != "now" {
            continue;
        }
        if next_nonspace(b, e).map(|p| b[p]) == Some(b'(') {
            emit(
                f,
                out,
                "epoch-clock",
                at,
                "raw Instant::now() — use trace::clock() (the epoch anchor)".into(),
            );
        }
    }
}

/// `metrics-naming`: counter names registered via the trace registry are
/// exposed with a `_total` suffix appended at exposition, so the literal
/// must be bare `[a-z0-9._]+` and must NOT already end in `_total`
/// (double suffix at scrape time).
fn metrics_naming(f: &ScannedFile, out: &mut Vec<Finding>) {
    let mb = f.masked.as_bytes();
    let sb = f.src.as_bytes();
    for at in find_word(&f.masked, "counter") {
        let Some(p) = prev_nonspace(mb, at) else { continue };
        if mb[p] != b'.' {
            continue;
        }
        let Some(op) = next_nonspace(mb, at + "counter".len()) else { continue };
        if mb[op] != b'(' {
            continue;
        }
        // the argument only matters when it is a string literal — read it
        // from the unmasked source
        let Some(q) = next_nonspace(sb, op + 1) else { continue };
        if sb[q] != b'"' {
            continue;
        }
        let mut e = q + 1;
        while e < sb.len() && sb[e] != b'"' && sb[e] != b'\\' {
            e += 1;
        }
        if sb.get(e) != Some(&b'"') {
            continue;
        }
        let name = &f.src[q + 1..e];
        let clean = !name.is_empty()
            && name
                .bytes()
                .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == b'.' || c == b'_');
        if name.ends_with("_total") || !clean {
            emit(
                f,
                out,
                "metrics-naming",
                at,
                format!(
                    "counter {name:?} — names are [a-z0-9._]+ and must not end in \
                     _total (the registry appends it at exposition)"
                ),
            );
        }
    }
}

/// `joined-spawn`: a `thread::spawn` whose `JoinHandle` is discarded
/// (bare statement or `let _ =`) leaks the thread past scope — bind the
/// handle and join it, or register it with the owning pool.
fn joined_spawn(f: &ScannedFile, out: &mut Vec<Finding>) {
    let b = f.masked.as_bytes();
    for at in find_word(&f.masked, "spawn") {
        let Some(c) = prev_nonspace(b, at) else { continue };
        if b[c] != b':' || c == 0 || b[c - 1] != b':' {
            continue;
        }
        let Some(tw) = prev_nonspace(b, c - 1) else { continue };
        if word_ending_at(&f.masked, tw + 1) != "thread" {
            continue;
        }
        let Some(op) = next_nonspace(b, at + "spawn".len()) else { continue };
        if b[op] != b'(' {
            continue;
        }
        let close = matching_close(b, op);
        if next_nonspace(b, close + 1).map(|p| b[p]) != Some(b';') {
            continue; // handle is bound, collected, chained, or returned
        }
        // statement start: `thread` or a leading `std::`
        let mut start = tw + 1 - "thread".len();
        if let Some(pc) = prev_nonspace(b, start) {
            if b[pc] == b':' && pc > 0 && b[pc - 1] == b':' {
                if let Some(se) = prev_nonspace(b, pc - 1) {
                    if word_ending_at(&f.masked, se + 1) == "std" {
                        start = se + 1 - "std".len();
                    }
                }
            }
        }
        let discarded = match prev_nonspace(b, start) {
            None => true,
            Some(p) => match b[p] {
                b';' | b'{' | b'}' => true,
                b'=' => {
                    // `let _ = thread::spawn(..);` still discards it
                    let mut is_let_underscore = false;
                    if let Some(we) = prev_nonspace(b, p) {
                        let w = word_ending_at(&f.masked, we + 1);
                        if w == "_" {
                            let ws = we + 1 - w.len();
                            if let Some(le) = prev_nonspace(b, ws) {
                                is_let_underscore =
                                    word_ending_at(&f.masked, le + 1) == "let";
                            }
                        }
                    }
                    is_let_underscore
                }
                _ => false,
            },
        };
        if discarded {
            emit(
                f,
                out,
                "joined-spawn",
                at,
                "thread::spawn handle discarded — join it or register it".into(),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn findings(rel: &str, src: &str) -> Vec<Finding> {
        let f = ScannedFile::new(rel, src);
        let mut out = Vec::new();
        check_file(&f, &mut out);
        out
    }

    fn rules_of(fs: &[Finding]) -> Vec<&'static str> {
        fs.iter().filter(|f| !f.suppressed).map(|f| f.rule).collect()
    }

    #[test]
    fn unwrap_fires_only_in_scoped_dirs() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        assert_eq!(rules_of(&findings("serve/a.rs", src)), vec!["no-panic-path"]);
        assert_eq!(rules_of(&findings("ckpt/sub/a.rs", src)), vec!["no-panic-path"]);
        assert!(rules_of(&findings("train/a.rs", src)).is_empty());
    }

    #[test]
    fn unwrap_in_test_or_string_or_comment_does_not_fire() {
        let src = "\
fn live() -> &'static str { \"x.unwrap()\" } // or .unwrap() in prose
#[cfg(test)]
mod tests {
    #[test]
    fn t() { Some(1).unwrap(); }
}
";
        assert!(rules_of(&findings("serve/a.rs", src)).is_empty());
    }

    #[test]
    fn unwrap_or_variants_do_not_fire() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap_or(0).min(x.unwrap_or_default()) }\n";
        assert!(rules_of(&findings("serve/a.rs", src)).is_empty());
    }

    #[test]
    fn panic_macros_fire_but_paths_do_not() {
        let src = "fn f() { if std::panic::catch_unwind(|| ()).is_err() { panic!(\"x\") } }\n";
        assert_eq!(rules_of(&findings("net/a.rs", src)), vec!["no-panic-path"]);
    }

    #[test]
    fn indexing_semantics() {
        let fire = "fn f(v: &[u32], i: usize) -> u32 { v[i] }\n";
        assert_eq!(rules_of(&findings("serve/a.rs", fire)), vec!["no-panic-path"]);
        let clean = "\
fn f(v: &[u32], h: &[u8; 8]) -> u32 {
    let a: [u8; 4] = [1, 2, 3, 4];
    let _s = &v[..2];
    let _t = &h[4..];
    let x = vec![1u32];
    v[0] + x[0] + (a[1] as u32)
}
";
        assert!(rules_of(&findings("serve/a.rs", clean)).is_empty());
    }

    #[test]
    fn lint_allow_suppresses_and_is_counted() {
        let src = "fn f(v: &[u32], i: usize) -> u32 {\n    v[i] // lint:allow(no-panic-path): i is bounded\n}\n";
        let fs = findings("serve/a.rs", src);
        assert!(rules_of(&fs).is_empty());
        assert_eq!(fs.iter().filter(|f| f.suppressed).count(), 1);
    }

    #[test]
    fn safety_comment_rule() {
        let bad = "fn f() { unsafe { g() } }\n";
        assert_eq!(rules_of(&findings("gemm/a.rs", bad)), vec!["safety-comment"]);
        let good = "fn f() {\n    // SAFETY: g has no preconditions\n    unsafe { g() }\n}\n";
        assert!(rules_of(&findings("gemm/a.rs", good)).is_empty());
    }

    #[test]
    fn narrowing_fires_in_parsers_only() {
        let src = "fn f(x: u64) -> u32 { x as u32 }\n";
        assert_eq!(rules_of(&findings("ckpt/a.rs", src)), vec!["checked-narrowing"]);
        assert!(rules_of(&findings("gemm/a.rs", src)).is_empty());
        let widen = "fn f(x: u8) -> u64 { (x as u64) + (1 as usize as u64) }\n";
        assert!(rules_of(&findings("ckpt/a.rs", widen)).is_empty());
    }

    #[test]
    fn epoch_clock_fires_outside_trace() {
        let src = "fn f() { let _t = std::time::Instant::now(); }\n";
        assert_eq!(rules_of(&findings("serve/a.rs", src)), vec!["epoch-clock"]);
        assert!(rules_of(&findings("trace/a.rs", src)).is_empty());
        let ok = "fn f() { let _t = crate::trace::clock(); }\n";
        assert!(rules_of(&findings("serve/a.rs", ok)).is_empty());
    }

    #[test]
    fn metrics_naming_checks_literals() {
        let bad = "fn f(r: &Registry) { r.counter(\"serve.hits_total\"); }\n";
        assert_eq!(rules_of(&findings("serve/a.rs", bad)), vec!["metrics-naming"]);
        let bad2 = "fn f(r: &Registry) { r.counter(\"Serve Hits\"); }\n";
        assert_eq!(rules_of(&findings("serve/a.rs", bad2)), vec!["metrics-naming"]);
        let good = "fn f(r: &Registry) { r.counter(\"serve.hits\"); }\n";
        assert!(rules_of(&findings("serve/a.rs", good)).is_empty());
        let dynamic = "fn f(r: &Registry, n: &str) { r.counter(n); }\n";
        assert!(rules_of(&findings("serve/a.rs", dynamic)).is_empty());
    }

    #[test]
    fn joined_spawn_fires_on_discarded_handles_only() {
        let bare = "fn f() { std::thread::spawn(|| work()); }\n";
        assert_eq!(rules_of(&findings("util/a.rs", bare)), vec!["joined-spawn"]);
        let let_us = "fn f() { let _ = thread::spawn(|| work()); }\n";
        assert_eq!(rules_of(&findings("util/a.rs", let_us)), vec!["joined-spawn"]);
        let bound = "fn f() { let h = thread::spawn(|| work()); h.join().unwrap(); }\n";
        assert!(rules_of(&findings("util/a.rs", bound)).is_empty());
        let collected = "\
fn f() -> Vec<std::thread::JoinHandle<()>> {
    (0..4)
        .map(|_| {
            std::thread::spawn(move || work())
        })
        .collect()
}
";
        assert!(rules_of(&findings("util/a.rs", collected)).is_empty());
    }

    #[test]
    fn in_dirs_matches_components_not_prefixes() {
        assert!(in_dirs("serve/a.rs", &["serve"]));
        assert!(in_dirs("x/serve/a.rs", &["serve"]));
        assert!(!in_dirs("observer/a.rs", &["serve"]));
        assert!(!in_dirs("serve.rs", &["serve"]));
    }
}
