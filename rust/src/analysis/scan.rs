//! Lexical Rust scanner: the token layer under the in-tree linter.
//!
//! One pass over a source file produces a [`ScannedFile`]:
//!
//! * `masked` — the source with every comment, string literal (plain,
//!   raw, byte, C), and char literal blanked to spaces, byte-for-byte the
//!   same length as `src` so offsets and line numbers line up.  Rules
//!   pattern-match on `masked` and can never fire inside a string or a
//!   comment by construction.
//! * `comments` — the text of every `//` comment, per line.  This is
//!   where `// lint:allow(rule)` suppressions and `// SAFETY:`
//!   justifications live.
//! * test regions — byte ranges owned by an item whose attribute
//!   mentions `test` (`#[test]`, `#[cfg(test)]`, `#[cfg(all(test, ..))]`);
//!   findings inside them are dropped, so test code may `.unwrap()`
//!   freely.
//!
//! The scanner is lexical, not a parser: it understands exactly enough
//! Rust (nested block comments, `r#".."#` hash-delimited raw strings,
//! char-literal vs. lifetime disambiguation, attribute bracket nesting)
//! to make the rule layer's substring matching sound.

/// A scanned source file: original text, masked text, comment map and
/// test regions, plus a line table for offset → line translation.
pub struct ScannedFile {
    /// Path relative to the lint root, `/`-separated (`serve/engine.rs`).
    pub rel: String,
    /// Original source text.
    pub src: String,
    /// Source with comments/strings/chars blanked to spaces (newlines
    /// kept), identical length to `src`.
    pub masked: String,
    /// `(line, text)` for every `//` comment, in file order.
    comments: Vec<(usize, String)>,
    /// Byte ranges `[start, end)` of test items.
    test_regions: Vec<(usize, usize)>,
    /// Byte offset of the start of each line (line 1 first).
    line_starts: Vec<usize>,
}

impl ScannedFile {
    pub fn new(rel: &str, src: &str) -> Self {
        let (masked, comments) = mask_source(src);
        let test_regions = test_regions(&masked);
        let mut line_starts = vec![0usize];
        for (i, b) in src.bytes().enumerate() {
            if b == b'\n' {
                line_starts.push(i + 1);
            }
        }
        ScannedFile {
            rel: rel.to_string(),
            src: src.to_string(),
            masked,
            comments,
            test_regions,
            line_starts,
        }
    }

    /// 1-based line containing byte offset `off`.
    pub fn line_of(&self, off: usize) -> usize {
        match self.line_starts.binary_search(&off) {
            Ok(i) => i + 1,
            Err(i) => i,
        }
    }

    /// Is `off` inside an item marked by a `test` attribute?
    pub fn in_test(&self, off: usize) -> bool {
        self.test_regions.iter().any(|&(a, b)| a <= off && off < b)
    }

    fn comments_on(&self, line: usize) -> impl Iterator<Item = &str> {
        self.comments
            .iter()
            .filter(move |(l, _)| *l == line)
            .map(|(_, t)| t.as_str())
    }

    /// Does a `// lint:allow(rule, ...)` comment on this line or the line
    /// above suppress `rule`?
    pub fn allow_on(&self, line: usize, rule: &str) -> bool {
        for l in [line, line.saturating_sub(1)] {
            for c in self.comments_on(l) {
                if let Some(rest) = c.split("lint:allow(").nth(1) {
                    if let Some(list) = rest.split(')').next() {
                        if list.split(',').any(|r| r.trim() == rule) {
                            return true;
                        }
                    }
                }
            }
        }
        false
    }

    /// Is there a `// SAFETY:` comment on `line` or within the three
    /// lines above it?
    pub fn safety_near(&self, line: usize) -> bool {
        (line.saturating_sub(3)..=line)
            .any(|l| self.comments_on(l).any(|c| c.contains("SAFETY:")))
    }
}

fn blank(out: &mut [u8], from: usize, to: usize) {
    for b in out.iter_mut().take(to).skip(from) {
        if *b != b'\n' {
            *b = b' ';
        }
    }
}

fn count_newlines(b: &[u8], from: usize, to: usize) -> usize {
    b[from..to.min(b.len())].iter().filter(|&&c| c == b'\n').count()
}

pub(crate) fn is_ident_byte(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

fn utf8_len(lead: u8) -> usize {
    match lead {
        c if c < 0x80 => 1,
        c if c < 0xE0 => 2,
        c if c < 0xF0 => 3,
        _ => 4,
    }
}

/// `r"..."`, `r#"..."#`, `br".."`, `cr#".."#` opener at `i`:
/// `(opener_len, hash_count)`.
fn raw_string_open(b: &[u8], i: usize) -> Option<(usize, usize)> {
    let r = match b[i] {
        b'r' => i,
        b'b' | b'c' if b.get(i + 1) == Some(&b'r') => i + 1,
        _ => return None,
    };
    let mut k = r + 1;
    let mut hashes = 0usize;
    while b.get(k) == Some(&b'#') {
        hashes += 1;
        k += 1;
    }
    if b.get(k) == Some(&b'"') {
        Some((k + 1 - i, hashes))
    } else {
        None
    }
}

fn find_raw_end(b: &[u8], start: usize, hashes: usize) -> usize {
    let mut j = start;
    while j < b.len() {
        if b[j] == b'"' {
            let mut h = 0;
            while h < hashes && b.get(j + 1 + h) == Some(&b'#') {
                h += 1;
            }
            if h == hashes {
                return j + 1 + hashes;
            }
        }
        j += 1;
    }
    b.len()
}

fn find_string_end(b: &[u8], mut j: usize) -> usize {
    while j < b.len() {
        match b[j] {
            b'\\' => j += 2,
            b'"' => return j + 1,
            _ => j += 1,
        }
    }
    b.len()
}

/// End of a char literal opening at quote `q`, or `None` for a lifetime.
fn char_literal_end(b: &[u8], q: usize) -> Option<usize> {
    let first = *b.get(q + 1)?;
    if first == b'\\' {
        // skip the escaped char itself so `'\''` terminates at its own
        // closing quote, then scan (bounded: longest escape is \u{10FFFF})
        let mut j = q + 3;
        let limit = (q + 16).min(b.len());
        while j < limit {
            if b[j] == b'\'' {
                return Some(j + 1);
            }
            j += 1;
        }
        return None;
    }
    if first == b'\'' {
        return None;
    }
    let l = utf8_len(first);
    if b.get(q + 1 + l) == Some(&b'\'') {
        return Some(q + 2 + l);
    }
    None
}

/// Blank comments, strings, and char literals; collect `//` comment text.
fn mask_source(src: &str) -> (String, Vec<(usize, String)>) {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = b.to_vec();
    let mut comments: Vec<(usize, String)> = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b'/' && b.get(i + 1) == Some(&b'/') {
            let end = src[i..].find('\n').map(|j| i + j).unwrap_or(n);
            comments.push((line, src[i + 2..end].to_string()));
            blank(&mut out, i, end);
            i = end;
            continue;
        }
        if c == b'/' && b.get(i + 1) == Some(&b'*') {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == b'/' && b.get(j + 1) == Some(&b'*') {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && b.get(j + 1) == Some(&b'/') {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            line += count_newlines(b, i, j);
            blank(&mut out, i, j);
            i = j;
            continue;
        }
        // a literal can only start where an identifier does not continue
        // (`carrier"` is an ident then a string; `br"` alone is a prefix)
        let fresh = i == 0 || !is_ident_byte(b[i - 1]);
        if fresh {
            if let Some((open, hashes)) = raw_string_open(b, i) {
                let j = find_raw_end(b, i + open, hashes);
                line += count_newlines(b, i, j);
                blank(&mut out, i, j);
                i = j;
                continue;
            }
        }
        if c == b'"'
            || (fresh
                && (c == b'b' || c == b'c')
                && b.get(i + 1) == Some(&b'"'))
        {
            let q = if c == b'"' { i } else { i + 1 };
            let j = find_string_end(b, q + 1);
            line += count_newlines(b, i, j);
            blank(&mut out, i, j);
            i = j;
            continue;
        }
        if c == b'\'' || (fresh && c == b'b' && b.get(i + 1) == Some(&b'\'')) {
            let q = if c == b'\'' { i } else { i + 1 };
            if let Some(j) = char_literal_end(b, q) {
                blank(&mut out, i, j);
                i = j;
                continue;
            }
            i = q + 1;
            continue;
        }
        i += 1;
    }
    let masked = String::from_utf8(out).expect("masking whole literals keeps utf-8");
    (masked, comments)
}

/// Does `s` contain `word` with non-identifier bytes on both sides?
pub(crate) fn has_word(s: &str, word: &str) -> bool {
    let b = s.as_bytes();
    let mut from = 0;
    while let Some(p) = s[from..].find(word) {
        let at = from + p;
        let pre_ok = at == 0 || !is_ident_byte(b[at - 1]);
        let post = at + word.len();
        let post_ok = post >= b.len() || !is_ident_byte(b[post]);
        if pre_ok && post_ok {
            return true;
        }
        from = at + word.len();
    }
    false
}

/// Byte offsets of every word-boundary occurrence of `word` in `s`.
pub(crate) fn find_word(s: &str, word: &str) -> Vec<usize> {
    let b = s.as_bytes();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = s[from..].find(word) {
        let at = from + p;
        let pre_ok = at == 0 || !is_ident_byte(b[at - 1]);
        let post = at + word.len();
        let post_ok = post >= b.len() || !is_ident_byte(b[post]);
        if pre_ok && post_ok {
            out.push(at);
        }
        from = at + word.len();
    }
    out
}

/// Index of the last non-whitespace byte strictly before `i`.
pub(crate) fn prev_nonspace(b: &[u8], i: usize) -> Option<usize> {
    let mut j = i;
    while j > 0 {
        j -= 1;
        if !b[j].is_ascii_whitespace() {
            return Some(j);
        }
    }
    None
}

/// Index of the first non-whitespace byte at or after `i`.
pub(crate) fn next_nonspace(b: &[u8], mut i: usize) -> Option<usize> {
    while i < b.len() {
        if !b[i].is_ascii_whitespace() {
            return Some(i);
        }
        i += 1;
    }
    None
}

/// The identifier ending exactly at byte `end` (exclusive), or `""`.
pub(crate) fn word_ending_at(s: &str, end: usize) -> &str {
    let b = s.as_bytes();
    let mut start = end;
    while start > 0 && is_ident_byte(b[start - 1]) {
        start -= 1;
    }
    &s[start..end]
}

/// Matching close bracket for the opener at `open` (same kind only), or
/// the end of the buffer if unbalanced.
pub(crate) fn matching_close(b: &[u8], open: usize) -> usize {
    let (o, c) = match b[open] {
        b'(' => (b'(', b')'),
        b'[' => (b'[', b']'),
        b'{' => (b'{', b'}'),
        _ => return open,
    };
    let mut depth = 1i32;
    let mut j = open + 1;
    while j < b.len() {
        if b[j] == o {
            depth += 1;
        } else if b[j] == c {
            depth -= 1;
            if depth == 0 {
                return j;
            }
        }
        j += 1;
    }
    b.len()
}

/// From the end of an item's attributes, find where its body ends:
/// `Some(end)` for a brace-bodied item, `None` for `...;` declarations.
pub(crate) fn item_body_end(b: &[u8], mut j: usize) -> Option<usize> {
    let n = b.len();
    let mut depth = 0i32;
    while j < n {
        match b[j] {
            b'(' | b'[' => depth += 1,
            b')' | b']' => depth -= 1,
            b';' if depth <= 0 => return None,
            b'{' if depth <= 0 => {
                let mut d = 1i32;
                let mut e = j + 1;
                while e < n && d > 0 {
                    match b[e] {
                        b'{' => d += 1,
                        b'}' => d -= 1,
                        _ => {}
                    }
                    e += 1;
                }
                return Some(e);
            }
            _ => {}
        }
        j += 1;
    }
    None
}

/// Byte ranges of items whose attribute mentions `test`.
fn test_regions(masked: &str) -> Vec<(usize, usize)> {
    let b = masked.as_bytes();
    let n = b.len();
    let mut regions = Vec::new();
    let mut i = 0usize;
    while i < n {
        if b[i] == b'#' && b.get(i + 1) == Some(&b'[') {
            let mut j = i + 2;
            let mut depth = 1i32;
            while j < n && depth > 0 {
                match b[j] {
                    b'[' => depth += 1,
                    b']' => depth -= 1,
                    _ => {}
                }
                j += 1;
            }
            if has_word(&masked[i..j], "test") {
                if let Some(end) = item_body_end(b, j) {
                    regions.push((i, end));
                }
            }
            i = j;
        } else {
            i += 1;
        }
    }
    regions
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_line_and_block_comments_and_keeps_text() {
        let f = ScannedFile::new(
            "x.rs",
            "let a = 1; // unwrap() here\n/* multi\nline panic!() */ let b = 2;\n",
        );
        assert!(!f.masked.contains("unwrap"));
        assert!(!f.masked.contains("panic"));
        assert!(f.masked.contains("let a = 1;"));
        assert!(f.masked.contains("let b = 2;"));
        assert_eq!(f.masked.len(), f.src.len());
        assert!(f.comments_on(1).any(|c| c.contains("unwrap() here")));
    }

    #[test]
    fn masks_nested_block_comments() {
        let f = ScannedFile::new("x.rs", "/* a /* b */ panic!() */ ok();");
        assert!(!f.masked.contains("panic"));
        assert!(f.masked.contains("ok();"));
    }

    #[test]
    fn masks_strings_raw_strings_and_chars() {
        let src = r####"let s = "a.unwrap()"; let r = r#"panic!("x")"#; let c = '[';"####;
        let f = ScannedFile::new("x.rs", src);
        assert!(!f.masked.contains("unwrap"));
        assert!(!f.masked.contains("panic"));
        assert!(!f.masked.contains('['));
        assert!(f.masked.contains("let s ="));
    }

    #[test]
    fn string_escapes_do_not_leak() {
        let f = ScannedFile::new("x.rs", r#"let s = "a\"b.unwrap()"; x();"#);
        assert!(!f.masked.contains("unwrap"));
        assert!(f.masked.contains("x();"));
    }

    #[test]
    fn lifetimes_survive_but_char_literals_do_not() {
        let f = ScannedFile::new("x.rs", "fn f<'a>(x: &'a str) { let c = 'x'; }");
        assert!(f.masked.contains("<'a>"));
        assert!(f.masked.contains("&'a str"));
        assert!(!f.masked.contains("'x'"));
    }

    #[test]
    fn escaped_quote_char_literal_terminates() {
        let f = ScannedFile::new("x.rs", r#"let q = '\''; let s = "unwrap";"#);
        assert!(!f.masked.contains("unwrap"), "masked: {}", f.masked);
    }

    #[test]
    fn ident_ending_in_r_is_not_a_raw_string() {
        let f = ScannedFile::new("x.rs", "let hdr = 1; for r in 0..2 { g(r); }");
        assert!(f.masked.contains("let hdr = 1;"));
        assert!(f.masked.contains("g(r);"));
    }

    #[test]
    fn line_numbers_track_multiline_literals() {
        let f = ScannedFile::new("x.rs", "let s = \"a\nb\nc\";\nfire();\n");
        let off = f.masked.find("fire").unwrap();
        assert_eq!(f.line_of(off), 4);
    }

    #[test]
    fn test_attribute_marks_next_item_body() {
        let src = "fn live() { a(); }\n#[cfg(test)]\nmod tests {\n  fn t() { b(); }\n}\n";
        let f = ScannedFile::new("x.rs", src);
        assert!(!f.in_test(f.masked.find("a()").unwrap()));
        assert!(f.in_test(f.masked.find("b()").unwrap()));
    }

    #[test]
    fn cfg_test_use_declaration_marks_nothing() {
        let src = "#[cfg(test)]\nuse crate::x;\nfn live() { a(); }\n";
        let f = ScannedFile::new("x.rs", src);
        assert!(!f.in_test(f.masked.find("a()").unwrap()));
    }

    #[test]
    fn attr_mentioning_test_in_string_does_not_mark() {
        let src = "#[doc = \"test\"]\nfn live() { a(); }\n";
        let f = ScannedFile::new("x.rs", src);
        assert!(!f.in_test(f.masked.find("a()").unwrap()));
    }

    #[test]
    fn allow_matches_same_and_previous_line_and_rule_lists() {
        let src = "\
a(); // lint:allow(no-panic-path)
b();
// lint:allow(epoch-clock, joined-spawn): reason
c();
";
        let f = ScannedFile::new("x.rs", src);
        assert!(f.allow_on(1, "no-panic-path"));
        assert!(!f.allow_on(2, "no-panic-path"));
        assert!(f.allow_on(4, "joined-spawn"));
        assert!(f.allow_on(4, "epoch-clock"));
        assert!(!f.allow_on(4, "no-panic-path"));
    }

    #[test]
    fn safety_comment_window_is_three_lines() {
        let src = "// SAFETY: fine\n\n\nunsafe { x() }\n\n\n\nunsafe { y() }\n";
        let f = ScannedFile::new("x.rs", src);
        assert!(f.safety_near(4));
        assert!(!f.safety_near(8));
    }

    #[test]
    fn word_helpers_respect_boundaries() {
        assert!(has_word("a test b", "test"));
        assert!(!has_word("attested", "test"));
        assert_eq!(find_word("spawn respawn spawn", "spawn"), vec![0, 14]);
        assert_eq!(word_ending_at("foo.bar_2[", 9), "bar_2");
        assert_eq!(word_ending_at("  [", 2), "");
    }

    #[test]
    fn bracket_matching_nests() {
        let s = "a[b[c]][d]";
        assert_eq!(matching_close(s.as_bytes(), 1), 6);
    }
}
