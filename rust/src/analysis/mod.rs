//! In-tree static analysis: the `switchback lint` invariant linter.
//!
//! Three layers (ISSUE/DESIGN §Static analysis):
//!
//! 1. [`scan`] — a lexical Rust scanner that masks comments/strings/char
//!    literals and tracks `#[test]`/`#[cfg(test)]` item bodies, so rules
//!    match code and only code.
//! 2. [`rules`] — the repo-invariant rules (`no-panic-path`,
//!    `safety-comment`, `checked-narrowing`, `epoch-clock`,
//!    `metrics-naming`, `joined-spawn`), each suppressible inline with
//!    `// lint:allow(rule): reason`.
//! 3. [`locks`] — the lock-order analyzer: per-function acquisition
//!    sequences, the inter-procedural acquisition graph, cycle and
//!    held-across-blocking detection over `serve/`, `trace/`, `ckpt/`.
//!
//! [`lint_root`] walks a source tree, runs all three, and returns a
//! [`LintReport`] that renders as human text and as the flat
//! `BENCH_lint.json` ledger gated by `benchdiff` (suppressions may only
//! shrink against the committed baseline).

pub mod locks;
pub mod rules;
pub mod scan;

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::ObjWriter;
pub use locks::LockGraph;
pub use rules::{Finding, Level, RULES};
pub use scan::ScannedFile;

/// Everything one lint pass produced.
pub struct LintReport {
    /// Files scanned.
    pub files: usize,
    /// All findings — rule findings and lock-order findings, suppressed
    /// ones included (they carry `suppressed: true`).
    pub findings: Vec<Finding>,
    /// The lock acquisition graph.
    pub graph: LockGraph,
}

impl LintReport {
    /// Unsuppressed findings, file/line ordered.
    pub fn active(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| !f.suppressed)
    }

    pub fn suppressed_total(&self) -> usize {
        self.findings.iter().filter(|f| f.suppressed).count()
    }

    /// Highest level among unsuppressed findings.
    pub fn worst(&self) -> Option<Level> {
        self.active().map(|f| f.level).max()
    }

    /// `(active, suppressed)` counts per rule, every known rule present.
    pub fn rule_counts(&self) -> BTreeMap<&'static str, (usize, usize)> {
        let mut out: BTreeMap<&'static str, (usize, usize)> =
            RULES.iter().map(|r| (*r, (0, 0))).collect();
        for f in &self.findings {
            let slot = out.entry(f.rule).or_insert((0, 0));
            if f.suppressed {
                slot.1 += 1;
            } else {
                slot.0 += 1;
            }
        }
        out
    }

    /// The flat `BENCH_lint.json` ledger (`schema: lint_ledger_v1`).
    pub fn ledger_json(&self) -> String {
        let mut w = ObjWriter::new();
        w.field_str("schema", "lint_ledger_v1");
        w.field_u64("files", self.files as u64);
        w.field_u64("findings_total", self.active().count() as u64);
        w.field_u64("suppressed_total", self.suppressed_total() as u64);
        for (rule, (active, sup)) in self.rule_counts() {
            let key = rule.replace('-', "_");
            w.field_u64(&format!("rule_{key}"), active as u64);
            w.field_u64(&format!("sup_{key}"), sup as u64);
        }
        w.field_u64("lock_nodes", self.graph.nodes.len() as u64);
        w.field_u64("lock_edges", self.graph.edges.len() as u64);
        w.field_u64("lock_cycles", self.graph.cycles.len() as u64);
        w.field_u64("blocking_holds", self.graph.blocking_holds() as u64);
        w.field_u64("lock_functions", self.graph.functions as u64);
        w.finish()
    }

    /// Human-readable report: findings, then the lock graph, then totals.
    pub fn render(&self, verbose: bool) -> String {
        let mut out = String::new();
        for f in self.active() {
            out.push_str(&format!(
                "{}:{}: [{}/{}] {}\n",
                f.rel,
                f.line,
                f.level.as_str(),
                f.rule,
                f.message
            ));
        }
        if verbose || self.active().count() == 0 {
            out.push_str(&format!(
                "lock graph: {} nodes, {} edges, {} cycles ({} functions)\n",
                self.graph.nodes.len(),
                self.graph.edges.len(),
                self.graph.cycles.len(),
                self.graph.functions
            ));
            for e in &self.graph.edges {
                out.push_str(&format!(
                    "  {} -> {}  ({}:{})\n",
                    e.from, e.to, e.rel, e.line
                ));
            }
        }
        let per_rule: Vec<String> = self
            .rule_counts()
            .iter()
            .filter(|(_, (a, s))| *a + *s > 0)
            .map(|(r, (a, s))| format!("{r}: {a} (+{s} suppressed)"))
            .collect();
        out.push_str(&format!(
            "lint: {} files, {} findings, {} suppressions{}\n",
            self.files,
            self.active().count(),
            self.suppressed_total(),
            if per_rule.is_empty() {
                String::new()
            } else {
                format!(" — {}", per_rule.join(", "))
            }
        ));
        out
    }
}

/// Lint in-memory sources (`(rel, src)` pairs) — the fixture/test entry
/// point, and the core of [`lint_root`].
pub fn lint_sources(sources: &[(String, String)]) -> LintReport {
    let scanned: Vec<ScannedFile> = sources
        .iter()
        .map(|(rel, src)| ScannedFile::new(rel, src))
        .collect();
    let mut findings = Vec::new();
    for f in &scanned {
        rules::check_file(f, &mut findings);
    }
    let graph = locks::analyze(&scanned);
    findings.extend(graph.findings.iter().cloned());
    findings.sort_by(|a, b| (&a.rel, a.line).cmp(&(&b.rel, b.line)));
    LintReport { files: scanned.len(), findings, graph }
}

/// Recursively collect `.rs` files under `root` (skipping `target/`,
/// `vendor/`, hidden dirs) as `(rel, src)`, sorted by path.
pub fn collect_sources(root: &Path) -> std::io::Result<Vec<(String, String)>> {
    let mut files = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir)? {
            let entry = entry?;
            let path = entry.path();
            let name = entry.file_name().to_string_lossy().into_owned();
            if path.is_dir() {
                if name == "target" || name == "vendor" || name.starts_with('.') {
                    continue;
                }
                stack.push(path);
            } else if name.ends_with(".rs") {
                let rel = path
                    .strip_prefix(root)
                    .unwrap_or(&path)
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy().into_owned())
                    .collect::<Vec<_>>()
                    .join("/");
                let src = std::fs::read_to_string(&path)?;
                files.push((rel, src));
            }
        }
    }
    files.sort();
    Ok(files)
}

/// Lint every `.rs` file under `root`.
pub fn lint_root(root: &Path) -> std::io::Result<LintReport> {
    Ok(lint_sources(&collect_sources(root)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn report(files: &[(&str, &str)]) -> LintReport {
        let sources: Vec<(String, String)> = files
            .iter()
            .map(|(r, s)| (r.to_string(), s.to_string()))
            .collect();
        lint_sources(&sources)
    }

    #[test]
    fn clean_tree_reports_zero_findings() {
        let r = report(&[("serve/a.rs", "fn f(x: Option<u32>) -> Option<u32> { x }\n")]);
        assert_eq!(r.active().count(), 0);
        assert_eq!(r.worst(), None);
        assert!(r.render(false).contains("0 findings"));
    }

    #[test]
    fn ledger_is_valid_flat_json_with_all_rules() {
        let r = report(&[
            ("serve/a.rs", "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n"),
            (
                "serve/b.rs",
                "fn g(v: &[u32], i: usize) -> u32 { v[i] // lint:allow(no-panic-path): bounded\n}\n",
            ),
        ]);
        let v = json::parse(&r.ledger_json()).expect("ledger parses");
        assert_eq!(v.get("schema").unwrap().as_str(), Some("lint_ledger_v1"));
        assert_eq!(v.get("files").unwrap().as_usize(), Some(2));
        assert_eq!(v.get("findings_total").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("suppressed_total").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("rule_no_panic_path").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("sup_no_panic_path").unwrap().as_usize(), Some(1));
        for rule in RULES {
            let key = rule.replace('-', "_");
            assert!(v.get(&format!("rule_{key}")).is_some(), "missing rule_{key}");
            assert!(v.get(&format!("sup_{key}")).is_some(), "missing sup_{key}");
        }
        assert_eq!(v.get("lock_cycles").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn worst_level_escalates_to_error_on_lock_findings() {
        let r = report(&[(
            "serve/a.rs",
            "fn f(s: &S) {\n    let g = s.state.lock().unwrap();\n    s.h.join();\n}\n",
        )]);
        assert_eq!(r.worst(), Some(Level::Error));
        let v = json::parse(&r.ledger_json()).unwrap();
        assert_eq!(v.get("blocking_holds").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn render_lists_findings_with_location() {
        let r = report(&[("net/a.rs", "fn f(x: u64) -> u32 { x as u32 }\n")]);
        let text = r.render(false);
        assert!(text.contains("net/a.rs:1:"), "got: {text}");
        assert!(text.contains("checked-narrowing"), "got: {text}");
    }
}
