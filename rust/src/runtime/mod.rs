//! PJRT runtime: load AOT artifacts (HLO text + manifest) and execute them.
//!
//! Wraps the `xla` crate (PJRT C API, CPU client): HLO text →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Text is the interchange format because jax ≥ 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects (see
//! `/opt/xla-example/README.md`).
//!
//! One [`Artifact`] = one compiled train-step executable (+ optionally the
//! encode executable) + the parameter manifest.  The train step's HLO
//! signature is `(p_0..p_N, images, tokens) → (loss, mags, g_0..g_N)`;
//! rust owns the parameters between steps (the optimizer lives here).

mod manifest;

pub use manifest::{Manifest, TensorSpec};

use crate::optim::ParamMeta;
use crate::tensor::{InitSpec, Rng};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// The PJRT client (one per process).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Self { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an artifact set by name from a directory.
    pub fn load(&self, dir: impl AsRef<Path>, name: &str) -> Result<Artifact> {
        let dir = dir.as_ref();
        let manifest_path = dir.join(format!("{name}.manifest.json"));
        let manifest = Manifest::from_json(
            &std::fs::read_to_string(&manifest_path)
                .with_context(|| format!("reading {}", manifest_path.display()))?,
        )?;
        let exe = self.compile_hlo(&dir.join(&manifest.hlo))?;
        let encode_exe = match &manifest.encode_hlo {
            Some(rel) => Some(self.compile_hlo(&dir.join(rel))?),
            None => None,
        };
        Ok(Artifact {
            manifest,
            dir: dir.to_path_buf(),
            exe,
            encode_exe,
        })
    }

    fn compile_hlo(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }
}

/// A loaded artifact: compiled executables + manifest.
pub struct Artifact {
    pub manifest: Manifest,
    dir: PathBuf,
    exe: xla::PjRtLoadedExecutable,
    encode_exe: Option<xla::PjRtLoadedExecutable>,
}

/// Output of one train-step execution.
pub struct StepOutput {
    pub loss: f32,
    /// per-block mean |features| (vision ++ text)
    pub mags: Vec<f32>,
    /// gradients, one per parameter tensor, in manifest order
    pub grads: Vec<Vec<f32>>,
}

impl Artifact {
    /// Initial parameters: the exact jax init from `params.bin` (seed 0), or
    /// a fresh re-init from the manifest init specs for other seeds.
    pub fn initial_params(&self, seed: u64, reinit: bool) -> Result<Vec<Vec<f32>>> {
        if !reinit && seed == 0 {
            return self.params_from_bin();
        }
        let base = Rng::seed(seed);
        self.manifest
            .tensors
            .iter()
            .enumerate()
            .map(|(i, t)| {
                let spec = InitSpec::parse(&t.init)
                    .with_context(|| format!("bad init spec {:?}", t.init))?;
                let mut buf = vec![0.0f32; t.numel];
                spec.fill(&mut buf, &mut base.fork(i as u64));
                Ok(buf)
            })
            .collect()
    }

    fn params_from_bin(&self) -> Result<Vec<Vec<f32>>> {
        let path = self.dir.join(&self.manifest.params_bin);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() != self.manifest.n_params * 4 {
            bail!(
                "params.bin size mismatch: {} bytes for {} params",
                bytes.len(),
                self.manifest.n_params
            );
        }
        let all: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(self
            .manifest
            .tensors
            .iter()
            .map(|t| all[t.offset..t.offset + t.numel].to_vec())
            .collect())
    }

    /// Optimizer metadata in manifest order.
    pub fn param_metas(&self) -> Vec<ParamMeta> {
        self.manifest
            .tensors
            .iter()
            .map(|t| ParamMeta {
                name: t.name.clone(),
                decay: t.decay,
                kind: t.kind.clone(),
            })
            .collect()
    }

    /// Index of the patch-embedding tensor (the Fig 9 probe target) and of
    /// a mid-transformer control tensor (the Fig 21 control).
    pub fn probe_indices(&self) -> (usize, usize) {
        let pe = self
            .manifest
            .tensors
            .iter()
            .position(|t| t.kind == "patch_embed")
            .unwrap_or(0);
        // control: an attention weight roughly midway through the vision tower
        let weights: Vec<usize> = self
            .manifest
            .tensors
            .iter()
            .enumerate()
            .filter(|(_, t)| t.name.contains("attn.wq") && t.name.contains("visual"))
            .map(|(i, _)| i)
            .collect();
        let mid = weights.get(weights.len() / 2).copied().unwrap_or(pe);
        (pe, mid)
    }

    fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
        let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(data).reshape(&dims)?)
    }

    /// Execute one training step.
    pub fn train_step(
        &self,
        params: &[Vec<f32>],
        images: &[f32],
        tokens: &[i32],
    ) -> Result<StepOutput> {
        let m = &self.manifest;
        if params.len() != m.tensors.len() {
            bail!("expected {} param tensors, got {}", m.tensors.len(), params.len());
        }
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(params.len() + 2);
        for (p, t) in params.iter().zip(&m.tensors) {
            inputs.push(Self::literal_f32(p, &t.shape)?);
        }
        inputs.push(Self::literal_f32(images, &m.inputs.images)?);
        let tok_dims: Vec<i64> = m.inputs.tokens.iter().map(|&d| d as i64).collect();
        inputs.push(xla::Literal::vec1(tokens).reshape(&tok_dims)?);

        let result = self.exe.execute::<xla::Literal>(&inputs)?[0][0]
            .to_literal_sync()?;
        let mut outs = result.to_tuple()?;
        if outs.len() != m.tensors.len() + 2 {
            bail!("expected {} outputs, got {}", m.tensors.len() + 2, outs.len());
        }
        let grads = outs
            .split_off(2)
            .iter()
            .map(|l| l.to_vec::<f32>().map_err(Into::into))
            .collect::<Result<Vec<_>>>()?;
        let mags = outs[1].to_vec::<f32>()?;
        let loss = outs[0].to_vec::<f32>()?[0];
        Ok(StepOutput { loss, mags, grads })
    }

    /// Execute the encode (eval) function on one batch.  Returns
    /// (image_embs, text_embs), each `[batch, embed_dim]` row-major.
    pub fn encode(
        &self,
        params: &[Vec<f32>],
        images: &[f32],
        tokens: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let exe = self
            .encode_exe
            .as_ref()
            .context("artifact has no encode executable")?;
        let m = &self.manifest;
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(params.len() + 2);
        for (p, t) in params.iter().zip(&m.tensors) {
            inputs.push(Self::literal_f32(p, &t.shape)?);
        }
        inputs.push(Self::literal_f32(images, &m.inputs.images)?);
        let tok_dims: Vec<i64> = m.inputs.tokens.iter().map(|&d| d as i64).collect();
        inputs.push(xla::Literal::vec1(tokens).reshape(&tok_dims)?);
        let result = exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        if outs.len() != 2 {
            bail!("encode: expected 2 outputs, got {}", outs.len());
        }
        Ok((outs[0].to_vec::<f32>()?, outs[1].to_vec::<f32>()?))
    }

    pub fn batch(&self) -> usize {
        self.manifest.batch
    }
}
