//! The artifact manifest emitted by `python/compile/aot.py`, parsed with
//! the in-tree JSON module.

use crate::util::json::{parse, Value};
use anyhow::{anyhow, Context, Result};

/// One parameter tensor's description.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub numel: usize,
    /// offset (in floats) into params.bin
    pub offset: usize,
    /// weight decay applies
    pub decay: bool,
    /// "patch_embed" | "embedding" | "weight" | "norm" | "layer_scale" | ...
    pub kind: String,
    /// re-init spec: "zeros" | "ones" | "const:<v>" | "normal:<std>"
    pub init: String,
}

#[derive(Debug, Clone)]
pub struct InputShapes {
    /// [batch, patches, patch_dim]
    pub images: Vec<usize>,
    /// [batch, seq]
    pub tokens: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct ModelShape {
    pub dim: usize,
    pub vision_blocks: usize,
    pub text_blocks: usize,
    pub heads: usize,
    pub patches: usize,
    pub patch_dim: usize,
    pub seq: usize,
    pub vocab: usize,
    pub embed_dim: usize,
    pub layer_scale: bool,
    pub kq_norm: bool,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    pub size: String,
    pub variant: String,
    pub batch: usize,
    pub config: ModelShape,
    pub n_tensors: usize,
    pub n_params: usize,
    pub inputs: InputShapes,
    pub hlo: String,
    pub encode_hlo: Option<String>,
    pub params_bin: String,
    pub tensors: Vec<TensorSpec>,
}

fn req<'a>(v: &'a Value, key: &str) -> Result<&'a Value> {
    v.get(key).ok_or_else(|| anyhow!("manifest missing key {key:?}"))
}

fn req_str(v: &Value, key: &str) -> Result<String> {
    Ok(req(v, key)?
        .as_str()
        .ok_or_else(|| anyhow!("{key} not a string"))?
        .to_string())
}

fn req_usize(v: &Value, key: &str) -> Result<usize> {
    req(v, key)?.as_usize().ok_or_else(|| anyhow!("{key} not a number"))
}

fn opt_bool(v: &Value, key: &str) -> bool {
    v.get(key).and_then(|x| x.as_bool()).unwrap_or(false)
}

impl Manifest {
    pub fn from_json(text: &str) -> Result<Self> {
        let v = parse(text).map_err(|e| anyhow!("manifest JSON: {e}"))?;
        let cfg = req(&v, "config")?;
        let config = ModelShape {
            dim: req_usize(cfg, "dim")?,
            vision_blocks: req_usize(cfg, "vision_blocks")?,
            text_blocks: req_usize(cfg, "text_blocks")?,
            heads: req_usize(cfg, "heads")?,
            patches: req_usize(cfg, "patches")?,
            patch_dim: req_usize(cfg, "patch_dim")?,
            seq: req_usize(cfg, "seq")?,
            vocab: req_usize(cfg, "vocab")?,
            embed_dim: req_usize(cfg, "embed_dim")?,
            layer_scale: opt_bool(cfg, "layer_scale"),
            kq_norm: opt_bool(cfg, "kq_norm"),
        };
        let ins = req(&v, "inputs")?;
        let inputs = InputShapes {
            images: req(ins, "images")?
                .as_usize_vec()
                .context("inputs.images")?,
            tokens: req(ins, "tokens")?
                .as_usize_vec()
                .context("inputs.tokens")?,
        };
        let tensors = req(&v, "tensors")?
            .as_arr()
            .context("tensors not an array")?
            .iter()
            .map(|t| {
                Ok(TensorSpec {
                    name: req_str(t, "name")?,
                    shape: req(t, "shape")?.as_usize_vec().context("shape")?,
                    numel: req_usize(t, "numel")?,
                    offset: req_usize(t, "offset")?,
                    decay: opt_bool(t, "decay"),
                    kind: req_str(t, "kind")?,
                    init: req_str(t, "init")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let encode_hlo = match v.get("encode_hlo") {
            Some(Value::Str(s)) => Some(s.clone()),
            _ => None,
        };
        Ok(Self {
            name: req_str(&v, "name")?,
            size: req_str(&v, "size")?,
            variant: req_str(&v, "variant")?,
            batch: req_usize(&v, "batch")?,
            config,
            n_tensors: req_usize(&v, "n_tensors")?,
            n_params: req_usize(&v, "n_params")?,
            inputs,
            hlo: req_str(&v, "hlo")?,
            encode_hlo,
            params_bin: req_str(&v, "params_bin")?,
            tensors,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_real_manifest_shape() {
        let json = r#"{
          "name": "x", "size": "micro", "variant": "highprec", "batch": 4,
          "config": {"dim": 64, "vision_blocks": 2, "text_blocks": 2,
                     "heads": 4, "patches": 16, "patch_dim": 48, "seq": 16,
                     "vocab": 512, "embed_dim": 64},
          "n_tensors": 1, "n_params": 4,
          "inputs": {"images": [4, 16, 48], "tokens": [4, 16]},
          "hlo": "x.hlo.txt", "encode_hlo": null, "params_bin": "x.params.bin",
          "tensors": [{"name": "t", "shape": [2, 2], "numel": 4, "offset": 0,
                       "decay": true, "kind": "weight", "init": "normal:0.1"}]
        }"#;
        let m = Manifest::from_json(json).unwrap();
        assert_eq!(m.config.dim, 64);
        assert_eq!(m.tensors[0].numel, 4);
        assert!(m.encode_hlo.is_none());
        assert!(!m.config.layer_scale);
        assert!(m.tensors[0].decay);
    }

    #[test]
    fn missing_key_is_an_error() {
        assert!(Manifest::from_json("{}").is_err());
    }
}
