//! The span tracer: scoped guards → thread-local buffers → one global ring.
//!
//! Recording a span costs two clock reads and a push onto a thread-local
//! `Vec` — no lock, no allocation on the steady state.  Buffers drain into
//! the bounded global ring every [`FLUSH_AT`] spans, when their thread
//! exits (a TLS drop guard, so scoped workers never lose spans), and when
//! [`take`] collects the trace.  The ring holds the most recent
//! [`RING_CAP`] spans; older ones are dropped and counted, never silently.
//!
//! Tracing is **on by default** (the overhead is gated in benchdiff via
//! `trace_overhead_pct`); [`set_enabled`]`(false)` reduces [`span`] to a
//! single relaxed load for A/B overhead measurements.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

/// Most recent spans retained by the global ring.
pub const RING_CAP: usize = 65_536;
/// Thread-local buffer length that triggers a drain into the ring.
const FLUSH_AT: usize = 64;

/// One completed span: a named, categorized `[start, start+dur)` interval
/// on one thread.  `seq` carries a small per-span argument (layer index,
/// shard index); it is exported as `args.seq` in the Chrome trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    pub name: &'static str,
    pub cat: &'static str,
    /// nanoseconds since the process trace epoch
    pub start_ns: u64,
    pub dur_ns: u64,
    /// tracer-assigned thread id (1-based, in thread-creation order)
    pub tid: u64,
    pub seq: u32,
}

static ENABLED: AtomicBool = AtomicBool::new(true);
static RECORDED: AtomicU64 = AtomicU64::new(0);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static RING: Mutex<Vec<Span>> = Mutex::new(Vec::new());

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // ring contents stay coherent across a panicking recorder; poisoning
    // carries no extra information here
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the process trace epoch (first trace call wins).
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

/// The process clock: the one audited `Instant` source outside `trace/`
/// (the `epoch-clock` lint rule bans raw `Instant::now()` elsewhere).
/// Reading it pins the trace epoch first, so durations measured from the
/// returned instant and span timestamps from [`now_ns`] share one
/// timeline.
pub fn clock() -> Instant {
    let _ = epoch();
    Instant::now()
}

/// Globally enable/disable span recording (metrics are unaffected).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Total spans recorded since process start (monotone; never reset).  The
/// trainer differences this across a run to compute spans-per-step for
/// the `trace_overhead_pct` bench field.
pub fn spans_recorded() -> u64 {
    RECORDED.load(Ordering::Relaxed)
}

struct ThreadBuf {
    tid: u64,
    buf: Vec<Span>,
}

impl Drop for ThreadBuf {
    // thread exit: whatever the buffer still holds reaches the ring, so
    // short-lived scoped workers (GEMM shards, ckpt shard writers) never
    // lose their spans
    fn drop(&mut self) {
        flush_into_ring(&mut self.buf);
    }
}

thread_local! {
    static TLS: RefCell<ThreadBuf> = RefCell::new(ThreadBuf {
        tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
        buf: Vec::with_capacity(FLUSH_AT),
    });
}

/// Append `buf` to the ring, dropping the oldest spans past [`RING_CAP`].
fn flush_into_ring(buf: &mut Vec<Span>) {
    if buf.is_empty() {
        return;
    }
    let mut ring = lock(&RING);
    let over = (ring.len() + buf.len()).saturating_sub(RING_CAP);
    if over > 0 {
        let from_ring = over.min(ring.len());
        ring.drain(..from_ring);
        let from_buf = over - from_ring;
        if from_buf > 0 {
            buf.drain(..from_buf.min(buf.len()));
        }
        DROPPED.fetch_add(over as u64, Ordering::Relaxed);
    }
    ring.append(buf);
}

fn push(mut sp: Span) {
    RECORDED.fetch_add(1, Ordering::Relaxed);
    let buffered = TLS
        .try_with(|cell| match cell.try_borrow_mut() {
            Ok(mut tb) => {
                sp.tid = tb.tid;
                tb.buf.push(sp);
                if tb.buf.len() >= FLUSH_AT {
                    flush_into_ring(&mut tb.buf);
                }
                true
            }
            Err(_) => false,
        })
        .unwrap_or(false);
    if !buffered {
        // TLS unavailable (thread teardown): straight to the ring, tid 0
        flush_into_ring(&mut vec![sp]);
    }
}

/// A scoped span: measures from construction to drop.
#[must_use = "a span measures until it is dropped — bind it with `let _sp = ...`"]
pub struct SpanGuard {
    name: &'static str,
    cat: &'static str,
    seq: u32,
    start_ns: u64,
    live: bool,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.live {
            push(Span {
                name: self.name,
                cat: self.cat,
                start_ns: self.start_ns,
                dur_ns: now_ns().saturating_sub(self.start_ns),
                tid: 0,
                seq: self.seq,
            });
        }
    }
}

/// Open a scoped span; it records itself when dropped.
pub fn span(name: &'static str, cat: &'static str) -> SpanGuard {
    span_n(name, cat, 0)
}

/// [`span`] with a small numeric argument (layer/shard index).
pub fn span_n(name: &'static str, cat: &'static str, seq: u32) -> SpanGuard {
    let live = enabled();
    SpanGuard {
        name,
        cat,
        seq,
        start_ns: if live { now_ns() } else { 0 },
        live,
    }
}

/// Record a span retroactively from explicit timestamps — for intervals
/// that do not nest on one call stack (queue waits, swap pauses measured
/// elsewhere).
pub fn event_at(name: &'static str, cat: &'static str, start_ns: u64, dur_ns: u64, seq: u32) {
    if enabled() {
        push(Span { name, cat, start_ns, dur_ns, tid: 0, seq });
    }
}

/// Everything [`take`] collected: the retained spans (start-ordered) and
/// how many older spans the bounded ring had to drop to stay within
/// [`RING_CAP`].
#[derive(Debug, Default)]
pub struct TraceDump {
    pub spans: Vec<Span>,
    pub dropped: u64,
}

/// Drain the calling thread's buffer and collect the global ring.
///
/// Buffers of *other still-running* threads are not reachable; they drain
/// on their own cadence ([`FLUSH_AT`]) and at thread exit, so call this
/// after worker pools have been joined for a complete trace.
pub fn take() -> TraceDump {
    let _ = TLS.try_with(|cell| {
        if let Ok(mut tb) = cell.try_borrow_mut() {
            flush_into_ring(&mut tb.buf);
        }
    });
    let mut spans = std::mem::take(&mut *lock(&RING));
    spans.sort_by_key(|s| (s.start_ns, s.tid));
    TraceDump { spans, dropped: DROPPED.swap(0, Ordering::Relaxed) }
}

/// Non-destructive [`take`]: drain the calling thread's buffer into the
/// ring, then *copy* the ring instead of emptying it, leaving the
/// `dropped` count in place.  This is the `/trace` telemetry endpoint's
/// read — a live scrape must not steal the spans the end-of-run
/// `--trace-out` dump is still going to collect.
pub fn peek() -> TraceDump {
    let _ = TLS.try_with(|cell| {
        if let Ok(mut tb) = cell.try_borrow_mut() {
            flush_into_ring(&mut tb.buf);
        }
    });
    let mut spans = lock(&RING).clone();
    spans.sort_by_key(|s| (s.start_ns, s.tid));
    TraceDump { spans, dropped: DROPPED.load(Ordering::Relaxed) }
}

/// Measured cost of one span record, in nanoseconds: two clock reads plus
/// a buffered push with the same amortized-drain shape as the live path.
/// Feeds `trace_overhead_pct = spans_per_step * cost / step_time`, the
/// honest alternative to re-running the whole bench with tracing off.
pub fn calibrate_span_cost_ns(iters: u32) -> f64 {
    let iters = iters.max(1);
    let mut scratch: Vec<Span> = Vec::with_capacity(FLUSH_AT);
    let t0 = Instant::now();
    for i in 0..iters {
        let s = now_ns();
        scratch.push(Span {
            name: "trace.calibrate",
            cat: "trace",
            start_ns: s,
            dur_ns: now_ns().saturating_sub(s),
            tid: 0,
            seq: i,
        });
        if scratch.len() >= FLUSH_AT {
            scratch.clear();
        }
    }
    std::hint::black_box(&scratch);
    t0.elapsed().as_nanos() as f64 / iters as f64
}

/// Serializes tests that drain the process-global ring with [`take`]
/// (cargo runs tests on parallel threads; two drains would race).
#[cfg(test)]
pub(crate) static RING_TEST_LOCK: Mutex<()> = Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;

    fn named(dump: &TraceDump, name: &str) -> Vec<Span> {
        dump.spans.iter().filter(|s| s.name == name).copied().collect()
    }

    #[test]
    fn guard_records_one_span_with_duration() {
        let _l = lock(&RING_TEST_LOCK);
        {
            let _sp = span("trace.test.guard", "test");
            std::hint::black_box(0u64);
        }
        let got = named(&take(), "trace.test.guard");
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].cat, "test");
        assert!(got[0].tid >= 1, "TLS must stamp a thread id");
    }

    #[test]
    fn disabled_tracing_records_nothing() {
        let _l = lock(&RING_TEST_LOCK);
        set_enabled(false);
        {
            let _sp = span("trace.test.disabled", "test");
        }
        event_at("trace.test.disabled", "test", 1, 2, 0);
        set_enabled(true);
        assert!(named(&take(), "trace.test.disabled").is_empty());
    }

    #[test]
    fn thread_exit_flushes_partial_buffers() {
        let _l = lock(&RING_TEST_LOCK);
        std::thread::spawn(|| {
            // fewer than FLUSH_AT: only the TLS drop guard can deliver these
            for i in 0..3u32 {
                let _sp = span_n("trace.test.exit", "test", i);
            }
        })
        .join()
        .expect("recorder thread");
        let got = named(&take(), "trace.test.exit");
        assert_eq!(got.len(), 3);
        let seqs: Vec<u32> = got.iter().map(|s| s.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2]);
    }

    #[test]
    fn event_at_preserves_explicit_timestamps() {
        let _l = lock(&RING_TEST_LOCK);
        event_at("trace.test.retro", "test", 12_345, 678, 9);
        let got = named(&take(), "trace.test.retro");
        assert_eq!(got.len(), 1);
        assert_eq!((got[0].start_ns, got[0].dur_ns, got[0].seq), (12_345, 678, 9));
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let _l = lock(&RING_TEST_LOCK);
        let _ = take(); // start from an empty ring
        for i in 0..(RING_CAP + 500) {
            event_at("trace.test.bound", "test", i as u64, 1, 0);
        }
        let dump = take();
        assert!(dump.spans.len() <= RING_CAP);
        assert!(dump.dropped >= 500, "dropped {}", dump.dropped);
        // the *newest* spans survive
        let got = named(&dump, "trace.test.bound");
        assert_eq!(got.last().map(|s| s.start_ns), Some((RING_CAP + 499) as u64));
    }

    #[test]
    fn take_orders_by_start_time() {
        let _l = lock(&RING_TEST_LOCK);
        event_at("trace.test.order", "test", 500, 1, 0);
        event_at("trace.test.order", "test", 100, 1, 0);
        event_at("trace.test.order", "test", 300, 1, 0);
        let got = named(&take(), "trace.test.order");
        let ts: Vec<u64> = got.iter().map(|s| s.start_ns).collect();
        assert_eq!(ts, vec![100, 300, 500]);
    }

    #[test]
    fn peek_is_non_destructive() {
        let _l = lock(&RING_TEST_LOCK);
        let _ = take(); // start from an empty ring
        event_at("trace.test.peek", "test", 10, 1, 0);
        event_at("trace.test.peek", "test", 20, 1, 0);
        let p1 = named(&peek(), "trace.test.peek");
        let p2 = named(&peek(), "trace.test.peek");
        assert_eq!(p1.len(), 2);
        assert_eq!(p1, p2, "peek must not drain the ring");
        // take() still sees everything afterwards
        assert_eq!(named(&take(), "trace.test.peek").len(), 2);
    }

    #[test]
    fn calibration_returns_a_sane_cost() {
        let ns = calibrate_span_cost_ns(10_000);
        assert!(ns > 0.0 && ns < 100_000.0, "per-span cost {ns} ns");
    }
}
