//! Unified tracing + metrics: the measurement substrate for every perf
//! claim in this repo.
//!
//! * [`span`] — the always-on span tracer: scoped guards record into
//!   thread-local buffers that drain to one bounded global ring; no locks
//!   on the record path, no new dependencies.  [`take`] collects the
//!   trace; overhead is itself a gated bench metric
//!   (`trace_overhead_pct`).
//! * [`registry`] — named counters/gauges/histograms behind one
//!   consistent-snapshot API with JSON and Prometheus-style exposition.
//!   `ServeMetrics`, the trainer's step telemetry and ckpt's save/load
//!   timers all record here.
//! * [`flight`] — the spike flight recorder: the last K steps of
//!   full-fidelity probes (loss, grad norm, per-tensor update RMS, and
//!   the paper's `g²/v` under-estimation ratio), dumped as a forensic
//!   JSON bundle when the spike detector or rollback guard fires.
//! * [`export`] — raw span dumps, Chrome trace-event/Perfetto conversion
//!   and the span-time table behind the `switchback trace` CLI.
//! * [`telemetry_http`] — the live scrape surface over all of the above:
//!   `/metrics`, `/metrics.json`, `/healthz`, `/readyz`, `/trace` and
//!   `/flight` served by the hand-rolled [`crate::net::http1`] stack,
//!   wired in via `--telemetry-addr` on `serve`/`train`/`pipeline`.

pub mod export;
pub mod flight;
pub mod registry;
pub mod span;
pub mod telemetry_http;

pub use export::{
    aggregate, chrome_trace_json, parse_span_dump, span_dump_json, top_table,
    write_span_dump, SpanDump, SpanRec, TopRow,
};
pub use flight::{analyze, parse_dump, FlightDump, FlightFrame, FlightRecorder};
pub use registry::{
    global, Counter, Gauge, Hist, HistSummary, MetricValue, MetricsSnapshot,
    Registry,
};
pub use span::{
    calibrate_span_cost_ns, clock, enabled, event_at, now_ns, peek,
    set_enabled, span, span_n, spans_recorded, take, Span, SpanGuard,
    TraceDump, RING_CAP,
};
pub use telemetry_http::{Readiness, TelemetryConfig, TelemetryServer};
