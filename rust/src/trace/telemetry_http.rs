//! The live telemetry plane: read-only HTTP endpoints over the tracing
//! substrate, served by [`crate::net::http1`].
//!
//! Until now every observability surface in this crate was post-hoc —
//! dumps written after the run.  This module is the live view: attach
//! `--telemetry-addr HOST:PORT` to `serve`, `train` or `pipeline` and
//! scrape while the process works.  Endpoints:
//!
//! | path            | body                                             |
//! |-----------------|--------------------------------------------------|
//! | `/metrics`      | Prometheus text exposition of a live snapshot    |
//! | `/metrics.json` | the same snapshot as one JSON object             |
//! | `/healthz`      | process liveness (always `200` while serving)    |
//! | `/readyz`       | mode-specific readiness, `200`/`503` + detail    |
//! | `/trace`        | Chrome trace-event JSON of the current span ring |
//! | `/flight`       | latest flight-recorder window as forensic JSON   |
//!
//! The module knows nothing about engines or trainers: callers hand in
//! closures ([`TelemetryConfig`]) producing the metrics snapshot, the
//! readiness verdict and the flight dump.  That keeps `trace` free of a
//! dependency on `serve`/`train` and makes the endpoints trivially
//! testable.  `/metrics` takes a full consistent
//! [`Registry::snapshot`](crate::trace::Registry::snapshot) per scrape —
//! grouped cross-metric invariants (promotions ≤ swaps) hold in every
//! response, which the wire-level torn-snapshot test below pins.
//! `/trace` uses the non-destructive [`super::span::peek`], so a scrape
//! never steals spans from an end-of-run `--trace-out` dump.

use std::sync::Arc;

use anyhow::Result;

use crate::net::http1::{Handler, Http1Config, Http1Server, Request, Response};
use crate::trace::registry::MetricsSnapshot;
use crate::util::json::ObjWriter;

/// A `/readyz` verdict: overall flag plus named detail fields.
#[derive(Debug, Clone, Default)]
pub struct Readiness {
    pub ready: bool,
    /// `(field, raw-JSON value)` pairs rendered into the response body —
    /// e.g. `("generation", "3")`, `("promoting", "false")`.
    pub detail: Vec<(String, String)>,
}

impl Readiness {
    pub fn new(ready: bool) -> Self {
        Readiness { ready, detail: Vec::new() }
    }

    /// Attach a detail field; `value` must already be valid raw JSON
    /// (number, `true`/`false`, or a quoted string).
    pub fn with(mut self, field: &str, value: impl Into<String>) -> Self {
        self.detail.push((field.to_string(), value.into()));
        self
    }

    fn body(&self, mode: &str) -> String {
        let mut w = ObjWriter::new();
        w.field_bool("ready", self.ready)
            .field_str("mode", mode);
        for (k, v) in &self.detail {
            w.field_raw(k, v);
        }
        w.finish()
    }
}

/// Provider closures wiring a process's live state into the endpoints.
pub struct TelemetryConfig {
    /// `"serve"`, `"train"` or `"pipeline"` — surfaced in `/healthz` and
    /// `/readyz`.
    pub mode: &'static str,
    /// Fresh consistent snapshot for `/metrics` + `/metrics.json`.
    pub snapshot: Arc<dyn Fn() -> MetricsSnapshot + Send + Sync>,
    /// Fresh readiness verdict for `/readyz`.
    pub ready: Arc<dyn Fn() -> Readiness + Send + Sync>,
    /// Flight-recorder dump for `/flight`; `None` (no closure, or the
    /// closure returns `None`) answers `404` — the recorder is optional
    /// run-control.
    pub flight: Option<Arc<dyn Fn() -> Option<String> + Send + Sync>>,
    /// HTTP limits/sizing; `Http1Config::default()` unless a test says
    /// otherwise.
    pub http: Http1Config,
}

/// A running telemetry server; shuts down on drop or explicitly.
pub struct TelemetryServer {
    server: Http1Server,
}

impl TelemetryServer {
    /// Bind `addr` (port 0 for ephemeral) and start serving.
    pub fn bind(addr: &str, cfg: TelemetryConfig) -> Result<TelemetryServer> {
        let http = cfg.http.clone();
        let handler = router(cfg);
        let server = Http1Server::bind(addr, http, handler)?;
        Ok(TelemetryServer { server })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.server.local_addr()
    }

    /// Base URL, e.g. `http://127.0.0.1:43812`.
    pub fn url(&self) -> String {
        format!("http://{}", self.local_addr())
    }

    pub fn shutdown(&mut self) {
        self.server.shutdown();
    }
}

fn router(cfg: TelemetryConfig) -> Handler {
    Arc::new(move |req: &Request| {
        match req.path.as_str() {
            "/metrics" => Response {
                status: 200,
                content_type: "text/plain; version=0.0.4; charset=utf-8".to_string(),
                body: (cfg.snapshot)().to_prometheus().into_bytes(),
            },
            "/metrics.json" => Response::json(200, (cfg.snapshot)().to_json()),
            "/healthz" => {
                let mut w = ObjWriter::new();
                w.field_bool("ok", true).field_str("mode", cfg.mode);
                Response::json(200, w.finish())
            }
            "/readyz" => {
                let r = (cfg.ready)();
                let status = if r.ready { 200 } else { 503 };
                Response::json(status, r.body(cfg.mode))
            }
            "/trace" => {
                // peek → raw span dump → Chrome trace-event JSON, reusing
                // the exact converters behind `switchback trace export`.
                let dump = super::span::peek();
                let raw = super::export::span_dump_json(&dump);
                match super::export::parse_span_dump(&raw) {
                    Ok(sd) => Response::json(200, super::export::chrome_trace_json(&sd)),
                    Err(e) => Response::text(500, format!("trace export failed: {e}\n")),
                }
            }
            "/flight" => match cfg.flight.as_ref().and_then(|f| f()) {
                Some(json) => Response::json(200, json),
                None => Response::text(404, "no flight recorder armed\n"),
            },
            _ => Response::text(
                404,
                "not found; endpoints: /metrics /metrics.json /healthz /readyz /trace /flight\n",
            ),
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::http1::http_get;
    use crate::trace::registry::{MetricValue, Registry};
    use crate::util::json::parse;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::time::Duration;

    const T: Duration = Duration::from_secs(5);

    fn bind(cfg: TelemetryConfig) -> TelemetryServer {
        TelemetryServer::bind("127.0.0.1:0", cfg).expect("bind telemetry")
    }

    fn basic_cfg(reg: Arc<Registry>, ready_flag: Arc<AtomicBool>) -> TelemetryConfig {
        TelemetryConfig {
            mode: "serve",
            snapshot: Arc::new(move || reg.snapshot()),
            ready: Arc::new(move || {
                let up = ready_flag.load(Ordering::Relaxed);
                Readiness::new(up).with("booted", if up { "true" } else { "false" })
            }),
            flight: None,
            http: Http1Config::default(),
        }
    }

    #[test]
    fn endpoints_serve_health_ready_metrics_trace_flight() {
        let reg = Arc::new(Registry::new());
        reg.counter("serve.requests").add(7);
        reg.histogram("serve.request_ns").record(1_000);
        let ready = Arc::new(AtomicBool::new(false));
        let mut cfg = basic_cfg(Arc::clone(&reg), Arc::clone(&ready));
        cfg.flight = Some(Arc::new(|| Some("{\"format\":\"switchback-flight\"}".to_string())));
        let srv = bind(cfg);
        let u = |p: &str| format!("{}{}", srv.url(), p);

        let h = http_get(&u("/healthz"), T).unwrap();
        assert_eq!(h.status, 200);
        assert!(h.body.contains("\"ok\":true"), "{}", h.body);
        assert!(h.body.contains("\"mode\":\"serve\""), "{}", h.body);

        // readiness flips with the provider's state
        let r = http_get(&u("/readyz"), T).unwrap();
        assert_eq!(r.status, 503);
        assert!(r.body.contains("\"ready\":false"), "{}", r.body);
        ready.store(true, Ordering::Relaxed);
        let r = http_get(&u("/readyz"), T).unwrap();
        assert_eq!(r.status, 200);
        assert!(r.body.contains("\"ready\":true"), "{}", r.body);
        assert!(r.body.contains("\"booted\":true"), "{}", r.body);

        let m = http_get(&u("/metrics"), T).unwrap();
        assert_eq!(m.status, 200);
        assert!(m.body.contains("serve_requests_total 7"), "{}", m.body);
        assert!(m.body.contains("serve_request_ns_count 1"), "{}", m.body);

        let mj = http_get(&u("/metrics.json"), T).unwrap();
        let v = parse(&mj.body).expect("metrics.json parses");
        assert_eq!(v.get("serve.requests").unwrap().as_usize(), Some(7));

        let t = http_get(&u("/trace"), T).unwrap();
        assert_eq!(t.status, 200);
        assert!(t.body.contains("\"traceEvents\""), "{}", t.body);

        let f = http_get(&u("/flight"), T).unwrap();
        assert_eq!(f.status, 200);
        assert!(f.body.contains("switchback-flight"), "{}", f.body);

        assert_eq!(http_get(&u("/nope"), T).unwrap().status, 404);
    }

    #[test]
    fn flight_unarmed_is_404() {
        let reg = Arc::new(Registry::new());
        let ready = Arc::new(AtomicBool::new(true));
        let srv = bind(basic_cfg(reg, ready));
        let f = http_get(&format!("{}/flight", srv.url()), T).unwrap();
        assert_eq!(f.status, 404);
    }

    /// Parse `name value` exposition samples out of a `/metrics` body.
    fn sample(body: &str, name: &str) -> Option<f64> {
        body.lines()
            .filter(|l| !l.starts_with('#'))
            .find_map(|l| {
                let (n, v) = l.split_once(' ')?;
                (n == name).then(|| v.parse::<f64>().ok())?
            })
    }

    /// PR 6's torn-snapshot regression, extended to the wire: hammer a
    /// grouped pair of counters and a histogram from writer threads while
    /// scraping `/metrics` over a real localhost socket.  Every scrape
    /// must parse, the grouped invariant must hold inside every scrape,
    /// and totals must be monotonic across scrapes.
    #[test]
    fn wire_scrapes_parse_and_totals_stay_monotonic_under_load() {
        let reg = Arc::new(Registry::new());
        let ready = Arc::new(AtomicBool::new(true));
        let srv = bind(basic_cfg(Arc::clone(&reg), ready));
        let url = format!("{}/metrics", srv.url());

        let stop = Arc::new(AtomicBool::new(false));
        std::thread::scope(|scope| {
            let mut writers = Vec::new();
            for _ in 0..3 {
                let (reg, stop) = (Arc::clone(&reg), Arc::clone(&stop));
                writers.push(scope.spawn(move || {
                    let first = reg.counter("pair.first");
                    let second = reg.counter("pair.second");
                    let hist = reg.histogram("work.ns");
                    while !stop.load(Ordering::Relaxed) {
                        {
                            let _g = reg.grouped();
                            first.inc();
                            second.inc();
                        }
                        hist.record(100);
                    }
                }));
            }

            let (mut last_first, mut last_count) = (0.0f64, 0.0f64);
            for i in 0..50 {
                let resp = http_get(&url, T).expect("scrape");
                assert_eq!(resp.status, 200);
                // every non-comment line is `name value` — the scrape parses
                for line in resp.body.lines().filter(|l| !l.starts_with('#')) {
                    assert_eq!(line.split(' ').count(), 2, "scrape {i}: bad line {line:?}");
                }
                let first = sample(&resp.body, "pair_first_total").unwrap_or(0.0);
                let second = sample(&resp.body, "pair_second_total").unwrap_or(0.0);
                assert_eq!(first, second, "scrape {i} split a grouped update");
                let count = sample(&resp.body, "work_ns_count").unwrap_or(0.0);
                assert!(first >= last_first, "scrape {i}: counter went backwards");
                assert!(count >= last_count, "scrape {i}: histogram count went backwards");
                (last_first, last_count) = (first, count);
            }
            assert!(last_first > 0.0, "writers never advanced the counters");

            stop.store(true, Ordering::Relaxed);
            for w in writers {
                w.join().expect("writer");
            }
        });
    }
}
