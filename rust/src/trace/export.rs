//! Trace serialization: the raw span dump runs write next to their bench
//! artifacts, the Chrome trace-event conversion (`switchback trace
//! export`, loads in Perfetto / `chrome://tracing`), and the per-span
//! aggregate table (`switchback trace top`).
//!
//! Raw dump format (nanoseconds since the process trace epoch):
//!
//! ```json
//! {"format": "switchback-trace", "version": 1, "clock": "ns",
//!  "dropped": 0,
//!  "spans": [{"name": "train.forward", "cat": "train",
//!             "ts": 1200, "dur": 340, "tid": 1, "seq": 0}, ...]}
//! ```

use super::span::TraceDump;
use crate::util::json::{parse, ObjWriter, Value};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One span as read back from a raw dump (owned strings — the in-process
/// [`super::span::Span`] uses `&'static str` names).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpanRec {
    pub name: String,
    pub cat: String,
    pub ts_ns: u64,
    pub dur_ns: u64,
    pub tid: u64,
    pub seq: u32,
}

/// A parsed raw span dump.
#[derive(Debug, Clone, Default)]
pub struct SpanDump {
    pub dropped: u64,
    pub spans: Vec<SpanRec>,
}

/// Serialize a live [`TraceDump`] as the raw dump format.
pub fn span_dump_json(dump: &TraceDump) -> String {
    let spans: Vec<String> = dump
        .spans
        .iter()
        .map(|s| {
            let mut w = ObjWriter::new();
            w.field_str("name", s.name)
                .field_str("cat", s.cat)
                .field_u64("ts", s.start_ns)
                .field_u64("dur", s.dur_ns)
                .field_u64("tid", s.tid)
                .field_u64("seq", s.seq as u64);
            w.finish()
        })
        .collect();
    let mut w = ObjWriter::new();
    w.field_str("format", "switchback-trace")
        .field_u64("version", 1)
        .field_str("clock", "ns")
        .field_u64("dropped", dump.dropped)
        .field_raw("spans", &format!("[{}]", spans.join(",")));
    w.finish()
}

/// [`span_dump_json`] straight to a file.
pub fn write_span_dump(path: &std::path::Path, dump: &TraceDump) -> std::io::Result<()> {
    std::fs::write(path, span_dump_json(dump))
}

/// Parse a raw span dump back.
pub fn parse_span_dump(text: &str) -> Result<SpanDump, String> {
    let v = parse(text)?;
    match v.get("format").and_then(Value::as_str) {
        Some("switchback-trace") => {}
        other => return Err(format!("not a span dump (format {other:?})")),
    }
    let spans = v
        .get("spans")
        .and_then(Value::as_arr)
        .ok_or("missing spans array")?
        .iter()
        .map(|s| {
            let u = |k: &str| s.get(k).and_then(Value::as_f64).unwrap_or(0.0) as u64;
            let txt = |k: &str| {
                s.get(k).and_then(Value::as_str).unwrap_or_default().to_string()
            };
            SpanRec {
                name: txt("name"),
                cat: txt("cat"),
                ts_ns: u("ts"),
                dur_ns: u("dur"),
                tid: u("tid"),
                seq: u("seq") as u32,
            }
        })
        .collect();
    Ok(SpanDump {
        dropped: v.get("dropped").and_then(Value::as_f64).unwrap_or(0.0) as u64,
        spans,
    })
}

/// Microseconds with sub-µs precision — Chrome trace timestamps are µs
/// floats; formatting through f64 keeps ns resolution that `f32` would
/// round away on long runs.
fn us(ns: u64) -> String {
    format!("{:.3}", ns as f64 / 1_000.0)
}

/// Convert a raw dump to Chrome trace-event JSON (complete `"X"` events),
/// the format Perfetto and `chrome://tracing` load directly.
pub fn chrome_trace_json(dump: &SpanDump) -> String {
    let events: Vec<String> = dump
        .spans
        .iter()
        .map(|s| {
            let mut args = ObjWriter::new();
            args.field_u64("seq", s.seq as u64);
            let mut w = ObjWriter::new();
            w.field_str("name", &s.name)
                .field_str("cat", &s.cat)
                .field_str("ph", "X")
                .field_raw("ts", &us(s.ts_ns))
                .field_raw("dur", &us(s.dur_ns))
                .field_u64("pid", 1)
                .field_u64("tid", s.tid)
                .field_raw("args", &args.finish());
            w.finish()
        })
        .collect();
    let mut other = ObjWriter::new();
    other.field_u64("dropped_spans", dump.dropped);
    let mut w = ObjWriter::new();
    w.field_raw("traceEvents", &format!("[{}]", events.join(",")))
        .field_str("displayTimeUnit", "ms")
        .field_raw("otherData", &other.finish());
    w.finish()
}

/// Aggregate rows for `trace top`: per span name, sorted by total time.
#[derive(Debug, Clone, PartialEq)]
pub struct TopRow {
    pub name: String,
    pub count: u64,
    pub total_ns: u64,
    pub max_ns: u64,
}

/// Aggregate a dump per span name, heaviest total first.
pub fn aggregate(dump: &SpanDump) -> Vec<TopRow> {
    let mut by_name: BTreeMap<&str, TopRow> = BTreeMap::new();
    for s in &dump.spans {
        let row = by_name.entry(&s.name).or_insert_with(|| TopRow {
            name: s.name.clone(),
            count: 0,
            total_ns: 0,
            max_ns: 0,
        });
        row.count += 1;
        row.total_ns += s.dur_ns;
        row.max_ns = row.max_ns.max(s.dur_ns);
    }
    let mut rows: Vec<TopRow> = by_name.into_values().collect();
    rows.sort_by(|a, b| b.total_ns.cmp(&a.total_ns).then(a.name.cmp(&b.name)));
    rows
}

/// Human-readable span-time table (the `trace top` output).
pub fn top_table(dump: &SpanDump) -> String {
    let rows = aggregate(dump);
    let name_w = rows.iter().map(|r| r.name.len()).max().unwrap_or(4).max(4);
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<name_w$}  {:>8}  {:>12}  {:>10}  {:>10}",
        "span", "count", "total_ms", "mean_us", "max_us"
    );
    for r in &rows {
        let _ = writeln!(
            out,
            "{:<name_w$}  {:>8}  {:>12.3}  {:>10.1}  {:>10.1}",
            r.name,
            r.count,
            r.total_ns as f64 / 1e6,
            r.total_ns as f64 / 1e3 / r.count.max(1) as f64,
            r.max_ns as f64 / 1e3,
        );
    }
    if dump.dropped > 0 {
        let _ = writeln!(out, "({} spans dropped by the bounded ring)", dump.dropped);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::span::Span;

    fn dump() -> SpanDump {
        let raw = TraceDump {
            spans: vec![
                Span {
                    name: "train.forward",
                    cat: "train",
                    start_ns: 1_500,
                    dur_ns: 2_000,
                    tid: 1,
                    seq: 0,
                },
                Span {
                    name: "serve.gemm",
                    cat: "serve",
                    start_ns: 4_000,
                    dur_ns: 750,
                    tid: 2,
                    seq: 3,
                },
                Span {
                    name: "train.forward",
                    cat: "train",
                    start_ns: 9_000,
                    dur_ns: 1_000,
                    tid: 1,
                    seq: 0,
                },
            ],
            dropped: 2,
        };
        parse_span_dump(&span_dump_json(&raw)).expect("round trip")
    }

    #[test]
    fn raw_dump_round_trips() {
        let d = dump();
        assert_eq!(d.dropped, 2);
        assert_eq!(d.spans.len(), 3);
        assert_eq!(d.spans[0].name, "train.forward");
        assert_eq!(d.spans[1].tid, 2);
        assert_eq!(d.spans[1].seq, 3);
        assert_eq!(d.spans[2].ts_ns, 9_000);
    }

    /// The acceptance-criteria schema check: every trace event carries the
    /// Chrome trace-event required fields with the right types/units.
    #[test]
    fn chrome_trace_schema_shape() {
        let text = chrome_trace_json(&dump());
        let v = parse(&text).expect("chrome trace must be valid JSON");
        assert_eq!(
            v.get("displayTimeUnit").and_then(Value::as_str),
            Some("ms")
        );
        assert_eq!(
            v.get("otherData").and_then(|o| o.get("dropped_spans")).and_then(Value::as_usize),
            Some(2)
        );
        let events = v.get("traceEvents").and_then(Value::as_arr).unwrap();
        assert_eq!(events.len(), 3);
        for e in events {
            assert_eq!(e.get("ph").and_then(Value::as_str), Some("X"));
            assert!(e.get("name").and_then(Value::as_str).is_some());
            assert!(e.get("cat").and_then(Value::as_str).is_some());
            assert_eq!(e.get("pid").and_then(Value::as_usize), Some(1));
            assert!(e.get("tid").and_then(Value::as_usize).is_some());
            // µs floats
            assert!(e.get("ts").and_then(Value::as_f64).is_some());
            assert!(e.get("dur").and_then(Value::as_f64).is_some());
            assert!(e.get("args").and_then(|a| a.get("seq")).is_some());
        }
        // 1500 ns → 1.5 µs, sub-µs precision preserved
        assert_eq!(events[0].get("ts").and_then(Value::as_f64), Some(1.5));
        assert_eq!(events[1].get("dur").and_then(Value::as_f64), Some(0.75));
    }

    #[test]
    fn top_aggregates_and_sorts_by_total() {
        let rows = aggregate(&dump());
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].name, "train.forward");
        assert_eq!(rows[0].count, 2);
        assert_eq!(rows[0].total_ns, 3_000);
        assert_eq!(rows[0].max_ns, 2_000);
        assert_eq!(rows[1].name, "serve.gemm");
        let table = top_table(&dump());
        assert!(table.contains("train.forward"));
        assert!(table.contains("2 spans dropped"));
    }

    #[test]
    fn parse_rejects_non_trace_documents() {
        assert!(parse_span_dump("{\"format\":\"flight\"}").is_err());
        assert!(parse_span_dump("[]").is_err());
    }
}
