//! The spike flight recorder: a bounded ring of full-fidelity per-step
//! probes, dumped as a forensic JSON bundle the moment the spike detector
//! or rollback guard fires.
//!
//! The paper's diagnostic (§3.3–3.4) is *temporal*: loss spikes follow the
//! moment squared gradients become under-estimated by AdamW's second
//! moment by 1–8 iterations.  Post-hoc JSONL often misses the lead-up
//! (probes are sampled every N steps); the flight recorder keeps the last
//! K steps at full fidelity — loss, grad norm, LR, per-tensor update RMS
//! **and the per-tensor `g²/v` under-estimation ratio** — so a dump
//! captures exactly the window the lead–lag machinery needs.
//!
//! Dump format (`switchback trace spikes <dump>` consumes it):
//!
//! ```json
//! {
//!   "format": "switchback-flight", "version": 1,
//!   "trigger": {"kind": "rollback_guard", "step": 123},
//!   "window": 64,
//!   "steps": [
//!     {"step": 60, "loss": 2.1, "grad_norm": 0.9, "lr": 1e-3,
//!      "rms": {"embed": 0.7, "head": 1.1},
//!      "under_estimation_ratio": {"embed": 1.4, "head": 0.9}},
//!     ...
//!   ]
//! }
//! ```

use crate::telemetry::analyzer::{lead_lag_analysis, LeadLagReport};
use crate::telemetry::spikes::SpikeConfig;
use crate::util::json::{parse, ObjWriter, Value};
use std::collections::{BTreeMap, VecDeque};

/// One step's full-fidelity probe set.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FlightFrame {
    pub step: u64,
    pub loss: f32,
    pub grad_norm: f32,
    pub lr: f32,
    /// per-tensor update RMS (paper RMS_t), keyed by probe name
    pub rms: BTreeMap<String, f32>,
    /// per-tensor mean g²/v under-estimation ratio, keyed by probe name
    pub under_est: BTreeMap<String, f32>,
}

impl FlightFrame {
    fn to_json(&self) -> String {
        let map_json = |m: &BTreeMap<String, f32>| {
            let mut w = ObjWriter::new();
            for (k, v) in m {
                w.field_f32(k, *v);
            }
            w.finish()
        };
        let mut w = ObjWriter::new();
        w.field_u64("step", self.step)
            .field_f32("loss", self.loss)
            .field_f32("grad_norm", self.grad_norm)
            .field_f32("lr", self.lr)
            .field_raw("rms", &map_json(&self.rms))
            .field_raw("under_estimation_ratio", &map_json(&self.under_est));
        w.finish()
    }
}

/// A bounded ring of the most recent [`FlightFrame`]s.
#[derive(Debug)]
pub struct FlightRecorder {
    cap: usize,
    frames: VecDeque<FlightFrame>,
}

impl FlightRecorder {
    /// `window`: how many trailing steps a dump covers (K).
    pub fn new(window: usize) -> Self {
        let cap = window.max(1);
        Self { cap, frames: VecDeque::with_capacity(cap) }
    }

    pub fn window(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.frames.len()
    }

    /// Step of the newest frame (0 while empty) — the `/flight`
    /// endpoint's trigger step for a live scrape.
    pub fn last_step(&self) -> u64 {
        self.frames.back().map_or(0, |f| f.step)
    }

    pub fn is_empty(&self) -> bool {
        self.frames.is_empty()
    }

    /// Record one step; the oldest frame falls off past the window.
    pub fn push(&mut self, frame: FlightFrame) {
        if self.frames.len() == self.cap {
            self.frames.pop_front();
        }
        self.frames.push_back(frame);
    }

    /// Serialize the current window as a forensic dump.  `trigger_kind` is
    /// what fired (`"rollback_guard"` / `"loss_spike"`), `trigger_step`
    /// the step it fired at.
    pub fn dump_json(&self, trigger_kind: &str, trigger_step: u64) -> String {
        let mut trig = ObjWriter::new();
        trig.field_str("kind", trigger_kind).field_u64("step", trigger_step);
        let steps: Vec<String> = self.frames.iter().map(|f| f.to_json()).collect();
        let mut w = ObjWriter::new();
        w.field_str("format", "switchback-flight")
            .field_u64("version", 1)
            .field_raw("trigger", &trig.finish())
            .field_u64("window", self.cap as u64)
            .field_raw("steps", &format!("[{}]", steps.join(",")));
        w.finish()
    }

    /// [`dump_json`](Self::dump_json) straight to a file.
    pub fn dump_to(
        &self,
        path: &std::path::Path,
        trigger_kind: &str,
        trigger_step: u64,
    ) -> std::io::Result<()> {
        std::fs::write(path, self.dump_json(trigger_kind, trigger_step))
    }
}

/// A parsed forensic dump.
#[derive(Debug, Clone)]
pub struct FlightDump {
    pub trigger_kind: String,
    pub trigger_step: u64,
    pub window: usize,
    pub frames: Vec<FlightFrame>,
}

fn f32_map(v: Option<&Value>) -> BTreeMap<String, f32> {
    let mut out = BTreeMap::new();
    if let Some(Value::Obj(m)) = v {
        for (k, val) in m {
            if let Some(x) = val.as_f64() {
                out.insert(k.clone(), x as f32);
            }
        }
    }
    out
}

/// Parse a dump produced by [`FlightRecorder::dump_json`].
pub fn parse_dump(text: &str) -> Result<FlightDump, String> {
    let v = parse(text)?;
    match v.get("format").and_then(Value::as_str) {
        Some("switchback-flight") => {}
        other => return Err(format!("not a flight dump (format {other:?})")),
    }
    let trigger = v.get("trigger").ok_or("missing trigger")?;
    let frames = v
        .get("steps")
        .and_then(Value::as_arr)
        .ok_or("missing steps array")?
        .iter()
        .map(|s| {
            let f64_field =
                |k: &str| s.get(k).and_then(Value::as_f64).unwrap_or(0.0);
            FlightFrame {
                step: f64_field("step") as u64,
                loss: f64_field("loss") as f32,
                grad_norm: f64_field("grad_norm") as f32,
                lr: f64_field("lr") as f32,
                rms: f32_map(s.get("rms")),
                under_est: f32_map(s.get("under_estimation_ratio")),
            }
        })
        .collect();
    Ok(FlightDump {
        trigger_kind: trigger
            .get("kind")
            .and_then(Value::as_str)
            .unwrap_or("unknown")
            .to_string(),
        trigger_step: trigger.get("step").and_then(Value::as_f64).unwrap_or(0.0)
            as u64,
        window: v.get("window").and_then(Value::as_usize).unwrap_or(0),
        frames,
    })
}

/// Run the paper's lead–lag analysis over a dump: the loss trace against
/// the per-step **max** update RMS across probed tensors (a spike in any
/// probe counts).  Thresholds come from the paper's Appendix D defaults;
/// the running-stat window and burn-in scale down to the dump length so a
/// K-step window is analyzable at all.
pub fn analyze(dump: &FlightDump) -> LeadLagReport {
    let loss: Vec<f32> = dump.frames.iter().map(|f| f.loss).collect();
    let rms: Vec<f32> = dump
        .frames
        .iter()
        .map(|f| f.rms.values().copied().fold(0.0f32, f32::max))
        .collect();
    let n = dump.frames.len();
    let cfg = SpikeConfig {
        stat_window: (n / 3).clamp(8, 20),
        burn_in: ((n / 4).clamp(4, 20)) as u64,
        ..Default::default()
    };
    lead_lag_analysis(&loss, &rms, &cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(step: u64, loss: f32, rms_a: f32, ratio_a: f32) -> FlightFrame {
        FlightFrame {
            step,
            loss,
            grad_norm: 1.0,
            lr: 1e-3,
            rms: BTreeMap::from([
                ("embed".to_string(), rms_a),
                ("head".to_string(), 0.5),
            ]),
            under_est: BTreeMap::from([
                ("embed".to_string(), ratio_a),
                ("head".to_string(), 1.0),
            ]),
        }
    }

    #[test]
    fn ring_keeps_only_the_last_window() {
        let mut fr = FlightRecorder::new(4);
        for step in 0..10 {
            fr.push(frame(step, 1.0, 0.5, 1.0));
        }
        assert_eq!(fr.len(), 4);
        let dump = parse_dump(&fr.dump_json("loss_spike", 9)).unwrap();
        let steps: Vec<u64> = dump.frames.iter().map(|f| f.step).collect();
        assert_eq!(steps, vec![6, 7, 8, 9], "oldest frames must fall off");
    }

    #[test]
    fn zero_window_still_holds_one_frame() {
        let mut fr = FlightRecorder::new(0);
        fr.push(frame(1, 1.0, 0.5, 1.0));
        fr.push(frame(2, 1.0, 0.5, 1.0));
        assert_eq!(fr.len(), 1);
    }

    /// The acceptance-criteria shape: a dump parses back with
    /// `under_estimation_ratio` for ≥ 2 probed tensors on every frame.
    #[test]
    fn dump_round_trips_with_ratios_for_two_tensors() {
        let mut fr = FlightRecorder::new(8);
        for step in 10..18 {
            fr.push(frame(step, 2.0 + step as f32 * 0.01, 0.7, 1.4));
        }
        let text = fr.dump_json("rollback_guard", 17);
        assert!(text.contains("\"under_estimation_ratio\""));
        let dump = parse_dump(&text).unwrap();
        assert_eq!(dump.trigger_kind, "rollback_guard");
        assert_eq!(dump.trigger_step, 17);
        assert_eq!(dump.window, 8);
        assert_eq!(dump.frames.len(), 8);
        for f in &dump.frames {
            assert!(
                f.under_est.len() >= 2,
                "need ≥2 probed tensors, got {:?}",
                f.under_est
            );
            assert!((f.under_est["embed"] - 1.4).abs() < 1e-6);
            assert_eq!(f.rms.len(), 2);
        }
        // frames survive the round trip exactly (f32-representable values)
        assert_eq!(dump.frames[0].step, 10);
        assert!((dump.frames[0].loss - 2.1).abs() < 1e-6);
    }

    #[test]
    fn parse_rejects_non_flight_documents() {
        assert!(parse_dump("{\"format\":\"other\"}").is_err());
        assert!(parse_dump("not json").is_err());
    }

    /// A synthetic dump where an RMS spike leads a loss spike by 3 steps
    /// must come out of `analyze` as predicted.
    #[test]
    fn analyze_finds_the_lead_lag_structure() {
        let mut fr = FlightRecorder::new(64);
        for step in 0..64u64 {
            // jitter so the loss running-std is nonzero
            let mut loss = 1.0 + ((step % 7) as f32 - 3.0) * 0.01;
            let mut rms = 0.5;
            if step == 40 {
                rms = 3.0; // RMS spike (≥ 2.3)
            }
            if (43..=45).contains(&step) {
                loss = 5.0; // confirmed loss spike 3 steps later
            }
            fr.push(frame(step, loss, rms, 1.0));
        }
        let dump = parse_dump(&fr.dump_json("loss_spike", 43)).unwrap();
        let report = analyze(&dump);
        assert_eq!(report.total_loss_spikes, 1, "{:?}", report.loss_spikes);
        assert_eq!(report.predicted, 1);
        assert_eq!(report.rms_spikes, vec![40]);
        assert!(report.summary().contains("loss spikes follow an RMS spike"));
    }
}
