//! The unified metrics registry: named counters, gauges and histograms
//! behind one snapshot API with JSON and Prometheus-style exposition.
//!
//! Handles ([`Counter`], [`Gauge`], [`Hist`]) are cheap `Arc` clones over
//! relaxed atomics — recording never takes the registry lock.  Consistency
//! across *groups* of metrics comes from the update gate: a multi-metric
//! update holds [`Registry::grouped`] (a shared read lock) while
//! [`Registry::snapshot`] takes the write side, so a snapshot observes a
//! grouped update entirely or not at all.  This is what keeps invariants
//! like `standby_promotions ≤ hot_swaps` true in every mid-run snapshot
//! ([`crate::serve::ServeMetrics`]).
//!
//! Subsystems either own a [`Registry`] instance (the serve engine: one
//! per engine, so tests and multi-engine processes never share counters)
//! or record into the process-wide [`global`] registry (the trainer's
//! step phases, checkpoint save/load timers).

use crate::telemetry::Histogram;
use crate::util::json::{num, quote, ObjWriter};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, RwLock, RwLockReadGuard};

/// A monotone counter handle (also carries max-style watermarks via
/// [`Counter::fetch_max`]).
#[derive(Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Overwrite the value (tests and gauge-like watermark resets).
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Raise the value to at least `v` (watermarks, e.g. worst swap pause).
    pub fn fetch_max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }
}

/// An f64 gauge handle (stored as IEEE bits in one atomic).
#[derive(Clone, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// A histogram handle; derefs to [`Histogram`] so `record`/`quantile`/
/// `percentiles`/`merge` are available directly.
#[derive(Clone)]
pub struct Hist(Arc<Histogram>);

impl Default for Hist {
    fn default() -> Self {
        Self(Arc::new(Histogram::new()))
    }
}

impl std::ops::Deref for Hist {
    type Target = Histogram;

    fn deref(&self) -> &Histogram {
        &self.0
    }
}

enum Slot {
    Counter(Counter),
    Gauge(Gauge),
    Hist(Hist),
}

impl Slot {
    fn kind(&self) -> &'static str {
        match self {
            Slot::Counter(_) => "counter",
            Slot::Gauge(_) => "gauge",
            Slot::Hist(_) => "histogram",
        }
    }
}

/// A named-metric registry with one consistent snapshot API.
#[derive(Default)]
pub struct Registry {
    slots: Mutex<BTreeMap<String, Slot>>,
    gate: RwLock<()>,
}

/// Holding this marks a multi-metric update as one atomic group with
/// respect to [`Registry::snapshot`].  Do not nest acquisitions on one
/// thread (a queued snapshot writer could deadlock a re-entrant reader).
#[must_use = "the update group lasts until the guard is dropped"]
pub struct UpdateGuard<'a>(#[allow(dead_code)] RwLockReadGuard<'a, ()>);

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get-or-create the named counter.  Panics if `name` is already
    /// registered as a different metric kind (a naming bug, not a runtime
    /// condition).
    pub fn counter(&self, name: &str) -> Counter {
        match self.slot(name, || Slot::Counter(Counter::default())) {
            Slot::Counter(c) => c.clone(),
            other => panic!("metric {name:?} is a {}, not a counter", other.kind()),
        }
    }

    /// Get-or-create the named gauge.
    pub fn gauge(&self, name: &str) -> Gauge {
        match self.slot(name, || Slot::Gauge(Gauge::default())) {
            Slot::Gauge(g) => g.clone(),
            other => panic!("metric {name:?} is a {}, not a gauge", other.kind()),
        }
    }

    /// Get-or-create the named histogram.
    pub fn histogram(&self, name: &str) -> Hist {
        match self.slot(name, || Slot::Hist(Hist::default())) {
            Slot::Hist(h) => h.clone(),
            other => panic!("metric {name:?} is a {}, not a histogram", other.kind()),
        }
    }

    fn slot(&self, name: &str, mk: impl FnOnce() -> Slot) -> Slot {
        let mut slots = lock(&self.slots);
        let slot = slots.entry(name.to_string()).or_insert_with(mk);
        match slot {
            Slot::Counter(c) => Slot::Counter(c.clone()),
            Slot::Gauge(g) => Slot::Gauge(g.clone()),
            Slot::Hist(h) => Slot::Hist(h.clone()),
        }
    }

    /// Mark a multi-metric update as atomic with respect to snapshots.
    pub fn grouped(&self) -> UpdateGuard<'_> {
        UpdateGuard(self.gate.read().unwrap_or_else(|e| e.into_inner()))
    }

    /// One-pass snapshot of every registered metric.  Takes the write
    /// side of the update gate: no [`grouped`](Self::grouped) update is
    /// in flight while the values are read, so cross-metric invariants
    /// maintained under the gate hold in the result.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let _gate = self.gate.write().unwrap_or_else(|e| e.into_inner());
        let slots = lock(&self.slots);
        let entries = slots
            .iter()
            .map(|(name, slot)| {
                let value = match slot {
                    Slot::Counter(c) => MetricValue::Counter(c.get()),
                    Slot::Gauge(g) => MetricValue::Gauge(g.get()),
                    Slot::Hist(h) => {
                        let (p50, p95, p99) = h.percentiles();
                        MetricValue::Hist(HistSummary {
                            count: h.count(),
                            sum: h.sum(),
                            max: h.max(),
                            p50,
                            p95,
                            p99,
                        })
                    }
                };
                (name.clone(), value)
            })
            .collect();
        MetricsSnapshot { entries }
    }
}

/// Quantile/total summary of one histogram at snapshot time.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HistSummary {
    pub count: u64,
    pub sum: u64,
    pub max: u64,
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
}

/// One metric's value at snapshot time.
#[derive(Debug, Clone, PartialEq)]
pub enum MetricValue {
    Counter(u64),
    Gauge(f64),
    Hist(HistSummary),
}

/// A point-in-time copy of a whole registry, name-sorted.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    pub entries: Vec<(String, MetricValue)>,
}

/// Prometheus metric names allow `[a-zA-Z0-9_:]`; ours use dots.
fn prom_name(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == ':' { c } else { '_' })
        .collect()
}

impl MetricsSnapshot {
    pub fn get(&self, name: &str) -> Option<&MetricValue> {
        self.entries
            .binary_search_by(|(n, _)| n.as_str().cmp(name))
            .ok()
            .map(|i| &self.entries[i].1)
    }

    /// Merge `other` into `self` (the telemetry plane exposes one scrape
    /// surface over an engine-owned registry *plus* the global one).  On
    /// a duplicate name, `self`'s entry wins — registries use disjoint
    /// prefixes (`serve.` / `train.` / `ckpt.`), so a collision here is a
    /// naming bug, not data to aggregate.
    pub fn merged(mut self, other: MetricsSnapshot) -> MetricsSnapshot {
        self.entries.extend(other.entries);
        // Stable sort: for equal names, self's entry stays first and
        // dedup keeps it.
        self.entries.sort_by(|a, b| a.0.cmp(&b.0));
        self.entries.dedup_by(|a, b| a.0 == b.0);
        self
    }

    /// Exposition sample names, one per entry in entry order: sanitized
    /// via [`prom_name`], counters suffixed `_total` (Prometheus
    /// convention), and sanitization collisions (`a.b` and `a_b` both
    /// sanitize to `a_b`) disambiguated deterministically — the first
    /// entry in name-sorted order keeps the base name, later ones get
    /// `_2`, `_3`, … — so no two entries ever emit the same sample name.
    fn exposition_names(&self) -> Vec<String> {
        let mut taken: std::collections::HashSet<String> = std::collections::HashSet::new();
        self.entries
            .iter()
            .map(|(name, v)| {
                let mut base = prom_name(name);
                if matches!(v, MetricValue::Counter(_)) {
                    base.push_str("_total");
                }
                let chosen = if taken.contains(&base) {
                    let mut i = 2usize;
                    loop {
                        let cand = format!("{base}_{i}");
                        if !taken.contains(&cand) {
                            break cand;
                        }
                        i += 1;
                    }
                } else {
                    base
                };
                taken.insert(chosen.clone());
                chosen
            })
            .collect()
    }

    /// One JSON object: counters/gauges as numbers, histograms as nested
    /// `{count, sum, max, p50, p95, p99}` objects.
    pub fn to_json(&self) -> String {
        let mut w = ObjWriter::new();
        for (name, v) in &self.entries {
            match v {
                MetricValue::Counter(c) => {
                    w.field_u64(name, *c);
                }
                MetricValue::Gauge(g) => {
                    w.field_raw(name, &num(*g as f32));
                }
                MetricValue::Hist(h) => {
                    let mut hw = ObjWriter::new();
                    hw.field_u64("count", h.count)
                        .field_u64("sum", h.sum)
                        .field_u64("max", h.max)
                        .field_u64("p50", h.p50)
                        .field_u64("p95", h.p95)
                        .field_u64("p99", h.p99);
                    w.field_raw(name, &hw.finish());
                }
            }
        }
        w.finish()
    }

    /// Prometheus-style text exposition: counters as `_total` samples,
    /// gauges as single samples, histograms as summaries
    /// (`{quantile=...}` + `_sum` + `_count`).  Sample names come from
    /// [`Self::exposition_names`], so sanitization collisions are
    /// disambiguated instead of silently emitting duplicate samples.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let names = self.exposition_names();
        for ((_, v), n) in self.entries.iter().zip(names) {
            match v {
                MetricValue::Counter(c) => {
                    let _ = writeln!(out, "# TYPE {n} counter\n{n} {c}");
                }
                MetricValue::Gauge(g) => {
                    let _ = writeln!(out, "# TYPE {n} gauge\n{n} {g}");
                }
                MetricValue::Hist(h) => {
                    let _ = writeln!(out, "# TYPE {n} summary");
                    for (q, qv) in [("0.5", h.p50), ("0.95", h.p95), ("0.99", h.p99)] {
                        let _ = writeln!(out, "{n}{{quantile={}}} {qv}", quote(q));
                    }
                    let _ = writeln!(out, "{n}_sum {}\n{n}_count {}", h.sum, h.count);
                }
            }
        }
        out
    }
}

/// The process-wide registry (trainer step phases, ckpt save/load timers,
/// anything without a natural owner).
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn handles_are_shared_by_name() {
        let r = Registry::new();
        r.counter("a.requests").add(3);
        r.counter("a.requests").inc();
        assert_eq!(r.counter("a.requests").get(), 4);
        r.gauge("a.load").set(0.5);
        assert_eq!(r.gauge("a.load").get(), 0.5);
        r.histogram("a.lat_ns").record(1000);
        assert_eq!(r.histogram("a.lat_ns").count(), 1);
    }

    #[test]
    #[should_panic(expected = "not a gauge")]
    fn kind_clash_panics() {
        let r = Registry::new();
        r.counter("x").inc();
        r.gauge("x");
    }

    #[test]
    fn snapshot_is_complete_and_sorted() {
        let r = Registry::new();
        r.counter("z.count").add(7);
        r.gauge("a.gauge").set(-1.5);
        let h = r.histogram("m.ns");
        h.record(100);
        h.record(300);
        let s = r.snapshot();
        let names: Vec<&str> = s.entries.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["a.gauge", "m.ns", "z.count"]);
        assert_eq!(s.get("z.count"), Some(&MetricValue::Counter(7)));
        assert_eq!(s.get("a.gauge"), Some(&MetricValue::Gauge(-1.5)));
        match s.get("m.ns") {
            Some(MetricValue::Hist(h)) => {
                assert_eq!(h.count, 2);
                assert_eq!(h.sum, 400);
                assert_eq!(h.max, 300);
            }
            other => panic!("m.ns: {other:?}"),
        }
        assert!(s.get("missing").is_none());
    }

    #[test]
    fn json_exposition_parses() {
        let r = Registry::new();
        r.counter("c").add(2);
        r.gauge("g").set(1.25);
        r.histogram("h").record(50);
        let v = parse(&r.snapshot().to_json()).unwrap();
        assert_eq!(v.get("c").unwrap().as_usize(), Some(2));
        assert_eq!(v.get("g").unwrap().as_f64(), Some(1.25));
        assert_eq!(v.get("h").unwrap().get("count").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("h").unwrap().get("sum").unwrap().as_usize(), Some(50));
    }

    #[test]
    fn prometheus_exposition_shape() {
        let r = Registry::new();
        r.counter("serve.requests").add(5);
        r.gauge("train.lr").set(0.001);
        let h = r.histogram("serve.request_ns");
        h.record(2_000_000);
        let text = r.snapshot().to_prometheus();
        assert!(text.contains("# TYPE serve_requests_total counter"), "{text}");
        assert!(text.contains("serve_requests_total 5"), "{text}");
        assert!(text.contains("# TYPE train_lr gauge"), "{text}");
        assert!(text.contains("# TYPE serve_request_ns summary"), "{text}");
        assert!(text.contains("serve_request_ns{quantile=\"0.5\"}"), "{text}");
        assert!(text.contains("serve_request_ns_count 1"), "{text}");
        // every non-comment line is `name value`
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(line.split(' ').count(), 2, "bad exposition line {line:?}");
        }
    }

    /// `a.b` and `a_b` both sanitize to `a_b`; exposition must not emit
    /// two samples under one name — later entries (name-sorted order) are
    /// deterministically suffixed `_2`, `_3`, ….
    #[test]
    fn prom_name_collisions_are_disambiguated() {
        let r = Registry::new();
        r.counter("a.b").add(1);
        r.counter("a_b").add(2);
        r.gauge("a.b.2").set(9.0); // sanitizes to a_b_2, adjacent to the suffix space
        let s = r.snapshot();
        let text = s.to_prometheus();
        // name-sorted entry order: "a.b" < "a.b.2" < "a_b" ('.' < '_')
        assert!(text.contains("a_b_total 1"), "{text}");
        assert!(text.contains("a_b_2 9"), "{text}");
        assert!(text.contains("a_b_total_2 2"), "{text}");
        // no duplicate sample names anywhere
        let mut sample_names: Vec<&str> = text
            .lines()
            .filter(|l| !l.starts_with('#') && !l.is_empty())
            .map(|l| l.split(' ').next().unwrap())
            .collect();
        let total = sample_names.len();
        sample_names.sort_unstable();
        sample_names.dedup();
        assert_eq!(sample_names.len(), total, "duplicate sample name: {text}");
        // deterministic: same snapshot → identical exposition
        assert_eq!(text, s.to_prometheus());
    }

    #[test]
    fn merged_unions_registries_and_prefers_self_on_clash() {
        let a = Registry::new();
        a.counter("serve.requests").add(4);
        a.counter("shared").add(1);
        let b = Registry::new();
        b.gauge("train.lr").set(0.5);
        b.counter("shared").add(99);
        let m = a.snapshot().merged(b.snapshot());
        let names: Vec<&str> = m.entries.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["serve.requests", "shared", "train.lr"]);
        assert_eq!(m.get("shared"), Some(&MetricValue::Counter(1)));
        // merged snapshots still binary-search correctly
        assert_eq!(m.get("serve.requests"), Some(&MetricValue::Counter(4)));
    }

    /// A snapshot racing grouped two-counter updates never observes the
    /// half-applied state (the gate is the serve promotions ≤ swaps fix).
    #[test]
    fn snapshot_never_splits_a_grouped_update() {
        let r = std::sync::Arc::new(Registry::new());
        let first = r.counter("pair.first");
        let second = r.counter("pair.second");
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        std::thread::scope(|scope| {
            let writer = {
                let (r, stop) = (Arc::clone(&r), Arc::clone(&stop));
                scope.spawn(move || {
                    let first = r.counter("pair.first");
                    let second = r.counter("pair.second");
                    while !stop.load(Ordering::Relaxed) {
                        let _g = r.grouped();
                        // invariant under the gate: first == second
                        first.inc();
                        second.inc();
                    }
                })
            };
            for _ in 0..2_000 {
                let s = r.snapshot();
                let (a, b) = match (s.get("pair.first"), s.get("pair.second")) {
                    (Some(MetricValue::Counter(a)), Some(MetricValue::Counter(b))) => (*a, *b),
                    other => panic!("missing counters: {other:?}"),
                };
                assert_eq!(a, b, "snapshot split a grouped update");
            }
            stop.store(true, Ordering::Relaxed);
            writer.join().expect("writer");
        });
        assert_eq!(first.get(), second.get());
    }
}
