//! `switchback` — CLI for the SwitchBack + StableAdamW reproduction.
//!
//! Subcommands:
//! * `train <artifact> [--steps N --lr X --optimizer K ...]`
//! * `exp <name> | --list | --all`   — regenerate a paper figure
//! * `info <artifact>`               — inspect an artifact manifest
//!
//! Argument parsing is hand-rolled (offline build: no clap) — see
//! `rust/src/util` for the other in-tree substrates.

use anyhow::{bail, Result};
use std::collections::HashMap;
use switchback::config::{OptimizerKind, ScalerKind, TrainConfig};
use switchback::coordinator::experiments::{self, ExpCtx};
use switchback::coordinator::Trainer;
use switchback::data::Shift;
use switchback::runtime::Runtime;

const USAGE: &str = "\
switchback — Stable and low-precision training for large-scale vision-language
models (NeurIPS 2023), rust+JAX+Pallas reproduction.

USAGE:
  switchback train <artifact> [OPTIONS]     one training run
  switchback exp <name> [OPTIONS]           regenerate a paper figure
  switchback exp --list                     list experiments
  switchback exp --all [--steps N]          run every experiment
  switchback info <artifact>                inspect an artifact manifest

TRAIN OPTIONS:
  --artifact-dir DIR     (default: artifacts)
  --steps N              (default: 300)
  --warmup N             (default: steps/4)
  --lr X                 (default: 2e-3)
  --weight-decay X       (default: 0.2)
  --beta1 X --beta2 X    (defaults: 0.9, 0.999)
  --optimizer K          adamw | stable_adamw | lion (default: stable_adamw)
  --grad-clip X          global-norm clipping (off by default)
  --scaler K             none | dynamic_global | fixed_tensor (default: none)
  --seed N               (default: 0 = exact jax init)
  --metrics PATH         write JSONL metrics
  --with-shifts          inject the stuck-in-the-past shift schedule
  --quiet

EXP OPTIONS:
  --steps N              override per-experiment default step count
  --out-dir DIR          (default: results)
  --verbose
";

/// Tiny flag parser: positionals + `--key value` + boolean `--key`.
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
    bools: Vec<String>,
}

const BOOL_FLAGS: &[&str] =
    &["--list", "--all", "--verbose", "--quiet", "--with-shifts", "-v", "-q"];

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut positional = vec![];
        let mut flags = HashMap::new();
        let mut bools = vec![];
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a.starts_with('-') {
                if BOOL_FLAGS.contains(&a.as_str()) {
                    bools.push(a.clone());
                } else {
                    let Some(v) = argv.get(i + 1) else {
                        bail!("flag {a} expects a value");
                    };
                    flags.insert(a.trim_start_matches('-').to_string(), v.clone());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Self { positional, flags, bools })
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("bad value for --{key}: {v:?}")),
        }
    }

    fn opt<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>> {
        match self.flags.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("bad value for --{key}: {v:?}")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key)
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let Some(artifact) = args.positional.first() else {
        bail!("train: missing <artifact> (e.g. switchback_int8_small_b32)");
    };
    let steps: u64 = args.get("steps", 300)?;
    let seed: u64 = args.get("seed", 0)?;
    let optimizer = args
        .flags
        .get("optimizer")
        .map(|s| OptimizerKind::parse(s).ok_or_else(|| anyhow::anyhow!("bad optimizer {s}")))
        .transpose()?
        .unwrap_or(OptimizerKind::StableAdamw);
    let scaler = args
        .flags
        .get("scaler")
        .map(|s| ScalerKind::parse(s).ok_or_else(|| anyhow::anyhow!("bad scaler {s}")))
        .transpose()?
        .unwrap_or(ScalerKind::None);
    let cfg = TrainConfig {
        artifact: artifact.clone(),
        artifact_dir: args.get("artifact-dir", "artifacts".to_string())?,
        steps,
        warmup: args.get("warmup", steps / 4)?,
        lr: args.get("lr", 2e-3)?,
        weight_decay: args.get("weight-decay", 0.2)?,
        beta1: args.get("beta1", 0.9)?,
        beta2: args.get("beta2", 0.999)?,
        optimizer,
        beta2_lambda: args.opt("beta2-lambda")?,
        grad_clip: args.opt("grad-clip")?,
        scaler,
        seed,
        reinit: seed != 0,
        shifts: if args.has("--with-shifts") {
            vec![
                Shift { at_step: steps * 55 / 100, image_gain: 6.0, remap_concepts: false },
                Shift { at_step: steps * 75 / 100, image_gain: 1.0 / 6.0, remap_concepts: true },
            ]
        } else {
            vec![]
        },
        probe_every: 1,
        metrics_path: args.flags.get("metrics").cloned(),
        eval_every: 0,
        eval_per_concept: 4,
    };
    let runtime = Runtime::cpu()?;
    println!("platform: {}", runtime.platform());
    println!("config  : {}", cfg.to_json());
    let mut trainer = Trainer::new(&runtime, cfg)?;
    let res = trainer.run(!args.has("--quiet") && !args.has("-q"))?;
    println!(
        "done: final loss {:.4}, tail loss {:.4}, zero-shot acc {}, {:.1} steps/s{}",
        res.final_loss,
        res.tail_loss,
        res.zero_shot_acc
            .map(|v| format!("{:.1}%", v * 100.0))
            .unwrap_or_else(|| "n/a".into()),
        res.steps_per_sec,
        if res.diverged { " [DIVERGED]" } else { "" },
    );
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    if args.has("--list") || (args.positional.is_empty() && !args.has("--all")) {
        println!("available experiments:");
        for (name, desc) in experiments::list() {
            println!("  {name:<16} {desc}");
        }
        return Ok(());
    }
    let ctx = ExpCtx::new(
        Runtime::cpu()?,
        args.get("steps", 0)?,
        args.get("out-dir", "results".to_string())?,
        args.has("--verbose") || args.has("-v"),
    );
    if args.has("--all") {
        for (name, _) in experiments::list() {
            println!("\n########## {name} ##########");
            experiments::run_experiment(&ctx, name)?;
        }
    } else {
        experiments::run_experiment(&ctx, &args.positional[0])?;
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let Some(artifact) = args.positional.first() else {
        bail!("info: missing <artifact>");
    };
    let dir: String = args.get("artifact-dir", "artifacts".to_string())?;
    let runtime = Runtime::cpu()?;
    let art = runtime.load(&dir, artifact)?;
    let m = &art.manifest;
    println!("artifact : {}", m.name);
    println!("variant  : {}   size: {}   batch: {}", m.variant, m.size, m.batch);
    println!(
        "model    : dim {} / vision {}x / text {}x / heads {} / layer_scale {}",
        m.config.dim, m.config.vision_blocks, m.config.text_blocks, m.config.heads,
        m.config.layer_scale
    );
    println!("tensors  : {}   params: {}", m.n_tensors, m.n_params);
    let (pe, mid) = art.probe_indices();
    println!(
        "probes   : patch_embed = {}, mid control = {}",
        m.tensors[pe].name, m.tensors[mid].name
    );
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "train" => cmd_train(&args),
        "exp" => cmd_exp(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}
