//! `switchback` — CLI for the SwitchBack + StableAdamW reproduction.
//!
//! Subcommands:
//! * `train [--kinds A,B --optimizers X,Y ...]` — native end-to-end CLIP
//!   training on the measured-speed substrate; writes BENCH_train.json
//! * `train-aot <artifact> [...]`    — one AOT training run  (pjrt)
//! * `exp <name> | --list | --all`   — regenerate a paper figure  (pjrt)
//! * `info <artifact>`               — inspect an artifact manifest  (pjrt)
//! * `serve [--kind K ...]`          — serving-engine smoke run
//! * `loadgen [--requests N ...]`    — closed-loop serving benchmark,
//!   writes BENCH_serve.json
//! * `probe <url> [--expect S ...]`  — scrape client for the live
//!   telemetry plane (`--telemetry-addr` on serve/train/pipeline)
//! * `benchdiff <baseline> <new>`    — bench-regression gate over the
//!   BENCH_*.json artifacts (the CI gate behind scripts/check_bench.sh)
//!
//! `train-aot`/`exp`/`info` execute AOT artifacts and need the `pjrt`
//! cargo feature; everything else runs entirely on the native substrate.
//!
//! Argument parsing is hand-rolled (offline build: no clap) — see
//! `rust/src/util` for the other in-tree substrates.

use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::Arc;
use switchback::analysis::{self, Level as LintLevel};
use switchback::ckpt;
use switchback::config::OptimizerKind;
use switchback::coordinator::common::spike_shifts;
use switchback::coordinator::eval::nearest_class_accuracy;
use switchback::coordinator::registry;
use switchback::data::SyntheticClip;
use switchback::net::http_get;
use switchback::nn::LinearKind;
use switchback::serve::standby::{self, StandbyConfig};
use switchback::serve::{
    planned_swaps, run_loadgen, run_loadgen_socket, write_bench_json, BatchPolicy,
    ClipEncoder, EncodeClient, EncodeInput, EncoderConfig, Engine, Frontend,
    FrontendConfig, LoadgenConfig, Router, ServeConfig, ServeSnapshot, SocketOutcome,
};
use switchback::tensor::Rng;
use switchback::trace::{self, Readiness, TelemetryConfig, TelemetryServer};
use switchback::train::{
    write_bench_train_json, ClipTrainModel, LiveHooks, NativeTrainConfig,
    NativeTrainer,
};
use switchback::util::json::{self, ObjWriter};
use switchback::util::regression::{compare_bench, DEFAULT_TOLERANCE};

#[cfg(feature = "pjrt")]
use switchback::config::{ScalerKind, TrainConfig};
#[cfg(feature = "pjrt")]
use switchback::coordinator::experiments::{self, ExpCtx};
#[cfg(feature = "pjrt")]
use switchback::coordinator::Trainer;
#[cfg(feature = "pjrt")]
use switchback::data::Shift;
#[cfg(feature = "pjrt")]
use switchback::runtime::Runtime;

const USAGE: &str = "\
switchback — Stable and low-precision training for large-scale vision-language
models (NeurIPS 2023), rust+JAX+Pallas reproduction.

USAGE:
  switchback train [scenario] [OPTIONS]     native end-to-end CLIP training
                                            (kinds × optimizers matrix,
                                            writes BENCH_train.json)
  switchback train --list                   list native scenarios
  switchback train-aot <artifact> [OPTIONS] one AOT training run    [pjrt]
  switchback exp <name> [OPTIONS]           regenerate a paper figure [pjrt]
  switchback exp --list                     list experiments        [pjrt]
  switchback exp --all [--steps N]          run every experiment    [pjrt]
  switchback info <artifact>                inspect an artifact manifest [pjrt]
  switchback serve [OPTIONS]                serving-engine smoke run
                                            (--weights CKPT loads trained
                                            weights at boot)
  switchback loadgen [OPTIONS]              closed-loop serving benchmark
  switchback pipeline [OPTIONS]             train → snapshot → serve →
                                            hot-swap → eval end-to-end,
                                            writes BENCH_ckpt.json
  switchback probe <url> [OPTIONS]          GET a telemetry endpoint and
                                            print status + body; exits
                                            nonzero unless 2xx (and
                                            --expect matched)
  switchback ckpt inspect <path>            checkpoint manifest + CRC check
  switchback ckpt diff <a> <b>              tensor-by-tensor comparison
  switchback trace export <dump> [--out P]  raw span dump (--trace-out) →
                                            Chrome trace-event JSON (open
                                            in Perfetto / chrome://tracing)
  switchback trace top <dump>               per-span time table from a
                                            raw span dump
  switchback trace spikes <dump>            lead–lag forensics on a
                                            flight-recorder dump
                                            (--flight-out)
  switchback benchdiff <baseline> <new>     bench-regression gate
                                            [--tol X --strict]
  switchback lint [PATH] [OPTIONS]          in-tree invariant linter +
                                            lock-order analyzer over the
                                            Rust sources (default PATH:
                                            rust/src, else src)

TRAIN OPTIONS (native):
  --steps N              (default: 200)
  --batch N              examples per step (default: 32)
  --kinds A,B,...        precision kinds to run (default:
                         switchback,standard)
  --optimizers A,B,...   adamw | stable_adamw | lion
                         (default: stable_adamw)
  --shards N             data-parallel gradient-accumulation shards
                         (default: 4; partition is thread-count
                         independent — workers via SWITCHBACK_THREADS)
  --warmup N             (default: steps/4)
  --lr X                 (default: 1e-3)
  --weight-decay X       (default: 0.1)
  --beta1 X --beta2 X    (defaults: 0.9, 0.999)
  --beta2-lambda X       β₂ schedule 1−t^−λ (off by default)
  --grad-clip X          global-norm clipping (off by default)
  --seed N               (default: 42)
  --with-shifts          inject the stuck-in-the-past shift schedule
                         (the spike scenario)
  --eval-per-concept N   final zero-shot eval size (default: 2, 0=off)
  --metrics PATH         write per-run JSONL metrics
  --out PATH             report path (default: BENCH_train.json)
  --assert-improves      exit nonzero unless every run's loss decreased
  --ckpt-every N         write a snapshot every N steps (needs --ckpt-dir)
  --ckpt-dir DIR         snapshot directory (ckpt-<step>.sbck files)
  --ckpt-keep K          snapshot retention (default: 3; counts only
                         complete snapshots — .tmp staging and mid-copy
                         entries are never counted or deleted)
  --ckpt-shards N        group tensors into N shard files written/read in
                         parallel (the v2 manifest-of-shards directory
                         layout; default: 1 = the v1 single file — both
                         load/peek/inspect/diff interchangeably)
  --ckpt-async           write snapshots from a step-boundary state
                         capture on a background saver thread: the step
                         loop never blocks on disk, saves stay
                         bit-identical to synchronous ones, and the saver
                         is joined (and error-checked) before the run
                         reports complete (needs --ckpt-every)
  --rollback-on-spike    restore the last snapshot when the loss spikes
                         and skip the offending shard window
  --spike-sigma X        rollback-guard deviation threshold in trailing
                         standard deviations (default: 3.2, the paper's
                         Appendix-D heuristic; reported spike counts
                         always use 3.2 regardless)
  --spike-cooldown N     steps the guard stays quiet after firing while
                         the loss baseline adapts (default: 30 = 3x the
                         Appendix-D dedup window)
  --trace-out PATH       write the run's raw span dump at exit (convert
                         with `switchback trace export`, summarize with
                         `switchback trace top`)
  --flight-out PATH      arm the spike flight recorder: when the rollback
                         guard fires (or, post-hoc, the loss-spike
                         detector) the last K steps of full-fidelity
                         probes — per-tensor RMS_t and the g²/v
                         under-estimation ratio — are dumped here as
                         forensic JSON (`switchback trace spikes`)
  --flight-window K      flight-recorder window in steps (default: 64)
  --resume PATH          continue bit-identically from a checkpoint file
                         or directory; shape/schedule/optimizer flags
                         conflict (the checkpoint's values apply) and
                         only run-control flags (--out, --metrics,
                         --ckpt-*, --trace-out, --flight-*, --quiet)
                         are accepted
  --dim/--heads/--blocks/--embed-dim/--patches/--patch-dim/--text-seq/--vocab
                         model shape (defaults: 64/4/2/32, 8/32/8/256)
  --quiet

PIPELINE OPTIONS:
  --steps N              training steps, >= 8 (default: 80; snapshots on
                         an N/4 cadence — the engine boots the first and
                         the standby watcher promotes the rest under
                         live traffic, then rejects an injected drifted
                         snapshot)
  --kind K               precision kind end to end (default: switchback)
  --optimizer K          adamw | stable_adamw | lion (default: stable_adamw)
  --requests N           minimum serving requests across the promotions
                         (default: 512)
  --concurrency N        client threads (default: 8)
  --ckpt-dir DIR         snapshot directory — cleared at start, the
                         scenario's workspace (default: ckpts_pipeline;
                         the watcher watches its watch/ subdirectory)
  --drift-max X          canary drift bound for promotions (default: 0.5;
                         must stay positive — the scenario asserts the
                         injected drifted snapshot is rejected)
  --ckpt-shards N        shard count for the training snapshots, written
                         by a background saver (--ckpt-async semantics;
                         default: 4).  The scenario proves the sharded
                         async snapshot is bit-identical to a synchronous
                         v1 save of the same step (`ckpt diff`) before
                         the watcher serves it
  --seed N               (default: 42)
  --out PATH             report path (default: BENCH_ckpt.json)
  --trace-out PATH       write the whole scenario's raw span dump at exit
                         (train + ckpt + serve spans end to end)
  --quiet

TRAIN-AOT OPTIONS:
  --artifact-dir DIR     (default: artifacts)
  --steps N              (default: 300)
  --warmup N             (default: steps/4)
  --lr X                 (default: 2e-3)
  --weight-decay X       (default: 0.2)
  --beta1 X --beta2 X    (defaults: 0.9, 0.999)
  --optimizer K          adamw | stable_adamw | lion (default: stable_adamw)
  --grad-clip X          global-norm clipping (off by default)
  --scaler K             none | dynamic_global | fixed_tensor (default: none)
  --seed N               (default: 0 = exact jax init)
  --metrics PATH         write JSONL metrics
  --with-shifts          inject the stuck-in-the-past shift schedule
  --quiet

EXP OPTIONS:
  --steps N              override per-experiment default step count
  --out-dir DIR          (default: results)
  --verbose

SERVE / LOADGEN OPTIONS:
  --kind K               standard | switchback | switchback_m | llmint8
                         (serve; default: switchback)
  --kinds A,B,...        precision kinds to sweep (loadgen;
                         default: standard,switchback)
  --requests N           total requests per run, k/m suffixes ok
                         (default: 2000)
  --concurrency A,B,...  closed-loop client counts to sweep (default: 32)
  --population N         distinct inputs (default: requests/2)
  --image-fraction X     image share of the population (default: 0.7)
  --batch-max N          micro-batch cap (default: 32)
  --wait-us N            micro-batch max wait, µs (default: 2000)
  --workers N            batch workers (default: auto)
  --cache-capacity N     embedding-cache entries (default: fits the
                         loadgen population, min 8192)
  --no-cache             disable the embedding cache
  --out PATH             loadgen report path (default: BENCH_serve.json)
  --dim N --heads N --blocks N --embed-dim N
  --patches N --patch-dim N --text-seq N --vocab N
                         serving model shape (defaults: 128/4/2/64,
                         16/64/16/512)
  --seed N               model + population seed (default: 42)
  --weights PATH         serve: boot from a training checkpoint (file or
                         snapshot dir; shape comes from the checkpoint,
                         --kind picks the serving quantization)
  --watch-dir DIR        serve: warm-standby watch directory — the
                         watcher peeks new ckpt-*.sbck manifests,
                         prepares + canary-validates off-thread, and
                         promotes via the generation-bump hot-swap
  --standby              serve (with --watch-dir): additionally *wait
                         for and assert* the promotion when the watched
                         directory already holds a snapshot newer than
                         the booted weights, before the smoke probes run
  --canary-every N       serve: post-promotion canary probe every N
                         watcher polls; a failed probe rolls back to
                         the previous generation (default: 4)
  --drift-max X          serve: max canary cosine distance live vs
                         candidate (default: 0.5; 0 disables the bound)
  --swap-every N         loadgen: add one swap-aware run that promotes a
                         fresh encoder generation every N requests
                         (sustained throughput + tail latency across
                         generations, standby counters in the entry)
  --scrape-every MS      loadgen: add one scraper-present run — a rider
                         thread GETs /metrics every MS milliseconds
                         while the closed loop runs, and the entry gains
                         scrapes/scrape_errors/scrape_p99_us (gated by
                         benchdiff: the scraper must neither fail nor
                         move the serve tail)
  --scrape-url URL       loadgen: /metrics URL the scraper hits
                         (default: a telemetry plane self-hosted on
                         127.0.0.1:0 over the engine under test)
  --listen H:P           serve: bind the network front door — POST
                         /encode over real TCP (HTTP/1.1, persistent
                         connections), fanned out across the engine
                         fleet by doc-hash affinity.  Port 0 picks an
                         ephemeral port; the bound address is printed
                         at boot (`frontend: listening on …`)
  --engines N            serve (with --listen): engine-fleet size the
                         router fans out across (default: 2)
  --max-inflight N       serve (with --listen): admission window — at
                         most N requests past the front door at once,
                         the rest get an explicit 429 and count as
                         rejected (default: 32; 0 = unlimited)
  --socket ADDR          loadgen: add two real-TCP runs against an
                         already-running `serve --listen` at ADDR —
                         one clean run at the base concurrency (zero
                         errors, zero sheds required) and one overload
                         run at 4x that concurrency (admission
                         rejections required).  The model-shape flags
                         must match the server's; entries are tagged
                         `socket` (and `overload`) for benchdiff

TELEMETRY OPTIONS (serve / train / pipeline):
  --telemetry-addr H:P   expose the live telemetry plane on HOST:PORT —
                         GET /metrics (Prometheus), /metrics.json,
                         /healthz, /readyz (mode-specific readiness +
                         detail), /trace (Chrome trace JSON of the span
                         ring), /flight (flight-recorder window).  Port
                         0 picks an ephemeral port; the bound address is
                         printed at boot (`telemetry: listening on …`)
  --hold-ms N            serve: keep the engine + telemetry plane up for
                         N ms after the smoke probes, so an external
                         scraper can hit the printed address (default: 0)

PROBE OPTIONS:
  --expect SUBSTR        succeed only when the response body contains
                         SUBSTR (in addition to a 2xx status)
  --follow N             retry up to N times until the probe succeeds
                         (default: 1 = single shot)
  --every MS             delay between --follow retries (default: 200)

LINT OPTIONS:
  --deny LEVEL           exit nonzero when any unsuppressed finding is at
                         or above LEVEL: info | warn | error (default:
                         warn; rule findings are warn, lock-order cycles
                         and locks held across blocking calls are error)
  --json                 print the BENCH_lint ledger JSON instead of the
                         findings report
  --out PATH             also write the ledger JSON to PATH (the
                         BENCH_lint.json artifact check_bench.sh gates —
                         suppression counts may only shrink)
  --verbose              print the lock acquisition graph even when
                         findings exist
";

/// Every `--key value` flag any subcommand accepts.  The parser rejects
/// flags outside this list and [`BOOL_FLAGS`] instead of silently eating
/// the next positional as a value (the classic `--quite` typo bug).
const VALUE_FLAGS: &[&str] = &[
    "--artifact-dir",
    "--steps",
    "--batch",
    "--shards",
    "--warmup",
    "--lr",
    "--weight-decay",
    "--beta1",
    "--beta2",
    "--beta2-lambda",
    "--optimizer",
    "--optimizers",
    "--grad-clip",
    "--scaler",
    "--seed",
    "--metrics",
    "--eval-per-concept",
    "--out-dir",
    "--kind",
    "--kinds",
    "--requests",
    "--concurrency",
    "--population",
    "--image-fraction",
    "--batch-max",
    "--wait-us",
    "--workers",
    "--cache-capacity",
    "--out",
    "--tol",
    "--weights",
    "--watch-dir",
    "--canary-every",
    "--drift-max",
    "--swap-every",
    "--telemetry-addr",
    "--hold-ms",
    "--scrape-every",
    "--scrape-url",
    "--listen",
    "--engines",
    "--max-inflight",
    "--socket",
    "--expect",
    "--follow",
    "--every",
    "--spike-sigma",
    "--spike-cooldown",
    "--trace-out",
    "--flight-out",
    "--flight-window",
    "--resume",
    "--ckpt-every",
    "--ckpt-dir",
    "--ckpt-keep",
    "--ckpt-shards",
    "--deny",
    "--dim",
    "--heads",
    "--blocks",
    "--embed-dim",
    "--patches",
    "--patch-dim",
    "--text-seq",
    "--vocab",
];

const BOOL_FLAGS: &[&str] = &[
    "--list",
    "--all",
    "--json",
    "--verbose",
    "--quiet",
    "--with-shifts",
    "--no-cache",
    "--assert-improves",
    "--strict",
    "--rollback-on-spike",
    "--standby",
    "--ckpt-async",
    "-v",
    "-q",
];

/// Tiny flag parser: positionals + `--key value` + boolean `--key`.
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut positional = vec![];
        let mut flags = HashMap::new();
        let mut bools = vec![];
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a.starts_with('-') {
                if BOOL_FLAGS.contains(&a.as_str()) {
                    bools.push(a.clone());
                } else if VALUE_FLAGS.contains(&a.as_str()) {
                    let Some(v) = argv.get(i + 1) else {
                        bail!("flag {a} expects a value");
                    };
                    flags.insert(a.trim_start_matches('-').to_string(), v.clone());
                    i += 1;
                } else {
                    bail!("unknown flag {a} (see `switchback help`)");
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Self { positional, flags, bools })
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("bad value for --{key}: {v:?}")),
        }
    }

    fn opt<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>> {
        match self.flags.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("bad value for --{key}: {v:?}")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key)
    }

    /// A count flag accepting `k`/`m` suffixes (`--requests 10k`).
    fn count(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => parse_count(v)
                .ok_or_else(|| anyhow::anyhow!("bad value for --{key}: {v:?}")),
        }
    }
}

/// Parse a non-negative count with an optional `k`/`m` suffix.
fn parse_count(s: &str) -> Option<usize> {
    let t = s.trim();
    let (digits, mult) = match t.chars().last() {
        Some('k') | Some('K') => (&t[..t.len() - 1], 1000usize),
        Some('m') | Some('M') => (&t[..t.len() - 1], 1_000_000),
        _ => (t, 1),
    };
    digits.parse::<usize>().ok().and_then(|v| v.checked_mul(mult))
}

#[cfg(feature = "pjrt")]
fn cmd_train_aot(args: &Args) -> Result<()> {
    let Some(artifact) = args.positional.first() else {
        bail!("train-aot: missing <artifact> (e.g. switchback_int8_small_b32)");
    };
    let steps: u64 = args.get("steps", 300)?;
    let seed: u64 = args.get("seed", 0)?;
    let optimizer = args
        .flags
        .get("optimizer")
        .map(|s| OptimizerKind::parse(s).ok_or_else(|| anyhow::anyhow!("bad optimizer {s}")))
        .transpose()?
        .unwrap_or(OptimizerKind::StableAdamw);
    let scaler = args
        .flags
        .get("scaler")
        .map(|s| ScalerKind::parse(s).ok_or_else(|| anyhow::anyhow!("bad scaler {s}")))
        .transpose()?
        .unwrap_or(ScalerKind::None);
    let cfg = TrainConfig {
        artifact: artifact.clone(),
        artifact_dir: args.get("artifact-dir", "artifacts".to_string())?,
        steps,
        warmup: args.get("warmup", steps / 4)?,
        lr: args.get("lr", 2e-3)?,
        weight_decay: args.get("weight-decay", 0.2)?,
        beta1: args.get("beta1", 0.9)?,
        beta2: args.get("beta2", 0.999)?,
        optimizer,
        beta2_lambda: args.opt("beta2-lambda")?,
        grad_clip: args.opt("grad-clip")?,
        scaler,
        seed,
        reinit: seed != 0,
        shifts: if args.has("--with-shifts") {
            vec![
                Shift { at_step: steps * 55 / 100, image_gain: 6.0, remap_concepts: false },
                Shift { at_step: steps * 75 / 100, image_gain: 1.0 / 6.0, remap_concepts: true },
            ]
        } else {
            vec![]
        },
        probe_every: 1,
        metrics_path: args.flags.get("metrics").cloned(),
        eval_every: 0,
        eval_per_concept: 4,
    };
    let runtime = Runtime::cpu()?;
    println!("platform: {}", runtime.platform());
    println!("config  : {}", cfg.to_json());
    let mut trainer = Trainer::new(&runtime, cfg)?;
    let res = trainer.run(!args.has("--quiet") && !args.has("-q"))?;
    println!(
        "done: final loss {:.4}, tail loss {:.4}, zero-shot acc {}, {:.1} steps/s{}",
        res.final_loss,
        res.tail_loss,
        res.zero_shot_acc
            .map(|v| format!("{:.1}%", v * 100.0))
            .unwrap_or_else(|| "n/a".into()),
        res.steps_per_sec,
        if res.diverged { " [DIVERGED]" } else { "" },
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_exp(args: &Args) -> Result<()> {
    if args.has("--list") || (args.positional.is_empty() && !args.has("--all")) {
        println!("available experiments:");
        for (name, desc) in experiments::list() {
            println!("  {name:<16} {desc}");
        }
        return Ok(());
    }
    let ctx = ExpCtx::new(
        Runtime::cpu()?,
        args.get("steps", 0)?,
        args.get("out-dir", "results".to_string())?,
        args.has("--verbose") || args.has("-v"),
    );
    if args.has("--all") {
        for (name, _) in experiments::list() {
            println!("\n########## {name} ##########");
            experiments::run_experiment(&ctx, name)?;
        }
    } else {
        experiments::run_experiment(&ctx, &args.positional[0])?;
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_info(args: &Args) -> Result<()> {
    let Some(artifact) = args.positional.first() else {
        bail!("info: missing <artifact>");
    };
    let dir: String = args.get("artifact-dir", "artifacts".to_string())?;
    let runtime = Runtime::cpu()?;
    let art = runtime.load(&dir, artifact)?;
    let m = &art.manifest;
    println!("artifact : {}", m.name);
    println!("variant  : {}   size: {}   batch: {}", m.variant, m.size, m.batch);
    println!(
        "model    : dim {} / vision {}x / text {}x / heads {} / layer_scale {}",
        m.config.dim, m.config.vision_blocks, m.config.text_blocks, m.config.heads,
        m.config.layer_scale
    );
    println!("tensors  : {}   params: {}", m.n_tensors, m.n_params);
    let (pe, mid) = art.probe_indices();
    println!(
        "probes   : patch_embed = {}, mid control = {}",
        m.tensors[pe].name, m.tensors[mid].name
    );
    Ok(())
}

/// Native end-to-end training: the kinds × optimizers scenario on the
/// measured-speed substrate (no PJRT).  The default run is the paper's
/// acceptance story — SwitchBack vs Standard under StableAdamW; add
/// `--with-shifts --optimizers adamw,stable_adamw` for the spike
/// comparison.  Writes BENCH_train.json.
fn cmd_train(args: &Args) -> Result<()> {
    if args.has("--list") {
        println!("native training scenarios (no pjrt; `switchback train <name>`):");
        for e in registry::native_scenarios() {
            println!("  {:<14} {}", e.name, e.desc);
        }
        println!("\n(`switchback exp --list` shows the PJRT figure experiments)");
        return Ok(());
    }
    if let Some(resume) = args.flags.get("resume") {
        return cmd_train_resume(args, resume);
    }
    // an optional scenario name (from coordinator::registry) presets the
    // run matrix; explicit flags still override
    let scenario = match args.positional.first().map(String::as_str) {
        None => None,
        Some(name) => {
            if !registry::native_scenarios().iter().any(|e| e.name == name) {
                bail!("unknown scenario {name:?} — see `switchback train --list`");
            }
            Some(name)
        }
    };
    let steps: u64 =
        args.get("steps", if scenario == Some("train-smoke") { 50 } else { 200 })?;
    if steps == 0 {
        bail!("--steps must be at least 1");
    }
    let kinds: Vec<LinearKind> = match args.flags.get("kind") {
        Some(k) => vec![k.parse().map_err(|e: String| anyhow::anyhow!("{e}"))?],
        None => {
            let s: String = args.get("kinds", "switchback,standard".to_string())?;
            csv_list(&s, "--kinds")?
        }
    };
    if kinds.is_empty() {
        bail!("--kinds must name at least one precision kind");
    }
    let opts_s: String = args.get("optimizers", String::new())?;
    let optimizers: Vec<OptimizerKind> = if !opts_s.is_empty() {
        csv_list(&opts_s, "--optimizers")?
    } else if let Some(o) = args.flags.get("optimizer") {
        vec![o.parse().map_err(|e: String| anyhow::anyhow!("{e}"))?]
    } else if scenario == Some("train-spikes") {
        vec![OptimizerKind::Adamw, OptimizerKind::StableAdamw]
    } else {
        vec![OptimizerKind::StableAdamw]
    };
    if optimizers.is_empty() {
        bail!("--optimizers must name at least one optimizer");
    }
    let with_shifts = args.has("--with-shifts") || scenario == Some("train-spikes");
    let assert_improves =
        args.has("--assert-improves") || scenario == Some("train-smoke");
    let out: String = args.get("out", "BENCH_train.json".to_string())?;
    let verbose = !args.has("--quiet") && !args.has("-q");
    let multi = kinds.len() * optimizers.len() > 1;
    if multi && args.get::<u64>("ckpt-every", 0)? > 0 {
        bail!(
            "--ckpt-every snapshots one run — narrow the matrix to a single \
             kind and optimizer (e.g. --kind switchback --optimizer stable_adamw)"
        );
    }

    // --telemetry-addr: one plane spans the whole matrix; every run
    // publishes into the same hooks sequentially
    let telemetry = arm_train_telemetry(args)?;
    let live_hooks = telemetry.as_ref().map(|(h, _)| h.clone());

    let build_cfg = |kind: LinearKind, optimizer: OptimizerKind| -> Result<NativeTrainConfig> {
        let mut cfg = NativeTrainConfig::preset(kind, steps);
        if scenario == Some("train-smoke") {
            // the verify.sh smoke shape: small dims, seconds not minutes
            cfg.batch = 16;
            cfg.encoder.dim = 32;
            cfg.encoder.blocks = 1;
            cfg.encoder.embed_dim = 16;
            cfg.encoder.patch_dim = 16;
            cfg.encoder.vocab = 128;
        }
        cfg.hyper.warmup = args.get("warmup", steps / 4)?;
        if cfg.hyper.warmup > steps {
            bail!("--warmup must not exceed --steps");
        }
        cfg.hyper.lr = args.get("lr", cfg.hyper.lr)?;
        cfg.hyper.weight_decay = args.get("weight-decay", cfg.hyper.weight_decay)?;
        cfg.hyper.beta1 = args.get("beta1", cfg.hyper.beta1)?;
        cfg.hyper.beta2 = args.get("beta2", cfg.hyper.beta2)?;
        cfg.hyper.beta2_lambda = args.opt("beta2-lambda")?;
        cfg.hyper.grad_clip = args.opt("grad-clip")?;
        cfg.hyper.optimizer = optimizer;
        cfg.hyper.seed = args.get("seed", cfg.hyper.seed)?;
        cfg.encoder.seed = cfg.hyper.seed;
        cfg.encoder.dim = args.get("dim", cfg.encoder.dim)?;
        cfg.encoder.heads = args.get("heads", cfg.encoder.heads)?;
        cfg.encoder.blocks = args.get("blocks", cfg.encoder.blocks)?;
        cfg.encoder.embed_dim = args.get("embed-dim", cfg.encoder.embed_dim)?;
        cfg.encoder.patches = args.get("patches", cfg.encoder.patches)?;
        cfg.encoder.patch_dim = args.get("patch-dim", cfg.encoder.patch_dim)?;
        cfg.encoder.text_seq = args.get("text-seq", cfg.encoder.text_seq)?;
        cfg.encoder.vocab = args.get("vocab", cfg.encoder.vocab)?;
        if cfg.encoder.dim == 0
            || cfg.encoder.heads == 0
            || cfg.encoder.dim % cfg.encoder.heads != 0
        {
            bail!("--dim must be a positive multiple of --heads");
        }
        if cfg.encoder.vocab == 0
            || cfg.encoder.text_seq == 0
            || cfg.encoder.patches == 0
            || cfg.encoder.patch_dim == 0
            || cfg.encoder.embed_dim == 0
            || cfg.encoder.blocks == 0
        {
            bail!(
                "--vocab/--text-seq/--patches/--patch-dim/--embed-dim/--blocks \
                 must be positive"
            );
        }
        cfg.batch = args.get("batch", cfg.batch)?;
        if cfg.batch == 0 {
            bail!("--batch must be at least 1");
        }
        cfg.grad_shards = args.get("shards", cfg.grad_shards)?;
        if cfg.grad_shards == 0 {
            bail!("--shards must be at least 1");
        }
        cfg.eval_per_concept = args.get("eval-per-concept", cfg.eval_per_concept)?;
        cfg.shifts = if with_shifts { spike_shifts(steps) } else { vec![] };
        cfg.ckpt_every = args.get("ckpt-every", 0)?;
        cfg.ckpt_dir = args.flags.get("ckpt-dir").cloned();
        cfg.ckpt_keep = args.get("ckpt-keep", 3)?;
        if cfg.ckpt_keep == 0 {
            bail!("--ckpt-keep must be at least 1");
        }
        if cfg.ckpt_every > 0 && cfg.ckpt_dir.is_none() {
            bail!("--ckpt-every needs --ckpt-dir");
        }
        apply_ckpt_io_flags(args, &mut cfg)?;
        cfg.rollback_on_spike = args.has("--rollback-on-spike");
        apply_spike_flags(args, &mut cfg)?;
        cfg.metrics_path = args.flags.get("metrics").map(|base| {
            if multi {
                format!("{base}.{}_{}.jsonl", kind.label(), optimizer.label())
            } else {
                base.clone()
            }
        });
        cfg.flight_path = args.flags.get("flight-out").map(|base| {
            if multi {
                format!("{base}.{}_{}.json", kind.label(), optimizer.label())
            } else {
                base.clone()
            }
        });
        cfg.flight_window = args.get("flight-window", cfg.flight_window)?;
        cfg.live = live_hooks.clone();
        Ok(cfg)
    };

    let mut results = vec![];
    let mut echo_cfg = None;
    for &kind in &kinds {
        for &optimizer in &optimizers {
            let cfg = build_cfg(kind, optimizer)?;
            if verbose {
                println!(
                    "== train: kind={} optimizer={} ==",
                    kind.label(),
                    optimizer.label()
                );
                println!("config: {}", cfg.to_json());
            }
            echo_cfg.get_or_insert_with(|| cfg.clone());
            let mut trainer = NativeTrainer::new(cfg);
            let res = trainer.run(verbose)?;
            res.print();
            results.push(res);
        }
    }

    // scenario summaries across the matrix
    for &optimizer in &optimizers {
        let by = |k: &str| {
            results
                .iter()
                .find(|r| r.kind == k && r.optimizer == optimizer.label())
        };
        if let (Some(sb), Some(std_r)) = (by("switchback"), by("standard")) {
            println!(
                "{}: switchback/standard steps/s ratio {:.2}×, tail-loss gap {:+.4}",
                optimizer.label(),
                sb.steps_per_sec / std_r.steps_per_sec.max(1e-9),
                sb.tail_loss - std_r.tail_loss,
            );
        }
    }
    for &kind in &kinds {
        let by = |o: &str| {
            results.iter().find(|r| r.optimizer == o && r.kind == kind.label())
        };
        if let (Some(plain), Some(stable)) = (by("adamw"), by("stable_adamw")) {
            println!(
                "{}: loss spikes adamw {} vs stable_adamw {} (paper: StableAdamW \
                 suppresses them)",
                kind.label(),
                plain.loss_spikes,
                stable.loss_spikes,
            );
        }
    }

    write_bench_train_json(&out, echo_cfg.as_ref().expect("≥1 run"), &results)?;
    println!("wrote {out}");
    write_trace_dump_if_requested(args)?;
    if let Some((_, mut srv)) = telemetry {
        srv.shutdown();
    }

    if assert_improves {
        for r in &results {
            if r.diverged {
                bail!("train: {}/{} diverged", r.kind, r.optimizer);
            }
            if r.final_loss.is_nan() || r.final_loss >= r.first_loss {
                bail!(
                    "train: {}/{} loss did not decrease ({:.4} → {:.4})",
                    r.kind,
                    r.optimizer,
                    r.first_loss,
                    r.final_loss
                );
            }
        }
        println!("train smoke OK — loss decreased in every run");
    }
    Ok(())
}

/// Parse + validate the snapshot-I/O flags (`--ckpt-shards` /
/// `--ckpt-async`) — shared by fresh and resumed runs.  Both are
/// run-control: they change how snapshots are written, never the bytes a
/// snapshot decodes to, so (like the guard flags) they are accepted on
/// `--resume`.
fn apply_ckpt_io_flags(args: &Args, cfg: &mut NativeTrainConfig) -> Result<()> {
    cfg.ckpt_shards = args.get("ckpt-shards", 1)?;
    if cfg.ckpt_shards == 0 {
        bail!("--ckpt-shards must be at least 1");
    }
    cfg.ckpt_async = args.has("--ckpt-async");
    if cfg.ckpt_async && cfg.ckpt_every == 0 {
        bail!(
            "--ckpt-async needs --ckpt-every/--ckpt-dir (it only changes \
             how snapshots are written)"
        );
    }
    Ok(())
}

/// Parse + validate the rollback-guard tuning flags
/// (`--spike-sigma`/`--spike-cooldown`) — shared by fresh and resumed
/// runs so the validation can never diverge between the two paths.
fn apply_spike_flags(args: &Args, cfg: &mut NativeTrainConfig) -> Result<()> {
    cfg.spike_sigma = args.get("spike-sigma", cfg.spike_sigma)?;
    if !cfg.spike_sigma.is_finite() || cfg.spike_sigma <= 0.0 {
        bail!("--spike-sigma must be a positive number");
    }
    cfg.spike_cooldown = args.get("spike-cooldown", cfg.spike_cooldown)?;
    Ok(())
}

/// `train --resume <path>`: continue a checkpointed run bit-identically.
/// Shape, hyperparameters, batch/shard geometry and the shift schedule are
/// adopted from the checkpoint (anything else would silently diverge from
/// the original run — see DESIGN.md §Checkpoint); only run-control flags
/// (--out, --metrics, --ckpt-*, --trace-out, --flight-*, --quiet) apply.
fn cmd_train_resume(args: &Args, resume: &str) -> Result<()> {
    // everything the resumed math depends on comes from the checkpoint;
    // accepting one of these flags and silently dropping it would let a
    // user believe they extended/retuned the run when nothing changed
    const RESUME_FIXED: &[&str] = &[
        "steps", "warmup", "lr", "weight-decay", "beta1", "beta2",
        "beta2-lambda", "grad-clip", "optimizer", "optimizers", "kind",
        "kinds", "seed", "batch", "shards", "dim", "heads", "blocks",
        "embed-dim", "patches", "patch-dim", "text-seq", "vocab",
    ];
    for key in RESUME_FIXED {
        if args.flags.contains_key(*key) {
            bail!(
                "--{key} conflicts with --resume: the value is adopted from \
                 the checkpoint (resume must replay the original run's math)"
            );
        }
    }
    if args.has("--with-shifts") {
        bail!("--with-shifts conflicts with --resume: the shift schedule is \
               adopted from the checkpoint");
    }
    let file = ckpt::resolve(resume)?;
    let (ck, io) = ckpt::load(&file)?;
    println!(
        "resuming from {} (step {}/{}, {:.1} MB/s load)",
        file.display(),
        ck.step,
        ck.hyper.steps,
        io.mb_per_s()
    );
    let mut cfg = NativeTrainConfig::preset(ck.encoder.kind, ck.hyper.steps);
    cfg.hyper = ck.hyper.clone();
    cfg.encoder = ck.encoder.clone();
    cfg.shifts = ck.shifts.clone();
    cfg.batch = ck.batch;
    cfg.grad_shards = ck.grad_shards;
    cfg.eval_per_concept = args.get("eval-per-concept", cfg.eval_per_concept)?;
    cfg.metrics_path = args.flags.get("metrics").cloned();
    cfg.ckpt_every = args.get("ckpt-every", 0)?;
    cfg.ckpt_dir = args.flags.get("ckpt-dir").cloned();
    cfg.ckpt_keep = args.get("ckpt-keep", 3)?;
    if cfg.ckpt_keep == 0 {
        bail!("--ckpt-keep must be at least 1");
    }
    if cfg.ckpt_every > 0 && cfg.ckpt_dir.is_none() {
        // default to snapshotting back into the directory we resumed from
        if let Some(dir) = file.parent() {
            cfg.ckpt_dir = Some(dir.to_string_lossy().into_owned());
        }
    }
    // snapshot I/O shape is run-control (the decoded bytes are identical
    // either way), so sharded/async writing is freely re-chosen on resume
    apply_ckpt_io_flags(args, &mut cfg)?;
    cfg.rollback_on_spike = args.has("--rollback-on-spike");
    // guard tuning is run-control (a reactive intervention, not training
    // math), so unlike the schedule flags it is accepted on resume
    apply_spike_flags(args, &mut cfg)?;
    // tracing/forensics are pure observers — freely re-chosen on resume
    cfg.flight_path = args.flags.get("flight-out").cloned();
    cfg.flight_window = args.get("flight-window", cfg.flight_window)?;
    if cfg.rollback_on_spike {
        // the guard's online loss-history/cooldown state is deliberately
        // not part of the checkpoint (DESIGN.md §Checkpoint): the
        // *training math* resumes bit-identically, but the detector
        // restarts cold, so a run that ROLLED BACK near the snapshot may
        // not be reproduced by resuming across that window
        println!(
            "note: --rollback-on-spike restarts the spike detector with an \
             empty loss history; guard decisions near the resume point may \
             differ from the uninterrupted run"
        );
    }
    let verbose = !args.has("--quiet") && !args.has("-q");
    // the telemetry plane is a pure observer (like --trace-out), so it is
    // freely armed on resume
    let telemetry = arm_train_telemetry(args)?;
    cfg.live = telemetry.as_ref().map(|(h, _)| h.clone());
    let echo = cfg.clone();
    let mut trainer = NativeTrainer::new(cfg);
    trainer.restore(&ck)?;
    let res = trainer.run(verbose)?;
    res.print();
    let out: String = args.get("out", "BENCH_train.json".to_string())?;
    write_bench_train_json(&out, &echo, &[res])?;
    println!("wrote {out}");
    write_trace_dump_if_requested(args)?;
    if let Some((_, mut srv)) = telemetry {
        srv.shutdown();
    }
    Ok(())
}

/// Drain the process-wide span ring to `--trace-out` (shared by `train`,
/// `train --resume` and `pipeline`).  Draining at exit keeps the hot path
/// free of any I/O: spans cost a thread-local push until this moment.
fn write_trace_dump_if_requested(args: &Args) -> Result<()> {
    if let Some(tp) = args.flags.get("trace-out") {
        let dump = trace::take();
        trace::write_span_dump(std::path::Path::new(tp), &dump)?;
        println!(
            "wrote {tp} ({} spans{}; `switchback trace export {tp}` → Perfetto)",
            dump.spans.len(),
            if dump.dropped > 0 {
                format!(", {} dropped by the ring", dump.dropped)
            } else {
                String::new()
            }
        );
    }
    Ok(())
}

/// Arm the train-mode telemetry plane (`--telemetry-addr` on `train` and
/// `train --resume`): [`LiveHooks`] the step loop publishes into, plus
/// the HTTP server reading them.  `/readyz` flips ready once the first
/// step completes; `/flight` serves the live flight-recorder window.
fn arm_train_telemetry(args: &Args) -> Result<Option<(LiveHooks, TelemetryServer)>> {
    let Some(addr) = args.flags.get("telemetry-addr") else {
        return Ok(None);
    };
    let hooks = LiveHooks::new(args.get("flight-window", 64)?);
    let ready_hooks = hooks.clone();
    let flight_hooks = hooks.clone();
    let srv = TelemetryServer::bind(
        addr,
        TelemetryConfig {
            mode: "train",
            // the trainer's live gauges + spike counters all live in the
            // process-wide registry
            snapshot: Arc::new(|| trace::global().snapshot()),
            ready: Arc::new(move || {
                let step = ready_hooks
                    .step_done
                    .load(std::sync::atomic::Ordering::Relaxed);
                Readiness::new(step > 0).with("step", step.to_string())
            }),
            flight: Some(Arc::new(move || flight_hooks.flight_json())),
            http: Default::default(),
        },
    )?;
    println!("telemetry: listening on {}", srv.url());
    Ok(Some((hooks, srv)))
}

/// `probe <url>` — the scrape client paired with `--telemetry-addr`:
/// GET the endpoint, print status + body, exit zero only on a 2xx
/// (and, with `--expect`, a body containing the substring).  `--follow N`
/// retries every `--every` ms, so scripts can wait for a readiness flip
/// or a promotion to become visible without a shell polling loop.
fn cmd_probe(args: &Args) -> Result<()> {
    let Some(url) = args.positional.first() else {
        bail!("probe: missing <url> (e.g. http://127.0.0.1:9100/healthz)");
    };
    let expect = args.flags.get("expect");
    let follow: u32 = args.get("follow", 1)?;
    if follow == 0 {
        bail!("--follow must be at least 1");
    }
    let every_ms: u64 = args.get("every", 200)?;
    let mut last = String::from("no response");
    for attempt in 1..=follow {
        match http_get(url, std::time::Duration::from_secs(5)) {
            Ok(resp) => {
                let matched = resp.is_ok()
                    && match expect {
                        Some(e) => resp.body.contains(e.as_str()),
                        None => true,
                    };
                if matched {
                    println!("HTTP {} {url} (attempt {attempt}/{follow})", resp.status);
                    print!("{}", resp.body);
                    if !resp.body.ends_with('\n') {
                        println!();
                    }
                    return Ok(());
                }
                last = format!(
                    "HTTP {} {}",
                    resp.status,
                    resp.body.lines().next().unwrap_or("")
                );
            }
            Err(e) => last = e.to_string(),
        }
        if attempt < follow {
            std::thread::sleep(std::time::Duration::from_millis(every_ms));
        }
    }
    match expect {
        Some(e) => bail!(
            "probe: {url} never matched {e:?} in {follow} attempt(s) (last: {last})"
        ),
        None => bail!("probe: {url} not OK after {follow} attempt(s) (last: {last})"),
    }
}

/// `trace export|top|spikes` — consume the tracer's artifacts: raw span
/// dumps (`--trace-out`) and flight-recorder dumps (`--flight-out`).
fn cmd_trace(args: &Args) -> Result<()> {
    let read_arg = |what: &str| -> Result<(String, String)> {
        let Some(p) = args.positional.get(1) else {
            bail!("trace: missing <{what}>");
        };
        let text = std::fs::read_to_string(p)
            .map_err(|e| anyhow::anyhow!("cannot read {p}: {e}"))?;
        Ok((p.clone(), text))
    };
    match args.positional.first().map(String::as_str) {
        Some("export") => {
            let (p, text) = read_arg("span-dump.json")?;
            let dump = trace::parse_span_dump(&text)
                .map_err(|e| anyhow::anyhow!("{p}: {e}"))?;
            let out: String = args.get("out", format!("{p}.perfetto.json"))?;
            std::fs::write(&out, trace::chrome_trace_json(&dump))?;
            println!(
                "wrote {out} ({} events; open in Perfetto or chrome://tracing)",
                dump.spans.len()
            );
            Ok(())
        }
        Some("top") => {
            let (p, text) = read_arg("span-dump.json")?;
            let dump = trace::parse_span_dump(&text)
                .map_err(|e| anyhow::anyhow!("{p}: {e}"))?;
            print!("{}", trace::top_table(&dump));
            Ok(())
        }
        Some("spikes") => {
            let (p, text) = read_arg("flight-dump.json")?;
            let dump =
                trace::parse_dump(&text).map_err(|e| anyhow::anyhow!("{p}: {e}"))?;
            println!(
                "flight dump: trigger {} at step {} ({} frames, window {})",
                dump.trigger_kind,
                dump.trigger_step,
                dump.frames.len(),
                dump.window
            );
            println!("{}", trace::analyze(&dump).summary());
            Ok(())
        }
        _ => bail!("usage: switchback trace <export|top|spikes> <dump> [--out P]"),
    }
}

/// `ckpt inspect <path>` / `ckpt diff <a> <b>` — every inspection is also
/// a full CRC-32 integrity check.
fn cmd_ckpt(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("inspect") => {
            let Some(path) = args.positional.get(1) else {
                bail!("ckpt inspect: missing <path> (file or snapshot dir)");
            };
            let file = ckpt::resolve(path)?;
            print!("{}", ckpt::inspect::inspect(&file)?);
            Ok(())
        }
        Some("diff") => {
            let (Some(a), Some(b)) = (args.positional.get(1), args.positional.get(2))
            else {
                bail!("ckpt diff: expected two paths");
            };
            let (report, _identical) =
                ckpt::inspect::diff(&ckpt::resolve(a)?, &ckpt::resolve(b)?)?;
            print!("{report}");
            Ok(())
        }
        _ => bail!("usage: switchback ckpt <inspect|diff> <path> [path2]"),
    }
}

/// The end-to-end `pipeline` scenario: train with snapshots on an N/4
/// cadence → verify the round trip → boot the serving engine from the
/// *first* snapshot → the warm-standby watcher picks the later snapshots
/// out of a watched directory and promotes them under live closed-loop
/// traffic (zero dropped requests, one generation bump each) → an
/// injected drifted snapshot is canary-rejected without touching the
/// live generation → eval the served weights against the train model
/// (bit-identical encodes).  Emits BENCH_ckpt.json (schema:
/// EXPERIMENTS.md §Ckpt).
fn cmd_pipeline(args: &Args) -> Result<()> {
    let steps: u64 = args.get("steps", 80)?;
    if steps < 8 {
        bail!("--steps must be at least 8 (snapshots on an N/4 cadence)");
    }
    let kind_s: String = args.get("kind", "switchback".to_string())?;
    let Some(kind) = LinearKind::parse(&kind_s) else {
        bail!("bad --kind {kind_s:?} (standard | switchback | switchback_m | llmint8)");
    };
    let optimizer = args
        .flags
        .get("optimizer")
        .map(|s| OptimizerKind::parse(s).ok_or_else(|| anyhow::anyhow!("bad optimizer {s}")))
        .transpose()?
        .unwrap_or(OptimizerKind::StableAdamw);
    let requests: usize = args.count("requests", 512)?;
    let concurrency: usize = args.get("concurrency", 8)?;
    if requests == 0 || concurrency == 0 {
        bail!("--requests and --concurrency must be positive");
    }
    let seed: u64 = args.get("seed", 42)?;
    let dir: String = args.get("ckpt-dir", "ckpts_pipeline".to_string())?;
    let out: String = args.get("out", "BENCH_ckpt.json".to_string())?;
    let verbose = !args.has("--quiet") && !args.has("-q");
    let drift_max: f32 = args.get("drift-max", 0.5)?;
    // the scenario *mandates* a canary rejection of the injected drifted
    // snapshot, so the bound cannot be disabled here (unlike `serve`)
    if !drift_max.is_finite() || drift_max <= 0.0 {
        bail!("--drift-max must be a positive number (pipeline requires the bound)");
    }
    let ckpt_shards: usize = args.get("ckpt-shards", 4)?;
    if ckpt_shards == 0 {
        bail!("--ckpt-shards must be at least 1");
    }

    // --telemetry-addr: one plane spans the whole scenario.  While the
    // engine slot is empty, /readyz reports the train phase (ready once
    // the first step lands); the moment the serving engine boots into
    // the slot, readiness hands over to the serve semantics (generation,
    // promoting) — a follower scraping /readyz watches the train→serve
    // transition and every standby promotion live
    let engine_slot: Arc<std::sync::RwLock<Option<Arc<Engine>>>> =
        Arc::new(std::sync::RwLock::new(None));
    let telemetry = match args.flags.get("telemetry-addr") {
        Some(addr) => {
            let hooks = LiveHooks::new(64);
            let snap_slot = Arc::clone(&engine_slot);
            let ready_slot = Arc::clone(&engine_slot);
            let ready_hooks = hooks.clone();
            let flight_hooks = hooks.clone();
            let srv = TelemetryServer::bind(
                addr,
                TelemetryConfig {
                    mode: "pipeline",
                    snapshot: Arc::new(move || {
                        let global = trace::global().snapshot();
                        match snap_slot.read().unwrap().as_ref() {
                            Some(engine) => {
                                engine.metrics().registry().snapshot().merged(global)
                            }
                            None => global,
                        }
                    }),
                    ready: Arc::new(move || {
                        match ready_slot.read().unwrap().as_ref() {
                            Some(engine) => {
                                let promoting = engine.metrics().is_promoting();
                                Readiness::new(!promoting)
                                    .with("phase", "\"serve\"")
                                    .with("generation", engine.generation().to_string())
                                    .with(
                                        "promoting",
                                        if promoting { "true" } else { "false" },
                                    )
                            }
                            None => {
                                let step = ready_hooks
                                    .step_done
                                    .load(std::sync::atomic::Ordering::Relaxed);
                                Readiness::new(step > 0)
                                    .with("phase", "\"train\"")
                                    .with("step", step.to_string())
                            }
                        }
                    }),
                    flight: Some(Arc::new(move || flight_hooks.flight_json())),
                    http: Default::default(),
                },
            )?;
            println!("telemetry: listening on {}", srv.url());
            Some((hooks, srv))
        }
        None => None,
    };

    // ---- 1) train, snapshotting on the N/4 cadence -------------------
    // the snapshot directory is this scenario's workspace: clear it so a
    // previous run's snapshots cannot leak into the staged promotions.
    // Snapshots are written the production way: sharded (v2
    // manifest-of-shards) from a background saver thread (--ckpt-async
    // semantics), so the whole standby/serve loop downstream runs on the
    // sharded artifacts
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = NativeTrainConfig::preset(kind, steps);
    cfg.hyper.optimizer = optimizer;
    cfg.hyper.seed = seed;
    cfg.encoder.seed = seed;
    cfg.ckpt_every = (steps / 4).max(1);
    cfg.ckpt_dir = Some(dir.clone());
    cfg.ckpt_keep = 8;
    cfg.ckpt_shards = ckpt_shards;
    cfg.ckpt_async = true;
    cfg.live = telemetry.as_ref().map(|(h, _)| h.clone());
    println!(
        "== pipeline 1/4: train {} steps (async sharded snapshots every {}, \
         {} shards) ==",
        steps, cfg.ckpt_every, ckpt_shards
    );
    let mut trainer = NativeTrainer::new(cfg);
    let train_res = trainer.run(verbose)?;
    train_res.print();
    let save_mb_s =
        train_res.ckpt_bytes as f64 / 1e6 / train_res.ckpt_save_secs.max(1e-9);

    // ---- 2) load the snapshots back, verify the round trip -----------
    let dir_path = std::path::Path::new(&dir);
    let snaps = ckpt::list_snapshots(dir_path);
    if snaps.len() < 4 {
        bail!(
            "pipeline expected ≥4 snapshots on the N/4 cadence, found {}",
            snaps.len()
        );
    }
    let (boot_step, boot_path) = snaps[0].clone();
    let (boot_ck, _) = ckpt::load(&boot_path)?;
    let (final_ck, load_io) = ckpt::load(&ckpt::snapshot_path(dir_path, steps))?;
    let live = trainer.final_checkpoint().expect("run just completed");
    let round_trip_ok = final_ck.params == live.params
        && final_ck.opt == live.opt
        && final_ck.data == live.data;
    if !round_trip_ok {
        bail!("checkpoint round trip is not bit-identical to the live trainer state");
    }
    // the sharded-async acceptance gate: a synchronous single-file (v1)
    // save of the same step must decode to exactly the same state, and
    // `ckpt diff` must agree through the CLI surface (the name never
    // matches ckpt-*.sbck, so the watcher staging below cannot see it)
    let sync_path = dir_path.join("sync-final.sbck");
    let sync_io = ckpt::save(&sync_path, live)?;
    let (sync_ck, sync_load_io) = ckpt::load(&sync_path)?;
    let sharded_bit_identical = final_ck.params == sync_ck.params
        && final_ck.opt == sync_ck.opt
        && final_ck.data == sync_ck.data;
    let (diff_report, diff_identical) =
        ckpt::inspect::diff(&ckpt::snapshot_path(dir_path, steps), &sync_path)?;
    if !sharded_bit_identical || !diff_identical {
        bail!(
            "sharded async snapshot is not bit-identical to the synchronous \
             v1 save of the same step:\n{diff_report}"
        );
    }
    let shard_peek = ckpt::peek(&ckpt::snapshot_path(dir_path, steps))?;
    println!(
        "== pipeline 2/4: round trip OK — v{} snapshot ({} shards): save \
         {:.1} MB/s, load {:.1} MB/s; sync v1 reference: save {:.1} MB/s, \
         load {:.1} MB/s; sharded ≡ sync (ckpt diff bit-identical) ==",
        shard_peek.version,
        shard_peek.shards,
        save_mb_s,
        load_io.mb_per_s(),
        sync_io.mb_per_s(),
        sync_load_io.mb_per_s(),
    );

    // ---- 3) boot from the first snapshot; the watcher promotes the
    //         rest under live traffic, then rejects injected drift -----
    let enc_cfg = boot_ck.encoder.clone();
    let image_len = enc_cfg.image_len();
    let (text_seq, vocab) = (enc_cfg.text_seq, enc_cfg.vocab);
    let serve_cfg = ServeConfig {
        encoder: enc_cfg.clone(),
        policy: BatchPolicy {
            max_batch: 16,
            max_wait: std::time::Duration::from_micros(500),
        },
        workers: 0,
        cache_capacity: 8192.max(requests * 2),
        cache_shards: 0,
    };
    let boot_enc = ClipEncoder::from_weights(
        enc_cfg.clone(),
        ckpt::encoder_weights(&enc_cfg, &boot_ck.params)?,
    );
    let engine = std::sync::Arc::new(Engine::start_with_encoder(serve_cfg, boot_enc));
    // hand the telemetry plane over to serve-phase readiness
    *engine_slot.write().unwrap() = Some(Arc::clone(&engine));
    let mut rng = Rng::seed(seed ^ 0x51BE);
    let probe: Vec<f32> = (0..image_len).map(|_| rng.normal()).collect();
    let pre = engine
        .encode(EncodeInput::Image(probe.clone()))
        .map_err(|e| anyhow::anyhow!("probe encode failed: {e}"))?;
    if !engine
        .encode(EncodeInput::Image(probe.clone()))
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .cache_hit
    {
        bail!("probe did not warm the cache");
    }

    let watch_dir = dir_path.join("watch");
    let _ = std::fs::remove_dir_all(&watch_dir);
    std::fs::create_dir_all(&watch_dir)?;
    let mut scfg = StandbyConfig::new(&watch_dir);
    scfg.poll = std::time::Duration::from_millis(5);
    scfg.drift_max = Some(drift_max);
    scfg.initial_step = boot_step;
    scfg.baseline = Some(boot_ck.params.clone());
    scfg.verbose = verbose;
    let watcher = standby::spawn(std::sync::Arc::clone(&engine), scfg);
    let staged: Vec<(u64, std::path::PathBuf)> = snaps[1..].to_vec();
    println!(
        "== pipeline 3/4: ≥{requests} requests × {concurrency} clients; the \
         watcher promotes {} staged snapshots mid-traffic, then must reject \
         an injected drifted one ==",
        staged.len()
    );
    use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
    let stop = AtomicBool::new(false);
    let issued = AtomicUsize::new(0);
    let errors = AtomicU64::new(0);
    let min_per_client = requests / concurrency + 1;
    let mut stage_err: Option<String> = None;
    std::thread::scope(|s| {
        for c in 0..concurrency {
            let engine = &engine;
            let stop = &stop;
            let issued = &issued;
            let errors = &errors;
            s.spawn(move || {
                let mut rng = Rng::seed(0xC11E07 + c as u64);
                let mut mine = 0usize;
                // traffic flows for the whole promote/reject sequence:
                // run until the coordinator says stop AND the per-client
                // minimum is met
                while !stop.load(Ordering::Relaxed) || mine < min_per_client {
                    let input = if rng.uniform() < 0.7 {
                        EncodeInput::Image((0..image_len).map(|_| rng.normal()).collect())
                    } else {
                        EncodeInput::Text(
                            (0..text_seq).map(|_| rng.below(vocab) as i32).collect(),
                        )
                    };
                    if engine.encode(input).is_err() {
                        errors.fetch_add(1, Ordering::Relaxed);
                    }
                    mine += 1;
                    issued.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
        // wait for `ok`, but fail *fast* (not at the 120 s timeout) when
        // `bad` observes the opposite outcome — e.g. a staged snapshot
        // being rejected, or the drift injection being promoted
        let wait_for = |what: &str,
                        ok: &dyn Fn(&ServeSnapshot) -> bool,
                        bad: &dyn Fn(&ServeSnapshot) -> Option<String>|
         -> Result<(), String> {
            let t0 = trace::clock();
            loop {
                let snap = engine.metrics().snapshot();
                if ok(&snap) {
                    return Ok(());
                }
                if let Some(why) = bad(&snap) {
                    return Err(format!("while waiting for {what}: {why}"));
                }
                if t0.elapsed().as_secs() > 120 {
                    return Err(format!("timed out waiting for {what}"));
                }
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        };
        let n_staged = staged.len();
        let stage = || -> Result<(), String> {
            for (k, (step, path)) in staged.iter().enumerate() {
                // atomic hand-off (stage + rename; for a v2 directory:
                // shards first, manifest last): the watcher must never
                // act on a half-written snapshot
                ckpt::stage_copy(path, &ckpt::snapshot_path(&watch_dir, *step))
                    .map_err(|e| e.to_string())?;
                wait_for(
                    &format!("promotion of step {step}"),
                    &|m| m.standby_promotions as usize >= k + 1,
                    &|m| {
                        (m.standby_rejects > 0).then(|| {
                            "the staged snapshot was canary-rejected \
                             (see watcher log; is --drift-max too tight?)"
                                .to_string()
                        })
                    },
                )?;
            }
            // drift injection: a different-seed model's weights dressed
            // up as a newer snapshot — the canary bound must refuse it
            let donor = ClipTrainModel::new(EncoderConfig {
                seed: seed ^ 0xBAD_5EED,
                ..enc_cfg.clone()
            });
            let mut bad = final_ck.clone();
            bad.step = steps + 1;
            bad.params = donor.collect_params();
            ckpt::save(&ckpt::snapshot_path(&watch_dir, steps + 1), &bad)
                .map_err(|e| e.to_string())?;
            wait_for(
                "canary rejection of the drifted snapshot",
                &|m| m.standby_rejects >= 1,
                &|m| {
                    (m.standby_promotions as usize > n_staged).then(|| {
                        "the drifted snapshot was PROMOTED instead of \
                         rejected (drift bound did not hold)"
                            .to_string()
                    })
                },
            )?;
            Ok(())
        };
        stage_err = stage().err();
        stop.store(true, Ordering::Relaxed);
    });
    watcher.stop();
    if let Some(e) = stage_err {
        bail!("pipeline standby phase failed: {e}");
    }
    let dropped = errors.load(Ordering::Relaxed);
    if dropped > 0 {
        bail!("{dropped} requests failed during the watcher-driven promotions");
    }
    let snap = engine.metrics().snapshot();
    let swap_requests = issued.load(Ordering::Relaxed);
    if snap.standby_promotions as usize != staged.len() {
        bail!(
            "expected {} watcher promotions, observed {}",
            staged.len(),
            snap.standby_promotions
        );
    }
    if snap.standby_rollbacks > 0 {
        bail!("unexpected post-promotion rollback(s): {}", snap.standby_rollbacks);
    }
    if snap.standby_quarantines > 0 {
        bail!(
            "unexpected snapshot quarantine(s): {} — staging must never \
             expose a half-written snapshot",
            snap.standby_quarantines
        );
    }
    if engine.generation() != staged.len() as u64 {
        bail!(
            "the rejected snapshot must leave the live generation untouched \
             (generation {}, expected {})",
            engine.generation(),
            staged.len()
        );
    }
    let post = engine
        .encode(EncodeInput::Image(probe.clone()))
        .map_err(|e| anyhow::anyhow!("post-swap probe failed: {e}"))?;
    let cache_invalidated = !post.cache_hit;
    let weights_changed = *post.embedding != *pre.embedding;
    println!(
        "   {} watcher promotions, {} canary reject(s), 0 rollbacks — \
         generation {}, swap-pause max {:.1} µs, prepare p99 {:.2} ms \
         (cache invalidated: {cache_invalidated}, weights changed: \
         {weights_changed})",
        snap.standby_promotions,
        snap.standby_rejects,
        engine.generation(),
        snap.swap_pause_max_us,
        snap.prepare_p99_ms,
    );
    snap.print(engine.kind_label());

    // ---- 4) eval: the served weights must encode exactly like the model
    println!("== pipeline 4/4: zero-shot eval through the serving engine ==");
    let mut model = ClipTrainModel::new(final_ck.encoder.clone());
    model.load_params(&final_ck.params);
    // rebuild the training corpus through the trainer's own constructor so
    // the eval distribution can never drift from what the model trained on
    let mut eval_train_cfg = NativeTrainConfig::preset(kind, steps);
    eval_train_cfg.hyper = final_ck.hyper.clone();
    eval_train_cfg.encoder = final_ck.encoder.clone();
    eval_train_cfg.shifts = final_ck.shifts.clone();
    let mut data = SyntheticClip::new(eval_train_cfg.data_config());
    data.restore(&final_ck.data)
        .map_err(|e| anyhow::anyhow!("eval data cursor: {e}"))?;
    let n_concepts = data.config().n_concepts;
    let embed_dim = enc_cfg.embed_dim;
    let mut class_embs: Vec<f32> = Vec::with_capacity(n_concepts * embed_dim);
    for c in 0..n_concepts {
        let caption = data.canonical_caption(c);
        let e = engine
            .encode(EncodeInput::Text(caption))
            .map_err(|e| anyhow::anyhow!("class encode failed: {e}"))?;
        class_embs.extend(e.embedding.iter());
    }
    let eval = data.eval_set(2);
    let mut img_embs: Vec<f32> = Vec::with_capacity(eval.concepts.len() * embed_dim);
    let mut eval_matches_model = true;
    for i in 0..eval.concepts.len() {
        let img = eval.images[i * image_len..(i + 1) * image_len].to_vec();
        let served = engine
            .encode(EncodeInput::Image(img.clone()))
            .map_err(|e| anyhow::anyhow!("eval encode failed: {e}"))?;
        let modeled = model.encode_images_infer(&switchback::tensor::Matrix::from_vec(
            enc_cfg.patches,
            enc_cfg.patch_dim,
            img,
        ));
        if modeled.row(0) != &served.embedding[..] {
            eval_matches_model = false;
        }
        img_embs.extend(served.embedding.iter());
    }
    let eval_acc =
        nearest_class_accuracy(&img_embs, &class_embs, embed_dim, &eval.concepts);
    println!(
        "   zero-shot acc {:.1}% over {} images ({} concepts) — engine/model \
         encodes {}",
        100.0 * eval_acc,
        eval.concepts.len(),
        n_concepts,
        if eval_matches_model { "bit-identical" } else { "DIVERGED" }
    );
    if !eval_matches_model {
        bail!("serving engine and train model disagree on the same weights");
    }
    // wind the telemetry plane down first: its closures hold engine
    // handles through the slot, and Engine::drop needs the last reference
    *engine_slot.write().unwrap() = None;
    if let Some((_, mut srv)) = telemetry {
        srv.shutdown();
    }
    drop(engine); // joins the worker pool (Engine::drop drains the queue)

    // ---- BENCH_ckpt.json ---------------------------------------------
    let mut config = ObjWriter::new();
    config
        .field_u64("steps", steps)
        .field_str("optimizer", optimizer.label())
        .field_u64("requests", requests as u64)
        .field_u64("concurrency", concurrency as u64)
        .field_u64("seed", seed)
        .field_u64("dim", enc_cfg.dim as u64)
        .field_u64("blocks", enc_cfg.blocks as u64);
    let mut entry = ObjWriter::new();
    entry
        .field_str("kind", kind.label())
        .field_f32("train_final_loss", train_res.final_loss)
        .field_f32("train_tail_loss", train_res.tail_loss)
        .field_u64("snapshots", train_res.snapshots as u64)
        .field_u64("ckpt_bytes", load_io.bytes)
        .field_f32("save_mb_s", sync_io.mb_per_s() as f32)
        .field_f32("load_mb_s", sync_load_io.mb_per_s() as f32)
        .field_u64("ckpt_shards", ckpt_shards as u64)
        .field_f32("shard_save_mb_s", save_mb_s as f32)
        .field_f32("shard_load_mb_s", load_io.mb_per_s() as f32)
        .field_bool("sharded_bit_identical", sharded_bit_identical)
        .field_bool("round_trip_ok", round_trip_ok)
        .field_f32("hot_swap_pause_us", snap.swap_pause_max_us as f32)
        .field_f32("swap_pause_p99_us", snap.swap_pause_p99_us as f32)
        .field_f32("prepare_p99_ms", snap.prepare_p99_ms as f32)
        .field_u64("hot_swaps", snap.hot_swaps)
        .field_u64("standby_promotions", snap.standby_promotions)
        .field_u64("standby_rejects", snap.standby_rejects)
        .field_u64("standby_rollbacks", snap.standby_rollbacks)
        .field_u64("standby_quarantines", snap.standby_quarantines)
        .field_u64("swap_requests", swap_requests as u64)
        .field_u64("dropped_requests", dropped)
        .field_bool("cache_invalidated", cache_invalidated)
        .field_bool("weights_changed", weights_changed)
        .field_f32("eval_acc", eval_acc)
        .field_bool("eval_matches_model", eval_matches_model);
    let mut top = ObjWriter::new();
    top.field_str("bench", "ckpt_pipeline")
        .field_raw("config", &config.finish())
        .field_raw("results", &format!("[{}]", entry.finish()));
    std::fs::write(&out, top.finish() + "\n")?;
    println!("wrote {out}");
    write_trace_dump_if_requested(args)?;
    Ok(())
}

/// Bench-regression gate: compare a fresh BENCH_*.json against a committed
/// baseline (see scripts/check_bench.sh and DESIGN.md §CI).
fn cmd_benchdiff(args: &Args) -> Result<()> {
    let [old_path, new_path] = args.positional.as_slice() else {
        bail!("benchdiff: expected exactly two paths: <baseline.json> <new.json>");
    };
    let tol: f64 = args.get("tol", DEFAULT_TOLERANCE)?;
    if !(0.0..1.0).contains(&tol) {
        bail!("--tol must be in [0, 1)");
    }
    let strict = args.has("--strict");
    let load = |p: &str| -> Result<json::Value> {
        let text = std::fs::read_to_string(p)
            .map_err(|e| anyhow::anyhow!("cannot read {p}: {e}"))?;
        json::parse(&text).map_err(|e| anyhow::anyhow!("cannot parse {p}: {e}"))
    };
    let old = load(old_path)?;
    let new = load(new_path)?;
    let regs = compare_bench(&old, &new, tol, strict)
        .map_err(|e| anyhow::anyhow!("benchdiff: {e}"))?;
    if regs.is_empty() {
        println!(
            "benchdiff OK — no regressions vs {old_path} (tol {:.0}%{})",
            tol * 100.0,
            if strict { ", strict" } else { "" }
        );
        Ok(())
    } else {
        for r in &regs {
            eprintln!("REGRESSION: {r}");
        }
        bail!("benchdiff: {} regression(s) vs {old_path}", regs.len());
    }
}

/// `switchback lint [PATH] [--deny LEVEL] [--json] [--out PATH]`: run the
/// in-tree invariant linter + lock-order analyzer (see `analysis`).
fn cmd_lint(args: &Args) -> Result<()> {
    let path = args.positional.first().cloned().unwrap_or_else(|| {
        if std::path::Path::new("rust/src").is_dir() {
            "rust/src".into()
        } else if std::path::Path::new("src").is_dir() {
            "src".into()
        } else {
            ".".into()
        }
    });
    let deny_s: String = args.get("deny", "warn".to_string())?;
    let Some(deny) = LintLevel::parse(&deny_s) else {
        bail!("--deny must be info|warn|error, got {deny_s:?}");
    };
    let root = std::path::Path::new(&path);
    if !root.is_dir() {
        bail!("lint: {path:?} is not a directory");
    }
    let report = analysis::lint_root(root)
        .map_err(|e| anyhow::anyhow!("lint: cannot read {path}: {e}"))?;
    if args.has("--json") {
        println!("{}", report.ledger_json());
    } else {
        print!("{}", report.render(args.has("--verbose") || args.has("-v")));
    }
    if let Some(out) = args.flags.get("out") {
        std::fs::write(out, report.ledger_json())
            .map_err(|e| anyhow::anyhow!("lint: cannot write {out}: {e}"))?;
        if !args.has("--json") {
            println!("wrote {out}");
        }
    }
    if report.worst().is_some_and(|w| w >= deny) {
        bail!(
            "lint: {} finding(s) at or above --deny {} in {path}",
            report.active().filter(|f| f.level >= deny).count(),
            deny.as_str()
        );
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_needs_pjrt(cmd: &str) -> Result<()> {
    bail!(
        "`{cmd}` executes AOT artifacts via PJRT, but this binary was built \
         without the `pjrt` feature.\nRebuild with `cargo build --release \
         --features pjrt` on a machine with the PJRT toolchain \
         (rust/Cargo.toml explains the vendor/xla swap).\nFor PJRT-free \
         end-to-end training on the native substrate, use `switchback train`."
    )
}

/// Model-shape + engine flags shared by `serve` and `loadgen`.
fn serve_config_from(args: &Args, kind: LinearKind) -> Result<ServeConfig> {
    let requests: usize = args.count("requests", 2000)?;
    let encoder = EncoderConfig {
        kind,
        dim: args.get("dim", 128)?,
        heads: args.get("heads", 4)?,
        blocks: args.get("blocks", 2)?,
        embed_dim: args.get("embed-dim", 64)?,
        patches: args.get("patches", 16)?,
        patch_dim: args.get("patch-dim", 64)?,
        text_seq: args.get("text-seq", 16)?,
        vocab: args.get("vocab", 512)?,
        seed: args.get("seed", 42)?,
    };
    if encoder.dim == 0 || encoder.heads == 0 || encoder.dim % encoder.heads != 0 {
        bail!("--dim must be a positive multiple of --heads");
    }
    if encoder.vocab == 0
        || encoder.text_seq == 0
        || encoder.patches == 0
        || encoder.patch_dim == 0
        || encoder.embed_dim == 0
    {
        bail!("--vocab/--text-seq/--patches/--patch-dim/--embed-dim must be positive");
    }
    // Same resolution as cmd_loadgen; 2× headroom because ShardedLru
    // splits capacity into per-shard caps and hash imbalance would
    // otherwise evict live population members at exactly-sized capacity.
    let population: usize = args.count("population", (requests / 2).max(1))?;
    let cache_capacity = if args.has("--no-cache") {
        0
    } else {
        args.count(
            "cache-capacity",
            8192.max(requests).max(population.saturating_mul(2)),
        )?
    };
    let max_batch: usize = args.get("batch-max", 32)?;
    if max_batch == 0 {
        bail!("--batch-max must be at least 1");
    }
    Ok(ServeConfig {
        encoder,
        policy: BatchPolicy {
            max_batch,
            max_wait: std::time::Duration::from_micros(args.get("wait-us", 2000)?),
        },
        workers: args.get("workers", 0)?,
        cache_capacity,
        cache_shards: 0,
    })
}

/// In-process smoke run of the serving engine, with `--listen` adding
/// the real network path: a [`Frontend`] (TCP `POST /encode`) over a
/// [`Router`] fanning out across `--engines` engines by doc-hash
/// affinity.  With `--watch-dir` the warm-standby watcher rides along
/// (fan-out aware: one watcher promotes every engine or none): if the
/// watched directory already holds a snapshot newer than the booted
/// weights, the smoke waits for (and asserts) its promotion.
fn cmd_serve(args: &Args) -> Result<()> {
    let kind_s: String = args.get("kind", "switchback".to_string())?;
    let Some(kind) = LinearKind::parse(&kind_s) else {
        bail!("bad --kind {kind_s:?} (standard | switchback | switchback_m | llmint8)");
    };
    let watch_dir = args.flags.get("watch-dir").cloned();
    if args.has("--standby") && watch_dir.is_none() {
        bail!("--standby needs --watch-dir <dir>");
    }
    let listen = args.flags.get("listen").cloned();
    let n_engines: usize = args.get("engines", if listen.is_some() { 2 } else { 1 })?;
    if n_engines == 0 {
        bail!("--engines must be at least 1");
    }
    if args.flags.contains_key("engines") && listen.is_none() && n_engines > 1 {
        bail!("--engines needs --listen (the fleet serves the front door)");
    }
    let max_inflight: usize = args.get("max-inflight", 32)?;
    let mut cfg = serve_config_from(args, kind)?;
    // --weights: boot from a training checkpoint — shape and f32 master
    // weights come from the file, --kind picks the serving quantization
    let mut boot: Option<(u64, Vec<Vec<f32>>)> = None;
    if let Some(wpath) = args.flags.get("weights") {
        let file = ckpt::resolve(wpath)?;
        let (ck, io) = ckpt::load(&file)?;
        cfg.encoder = EncoderConfig { kind, ..ck.encoder.clone() };
        println!(
            "loaded {} (step {}/{}, {} bytes, {:.1} MB/s) — serving as {}",
            file.display(),
            ck.step,
            ck.hyper.steps,
            io.bytes,
            io.mb_per_s(),
            kind.label()
        );
        boot = Some((ck.step, ck.params));
    }
    let image_len = cfg.encoder.image_len();
    let text_seq = cfg.encoder.text_seq;
    let vocab = cfg.encoder.vocab;
    println!(
        "starting engine: kind={} dim={} blocks={} weights={} engines={}",
        kind.label(),
        cfg.encoder.dim,
        cfg.encoder.blocks,
        if boot.is_some() { "checkpoint" } else { "seeded" },
        n_engines,
    );
    // Every engine in the fleet boots the same generation-0 weights:
    // seeded engines share the config seed, checkpoint boots rebuild the
    // encoder per engine from the same master params.
    let engines: Vec<std::sync::Arc<Engine>> = (0..n_engines)
        .map(|_| -> Result<std::sync::Arc<Engine>> {
            Ok(std::sync::Arc::new(match &boot {
                Some((_, params)) => {
                    let weights = ckpt::encoder_weights(&cfg.encoder, params)?;
                    Engine::start_with_encoder(
                        cfg.clone(),
                        ClipEncoder::from_weights(cfg.encoder.clone(), weights),
                    )
                }
                None => Engine::start(cfg.clone()),
            }))
        })
        .collect::<Result<_>>()?;
    let router = std::sync::Arc::new(Router::from_engines(engines));
    let engine = std::sync::Arc::clone(&router.engines()[0]);
    println!(
        "encoder resident weights: {:.1} KiB (pre-quantized at load)",
        engine.weight_bytes() as f64 / 1024.0
    );

    // --telemetry-addr: the live plane rides the whole smoke (including
    // any standby wait and the --hold-ms window).  /metrics is the
    // engine's registry merged with the process-wide one; /readyz is
    // "booted and not mid-promotion", with the generation, promoting
    // flag and quarantine count as detail
    let mut telemetry = match args.flags.get("telemetry-addr") {
        Some(addr) => {
            let snap_eng = Arc::clone(&engine);
            let ready_router = Arc::clone(&router);
            let srv = TelemetryServer::bind(
                addr,
                TelemetryConfig {
                    mode: "serve",
                    snapshot: Arc::new(move || {
                        snap_eng
                            .metrics()
                            .registry()
                            .snapshot()
                            .merged(trace::global().snapshot())
                    }),
                    ready: Arc::new(move || {
                        // ready = no engine mid-promotion AND the fleet
                        // agrees on one weight generation (a torn fan-out
                        // must never look ready)
                        let promoting = ready_router.is_promoting();
                        let agreement = ready_router.generation_agreement();
                        let primary = &ready_router.engines()[0];
                        Readiness::new(!promoting && agreement.is_ok())
                            .with(
                                "generation",
                                match &agreement {
                                    Ok(g) => g.to_string(),
                                    Err(_) => "\"disagreement\"".to_string(),
                                },
                            )
                            .with("engines", ready_router.len().to_string())
                            .with("promoting", if promoting { "true" } else { "false" })
                            .with(
                                "quarantines",
                                primary.metrics().snapshot().standby_quarantines.to_string(),
                            )
                    }),
                    flight: None,
                    http: Default::default(),
                },
            )?;
            println!("telemetry: listening on {}", srv.url());
            Some(srv)
        }
        None => None,
    };

    // --listen: bind the network front door — the Http1Server as the
    // serving data plane, admission-gated and fanned out by doc hash.
    // verify.sh sed-parses the printed line, so its shape is load-bearing.
    let mut frontend = match &listen {
        Some(addr) => {
            let fe = Frontend::bind(
                addr,
                Arc::clone(&router),
                FrontendConfig { max_inflight, ..FrontendConfig::default() },
            )
            .map_err(|e| anyhow::anyhow!("frontend bind failed: {e}"))?;
            println!(
                "frontend: listening on {} (engines={}, max-inflight={})",
                fe.local_addr(),
                router.len(),
                max_inflight
            );
            Some(fe)
        }
        None => None,
    };

    // warm-standby: watch the directory and (when it already holds a
    // newer snapshot) require one promotion before the smoke probes run,
    // so the probes exercise the promoted generation
    let mut standby_handle = None;
    if let Some(dir) = watch_dir {
        let boot_step = boot.as_ref().map(|(s, _)| *s).unwrap_or(0);
        let drift_max: f32 = args.get("drift-max", 0.5)?;
        if !drift_max.is_finite() || drift_max < 0.0 {
            bail!("--drift-max must be a non-negative number");
        }
        let mut scfg = StandbyConfig::new(&dir);
        scfg.probe_every = args.get("canary-every", 4u32)?;
        scfg.drift_max = if drift_max > 0.0 { Some(drift_max) } else { None };
        scfg.initial_step = boot_step;
        scfg.baseline = boot.map(|(_, params)| params);
        scfg.verbose = true;
        let newest = ckpt::list_snapshots(std::path::Path::new(&dir))
            .into_iter()
            .filter_map(|(_, p)| ckpt::peek(&p).ok())
            .map(|p| p.step)
            .max()
            .unwrap_or(0);
        // fan-out aware: the one watcher validates once and installs the
        // candidate on every engine (or none), so the fleet's generations
        // never tear apart
        standby_handle = Some(standby::spawn_fanout(router.engines().to_vec(), scfg));
        // --watch-dir alone spawns the watcher and moves on; --standby
        // additionally *requires* the pending promotion before the smoke
        // probes run, so they exercise the promoted generation
        if args.has("--standby") && newest > boot_step {
            println!(
                "standby: watching {dir} — newest snapshot step {newest} > \
                 booted step {boot_step}, waiting for its promotion"
            );
            let t0 = trace::clock();
            loop {
                let snap = engine.metrics().snapshot();
                if snap.standby_promotions >= 1 {
                    println!(
                        "standby: promoted to generation {} \
                         (prepare p99 {:.2} ms, swap pause max {:.1} µs)",
                        engine.generation(),
                        snap.prepare_p99_ms,
                        snap.swap_pause_max_us,
                    );
                    break;
                }
                // a reject is not fatal yet: it may be an unrelated bad
                // file in the directory — a valid candidate can still
                // promote on a later poll, so only the timeout gives up
                if t0.elapsed().as_secs() > 30 {
                    bail!(
                        "standby: no promotion within 30s ({} snapshot(s) \
                         rejected — see the watcher log above)",
                        snap.standby_rejects
                    );
                }
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
        } else {
            println!(
                "standby: watching {dir} (booted step {boot_step}, newest \
                 snapshot step {newest} — promotions happen live)"
            );
        }
    }
    let mut rng = Rng::seed(7);
    let img: Vec<f32> = (0..image_len).map(|_| rng.normal()).collect();
    let toks: Vec<i32> = (0..text_seq).map(|_| rng.below(vocab) as i32).collect();
    let e1 = engine
        .encode(EncodeInput::Image(img.clone()))
        .map_err(|e| anyhow::anyhow!("image encode failed: {e}"))?;
    let e2 = engine
        .encode(EncodeInput::Text(toks))
        .map_err(|e| anyhow::anyhow!("text encode failed: {e}"))?;
    let e3 = engine
        .encode(EncodeInput::Image(img))
        .map_err(|e| anyhow::anyhow!("repeat encode failed: {e}"))?;
    println!(
        "image embedding: dim {} (first 4: {:?})",
        e1.embedding.len(),
        &e1.embedding[..4.min(e1.embedding.len())]
    );
    println!("text  embedding: dim {}", e2.embedding.len());
    if engine.cache_stats().is_some() {
        if !e3.cache_hit {
            bail!("smoke failure: repeated input did not hit the cache");
        }
        if *e3.embedding != *e1.embedding {
            bail!("smoke failure: cache returned a different embedding");
        }
        println!("repeat request served from cache (no GEMM work)");
    }
    // With the front door up, prove the full network path once before
    // declaring the smoke good: TCP connect, POST /encode, parse the
    // embedding back, and require the router to agree on one generation.
    if let Some(fe) = frontend.as_ref() {
        let mut client = EncodeClient::connect(
            &fe.local_addr().to_string(),
            std::time::Duration::from_secs(5),
        )
        .map_err(|e| anyhow::anyhow!("socket self-probe connect failed: {e}"))?;
        let probe: Vec<f32> = (0..image_len).map(|_| rng.normal()).collect();
        match client.encode(&EncodeInput::Image(probe)) {
            Ok(SocketOutcome::Ok { embedding, .. }) => {
                println!("socket self-probe OK (embedding dim {})", embedding.len());
            }
            Ok(SocketOutcome::Rejected(status)) => {
                bail!("socket self-probe was shed with status {status} on an idle door");
            }
            Err(e) => bail!("socket self-probe failed: {e}"),
        }
        let generation = router
            .generation_agreement()
            .map_err(|e| anyhow::anyhow!("fleet generation disagreement: {e}"))?;
        println!(
            "fleet: {} engine(s) all at generation {generation}",
            router.len()
        );
    }
    let snap = engine.metrics().snapshot();
    snap.print(kind.label());
    if let Some(handle) = standby_handle {
        handle.stop();
        println!(
            "standby: {} promotion(s), {} reject(s), {} rollback(s)",
            snap.standby_promotions, snap.standby_rejects, snap.standby_rollbacks
        );
    }
    // --hold-ms: keep the engine + telemetry plane up so an external
    // scraper (verify.sh, a Prometheus dev box) can hit the printed
    // address before the process exits
    let hold_ms: u64 = args.get("hold-ms", 0)?;
    if hold_ms > 0 {
        println!("holding for {hold_ms} ms (front door + telemetry stay up)");
        std::thread::sleep(std::time::Duration::from_millis(hold_ms));
    }
    // teardown order: stop accepting network work first (front door),
    // then the telemetry plane, then the engines themselves
    if let Some(fe) = frontend.as_mut() {
        fe.shutdown();
    }
    drop(frontend);
    if let Some(srv) = telemetry.as_mut() {
        // join the HTTP workers (and release their engine handles) before
        // the engine itself winds down
        srv.shutdown();
    }
    drop(telemetry);
    drop(engine);
    drop(router); // last fleet handles: Engine::drop joins each worker pool
    println!("serve smoke OK");
    Ok(())
}

/// Parse a CSV list flag into typed values.
fn csv_list<T: std::str::FromStr>(s: &str, what: &str) -> Result<Vec<T>> {
    s.split(',')
        .filter(|p| !p.is_empty())
        .map(|p| {
            p.trim()
                .parse::<T>()
                .map_err(|_| anyhow::anyhow!("bad {what} entry {p:?}"))
        })
        .collect()
}

fn cmd_loadgen(args: &Args) -> Result<()> {
    let kinds_s: String = args.get("kinds", "standard,switchback".to_string())?;
    let kinds: Vec<LinearKind> = csv_list(&kinds_s, "--kinds")?;
    if kinds.is_empty() {
        bail!("--kinds must name at least one precision kind");
    }
    let requests: usize = args.count("requests", 2000)?;
    let conc_s: String = args.get("concurrency", "32".to_string())?;
    let concurrencies: Vec<usize> = csv_list(&conc_s, "--concurrency")?;
    if concurrencies.is_empty() || concurrencies.contains(&0) {
        bail!("--concurrency must list positive client counts");
    }
    let population: usize = args.count("population", (requests / 2).max(1))?;
    if population == 0 {
        bail!("--population must be positive");
    }
    let image_fraction: f32 = args.get("image-fraction", 0.7)?;
    let out: String = args.get("out", "BENCH_serve.json".to_string())?;
    let seed: u64 = args.get("seed", 42)?;

    let mut reports = vec![];
    let mut policy_echo = (0usize, 0u64);
    for &kind in &kinds {
        for &concurrency in &concurrencies {
            // fresh engine per run: cold cache, clean metrics
            let cfg = serve_config_from(args, kind)?;
            policy_echo =
                (cfg.policy.max_batch, cfg.policy.max_wait.as_micros() as u64);
            let engine = Engine::start(cfg);
            let lg = LoadgenConfig {
                requests,
                concurrency,
                population,
                image_fraction,
                seed,
                ..LoadgenConfig::default()
            };
            let report = run_loadgen(&engine, &lg);
            report.print();
            if report.errors > 0 {
                bail!("loadgen: {} requests failed", report.errors);
            }
            reports.push(report);
            engine.shutdown();
        }
    }

    // --swap-every: one extra run measuring sustained throughput + tail
    // latency *across repeated generations* — the swapper promotes a
    // fresh encoder every N requests through the standby path, so the
    // entry carries promotion counters and swap-pause percentiles that
    // benchdiff gates as invariants
    let swap_every: usize = args.count("swap-every", 0)?;
    if swap_every > 0 {
        if swap_every >= requests {
            bail!("--swap-every must be smaller than --requests for a swap to happen");
        }
        let kind = kinds
            .iter()
            .copied()
            .find(|k| *k == LinearKind::SwitchBack)
            .unwrap_or(kinds[0]);
        let cfg = serve_config_from(args, kind)?;
        let engine = Engine::start(cfg);
        let lg = LoadgenConfig {
            requests,
            concurrency: concurrencies[0],
            population,
            image_fraction,
            seed,
            swap_every,
            ..LoadgenConfig::default()
        };
        let report = run_loadgen(&engine, &lg);
        report.print();
        if report.errors > 0 {
            bail!("loadgen --swap-every: {} requests failed", report.errors);
        }
        // the swapper promotes every due generation, deterministically
        let expected = planned_swaps(requests, swap_every) as u64;
        if report.snapshot.standby_promotions != expected
            || report.snapshot.standby_rejects > 0
        {
            bail!(
                "loadgen --swap-every: expected {expected} promotions and 0 \
                 rejects, observed {} and {}",
                report.snapshot.standby_promotions,
                report.snapshot.standby_rejects
            );
        }
        reports.push(report);
        engine.shutdown();
    }

    // --scrape-every: one extra scraper-present run — a rider thread GETs
    // /metrics every N ms while the closed loop runs, so the entry
    // records how the serve tail behaves with a scraper attached and how
    // long scrapes take under load (both gated by benchdiff)
    let scrape_every_ms: u64 = args.get("scrape-every", 0)?;
    if scrape_every_ms > 0 {
        let kind = kinds
            .iter()
            .copied()
            .find(|k| *k == LinearKind::SwitchBack)
            .unwrap_or(kinds[0]);
        let cfg = serve_config_from(args, kind)?;
        let engine = Arc::new(Engine::start(cfg));
        // default scrape target: a telemetry plane self-hosted over the
        // engine under test (exactly what `serve --telemetry-addr` serves)
        let (url, mut own_srv) = match args.flags.get("scrape-url") {
            Some(u) => (u.clone(), None),
            None => {
                let snap_eng = Arc::clone(&engine);
                let srv = TelemetryServer::bind(
                    "127.0.0.1:0",
                    TelemetryConfig {
                        mode: "serve",
                        snapshot: Arc::new(move || {
                            snap_eng
                                .metrics()
                                .registry()
                                .snapshot()
                                .merged(trace::global().snapshot())
                        }),
                        ready: Arc::new(|| Readiness::new(true)),
                        flight: None,
                        http: Default::default(),
                    },
                )?;
                println!("telemetry: listening on {}", srv.url());
                (format!("{}/metrics", srv.url()), Some(srv))
            }
        };
        let lg = LoadgenConfig {
            requests,
            concurrency: concurrencies[0],
            population,
            image_fraction,
            seed,
            swap_every: 0,
            scrape_every_ms,
            scrape_url: Some(url),
        };
        let report = run_loadgen(&engine, &lg);
        report.print();
        if report.errors > 0 {
            bail!("loadgen --scrape-every: {} requests failed", report.errors);
        }
        if report.scrapes == 0 || report.scrape_errors > 0 {
            bail!(
                "loadgen --scrape-every: {} well-formed scrapes, {} scrape \
                 errors (want ≥1 and 0)",
                report.scrapes,
                report.scrape_errors
            );
        }
        reports.push(report);
        if let Some(srv) = own_srv.as_mut() {
            srv.shutdown();
        }
        drop(own_srv);
        drop(engine); // joins the worker pool (Engine::drop drains the queue)
    }

    // --socket ADDR: two extra runs through an already-running
    // `serve --listen` front door, over real TCP.  The clean run (base
    // concurrency, under the admission window) must finish with zero
    // request errors and zero sheds; the overload run (4× base, past the
    // default window) must observe admission rejections — both gated
    // again by benchdiff against the checked-in baseline
    if let Some(addr) = args.flags.get("socket").cloned() {
        let kind = kinds
            .iter()
            .copied()
            .find(|k| *k == LinearKind::SwitchBack)
            .unwrap_or(kinds[0]);
        // the population is rebuilt client-side from the shape flags, so
        // they must match the server's boot flags for affinity + cache
        // behavior to line up with the in-process entries
        let cfg = serve_config_from(args, kind)?;
        policy_echo = (cfg.policy.max_batch, cfg.policy.max_wait.as_micros() as u64);
        let base_conc = concurrencies[0];
        for (overload, concurrency) in
            [(false, base_conc), (true, base_conc.saturating_mul(4))]
        {
            let lg = LoadgenConfig {
                requests,
                concurrency,
                population,
                image_fraction,
                seed,
                ..LoadgenConfig::default()
            };
            let report =
                run_loadgen_socket(&addr, kind.label(), &cfg.encoder, &lg, overload)
                    .map_err(|e| anyhow::anyhow!("loadgen --socket: {e}"))?;
            report.print();
            if report.errors > 0 {
                bail!(
                    "loadgen --socket{}: {} requests failed",
                    if overload { " (overload)" } else { "" },
                    report.errors
                );
            }
            if overload && report.snapshot.rejected == 0 {
                bail!(
                    "loadgen --socket (overload, c={concurrency}): no admission \
                     rejections — the window never filled, overload not proven"
                );
            }
            if !overload && report.snapshot.rejected > 0 {
                bail!(
                    "loadgen --socket (c={concurrency}): {} requests shed under \
                     the admission window — the clean run must not overload",
                    report.snapshot.rejected
                );
            }
            reports.push(report);
        }
    }

    // the acceptance ratio: int8 serving vs the f32 baseline
    for &concurrency in &concurrencies {
        let rps = |label: &str| {
            reports
                .iter()
                .find(|r| r.kind == label && r.concurrency == concurrency)
                .map(|r| r.requests_per_sec)
        };
        if let (Some(std_rps), Some(sb_rps)) = (rps("standard"), rps("switchback")) {
            println!(
                "c={concurrency}: switchback/standard throughput ratio: {:.2}×",
                sb_rps / std_rps
            );
        }
    }
    write_bench_json(&out, policy_echo.0, policy_echo.1, &reports)?;
    println!("wrote {out}");
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "train" => cmd_train(&args),
        #[cfg(feature = "pjrt")]
        "train-aot" => cmd_train_aot(&args),
        #[cfg(feature = "pjrt")]
        "exp" => cmd_exp(&args),
        #[cfg(feature = "pjrt")]
        "info" => cmd_info(&args),
        #[cfg(not(feature = "pjrt"))]
        "train-aot" | "exp" | "info" => cmd_needs_pjrt(&cmd),
        "serve" => cmd_serve(&args),
        "loadgen" => cmd_loadgen(&args),
        "pipeline" => cmd_pipeline(&args),
        "probe" => cmd_probe(&args),
        "ckpt" => cmd_ckpt(&args),
        "trace" => cmd_trace(&args),
        "benchdiff" => cmd_benchdiff(&args),
        "lint" => cmd_lint(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|v| v.to_string()).collect()
    }

    #[test]
    fn parses_positionals_value_flags_and_bools_mixed() {
        let a = Args::parse(&argv(&[
            "my_artifact",
            "--steps",
            "50",
            "--quiet",
            "--lr",
            "1e-3",
            "second_pos",
            "-v",
        ]))
        .unwrap();
        assert_eq!(a.positional, vec!["my_artifact", "second_pos"]);
        assert_eq!(a.get::<u64>("steps", 0).unwrap(), 50);
        assert_eq!(a.get::<f32>("lr", 0.0).unwrap(), 1e-3);
        assert!(a.has("--quiet"));
        assert!(a.has("-v"));
        assert!(!a.has("--all"));
    }

    #[test]
    fn unknown_boolean_flag_is_rejected_not_swallowed() {
        // the old parser treated `--quite` (typo) as a value flag and ate
        // the following positional — it must be a hard error instead
        let err = Args::parse(&argv(&["--quite", "my_artifact"])).unwrap_err();
        assert!(err.to_string().contains("unknown flag --quite"), "{err}");
        let err = Args::parse(&argv(&["--bogus"])).unwrap_err();
        assert!(err.to_string().contains("unknown flag"), "{err}");
    }

    #[test]
    fn value_flag_at_end_without_value_errors() {
        let err = Args::parse(&argv(&["art", "--steps"])).unwrap_err();
        assert!(err.to_string().contains("expects a value"), "{err}");
    }

    #[test]
    fn value_flag_consumes_exactly_one_token() {
        let a = Args::parse(&argv(&["--steps", "10", "pos"])).unwrap();
        assert_eq!(a.positional, vec!["pos"]);
        assert_eq!(a.flags.get("steps").map(String::as_str), Some("10"));
    }

    #[test]
    fn bad_typed_value_reports_flag_name() {
        let a = Args::parse(&argv(&["--steps", "abc"])).unwrap();
        let err = a.get::<u64>("steps", 0).unwrap_err();
        assert!(err.to_string().contains("--steps"), "{err}");
    }

    #[test]
    fn count_suffixes() {
        assert_eq!(parse_count("123"), Some(123));
        assert_eq!(parse_count("10k"), Some(10_000));
        assert_eq!(parse_count("2K"), Some(2_000));
        assert_eq!(parse_count("1m"), Some(1_000_000));
        assert_eq!(parse_count("x"), None);
        assert_eq!(parse_count("10kk"), None);
        let a = Args::parse(&argv(&["--requests", "10k"])).unwrap();
        assert_eq!(a.count("requests", 0).unwrap(), 10_000);
    }

    #[test]
    fn csv_list_parses_and_rejects() {
        assert_eq!(csv_list::<usize>("8,32, 64", "c").unwrap(), vec![8, 32, 64]);
        assert!(csv_list::<usize>("8,x", "c").is_err());
        assert!(csv_list::<usize>("", "c").unwrap().is_empty());
        let kinds = csv_list::<LinearKind>("standard, switchback", "k").unwrap();
        assert_eq!(kinds, vec![LinearKind::Standard, LinearKind::SwitchBack]);
        assert!(csv_list::<LinearKind>("standard,bogus", "k").is_err());
    }

    #[test]
    fn serve_config_validates_shape() {
        let a = Args::parse(&argv(&["--dim", "10", "--heads", "4"])).unwrap();
        assert!(serve_config_from(&a, LinearKind::Standard).is_err());
        let a = Args::parse(&argv(&["--dim", "32", "--heads", "4"])).unwrap();
        let cfg = serve_config_from(&a, LinearKind::SwitchBack).unwrap();
        assert_eq!(cfg.encoder.dim, 32);
        assert_eq!(cfg.policy.max_batch, 32);
    }

    #[test]
    fn no_cache_flag_disables_cache() {
        let a = Args::parse(&argv(&["--no-cache"])).unwrap();
        let cfg = serve_config_from(&a, LinearKind::SwitchBack).unwrap();
        assert_eq!(cfg.cache_capacity, 0);
    }

    #[test]
    fn optimizer_csv_parses_and_rejects() {
        let opts = csv_list::<OptimizerKind>("adamw, stable_adamw", "o").unwrap();
        assert_eq!(opts, vec![OptimizerKind::Adamw, OptimizerKind::StableAdamw]);
        assert!(csv_list::<OptimizerKind>("adamw,bogus", "o").is_err());
    }

    #[test]
    fn benchdiff_requires_two_paths() {
        let a = Args::parse(&argv(&["only_one.json"])).unwrap();
        let err = cmd_benchdiff(&a).unwrap_err();
        assert!(err.to_string().contains("two paths"), "{err}");
        let a = Args::parse(&argv(&["a.json", "b.json", "--tol", "2.0"])).unwrap();
        let err = cmd_benchdiff(&a).unwrap_err();
        assert!(err.to_string().contains("--tol"), "{err}");
    }

    #[test]
    fn train_rejects_unknown_scenario() {
        let a = Args::parse(&argv(&["bogus-scenario"])).unwrap();
        let err = cmd_train(&a).unwrap_err();
        assert!(err.to_string().contains("unknown scenario"), "{err}");
    }

    #[test]
    fn train_bool_flags_are_known() {
        let a = Args::parse(&argv(&["--assert-improves", "--strict", "--with-shifts"]))
            .unwrap();
        assert!(a.has("--assert-improves"));
        assert!(a.has("--strict"));
    }

    #[test]
    fn ckpt_flags_validate() {
        // --ckpt-every without --ckpt-dir is a hard error
        let a = Args::parse(&argv(&[
            "--ckpt-every",
            "10",
            "--kind",
            "switchback",
            "--steps",
            "2",
        ]))
        .unwrap();
        let err = cmd_train(&a).unwrap_err();
        assert!(err.to_string().contains("--ckpt-dir"), "{err}");
        // snapshotting a multi-run matrix is rejected up front
        let a = Args::parse(&argv(&[
            "--ckpt-every",
            "10",
            "--ckpt-dir",
            "/tmp/nowhere",
            "--kinds",
            "standard,switchback",
            "--steps",
            "2",
        ]))
        .unwrap();
        let err = cmd_train(&a).unwrap_err();
        assert!(err.to_string().contains("single"), "{err}");
        // resume from a nonexistent path fails with a clear message
        let a = Args::parse(&argv(&["--resume", "/nonexistent/ckpts"])).unwrap();
        let err = cmd_train(&a).unwrap_err();
        assert!(err.to_string().contains("does not exist"), "{err}");
        // flags the checkpoint fixes are rejected, not silently dropped
        let a = Args::parse(&argv(&[
            "--resume",
            "/nonexistent/ckpts",
            "--steps",
            "200",
        ]))
        .unwrap();
        let err = cmd_train(&a).unwrap_err();
        assert!(err.to_string().contains("--steps conflicts"), "{err}");
        let a = Args::parse(&argv(&[
            "--resume",
            "/nonexistent/ckpts",
            "--with-shifts",
        ]))
        .unwrap();
        let err = cmd_train(&a).unwrap_err();
        assert!(err.to_string().contains("--with-shifts conflicts"), "{err}");
    }

    #[test]
    fn ckpt_shard_and_async_flags_validate() {
        // --ckpt-async without a snapshot cadence is a hard error
        let a = Args::parse(&argv(&[
            "--ckpt-async",
            "--kind",
            "switchback",
            "--steps",
            "2",
        ]))
        .unwrap();
        let err = cmd_train(&a).unwrap_err();
        assert!(err.to_string().contains("--ckpt-every"), "{err}");
        // --ckpt-shards 0 is rejected
        let a = Args::parse(&argv(&[
            "--ckpt-shards",
            "0",
            "--kind",
            "switchback",
            "--steps",
            "2",
        ]))
        .unwrap();
        let err = cmd_train(&a).unwrap_err();
        assert!(err.to_string().contains("--ckpt-shards"), "{err}");
        // pipeline validates its shard count too
        let a = Args::parse(&argv(&["--ckpt-shards", "0"])).unwrap();
        let err = cmd_pipeline(&a).unwrap_err();
        assert!(err.to_string().contains("--ckpt-shards"), "{err}");
        // …and both are accepted on --resume (run-control), failing later
        // only because the checkpoint path does not exist
        let a = Args::parse(&argv(&[
            "--resume",
            "/nonexistent/ckpts",
            "--ckpt-shards",
            "4",
            "--ckpt-async",
        ]))
        .unwrap();
        let err = cmd_train(&a).unwrap_err();
        assert!(err.to_string().contains("does not exist"), "{err}");
    }

    #[test]
    fn ckpt_subcommand_usage_errors() {
        let a = Args::parse(&argv(&[])).unwrap();
        assert!(cmd_ckpt(&a).unwrap_err().to_string().contains("usage"));
        let a = Args::parse(&argv(&["inspect"])).unwrap();
        assert!(cmd_ckpt(&a).unwrap_err().to_string().contains("missing"));
        let a = Args::parse(&argv(&["diff", "only_one"])).unwrap();
        assert!(cmd_ckpt(&a).unwrap_err().to_string().contains("two paths"));
    }

    #[test]
    fn probe_validates_args_and_fails_fast_on_dead_target() {
        let a = Args::parse(&argv(&[])).unwrap();
        let err = cmd_probe(&a).unwrap_err();
        assert!(err.to_string().contains("missing <url>"), "{err}");
        let a = Args::parse(&argv(&[
            "http://127.0.0.1:1/healthz",
            "--follow",
            "0",
        ]))
        .unwrap();
        let err = cmd_probe(&a).unwrap_err();
        assert!(err.to_string().contains("--follow"), "{err}");
        // nothing listens on the discard port: a single-shot probe fails
        // with the connect error, not a hang or a panic
        let a = Args::parse(&argv(&["http://127.0.0.1:1/healthz"])).unwrap();
        let err = cmd_probe(&a).unwrap_err();
        assert!(err.to_string().contains("not OK"), "{err}");
        // non-http schemes are rejected by the client
        let a = Args::parse(&argv(&["https://example.com/"])).unwrap();
        assert!(cmd_probe(&a).is_err());
    }

    #[test]
    fn telemetry_and_scrape_flags_parse() {
        let a = Args::parse(&argv(&[
            "--telemetry-addr",
            "127.0.0.1:0",
            "--scrape-every",
            "5",
            "--scrape-url",
            "http://127.0.0.1:9/metrics",
            "--hold-ms",
            "10",
            "--expect",
            "\"ready\":true",
            "--follow",
            "3",
            "--every",
            "50",
        ]))
        .unwrap();
        assert_eq!(
            a.flags.get("telemetry-addr").map(String::as_str),
            Some("127.0.0.1:0")
        );
        assert_eq!(a.get::<u64>("scrape-every", 0).unwrap(), 5);
        assert_eq!(a.get::<u64>("hold-ms", 0).unwrap(), 10);
        assert_eq!(a.get::<u32>("follow", 1).unwrap(), 3);
    }

    #[test]
    fn socket_and_frontend_flags_parse() {
        let a = Args::parse(&argv(&[
            "--listen",
            "127.0.0.1:0",
            "--engines",
            "3",
            "--max-inflight",
            "8",
            "--socket",
            "127.0.0.1:9",
        ]))
        .unwrap();
        assert_eq!(a.flags.get("listen").map(String::as_str), Some("127.0.0.1:0"));
        assert_eq!(a.get::<usize>("engines", 2).unwrap(), 3);
        assert_eq!(a.get::<usize>("max-inflight", 32).unwrap(), 8);
        assert_eq!(a.flags.get("socket").map(String::as_str), Some("127.0.0.1:9"));
    }

    #[test]
    fn serve_rejects_fleet_without_front_door() {
        // a multi-engine fleet only makes sense behind --listen
        let a = Args::parse(&argv(&["--engines", "3"])).unwrap();
        let err = cmd_serve(&a).unwrap_err();
        assert!(err.to_string().contains("--listen"), "{err}");
        let a = Args::parse(&argv(&["--engines", "0", "--listen", "127.0.0.1:0"]))
            .unwrap();
        let err = cmd_serve(&a).unwrap_err();
        assert!(err.to_string().contains("--engines"), "{err}");
    }

    #[test]
    fn pipeline_validates_args() {
        let a = Args::parse(&argv(&["--steps", "2"])).unwrap();
        let err = cmd_pipeline(&a).unwrap_err();
        assert!(err.to_string().contains("--steps"), "{err}");
        let a = Args::parse(&argv(&["--kind", "bogus"])).unwrap();
        assert!(cmd_pipeline(&a).is_err());
        let a = Args::parse(&argv(&["--requests", "0"])).unwrap();
        let err = cmd_pipeline(&a).unwrap_err();
        assert!(err.to_string().contains("--requests"), "{err}");
    }
}
