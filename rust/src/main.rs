//! `switchback` — CLI for the SwitchBack + StableAdamW reproduction.
//!
//! Subcommands:
//! * `train [--kinds A,B --optimizers X,Y ...]` — native end-to-end CLIP
//!   training on the measured-speed substrate; writes BENCH_train.json
//! * `train-aot <artifact> [...]`    — one AOT training run  (pjrt)
//! * `exp <name> | --list | --all`   — regenerate a paper figure  (pjrt)
//! * `info <artifact>`               — inspect an artifact manifest  (pjrt)
//! * `serve [--kind K ...]`          — serving-engine smoke run
//! * `loadgen [--requests N ...]`    — closed-loop serving benchmark,
//!   writes BENCH_serve.json
//! * `benchdiff <baseline> <new>`    — bench-regression gate over the
//!   BENCH_*.json artifacts (the CI gate behind scripts/check_bench.sh)
//!
//! `train-aot`/`exp`/`info` execute AOT artifacts and need the `pjrt`
//! cargo feature; everything else runs entirely on the native substrate.
//!
//! Argument parsing is hand-rolled (offline build: no clap) — see
//! `rust/src/util` for the other in-tree substrates.

use anyhow::{bail, Result};
use std::collections::HashMap;
use switchback::config::OptimizerKind;
use switchback::coordinator::common::spike_shifts;
use switchback::coordinator::registry;
use switchback::nn::LinearKind;
use switchback::serve::{
    run_loadgen, write_bench_json, BatchPolicy, EncodeInput, EncoderConfig, Engine,
    LoadgenConfig, ServeConfig,
};
use switchback::tensor::Rng;
use switchback::train::{write_bench_train_json, NativeTrainConfig, NativeTrainer};
use switchback::util::json;
use switchback::util::regression::{compare_bench, DEFAULT_TOLERANCE};

#[cfg(feature = "pjrt")]
use switchback::config::{ScalerKind, TrainConfig};
#[cfg(feature = "pjrt")]
use switchback::coordinator::experiments::{self, ExpCtx};
#[cfg(feature = "pjrt")]
use switchback::coordinator::Trainer;
#[cfg(feature = "pjrt")]
use switchback::data::Shift;
#[cfg(feature = "pjrt")]
use switchback::runtime::Runtime;

const USAGE: &str = "\
switchback — Stable and low-precision training for large-scale vision-language
models (NeurIPS 2023), rust+JAX+Pallas reproduction.

USAGE:
  switchback train [scenario] [OPTIONS]     native end-to-end CLIP training
                                            (kinds × optimizers matrix,
                                            writes BENCH_train.json)
  switchback train --list                   list native scenarios
  switchback train-aot <artifact> [OPTIONS] one AOT training run    [pjrt]
  switchback exp <name> [OPTIONS]           regenerate a paper figure [pjrt]
  switchback exp --list                     list experiments        [pjrt]
  switchback exp --all [--steps N]          run every experiment    [pjrt]
  switchback info <artifact>                inspect an artifact manifest [pjrt]
  switchback serve [OPTIONS]                serving-engine smoke run
  switchback loadgen [OPTIONS]              closed-loop serving benchmark
  switchback benchdiff <baseline> <new>     bench-regression gate
                                            [--tol X --strict]

TRAIN OPTIONS (native):
  --steps N              (default: 200)
  --batch N              examples per step (default: 32)
  --kinds A,B,...        precision kinds to run (default:
                         switchback,standard)
  --optimizers A,B,...   adamw | stable_adamw | lion
                         (default: stable_adamw)
  --shards N             data-parallel gradient-accumulation shards
                         (default: 4; partition is thread-count
                         independent — workers via SWITCHBACK_THREADS)
  --warmup N             (default: steps/4)
  --lr X                 (default: 1e-3)
  --weight-decay X       (default: 0.1)
  --beta1 X --beta2 X    (defaults: 0.9, 0.999)
  --beta2-lambda X       β₂ schedule 1−t^−λ (off by default)
  --grad-clip X          global-norm clipping (off by default)
  --seed N               (default: 42)
  --with-shifts          inject the stuck-in-the-past shift schedule
                         (the spike scenario)
  --eval-per-concept N   final zero-shot eval size (default: 2, 0=off)
  --metrics PATH         write per-run JSONL metrics
  --out PATH             report path (default: BENCH_train.json)
  --assert-improves      exit nonzero unless every run's loss decreased
  --dim/--heads/--blocks/--embed-dim/--patches/--patch-dim/--text-seq/--vocab
                         model shape (defaults: 64/4/2/32, 8/32/8/256)
  --quiet

TRAIN-AOT OPTIONS:
  --artifact-dir DIR     (default: artifacts)
  --steps N              (default: 300)
  --warmup N             (default: steps/4)
  --lr X                 (default: 2e-3)
  --weight-decay X       (default: 0.2)
  --beta1 X --beta2 X    (defaults: 0.9, 0.999)
  --optimizer K          adamw | stable_adamw | lion (default: stable_adamw)
  --grad-clip X          global-norm clipping (off by default)
  --scaler K             none | dynamic_global | fixed_tensor (default: none)
  --seed N               (default: 0 = exact jax init)
  --metrics PATH         write JSONL metrics
  --with-shifts          inject the stuck-in-the-past shift schedule
  --quiet

EXP OPTIONS:
  --steps N              override per-experiment default step count
  --out-dir DIR          (default: results)
  --verbose

SERVE / LOADGEN OPTIONS:
  --kind K               standard | switchback | switchback_m | llmint8
                         (serve; default: switchback)
  --kinds A,B,...        precision kinds to sweep (loadgen;
                         default: standard,switchback)
  --requests N           total requests per run, k/m suffixes ok
                         (default: 2000)
  --concurrency A,B,...  closed-loop client counts to sweep (default: 32)
  --population N         distinct inputs (default: requests/2)
  --image-fraction X     image share of the population (default: 0.7)
  --batch-max N          micro-batch cap (default: 32)
  --wait-us N            micro-batch max wait, µs (default: 2000)
  --workers N            batch workers (default: auto)
  --cache-capacity N     embedding-cache entries (default: fits the
                         loadgen population, min 8192)
  --no-cache             disable the embedding cache
  --out PATH             loadgen report path (default: BENCH_serve.json)
  --dim N --heads N --blocks N --embed-dim N
  --patches N --patch-dim N --text-seq N --vocab N
                         serving model shape (defaults: 128/4/2/64,
                         16/64/16/512)
  --seed N               model + population seed (default: 42)
";

/// Every `--key value` flag any subcommand accepts.  The parser rejects
/// flags outside this list and [`BOOL_FLAGS`] instead of silently eating
/// the next positional as a value (the classic `--quite` typo bug).
const VALUE_FLAGS: &[&str] = &[
    "--artifact-dir",
    "--steps",
    "--batch",
    "--shards",
    "--warmup",
    "--lr",
    "--weight-decay",
    "--beta1",
    "--beta2",
    "--beta2-lambda",
    "--optimizer",
    "--optimizers",
    "--grad-clip",
    "--scaler",
    "--seed",
    "--metrics",
    "--eval-per-concept",
    "--out-dir",
    "--kind",
    "--kinds",
    "--requests",
    "--concurrency",
    "--population",
    "--image-fraction",
    "--batch-max",
    "--wait-us",
    "--workers",
    "--cache-capacity",
    "--out",
    "--tol",
    "--dim",
    "--heads",
    "--blocks",
    "--embed-dim",
    "--patches",
    "--patch-dim",
    "--text-seq",
    "--vocab",
];

const BOOL_FLAGS: &[&str] = &[
    "--list",
    "--all",
    "--verbose",
    "--quiet",
    "--with-shifts",
    "--no-cache",
    "--assert-improves",
    "--strict",
    "-v",
    "-q",
];

/// Tiny flag parser: positionals + `--key value` + boolean `--key`.
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut positional = vec![];
        let mut flags = HashMap::new();
        let mut bools = vec![];
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a.starts_with('-') {
                if BOOL_FLAGS.contains(&a.as_str()) {
                    bools.push(a.clone());
                } else if VALUE_FLAGS.contains(&a.as_str()) {
                    let Some(v) = argv.get(i + 1) else {
                        bail!("flag {a} expects a value");
                    };
                    flags.insert(a.trim_start_matches('-').to_string(), v.clone());
                    i += 1;
                } else {
                    bail!("unknown flag {a} (see `switchback help`)");
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Self { positional, flags, bools })
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("bad value for --{key}: {v:?}")),
        }
    }

    fn opt<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>> {
        match self.flags.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("bad value for --{key}: {v:?}")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key)
    }

    /// A count flag accepting `k`/`m` suffixes (`--requests 10k`).
    fn count(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => parse_count(v)
                .ok_or_else(|| anyhow::anyhow!("bad value for --{key}: {v:?}")),
        }
    }
}

/// Parse a non-negative count with an optional `k`/`m` suffix.
fn parse_count(s: &str) -> Option<usize> {
    let t = s.trim();
    let (digits, mult) = match t.chars().last() {
        Some('k') | Some('K') => (&t[..t.len() - 1], 1000usize),
        Some('m') | Some('M') => (&t[..t.len() - 1], 1_000_000),
        _ => (t, 1),
    };
    digits.parse::<usize>().ok().and_then(|v| v.checked_mul(mult))
}

#[cfg(feature = "pjrt")]
fn cmd_train_aot(args: &Args) -> Result<()> {
    let Some(artifact) = args.positional.first() else {
        bail!("train-aot: missing <artifact> (e.g. switchback_int8_small_b32)");
    };
    let steps: u64 = args.get("steps", 300)?;
    let seed: u64 = args.get("seed", 0)?;
    let optimizer = args
        .flags
        .get("optimizer")
        .map(|s| OptimizerKind::parse(s).ok_or_else(|| anyhow::anyhow!("bad optimizer {s}")))
        .transpose()?
        .unwrap_or(OptimizerKind::StableAdamw);
    let scaler = args
        .flags
        .get("scaler")
        .map(|s| ScalerKind::parse(s).ok_or_else(|| anyhow::anyhow!("bad scaler {s}")))
        .transpose()?
        .unwrap_or(ScalerKind::None);
    let cfg = TrainConfig {
        artifact: artifact.clone(),
        artifact_dir: args.get("artifact-dir", "artifacts".to_string())?,
        steps,
        warmup: args.get("warmup", steps / 4)?,
        lr: args.get("lr", 2e-3)?,
        weight_decay: args.get("weight-decay", 0.2)?,
        beta1: args.get("beta1", 0.9)?,
        beta2: args.get("beta2", 0.999)?,
        optimizer,
        beta2_lambda: args.opt("beta2-lambda")?,
        grad_clip: args.opt("grad-clip")?,
        scaler,
        seed,
        reinit: seed != 0,
        shifts: if args.has("--with-shifts") {
            vec![
                Shift { at_step: steps * 55 / 100, image_gain: 6.0, remap_concepts: false },
                Shift { at_step: steps * 75 / 100, image_gain: 1.0 / 6.0, remap_concepts: true },
            ]
        } else {
            vec![]
        },
        probe_every: 1,
        metrics_path: args.flags.get("metrics").cloned(),
        eval_every: 0,
        eval_per_concept: 4,
    };
    let runtime = Runtime::cpu()?;
    println!("platform: {}", runtime.platform());
    println!("config  : {}", cfg.to_json());
    let mut trainer = Trainer::new(&runtime, cfg)?;
    let res = trainer.run(!args.has("--quiet") && !args.has("-q"))?;
    println!(
        "done: final loss {:.4}, tail loss {:.4}, zero-shot acc {}, {:.1} steps/s{}",
        res.final_loss,
        res.tail_loss,
        res.zero_shot_acc
            .map(|v| format!("{:.1}%", v * 100.0))
            .unwrap_or_else(|| "n/a".into()),
        res.steps_per_sec,
        if res.diverged { " [DIVERGED]" } else { "" },
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_exp(args: &Args) -> Result<()> {
    if args.has("--list") || (args.positional.is_empty() && !args.has("--all")) {
        println!("available experiments:");
        for (name, desc) in experiments::list() {
            println!("  {name:<16} {desc}");
        }
        return Ok(());
    }
    let ctx = ExpCtx::new(
        Runtime::cpu()?,
        args.get("steps", 0)?,
        args.get("out-dir", "results".to_string())?,
        args.has("--verbose") || args.has("-v"),
    );
    if args.has("--all") {
        for (name, _) in experiments::list() {
            println!("\n########## {name} ##########");
            experiments::run_experiment(&ctx, name)?;
        }
    } else {
        experiments::run_experiment(&ctx, &args.positional[0])?;
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_info(args: &Args) -> Result<()> {
    let Some(artifact) = args.positional.first() else {
        bail!("info: missing <artifact>");
    };
    let dir: String = args.get("artifact-dir", "artifacts".to_string())?;
    let runtime = Runtime::cpu()?;
    let art = runtime.load(&dir, artifact)?;
    let m = &art.manifest;
    println!("artifact : {}", m.name);
    println!("variant  : {}   size: {}   batch: {}", m.variant, m.size, m.batch);
    println!(
        "model    : dim {} / vision {}x / text {}x / heads {} / layer_scale {}",
        m.config.dim, m.config.vision_blocks, m.config.text_blocks, m.config.heads,
        m.config.layer_scale
    );
    println!("tensors  : {}   params: {}", m.n_tensors, m.n_params);
    let (pe, mid) = art.probe_indices();
    println!(
        "probes   : patch_embed = {}, mid control = {}",
        m.tensors[pe].name, m.tensors[mid].name
    );
    Ok(())
}

/// Native end-to-end training: the kinds × optimizers scenario on the
/// measured-speed substrate (no PJRT).  The default run is the paper's
/// acceptance story — SwitchBack vs Standard under StableAdamW; add
/// `--with-shifts --optimizers adamw,stable_adamw` for the spike
/// comparison.  Writes BENCH_train.json.
fn cmd_train(args: &Args) -> Result<()> {
    if args.has("--list") {
        println!("native training scenarios (no pjrt; `switchback train <name>`):");
        for e in registry::native_scenarios() {
            println!("  {:<14} {}", e.name, e.desc);
        }
        println!("\n(`switchback exp --list` shows the PJRT figure experiments)");
        return Ok(());
    }
    // an optional scenario name (from coordinator::registry) presets the
    // run matrix; explicit flags still override
    let scenario = match args.positional.first().map(String::as_str) {
        None => None,
        Some(name) => {
            if !registry::native_scenarios().iter().any(|e| e.name == name) {
                bail!("unknown scenario {name:?} — see `switchback train --list`");
            }
            Some(name)
        }
    };
    let steps: u64 =
        args.get("steps", if scenario == Some("train-smoke") { 50 } else { 200 })?;
    if steps == 0 {
        bail!("--steps must be at least 1");
    }
    let kinds: Vec<LinearKind> = match args.flags.get("kind") {
        Some(k) => vec![k.parse().map_err(|e: String| anyhow::anyhow!("{e}"))?],
        None => {
            let s: String = args.get("kinds", "switchback,standard".to_string())?;
            csv_list(&s, "--kinds")?
        }
    };
    if kinds.is_empty() {
        bail!("--kinds must name at least one precision kind");
    }
    let opts_s: String = args.get("optimizers", String::new())?;
    let optimizers: Vec<OptimizerKind> = if !opts_s.is_empty() {
        csv_list(&opts_s, "--optimizers")?
    } else if let Some(o) = args.flags.get("optimizer") {
        vec![o.parse().map_err(|e: String| anyhow::anyhow!("{e}"))?]
    } else if scenario == Some("train-spikes") {
        vec![OptimizerKind::Adamw, OptimizerKind::StableAdamw]
    } else {
        vec![OptimizerKind::StableAdamw]
    };
    if optimizers.is_empty() {
        bail!("--optimizers must name at least one optimizer");
    }
    let with_shifts = args.has("--with-shifts") || scenario == Some("train-spikes");
    let assert_improves =
        args.has("--assert-improves") || scenario == Some("train-smoke");
    let out: String = args.get("out", "BENCH_train.json".to_string())?;
    let verbose = !args.has("--quiet") && !args.has("-q");
    let multi = kinds.len() * optimizers.len() > 1;

    let build_cfg = |kind: LinearKind, optimizer: OptimizerKind| -> Result<NativeTrainConfig> {
        let mut cfg = NativeTrainConfig::preset(kind, steps);
        if scenario == Some("train-smoke") {
            // the verify.sh smoke shape: small dims, seconds not minutes
            cfg.batch = 16;
            cfg.encoder.dim = 32;
            cfg.encoder.blocks = 1;
            cfg.encoder.embed_dim = 16;
            cfg.encoder.patch_dim = 16;
            cfg.encoder.vocab = 128;
        }
        cfg.hyper.warmup = args.get("warmup", steps / 4)?;
        if cfg.hyper.warmup > steps {
            bail!("--warmup must not exceed --steps");
        }
        cfg.hyper.lr = args.get("lr", cfg.hyper.lr)?;
        cfg.hyper.weight_decay = args.get("weight-decay", cfg.hyper.weight_decay)?;
        cfg.hyper.beta1 = args.get("beta1", cfg.hyper.beta1)?;
        cfg.hyper.beta2 = args.get("beta2", cfg.hyper.beta2)?;
        cfg.hyper.beta2_lambda = args.opt("beta2-lambda")?;
        cfg.hyper.grad_clip = args.opt("grad-clip")?;
        cfg.hyper.optimizer = optimizer;
        cfg.hyper.seed = args.get("seed", cfg.hyper.seed)?;
        cfg.encoder.seed = cfg.hyper.seed;
        cfg.encoder.dim = args.get("dim", cfg.encoder.dim)?;
        cfg.encoder.heads = args.get("heads", cfg.encoder.heads)?;
        cfg.encoder.blocks = args.get("blocks", cfg.encoder.blocks)?;
        cfg.encoder.embed_dim = args.get("embed-dim", cfg.encoder.embed_dim)?;
        cfg.encoder.patches = args.get("patches", cfg.encoder.patches)?;
        cfg.encoder.patch_dim = args.get("patch-dim", cfg.encoder.patch_dim)?;
        cfg.encoder.text_seq = args.get("text-seq", cfg.encoder.text_seq)?;
        cfg.encoder.vocab = args.get("vocab", cfg.encoder.vocab)?;
        if cfg.encoder.dim == 0
            || cfg.encoder.heads == 0
            || cfg.encoder.dim % cfg.encoder.heads != 0
        {
            bail!("--dim must be a positive multiple of --heads");
        }
        if cfg.encoder.vocab == 0
            || cfg.encoder.text_seq == 0
            || cfg.encoder.patches == 0
            || cfg.encoder.patch_dim == 0
            || cfg.encoder.embed_dim == 0
            || cfg.encoder.blocks == 0
        {
            bail!(
                "--vocab/--text-seq/--patches/--patch-dim/--embed-dim/--blocks \
                 must be positive"
            );
        }
        cfg.batch = args.get("batch", cfg.batch)?;
        if cfg.batch == 0 {
            bail!("--batch must be at least 1");
        }
        cfg.grad_shards = args.get("shards", cfg.grad_shards)?;
        if cfg.grad_shards == 0 {
            bail!("--shards must be at least 1");
        }
        cfg.eval_per_concept = args.get("eval-per-concept", cfg.eval_per_concept)?;
        cfg.shifts = if with_shifts { spike_shifts(steps) } else { vec![] };
        cfg.metrics_path = args.flags.get("metrics").map(|base| {
            if multi {
                format!("{base}.{}_{}.jsonl", kind.label(), optimizer.label())
            } else {
                base.clone()
            }
        });
        Ok(cfg)
    };

    let mut results = vec![];
    let mut echo_cfg = None;
    for &kind in &kinds {
        for &optimizer in &optimizers {
            let cfg = build_cfg(kind, optimizer)?;
            if verbose {
                println!(
                    "== train: kind={} optimizer={} ==",
                    kind.label(),
                    optimizer.label()
                );
                println!("config: {}", cfg.to_json());
            }
            echo_cfg.get_or_insert_with(|| cfg.clone());
            let mut trainer = NativeTrainer::new(cfg);
            let res = trainer.run(verbose)?;
            res.print();
            results.push(res);
        }
    }

    // scenario summaries across the matrix
    for &optimizer in &optimizers {
        let by = |k: &str| {
            results
                .iter()
                .find(|r| r.kind == k && r.optimizer == optimizer.label())
        };
        if let (Some(sb), Some(std_r)) = (by("switchback"), by("standard")) {
            println!(
                "{}: switchback/standard steps/s ratio {:.2}×, tail-loss gap {:+.4}",
                optimizer.label(),
                sb.steps_per_sec / std_r.steps_per_sec.max(1e-9),
                sb.tail_loss - std_r.tail_loss,
            );
        }
    }
    for &kind in &kinds {
        let by = |o: &str| {
            results.iter().find(|r| r.optimizer == o && r.kind == kind.label())
        };
        if let (Some(plain), Some(stable)) = (by("adamw"), by("stable_adamw")) {
            println!(
                "{}: loss spikes adamw {} vs stable_adamw {} (paper: StableAdamW \
                 suppresses them)",
                kind.label(),
                plain.loss_spikes,
                stable.loss_spikes,
            );
        }
    }

    write_bench_train_json(&out, echo_cfg.as_ref().expect("≥1 run"), &results)?;
    println!("wrote {out}");

    if assert_improves {
        for r in &results {
            if r.diverged {
                bail!("train: {}/{} diverged", r.kind, r.optimizer);
            }
            if r.final_loss.is_nan() || r.final_loss >= r.first_loss {
                bail!(
                    "train: {}/{} loss did not decrease ({:.4} → {:.4})",
                    r.kind,
                    r.optimizer,
                    r.first_loss,
                    r.final_loss
                );
            }
        }
        println!("train smoke OK — loss decreased in every run");
    }
    Ok(())
}

/// Bench-regression gate: compare a fresh BENCH_*.json against a committed
/// baseline (see scripts/check_bench.sh and DESIGN.md §CI).
fn cmd_benchdiff(args: &Args) -> Result<()> {
    let [old_path, new_path] = args.positional.as_slice() else {
        bail!("benchdiff: expected exactly two paths: <baseline.json> <new.json>");
    };
    let tol: f64 = args.get("tol", DEFAULT_TOLERANCE)?;
    if !(0.0..1.0).contains(&tol) {
        bail!("--tol must be in [0, 1)");
    }
    let strict = args.has("--strict");
    let load = |p: &str| -> Result<json::Value> {
        let text = std::fs::read_to_string(p)
            .map_err(|e| anyhow::anyhow!("cannot read {p}: {e}"))?;
        json::parse(&text).map_err(|e| anyhow::anyhow!("cannot parse {p}: {e}"))
    };
    let old = load(old_path)?;
    let new = load(new_path)?;
    let regs = compare_bench(&old, &new, tol, strict)
        .map_err(|e| anyhow::anyhow!("benchdiff: {e}"))?;
    if regs.is_empty() {
        println!(
            "benchdiff OK — no regressions vs {old_path} (tol {:.0}%{})",
            tol * 100.0,
            if strict { ", strict" } else { "" }
        );
        Ok(())
    } else {
        for r in &regs {
            eprintln!("REGRESSION: {r}");
        }
        bail!("benchdiff: {} regression(s) vs {old_path}", regs.len());
    }
}

#[cfg(not(feature = "pjrt"))]
fn cmd_needs_pjrt(cmd: &str) -> Result<()> {
    bail!(
        "`{cmd}` executes AOT artifacts via PJRT, but this binary was built \
         without the `pjrt` feature.\nRebuild with `cargo build --release \
         --features pjrt` on a machine with the PJRT toolchain \
         (rust/Cargo.toml explains the vendor/xla swap).\nFor PJRT-free \
         end-to-end training on the native substrate, use `switchback train`."
    )
}

/// Model-shape + engine flags shared by `serve` and `loadgen`.
fn serve_config_from(args: &Args, kind: LinearKind) -> Result<ServeConfig> {
    let requests: usize = args.count("requests", 2000)?;
    let encoder = EncoderConfig {
        kind,
        dim: args.get("dim", 128)?,
        heads: args.get("heads", 4)?,
        blocks: args.get("blocks", 2)?,
        embed_dim: args.get("embed-dim", 64)?,
        patches: args.get("patches", 16)?,
        patch_dim: args.get("patch-dim", 64)?,
        text_seq: args.get("text-seq", 16)?,
        vocab: args.get("vocab", 512)?,
        seed: args.get("seed", 42)?,
    };
    if encoder.dim == 0 || encoder.heads == 0 || encoder.dim % encoder.heads != 0 {
        bail!("--dim must be a positive multiple of --heads");
    }
    if encoder.vocab == 0
        || encoder.text_seq == 0
        || encoder.patches == 0
        || encoder.patch_dim == 0
        || encoder.embed_dim == 0
    {
        bail!("--vocab/--text-seq/--patches/--patch-dim/--embed-dim must be positive");
    }
    // Same resolution as cmd_loadgen; 2× headroom because ShardedLru
    // splits capacity into per-shard caps and hash imbalance would
    // otherwise evict live population members at exactly-sized capacity.
    let population: usize = args.count("population", (requests / 2).max(1))?;
    let cache_capacity = if args.has("--no-cache") {
        0
    } else {
        args.count(
            "cache-capacity",
            8192.max(requests).max(population.saturating_mul(2)),
        )?
    };
    let max_batch: usize = args.get("batch-max", 32)?;
    if max_batch == 0 {
        bail!("--batch-max must be at least 1");
    }
    Ok(ServeConfig {
        encoder,
        policy: BatchPolicy {
            max_batch,
            max_wait: std::time::Duration::from_micros(args.get("wait-us", 2000)?),
        },
        workers: args.get("workers", 0)?,
        cache_capacity,
        cache_shards: 0,
    })
}

/// In-process smoke run of the serving engine (the network front-end is a
/// future scaling PR; the engine API is the subsystem this PR lands).
fn cmd_serve(args: &Args) -> Result<()> {
    let kind_s: String = args.get("kind", "switchback".to_string())?;
    let Some(kind) = LinearKind::parse(&kind_s) else {
        bail!("bad --kind {kind_s:?} (standard | switchback | switchback_m | llmint8)");
    };
    let cfg = serve_config_from(args, kind)?;
    let image_len = cfg.encoder.image_len();
    let text_seq = cfg.encoder.text_seq;
    let vocab = cfg.encoder.vocab;
    println!(
        "starting engine: kind={} dim={} blocks={}",
        kind.label(),
        cfg.encoder.dim,
        cfg.encoder.blocks
    );
    let engine = Engine::start(cfg);
    println!(
        "encoder resident weights: {:.1} KiB (pre-quantized at load)",
        engine.weight_bytes() as f64 / 1024.0
    );
    let mut rng = Rng::seed(7);
    let img: Vec<f32> = (0..image_len).map(|_| rng.normal()).collect();
    let toks: Vec<i32> = (0..text_seq).map(|_| rng.below(vocab) as i32).collect();
    let e1 = engine
        .encode(EncodeInput::Image(img.clone()))
        .map_err(|e| anyhow::anyhow!("image encode failed: {e}"))?;
    let e2 = engine
        .encode(EncodeInput::Text(toks))
        .map_err(|e| anyhow::anyhow!("text encode failed: {e}"))?;
    let e3 = engine
        .encode(EncodeInput::Image(img))
        .map_err(|e| anyhow::anyhow!("repeat encode failed: {e}"))?;
    println!(
        "image embedding: dim {} (first 4: {:?})",
        e1.embedding.len(),
        &e1.embedding[..4.min(e1.embedding.len())]
    );
    println!("text  embedding: dim {}", e2.embedding.len());
    if engine.cache_stats().is_some() {
        if !e3.cache_hit {
            bail!("smoke failure: repeated input did not hit the cache");
        }
        if *e3.embedding != *e1.embedding {
            bail!("smoke failure: cache returned a different embedding");
        }
        println!("repeat request served from cache (no GEMM work)");
    }
    let snap = engine.metrics().snapshot();
    snap.print(kind.label());
    engine.shutdown();
    println!("serve smoke OK");
    Ok(())
}

/// Parse a CSV list flag into typed values.
fn csv_list<T: std::str::FromStr>(s: &str, what: &str) -> Result<Vec<T>> {
    s.split(',')
        .filter(|p| !p.is_empty())
        .map(|p| {
            p.trim()
                .parse::<T>()
                .map_err(|_| anyhow::anyhow!("bad {what} entry {p:?}"))
        })
        .collect()
}

fn cmd_loadgen(args: &Args) -> Result<()> {
    let kinds_s: String = args.get("kinds", "standard,switchback".to_string())?;
    let kinds: Vec<LinearKind> = csv_list(&kinds_s, "--kinds")?;
    if kinds.is_empty() {
        bail!("--kinds must name at least one precision kind");
    }
    let requests: usize = args.count("requests", 2000)?;
    let conc_s: String = args.get("concurrency", "32".to_string())?;
    let concurrencies: Vec<usize> = csv_list(&conc_s, "--concurrency")?;
    if concurrencies.is_empty() || concurrencies.contains(&0) {
        bail!("--concurrency must list positive client counts");
    }
    let population: usize = args.count("population", (requests / 2).max(1))?;
    if population == 0 {
        bail!("--population must be positive");
    }
    let image_fraction: f32 = args.get("image-fraction", 0.7)?;
    let out: String = args.get("out", "BENCH_serve.json".to_string())?;
    let seed: u64 = args.get("seed", 42)?;

    let mut reports = vec![];
    let mut policy_echo = (0usize, 0u64);
    for &kind in &kinds {
        for &concurrency in &concurrencies {
            // fresh engine per run: cold cache, clean metrics
            let cfg = serve_config_from(args, kind)?;
            policy_echo =
                (cfg.policy.max_batch, cfg.policy.max_wait.as_micros() as u64);
            let engine = Engine::start(cfg);
            let lg = LoadgenConfig {
                requests,
                concurrency,
                population,
                image_fraction,
                seed,
            };
            let report = run_loadgen(&engine, &lg);
            report.print();
            if report.errors > 0 {
                bail!("loadgen: {} requests failed", report.errors);
            }
            reports.push(report);
            engine.shutdown();
        }
    }

    // the acceptance ratio: int8 serving vs the f32 baseline
    for &concurrency in &concurrencies {
        let rps = |label: &str| {
            reports
                .iter()
                .find(|r| r.kind == label && r.concurrency == concurrency)
                .map(|r| r.requests_per_sec)
        };
        if let (Some(std_rps), Some(sb_rps)) = (rps("standard"), rps("switchback")) {
            println!(
                "c={concurrency}: switchback/standard throughput ratio: {:.2}×",
                sb_rps / std_rps
            );
        }
    }
    write_bench_json(&out, policy_echo.0, policy_echo.1, &reports)?;
    println!("wrote {out}");
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "train" => cmd_train(&args),
        #[cfg(feature = "pjrt")]
        "train-aot" => cmd_train_aot(&args),
        #[cfg(feature = "pjrt")]
        "exp" => cmd_exp(&args),
        #[cfg(feature = "pjrt")]
        "info" => cmd_info(&args),
        #[cfg(not(feature = "pjrt"))]
        "train-aot" | "exp" | "info" => cmd_needs_pjrt(&cmd),
        "serve" => cmd_serve(&args),
        "loadgen" => cmd_loadgen(&args),
        "benchdiff" => cmd_benchdiff(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|v| v.to_string()).collect()
    }

    #[test]
    fn parses_positionals_value_flags_and_bools_mixed() {
        let a = Args::parse(&argv(&[
            "my_artifact",
            "--steps",
            "50",
            "--quiet",
            "--lr",
            "1e-3",
            "second_pos",
            "-v",
        ]))
        .unwrap();
        assert_eq!(a.positional, vec!["my_artifact", "second_pos"]);
        assert_eq!(a.get::<u64>("steps", 0).unwrap(), 50);
        assert_eq!(a.get::<f32>("lr", 0.0).unwrap(), 1e-3);
        assert!(a.has("--quiet"));
        assert!(a.has("-v"));
        assert!(!a.has("--all"));
    }

    #[test]
    fn unknown_boolean_flag_is_rejected_not_swallowed() {
        // the old parser treated `--quite` (typo) as a value flag and ate
        // the following positional — it must be a hard error instead
        let err = Args::parse(&argv(&["--quite", "my_artifact"])).unwrap_err();
        assert!(err.to_string().contains("unknown flag --quite"), "{err}");
        let err = Args::parse(&argv(&["--bogus"])).unwrap_err();
        assert!(err.to_string().contains("unknown flag"), "{err}");
    }

    #[test]
    fn value_flag_at_end_without_value_errors() {
        let err = Args::parse(&argv(&["art", "--steps"])).unwrap_err();
        assert!(err.to_string().contains("expects a value"), "{err}");
    }

    #[test]
    fn value_flag_consumes_exactly_one_token() {
        let a = Args::parse(&argv(&["--steps", "10", "pos"])).unwrap();
        assert_eq!(a.positional, vec!["pos"]);
        assert_eq!(a.flags.get("steps").map(String::as_str), Some("10"));
    }

    #[test]
    fn bad_typed_value_reports_flag_name() {
        let a = Args::parse(&argv(&["--steps", "abc"])).unwrap();
        let err = a.get::<u64>("steps", 0).unwrap_err();
        assert!(err.to_string().contains("--steps"), "{err}");
    }

    #[test]
    fn count_suffixes() {
        assert_eq!(parse_count("123"), Some(123));
        assert_eq!(parse_count("10k"), Some(10_000));
        assert_eq!(parse_count("2K"), Some(2_000));
        assert_eq!(parse_count("1m"), Some(1_000_000));
        assert_eq!(parse_count("x"), None);
        assert_eq!(parse_count("10kk"), None);
        let a = Args::parse(&argv(&["--requests", "10k"])).unwrap();
        assert_eq!(a.count("requests", 0).unwrap(), 10_000);
    }

    #[test]
    fn csv_list_parses_and_rejects() {
        assert_eq!(csv_list::<usize>("8,32, 64", "c").unwrap(), vec![8, 32, 64]);
        assert!(csv_list::<usize>("8,x", "c").is_err());
        assert!(csv_list::<usize>("", "c").unwrap().is_empty());
        let kinds = csv_list::<LinearKind>("standard, switchback", "k").unwrap();
        assert_eq!(kinds, vec![LinearKind::Standard, LinearKind::SwitchBack]);
        assert!(csv_list::<LinearKind>("standard,bogus", "k").is_err());
    }

    #[test]
    fn serve_config_validates_shape() {
        let a = Args::parse(&argv(&["--dim", "10", "--heads", "4"])).unwrap();
        assert!(serve_config_from(&a, LinearKind::Standard).is_err());
        let a = Args::parse(&argv(&["--dim", "32", "--heads", "4"])).unwrap();
        let cfg = serve_config_from(&a, LinearKind::SwitchBack).unwrap();
        assert_eq!(cfg.encoder.dim, 32);
        assert_eq!(cfg.policy.max_batch, 32);
    }

    #[test]
    fn no_cache_flag_disables_cache() {
        let a = Args::parse(&argv(&["--no-cache"])).unwrap();
        let cfg = serve_config_from(&a, LinearKind::SwitchBack).unwrap();
        assert_eq!(cfg.cache_capacity, 0);
    }

    #[test]
    fn optimizer_csv_parses_and_rejects() {
        let opts = csv_list::<OptimizerKind>("adamw, stable_adamw", "o").unwrap();
        assert_eq!(opts, vec![OptimizerKind::Adamw, OptimizerKind::StableAdamw]);
        assert!(csv_list::<OptimizerKind>("adamw,bogus", "o").is_err());
    }

    #[test]
    fn benchdiff_requires_two_paths() {
        let a = Args::parse(&argv(&["only_one.json"])).unwrap();
        let err = cmd_benchdiff(&a).unwrap_err();
        assert!(err.to_string().contains("two paths"), "{err}");
        let a = Args::parse(&argv(&["a.json", "b.json", "--tol", "2.0"])).unwrap();
        let err = cmd_benchdiff(&a).unwrap_err();
        assert!(err.to_string().contains("--tol"), "{err}");
    }

    #[test]
    fn train_rejects_unknown_scenario() {
        let a = Args::parse(&argv(&["bogus-scenario"])).unwrap();
        let err = cmd_train(&a).unwrap_err();
        assert!(err.to_string().contains("unknown scenario"), "{err}");
    }

    #[test]
    fn train_bool_flags_are_known() {
        let a = Args::parse(&argv(&["--assert-improves", "--strict", "--with-shifts"]))
            .unwrap();
        assert!(a.has("--assert-improves"));
        assert!(a.has("--strict"));
    }
}
