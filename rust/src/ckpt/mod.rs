//! `ckpt` — versioned checkpoint/restore for the native training path
//! (DESIGN.md §Checkpoint).
//!
//! A checkpoint captures *everything* a training step's math depends on,
//! so `train --resume` continues **bit-identically** with an uninterrupted
//! run (tested in [`crate::train`]):
//!
//! * model parameters (the [`crate::train::ClipTrainModel`] flat layout,
//!   including the logit scale),
//! * optimizer state ([`crate::optim::OptimizerState`]: AdamW/StableAdamW
//!   first+second moments and the debiasing counter, Lion momentum),
//! * the data-stream cursor ([`crate::data::DataCursor`]: RNG words,
//!   Box–Muller spare, applied shift effects, step counter),
//! * the run's schedule/hyper echo (steps, warmup, lr, optimizer, seed,
//!   shift schedule) so a resume can rebuild the exact LR cosine and the
//!   un-fired tail of the shift schedule — and fail closed on mismatch.
//!
//! On-disk formats ([`format`]): **v1** is a single file — magic +
//! version, a JSON manifest (via the in-tree [`crate::util::json`] writer
//! — human-inspectable with any JSON tool), then raw little-endian f32
//! tensor blobs, each CRC-32-checked ([`crate::util::crc32`]).  **v2** is
//! a *manifest-of-shards directory*: tensors grouped into per-shard blob
//! files written and read in parallel ([`crate::util::threads`]), each
//! with a whole-file CRC-32, plus a root manifest committed last so a
//! snapshot is visible only when complete.  All writes go through
//! temp + rename, so a crash mid-snapshot never corrupts an existing
//! checkpoint; `load`/`peek`/`inspect`/`diff` accept either version
//! interchangeably.
//!
//! **Background saves** ([`background::AsyncSaver`]): the trainer can
//! hand a step-boundary state capture to a dedicated saver thread
//! (`train --ckpt-async`) so the step loop never blocks on disk; the
//! saver registers every in-flight path so [`prune_snapshots_guarded`]
//! can never delete a snapshot that is still being written, and saves are
//! bit-identical to their synchronous counterparts.
//!
//! The same artifact feeds the serving path: [`encoder_weights`] reshapes
//! a checkpoint's parameter vector into [`crate::serve::EncoderWeights`],
//! which `serve --weights` loads at boot and the engine's
//! `install_encoder` hot-swaps live (re-quantized for whatever
//! [`crate::nn::LinearKind`] serving runs at).
//!
//! Consumers:
//! * `train --ckpt-every/--ckpt-dir/--resume` — periodic snapshots with
//!   retention + bit-identical resume (`crate::train::NativeTrainer`),
//! * the trainer's **spike-rollback guard** (`--rollback-on-spike`),
//!   which restores the last in-memory snapshot when the loss spikes and
//!   skips the offending shard window,
//! * `serve --weights` / `switchback pipeline` — load-at-boot + live
//!   hot-swap, benchmarked in `BENCH_ckpt.json`,
//! * the serve-side **warm-standby watcher** ([`crate::serve::standby`]),
//!   which uses [`peek`] to pick the newest compatible snapshot in a
//!   watched directory (manifest-only read, no tensor I/O) before paying
//!   for the full CRC-checked [`load`],
//! * `ckpt inspect` / `ckpt diff` ([`inspect`]).

pub mod background;
pub mod format;
pub mod inspect;

pub use background::{AsyncSaver, SaveTotals};
pub use format::{
    load, peek, save, save_sharded, CkptPeek, IoStats, TrainCheckpoint,
    FORMAT_VERSION, FORMAT_VERSION_V2, MANIFEST_FILE,
};

use crate::serve::{EncoderConfig, EncoderWeights};
use crate::tensor::Matrix;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashSet;
use std::path::{Path, PathBuf};

/// Canonical snapshot filename inside a checkpoint directory.
pub fn snapshot_filename(step: u64) -> String {
    format!("ckpt-{step:08}.sbck")
}

/// `dir/ckpt-<step>.sbck`.
pub fn snapshot_path(dir: &Path, step: u64) -> PathBuf {
    dir.join(snapshot_filename(step))
}

/// All snapshots in `dir`, sorted by step ascending.  Matches both v1
/// files and v2 shard directories (same `ckpt-<step>.sbck` name); `.tmp`
/// staging entries never match the suffix and are therefore invisible.
pub fn list_snapshots(dir: &Path) -> Vec<(u64, PathBuf)> {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return vec![];
    };
    let mut out: Vec<(u64, PathBuf)> = entries
        .flatten()
        .filter_map(|e| {
            let name = e.file_name().into_string().ok()?;
            let step = name
                .strip_prefix("ckpt-")?
                .strip_suffix(".sbck")?
                .parse::<u64>()
                .ok()?;
            Some((step, e.path()))
        })
        .collect();
    out.sort_unstable_by_key(|(s, _)| *s);
    out
}

/// Newest snapshot in `dir`, if any.
pub fn latest_snapshot(dir: &Path) -> Option<(u64, PathBuf)> {
    list_snapshots(dir).pop()
}

/// Delete all but the newest `keep` snapshots; returns how many were
/// removed.  Equivalent to [`prune_snapshots_guarded`] with no in-flight
/// saves.
pub fn prune_snapshots(dir: &Path, keep: usize) -> usize {
    prune_snapshots_guarded(dir, keep, &HashSet::new())
}

/// Retention with in-flight protection: delete the oldest *complete*
/// snapshots beyond `keep`, never touching
///
/// * `.tmp` staging files/directories (invisible to [`list_snapshots`]),
/// * **incomplete** snapshots — a v2 directory whose shards are still
///   being written/copied, or a v1 file shorter than its manifest
///   promises (these are also excluded from the retention *count*: a
///   half-copied snapshot must not push a good one over the edge),
/// * any path in `in_flight` — the [`AsyncSaver`]'s registry of saves
///   that are queued or mid-write (`train --ckpt-async`).
///
/// Returns how many snapshots were removed (best-effort: an unremovable
/// entry is skipped, not fatal).
pub fn prune_snapshots_guarded(
    dir: &Path,
    keep: usize,
    in_flight: &HashSet<PathBuf>,
) -> usize {
    let prunable: Vec<(u64, PathBuf)> = list_snapshots(dir)
        .into_iter()
        .filter(|(_, p)| !in_flight.contains(p))
        // peek is a header+manifest read (KiB) — cheap enough per cadence;
        // unreadable counts as incomplete (fail closed: never delete what
        // we cannot prove is a finished snapshot)
        .filter(|(_, p)| format::peek(p).map(|pk| pk.is_complete()).unwrap_or(false))
        .collect();
    let excess = prunable.len().saturating_sub(keep.max(1));
    prunable[..excess]
        .iter()
        .filter(|(_, p)| format::remove_path(p).is_ok())
        .count()
}

/// Resolve a CLI checkpoint argument: a `.sbck` file — or a v2 snapshot
/// *directory* (it holds a [`MANIFEST_FILE`]) — is used as-is; any other
/// directory resolves to its newest snapshot.
pub fn resolve(path: &str) -> Result<PathBuf> {
    let p = Path::new(path);
    if p.is_file() {
        return Ok(p.to_path_buf());
    }
    if p.is_dir() {
        if p.join(MANIFEST_FILE).is_file() {
            return Ok(p.to_path_buf());
        }
        return latest_snapshot(p)
            .map(|(_, f)| f)
            .ok_or_else(|| anyhow!("no ckpt-*.sbck snapshots in {path:?}"));
    }
    bail!("checkpoint path {path:?} does not exist");
}

/// Copy a snapshot (v1 file or v2 directory) to `dst` with the same
/// commit discipline as a save: everything lands under a temporary name
/// first — for v2, shard files before the root manifest — and the final
/// rename makes it visible atomically.  Used by `pipeline` to stage
/// snapshots into a watch directory without ever exposing a half-copy.
pub fn stage_copy(src: &Path, dst: &Path) -> Result<()> {
    let tmp = dst.with_extension("sbck.stage");
    // a crashed earlier stage may have left either shape at the temp name
    format::remove_path(&tmp)?;
    if src.is_dir() {
        std::fs::create_dir_all(&tmp).with_context(|| format!("creating {tmp:?}"))?;
        let mut names: Vec<String> = std::fs::read_dir(src)
            .with_context(|| format!("reading {src:?}"))?
            .flatten()
            .filter_map(|e| e.file_name().into_string().ok())
            .collect();
        names.sort();
        // manifest last: a reader that races the copy sees shards without
        // a manifest (unreadable → retried), never the reverse
        names.retain(|n| n != MANIFEST_FILE);
        names.push(MANIFEST_FILE.to_string());
        for name in &names {
            if !src.join(name).is_file() {
                continue;
            }
            std::fs::copy(src.join(name), tmp.join(name))
                .with_context(|| format!("copying {name}"))?;
        }
    } else {
        std::fs::copy(src, &tmp).with_context(|| format!("copying {src:?}"))?;
    }
    // rename first (atomic for file-over-file and fresh names); only a
    // same-name directory snapshot at dst needs the clear + retry
    if std::fs::rename(&tmp, dst).is_err() {
        format::remove_path(dst)?;
        std::fs::rename(&tmp, dst).with_context(|| format!("renaming to {dst:?}"))?;
    }
    Ok(())
}

/// Reshape a checkpoint's flat parameter vector into the serving-encoder
/// weight layout.  The layout contract is `ClipTrainModel::collect_params`
/// order: patch_embed, tok_embed, image blocks (6 projections each),
/// image out-proj, text blocks, text out-proj, logit scale.
pub fn encoder_weights(cfg: &EncoderConfig, params: &[Vec<f32>]) -> Result<EncoderWeights> {
    let expected = 2 + 6 * (cfg.blocks * 2) + 2 + 1;
    if params.len() != expected {
        bail!(
            "checkpoint has {} tensors, a {}-block model needs {expected}",
            params.len(),
            cfg.blocks
        );
    }
    let d = cfg.dim;
    // (rows, cols) of the six block projections, canonical order
    let proj_shapes = [(d, d), (d, d), (d, d), (d, d), (4 * d, d), (d, 4 * d)];
    let mat = |data: &Vec<f32>, rows: usize, cols: usize, what: &str| -> Result<Matrix> {
        if data.len() != rows * cols {
            bail!("{what}: {} floats, expected {rows}×{cols}", data.len());
        }
        Ok(Matrix::from_vec(rows, cols, data.clone()))
    };
    let mut it = params.iter();
    let mut next = |rows: usize, cols: usize, what: &str| -> Result<Matrix> {
        let data = it.next().ok_or_else(|| anyhow!("{what}: tensor list exhausted"))?;
        mat(data, rows, cols, what)
    };
    let patch_embed = next(d, cfg.patch_dim, "patch_embed")?;
    let tok_embed = next(cfg.vocab, d, "tok_embed")?;
    let mut tower = |label: &str| -> Result<(Vec<[Matrix; 6]>, Matrix)> {
        let mut blocks = Vec::with_capacity(cfg.blocks);
        for b in 0..cfg.blocks {
            let mut mats = Vec::with_capacity(6);
            for (p, &(r, c)) in proj_shapes.iter().enumerate() {
                mats.push(next(r, c, &format!("{label}.block{b}.proj{p}"))?);
            }
            let arr: [Matrix; 6] = mats.try_into().map_err(|_| anyhow!("6 projections"))?;
            blocks.push(arr);
        }
        let out = next(cfg.embed_dim, d, &format!("{label}.out_proj"))?;
        Ok((blocks, out))
    };
    let (image_blocks, image_out) = tower("img")?;
    let (text_blocks, text_out) = tower("txt")?;
    Ok(EncoderWeights {
        patch_embed,
        tok_embed,
        image_blocks,
        image_out,
        text_blocks,
        text_out,
    })
}

/// The checkpoint's logit scale (last tensor in the layout).
pub fn log_scale(params: &[Vec<f32>]) -> Option<f32> {
    params.last().and_then(|t| t.first()).copied()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::LinearKind;
    use crate::serve::ClipEncoder;
    use crate::tensor::Rng;
    use crate::train::ClipTrainModel;

    fn tiny(kind: LinearKind) -> EncoderConfig {
        EncoderConfig {
            kind,
            dim: 16,
            heads: 2,
            blocks: 2,
            embed_dim: 8,
            patches: 4,
            patch_dim: 12,
            text_seq: 5,
            vocab: 64,
            seed: 7,
        }
    }

    /// The ckpt → serve contract: an encoder rebuilt from a train model's
    /// parameter vector encodes bit-identically to that model, for every
    /// precision kind (the weights are the same f32 master; serving only
    /// re-quantizes them).
    #[test]
    fn encoder_from_params_matches_train_model_bit_for_bit() {
        for kind in [LinearKind::Standard, LinearKind::SwitchBack, LinearKind::LlmInt8] {
            let cfg = tiny(kind);
            let model = ClipTrainModel::new(cfg.clone());
            let params = model.collect_params();
            let w = encoder_weights(&cfg, &params).unwrap();
            let enc = ClipEncoder::from_weights(cfg.clone(), w);
            let mut rng = Rng::seed(31);
            let img: Vec<f32> = (0..cfg.image_len()).map(|_| rng.normal()).collect();
            let toks: Vec<i32> =
                (0..cfg.text_seq).map(|_| rng.below(cfg.vocab) as i32).collect();
            let m_img = model.encode_images_infer(&Matrix::from_vec(
                cfg.patches,
                cfg.patch_dim,
                img.clone(),
            ));
            let e_img = &enc.encode_images(&[&img])[0];
            assert_eq!(m_img.row(0), &e_img[..], "{kind:?} image tower drifted");
            let m_txt = model.encode_texts_infer(&toks);
            let e_txt = &enc.encode_texts(&[&toks])[0];
            assert_eq!(m_txt.row(0), &e_txt[..], "{kind:?} text tower drifted");
        }
    }

    #[test]
    fn encoder_weights_rejects_bad_layouts() {
        let cfg = tiny(LinearKind::Standard);
        let model = ClipTrainModel::new(cfg.clone());
        let mut params = model.collect_params();
        params.pop();
        assert!(encoder_weights(&cfg, &params).is_err(), "missing tensor");
        let mut params = model.collect_params();
        params[0].pop();
        assert!(encoder_weights(&cfg, &params).is_err(), "mis-sized tensor");
        assert_eq!(log_scale(&model.collect_params()), Some(model.log_scale));
    }

    #[test]
    fn snapshot_dir_listing_and_retention() {
        let dir = std::env::temp_dir().join("sbck_dir_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let ck = format::tests::sample_ckpt();
        for step in [5u64, 30, 10, 20] {
            format::save(&snapshot_path(&dir, step), &ck).unwrap();
        }
        std::fs::write(dir.join("not-a-ckpt.txt"), b"x").unwrap();
        let steps: Vec<u64> = list_snapshots(&dir).iter().map(|(s, _)| *s).collect();
        assert_eq!(steps, vec![5, 10, 20, 30]);
        assert_eq!(latest_snapshot(&dir).unwrap().0, 30);
        assert_eq!(prune_snapshots(&dir, 2), 2);
        let steps: Vec<u64> = list_snapshots(&dir).iter().map(|(s, _)| *s).collect();
        assert_eq!(steps, vec![20, 30]);
        // resolve: dir → latest, file → itself, bogus → error
        let latest = resolve(dir.to_str().unwrap()).unwrap();
        assert!(latest.ends_with(snapshot_filename(30)));
        let file = snapshot_path(&dir, 20);
        assert_eq!(resolve(file.to_str().unwrap()).unwrap(), file);
        assert!(resolve("/nonexistent/nowhere").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The prune-during-save regression (ISSUE 5 satellite): retention
    /// must skip `.tmp` staging entries, never count or delete an
    /// incomplete (mid-copy) snapshot, and never delete a path the async
    /// saver still holds in its in-flight registry.
    #[test]
    fn prune_spares_tmp_incomplete_and_in_flight_snapshots() {
        let dir = std::env::temp_dir().join("sbck_prune_guard_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let ck = format::tests::sample_ckpt();
        // four complete snapshots: v1 files at 10/20, v2 dirs at 30/40
        for step in [10u64, 20] {
            format::save(&snapshot_path(&dir, step), &ck).unwrap();
        }
        for step in [30u64, 40] {
            format::save_sharded(&snapshot_path(&dir, step), &ck, 3).unwrap();
        }
        // a staging leftover (crashed save): name never matches listing
        std::fs::write(dir.join("ckpt-00000050.sbck.tmp"), b"half").unwrap();
        // an incomplete v2 snapshot: manifest present, one shard missing
        // (a non-atomic copy in flight)
        let midcopy = snapshot_path(&dir, 60);
        format::save_sharded(&midcopy, &ck, 3).unwrap();
        std::fs::remove_file(midcopy.join(format::shard_filename(1))).unwrap();
        assert!(!format::peek(&midcopy).unwrap().is_complete());
        // an unreadable junk file: also never counted, never deleted
        std::fs::write(snapshot_path(&dir, 70), b"torn").unwrap();

        // the async saver still "holds" step 10 (the oldest complete one)
        let mut in_flight = HashSet::new();
        in_flight.insert(snapshot_path(&dir, 10));

        // complete ∧ unguarded = {20, 30, 40}; keep 2 → only 20 goes
        assert_eq!(prune_snapshots_guarded(&dir, 2, &in_flight), 1);
        assert!(snapshot_path(&dir, 10).exists(), "in-flight save deleted");
        assert!(!snapshot_path(&dir, 20).exists(), "oldest complete must go");
        assert!(snapshot_path(&dir, 30).exists());
        assert!(snapshot_path(&dir, 40).exists());
        assert!(midcopy.exists(), "mid-copy snapshot deleted");
        assert!(snapshot_path(&dir, 70).exists(), "unreadable file deleted");
        assert!(dir.join("ckpt-00000050.sbck.tmp").exists(), "staging deleted");

        // release the registry: 10 is now the oldest prunable snapshot
        assert_eq!(prune_snapshots_guarded(&dir, 2, &HashSet::new()), 1);
        assert!(!snapshot_path(&dir, 10).exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// `resolve` and `latest_snapshot` treat a v2 directory as one
    /// snapshot, not as a directory of snapshots.
    #[test]
    fn resolve_accepts_v2_snapshot_directories() {
        let dir = std::env::temp_dir().join("sbck_resolve_v2_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let ck = format::tests::sample_ckpt();
        let snap = snapshot_path(&dir, 7);
        format::save_sharded(&snap, &ck, 2).unwrap();
        // the snapshot dir itself resolves to itself…
        assert_eq!(resolve(snap.to_str().unwrap()).unwrap(), snap);
        // …and the containing dir resolves to it as the newest snapshot
        assert_eq!(resolve(dir.to_str().unwrap()).unwrap(), snap);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// `stage_copy` reproduces a snapshot byte-for-byte under a new name,
    /// for both on-disk shapes.
    #[test]
    fn stage_copy_round_trips_both_versions() {
        let dir = std::env::temp_dir().join("sbck_stage_copy_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let ck = format::tests::sample_ckpt();
        let v1 = snapshot_path(&dir, 1);
        format::save(&v1, &ck).unwrap();
        let v1_dst = snapshot_path(&dir, 2);
        stage_copy(&v1, &v1_dst).unwrap();
        assert_eq!(
            std::fs::read(&v1).unwrap(),
            std::fs::read(&v1_dst).unwrap(),
            "v1 copy must be byte-identical"
        );
        let v2 = snapshot_path(&dir, 3);
        format::save_sharded(&v2, &ck, 3).unwrap();
        let v2_dst = snapshot_path(&dir, 4);
        stage_copy(&v2, &v2_dst).unwrap();
        let (a, _) = format::load(&v2).unwrap();
        let (b, _) = format::load(&v2_dst).unwrap();
        assert_eq!(a.params, b.params);
        assert_eq!(a.opt, b.opt);
        assert_eq!(a.data, b.data);
        // no staging leftovers
        assert!(!dir.join("ckpt-00000004.sbck.stage").exists());

        // a v1 file staged over an existing v2 *directory* at the same
        // destination replaces it (rename cannot overwrite a dir; the
        // clear-and-retry path must)
        stage_copy(&v1, &v2_dst).unwrap();
        assert!(v2_dst.is_file());
        assert_eq!(std::fs::read(&v1).unwrap(), std::fs::read(&v2_dst).unwrap());

        // a stale .stage leftover of the *other* shape does not wedge a
        // later stage to the same destination
        let dst5 = snapshot_path(&dir, 5);
        std::fs::create_dir_all(dir.join("ckpt-00000005.sbck.stage")).unwrap();
        stage_copy(&v1, &dst5).unwrap();
        assert!(dst5.is_file());
        std::fs::write(dir.join("ckpt-00000006.sbck.stage"), b"stale file").unwrap();
        let dst6 = snapshot_path(&dir, 6);
        stage_copy(&v2, &dst6).unwrap();
        format::load(&dst6).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }
}
