//! `ckpt` — versioned checkpoint/restore for the native training path
//! (DESIGN.md §Checkpoint).
//!
//! A checkpoint captures *everything* a training step's math depends on,
//! so `train --resume` continues **bit-identically** with an uninterrupted
//! run (tested in [`crate::train`]):
//!
//! * model parameters (the [`crate::train::ClipTrainModel`] flat layout,
//!   including the logit scale),
//! * optimizer state ([`crate::optim::OptimizerState`]: AdamW/StableAdamW
//!   first+second moments and the debiasing counter, Lion momentum),
//! * the data-stream cursor ([`crate::data::DataCursor`]: RNG words,
//!   Box–Muller spare, applied shift effects, step counter),
//! * the run's schedule/hyper echo (steps, warmup, lr, optimizer, seed,
//!   shift schedule) so a resume can rebuild the exact LR cosine and the
//!   un-fired tail of the shift schedule — and fail closed on mismatch.
//!
//! On-disk format ([`format`]): magic + version, a JSON manifest (via the
//! in-tree [`crate::util::json`] writer — human-inspectable with any JSON
//! tool), then raw little-endian f32 tensor blobs, each CRC-32-checked
//! ([`crate::util::crc32`]).  Writes go through a temp file + rename, so a
//! crash mid-snapshot never corrupts an existing checkpoint.
//!
//! The same artifact feeds the serving path: [`encoder_weights`] reshapes
//! a checkpoint's parameter vector into [`crate::serve::EncoderWeights`],
//! which `serve --weights` loads at boot and the engine's
//! `install_encoder` hot-swaps live (re-quantized for whatever
//! [`crate::nn::LinearKind`] serving runs at).
//!
//! Consumers:
//! * `train --ckpt-every/--ckpt-dir/--resume` — periodic snapshots with
//!   retention + bit-identical resume (`crate::train::NativeTrainer`),
//! * the trainer's **spike-rollback guard** (`--rollback-on-spike`),
//!   which restores the last in-memory snapshot when the loss spikes and
//!   skips the offending shard window,
//! * `serve --weights` / `switchback pipeline` — load-at-boot + live
//!   hot-swap, benchmarked in `BENCH_ckpt.json`,
//! * the serve-side **warm-standby watcher** ([`crate::serve::standby`]),
//!   which uses [`peek`] to pick the newest compatible snapshot in a
//!   watched directory (manifest-only read, no tensor I/O) before paying
//!   for the full CRC-checked [`load`],
//! * `ckpt inspect` / `ckpt diff` ([`inspect`]).

pub mod format;
pub mod inspect;

pub use format::{load, peek, save, CkptPeek, IoStats, TrainCheckpoint, FORMAT_VERSION};

use crate::serve::{EncoderConfig, EncoderWeights};
use crate::tensor::Matrix;
use anyhow::{anyhow, bail, Result};
use std::path::{Path, PathBuf};

/// Canonical snapshot filename inside a checkpoint directory.
pub fn snapshot_filename(step: u64) -> String {
    format!("ckpt-{step:08}.sbck")
}

/// `dir/ckpt-<step>.sbck`.
pub fn snapshot_path(dir: &Path, step: u64) -> PathBuf {
    dir.join(snapshot_filename(step))
}

/// All snapshots in `dir`, sorted by step ascending.
pub fn list_snapshots(dir: &Path) -> Vec<(u64, PathBuf)> {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return vec![];
    };
    let mut out: Vec<(u64, PathBuf)> = entries
        .flatten()
        .filter_map(|e| {
            let name = e.file_name().into_string().ok()?;
            let step = name
                .strip_prefix("ckpt-")?
                .strip_suffix(".sbck")?
                .parse::<u64>()
                .ok()?;
            Some((step, e.path()))
        })
        .collect();
    out.sort_unstable_by_key(|(s, _)| *s);
    out
}

/// Newest snapshot in `dir`, if any.
pub fn latest_snapshot(dir: &Path) -> Option<(u64, PathBuf)> {
    list_snapshots(dir).pop()
}

/// Delete all but the newest `keep` snapshots; returns how many were
/// removed (best-effort: an unremovable file is skipped, not fatal).
pub fn prune_snapshots(dir: &Path, keep: usize) -> usize {
    let snaps = list_snapshots(dir);
    let excess = snaps.len().saturating_sub(keep.max(1));
    snaps[..excess]
        .iter()
        .filter(|(_, p)| std::fs::remove_file(p).is_ok())
        .count()
}

/// Resolve a CLI checkpoint argument: a `.sbck` file is used as-is, a
/// directory resolves to its newest snapshot.
pub fn resolve(path: &str) -> Result<PathBuf> {
    let p = Path::new(path);
    if p.is_file() {
        return Ok(p.to_path_buf());
    }
    if p.is_dir() {
        return latest_snapshot(p)
            .map(|(_, f)| f)
            .ok_or_else(|| anyhow!("no ckpt-*.sbck snapshots in {path:?}"));
    }
    bail!("checkpoint path {path:?} does not exist");
}

/// Reshape a checkpoint's flat parameter vector into the serving-encoder
/// weight layout.  The layout contract is `ClipTrainModel::collect_params`
/// order: patch_embed, tok_embed, image blocks (6 projections each),
/// image out-proj, text blocks, text out-proj, logit scale.
pub fn encoder_weights(cfg: &EncoderConfig, params: &[Vec<f32>]) -> Result<EncoderWeights> {
    let expected = 2 + 6 * (cfg.blocks * 2) + 2 + 1;
    if params.len() != expected {
        bail!(
            "checkpoint has {} tensors, a {}-block model needs {expected}",
            params.len(),
            cfg.blocks
        );
    }
    let d = cfg.dim;
    // (rows, cols) of the six block projections, canonical order
    let proj_shapes = [(d, d), (d, d), (d, d), (d, d), (4 * d, d), (d, 4 * d)];
    let mat = |data: &Vec<f32>, rows: usize, cols: usize, what: &str| -> Result<Matrix> {
        if data.len() != rows * cols {
            bail!("{what}: {} floats, expected {rows}×{cols}", data.len());
        }
        Ok(Matrix::from_vec(rows, cols, data.clone()))
    };
    let mut it = params.iter();
    let mut next = |rows: usize, cols: usize, what: &str| -> Result<Matrix> {
        mat(it.next().expect("count checked above"), rows, cols, what)
    };
    let patch_embed = next(d, cfg.patch_dim, "patch_embed")?;
    let tok_embed = next(cfg.vocab, d, "tok_embed")?;
    let mut tower = |label: &str| -> Result<(Vec<[Matrix; 6]>, Matrix)> {
        let mut blocks = Vec::with_capacity(cfg.blocks);
        for b in 0..cfg.blocks {
            let mut mats = Vec::with_capacity(6);
            for (p, &(r, c)) in proj_shapes.iter().enumerate() {
                mats.push(next(r, c, &format!("{label}.block{b}.proj{p}"))?);
            }
            let arr: [Matrix; 6] = mats.try_into().map_err(|_| anyhow!("6 projections"))?;
            blocks.push(arr);
        }
        let out = next(cfg.embed_dim, d, &format!("{label}.out_proj"))?;
        Ok((blocks, out))
    };
    let (image_blocks, image_out) = tower("img")?;
    let (text_blocks, text_out) = tower("txt")?;
    Ok(EncoderWeights {
        patch_embed,
        tok_embed,
        image_blocks,
        image_out,
        text_blocks,
        text_out,
    })
}

/// The checkpoint's logit scale (last tensor in the layout).
pub fn log_scale(params: &[Vec<f32>]) -> Option<f32> {
    params.last().and_then(|t| t.first()).copied()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::LinearKind;
    use crate::serve::ClipEncoder;
    use crate::tensor::Rng;
    use crate::train::ClipTrainModel;

    fn tiny(kind: LinearKind) -> EncoderConfig {
        EncoderConfig {
            kind,
            dim: 16,
            heads: 2,
            blocks: 2,
            embed_dim: 8,
            patches: 4,
            patch_dim: 12,
            text_seq: 5,
            vocab: 64,
            seed: 7,
        }
    }

    /// The ckpt → serve contract: an encoder rebuilt from a train model's
    /// parameter vector encodes bit-identically to that model, for every
    /// precision kind (the weights are the same f32 master; serving only
    /// re-quantizes them).
    #[test]
    fn encoder_from_params_matches_train_model_bit_for_bit() {
        for kind in [LinearKind::Standard, LinearKind::SwitchBack, LinearKind::LlmInt8] {
            let cfg = tiny(kind);
            let model = ClipTrainModel::new(cfg.clone());
            let params = model.collect_params();
            let w = encoder_weights(&cfg, &params).unwrap();
            let enc = ClipEncoder::from_weights(cfg.clone(), w);
            let mut rng = Rng::seed(31);
            let img: Vec<f32> = (0..cfg.image_len()).map(|_| rng.normal()).collect();
            let toks: Vec<i32> =
                (0..cfg.text_seq).map(|_| rng.below(cfg.vocab) as i32).collect();
            let m_img = model.encode_images_infer(&Matrix::from_vec(
                cfg.patches,
                cfg.patch_dim,
                img.clone(),
            ));
            let e_img = &enc.encode_images(&[&img])[0];
            assert_eq!(m_img.row(0), &e_img[..], "{kind:?} image tower drifted");
            let m_txt = model.encode_texts_infer(&toks);
            let e_txt = &enc.encode_texts(&[&toks])[0];
            assert_eq!(m_txt.row(0), &e_txt[..], "{kind:?} text tower drifted");
        }
    }

    #[test]
    fn encoder_weights_rejects_bad_layouts() {
        let cfg = tiny(LinearKind::Standard);
        let model = ClipTrainModel::new(cfg.clone());
        let mut params = model.collect_params();
        params.pop();
        assert!(encoder_weights(&cfg, &params).is_err(), "missing tensor");
        let mut params = model.collect_params();
        params[0].pop();
        assert!(encoder_weights(&cfg, &params).is_err(), "mis-sized tensor");
        assert_eq!(log_scale(&model.collect_params()), Some(model.log_scale));
    }

    #[test]
    fn snapshot_dir_listing_and_retention() {
        let dir = std::env::temp_dir().join("sbck_dir_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for step in [5u64, 30, 10, 20] {
            std::fs::write(snapshot_path(&dir, step), b"stub").unwrap();
        }
        std::fs::write(dir.join("not-a-ckpt.txt"), b"x").unwrap();
        let steps: Vec<u64> = list_snapshots(&dir).iter().map(|(s, _)| *s).collect();
        assert_eq!(steps, vec![5, 10, 20, 30]);
        assert_eq!(latest_snapshot(&dir).unwrap().0, 30);
        assert_eq!(prune_snapshots(&dir, 2), 2);
        let steps: Vec<u64> = list_snapshots(&dir).iter().map(|(s, _)| *s).collect();
        assert_eq!(steps, vec![20, 30]);
        // resolve: dir → latest, file → itself, bogus → error
        let latest = resolve(dir.to_str().unwrap()).unwrap();
        assert!(latest.ends_with(snapshot_filename(30)));
        let file = snapshot_path(&dir, 20);
        assert_eq!(resolve(file.to_str().unwrap()).unwrap(), file);
        assert!(resolve("/nonexistent/nowhere").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
