//! `ckpt inspect` / `ckpt diff` — human-readable views over checkpoint
//! files (v1 single files and v2 shard directories alike).  Both go
//! through [`super::format::load`], so every inspection is also a full
//! integrity check (magic, version, per-blob/per-shard CRC-32).

use super::format::{load, peek, TrainCheckpoint};
use anyhow::Result;
use std::fmt::Write as _;
use std::path::Path;

fn total_floats(ck: &TrainCheckpoint) -> usize {
    ck.params.iter().map(Vec::len).sum()
}

/// One-screen summary of a checkpoint (the `ckpt inspect` output).
pub fn inspect(path: &Path) -> Result<String> {
    let pk = peek(path)?; // version + shard layout (manifest-only read)
    let (ck, io) = load(path)?;
    let e = &ck.encoder;
    let h = &ck.hyper;
    let mut s = String::new();
    let _ = writeln!(s, "checkpoint : {}", path.display());
    let shard_note = if pk.shards > 0 {
        format!(" ({} shards)", pk.shards)
    } else {
        String::new()
    };
    let _ = writeln!(
        s,
        "format     : switchback-ckpt v{}{shard_note}   {} bytes   (all CRCs OK)",
        pk.version, io.bytes
    );
    let _ = writeln!(s, "step       : {} / {} (warmup {})", ck.step, h.steps, h.warmup);
    let _ = writeln!(
        s,
        "model      : kind {}  dim {}  heads {}  blocks {}  embed {}  \
         patches {}x{}  text {}x{} vocab  seed {}",
        e.kind.label(),
        e.dim,
        e.heads,
        e.blocks,
        e.embed_dim,
        e.patches,
        e.patch_dim,
        e.text_seq,
        e.vocab,
        e.seed
    );
    let _ = writeln!(
        s,
        "optimizer  : {} (t={})  lr {:e}  wd {}  betas ({}, {})",
        ck.opt.name, ck.opt.t, h.lr, h.weight_decay, h.beta1, h.beta2
    );
    let _ = writeln!(
        s,
        "data       : step {}  gain {}  {} concepts  {} scheduled shift(s)",
        ck.data.step,
        ck.data.gain,
        ck.data.mapping.len(),
        ck.shifts.len()
    );
    let slot_names: Vec<&str> = ck.opt.slots.iter().map(|(l, _)| l.as_str()).collect();
    let _ = writeln!(
        s,
        "tensors    : {} params ({} floats) + {} opt slot(s) [{}]",
        ck.params.len(),
        total_floats(&ck),
        ck.opt.slots.len(),
        slot_names.join(", ")
    );
    if let Some(ls) = super::log_scale(&ck.params) {
        let _ = writeln!(s, "logit scale: {ls}  (temperature {})", ls.exp());
    }
    let _ = writeln!(s, "--- parameter tensors ---");
    for (name, p) in ck.param_names.iter().zip(&ck.params) {
        let rms = (p.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
            / p.len().max(1) as f64)
            .sqrt();
        let _ = writeln!(s, "  {name:<24} {:>9} floats   rms {rms:.5}", p.len());
    }
    Ok(s)
}

/// Tensor-by-tensor comparison of two checkpoints (the `ckpt diff`
/// output).  Returns the report and whether the *parameters* are
/// bit-identical (optimizer state and cursors are reported separately).
pub fn diff(a: &Path, b: &Path) -> Result<(String, bool)> {
    let (ca, _) = load(a)?;
    let (cb, _) = load(b)?;
    let mut s = String::new();
    let _ = writeln!(s, "a: {} (step {})", a.display(), ca.step);
    let _ = writeln!(s, "b: {} (step {})", b.display(), cb.step);
    if ca.param_names != cb.param_names {
        let _ = writeln!(
            s,
            "LAYOUT MISMATCH: {} vs {} tensors — not comparable further",
            ca.param_names.len(),
            cb.param_names.len()
        );
        return Ok((s, false));
    }
    let mut identical = true;
    let mut changed = 0usize;
    for (name, (pa, pb)) in ca.param_names.iter().zip(ca.params.iter().zip(&cb.params)) {
        if pa == pb {
            continue;
        }
        identical = false;
        changed += 1;
        let n_diff = pa.iter().zip(pb).filter(|(x, y)| x != y).count();
        let max_abs = pa
            .iter()
            .zip(pb)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        let _ = writeln!(
            s,
            "  {name:<24} {n_diff:>9}/{} elems differ   max |Δ| {max_abs:.6}",
            pa.len()
        );
    }
    if identical {
        let _ = writeln!(s, "parameters: bit-identical ({} tensors)", ca.params.len());
    } else {
        let _ = writeln!(
            s,
            "parameters: {changed}/{} tensors differ",
            ca.params.len()
        );
    }
    let _ = writeln!(
        s,
        "optimizer : {} (t={}) vs {} (t={}) — state {}",
        ca.opt.name,
        ca.opt.t,
        cb.opt.name,
        cb.opt.t,
        if ca.opt == cb.opt { "identical" } else { "differs" }
    );
    let _ = writeln!(
        s,
        "data      : step {} vs {} — cursor {}",
        ca.data.step,
        cb.data.step,
        if ca.data == cb.data { "identical" } else { "differs" }
    );
    Ok((s, identical))
}

#[cfg(test)]
mod tests {
    use super::super::format::{save, tests::sample_ckpt};
    use super::*;

    #[test]
    fn inspect_and_diff_report() {
        let dir = std::env::temp_dir().join("sbck_inspect_test");
        std::fs::create_dir_all(&dir).unwrap();
        let pa = dir.join("a.sbck");
        let pb = dir.join("b.sbck");
        let ck = sample_ckpt();
        save(&pa, &ck).unwrap();
        let mut ck2 = ck.clone();
        ck2.params[0][1] += 0.5;
        ck2.step = 18;
        save(&pb, &ck2).unwrap();

        let report = inspect(&pa).unwrap();
        assert!(report.contains("switchback-ckpt v1"), "{report}");
        assert!(report.contains("step       : 17"), "{report}");
        assert!(report.contains("stable_adamw"), "{report}");
        assert!(report.contains("logit scale"), "{report}");

        let (d, same) = diff(&pa, &pa).unwrap();
        assert!(same, "{d}");
        assert!(d.contains("bit-identical"), "{d}");
        let (d, same) = diff(&pa, &pb).unwrap();
        assert!(!same, "{d}");
        assert!(d.contains("1/3 elems differ") || d.contains("elems differ"), "{d}");
        assert!(d.contains("1/2 tensors differ"), "{d}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Inspect and diff understand v2 shard directories, and a v1-vs-v2
    /// pair of the same checkpoint diffs bit-identical (the
    /// cross-version compatibility contract verify.sh greps for).
    #[test]
    fn inspect_and_diff_across_versions() {
        let dir = std::env::temp_dir().join("sbck_inspect_v2_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let ck = sample_ckpt();
        let v1 = dir.join("a.sbck");
        let v2 = dir.join("b.sbck");
        save(&v1, &ck).unwrap();
        super::super::format::save_sharded(&v2, &ck, 3).unwrap();

        let report = inspect(&v2).unwrap();
        assert!(report.contains("switchback-ckpt v2 (3 shards)"), "{report}");
        assert!(report.contains("all CRCs OK"), "{report}");

        let (d, same) = diff(&v1, &v2).unwrap();
        assert!(same, "v1 and v2 of the same checkpoint must diff clean:\n{d}");
        assert!(d.contains("bit-identical"), "{d}");
        assert!(d.contains("state identical"), "{d}");
        assert!(d.contains("cursor identical"), "{d}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
