//! Non-blocking background checkpoint saves (`train --ckpt-async`).
//!
//! The trainer captures a [`TrainCheckpoint`] at a step boundary — an
//! O(bytes) memcpy of the params/optimizer-state view, nothing else —
//! and hands it to the [`AsyncSaver`]'s dedicated thread, which pays for
//! serialization, CRC-32 and disk entirely off the step loop.  Because
//! the capture is taken between steps and never mutated afterwards, the
//! bytes an async save writes are **bit-identical** to what a
//! synchronous [`format::save_sharded`] of the same step would have
//! written (tested here and end-to-end in `crate::train`).
//!
//! Two guarantees the trainer leans on:
//!
//! * **join-on-exit** — [`AsyncSaver::finish`] closes the queue, drains
//!   every pending save and surfaces the first I/O error; dropping the
//!   saver without calling `finish` still joins the thread (the Drop
//!   guard), so a panicking run can never leak a half-written snapshot
//!   *and* keep running past it.
//! * **in-flight registry** — every enqueued path stays registered until
//!   its save has fully committed (final rename done), and
//!   [`super::prune_snapshots_guarded`] refuses to delete registered
//!   paths, so retention can never race a save it is about to expose.

use super::format::{self, TrainCheckpoint};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashSet;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// One queued save: where, what, and how many v2 shards (≤ 1 = v1 file).
struct SaveJob {
    path: PathBuf,
    ck: TrainCheckpoint,
    shards: usize,
}

/// Accumulated outcome of every save a saver performed.
#[derive(Debug, Clone, Copy, Default)]
pub struct SaveTotals {
    /// snapshots fully committed to disk
    pub snapshots: usize,
    /// bytes written across them
    pub bytes: u64,
    /// wall seconds spent writing (saver-thread time, not step-loop time)
    pub secs: f64,
}

/// A dedicated checkpoint-writer thread with a bounded lifecycle:
/// [`spawn`](Self::spawn) → [`enqueue`](Self::enqueue)× →
/// [`finish`](Self::finish).
pub struct AsyncSaver {
    tx: Option<Sender<SaveJob>>,
    join: Option<JoinHandle<Result<SaveTotals>>>,
    in_flight: Arc<Mutex<HashSet<PathBuf>>>,
}

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    // a poisoned registry only means a saver-thread panic mid-save; the
    // set itself is still coherent (inserts/removes are atomic under it)
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl AsyncSaver {
    /// Start the saver thread (idle until the first [`enqueue`]).
    ///
    /// [`enqueue`]: Self::enqueue
    pub fn spawn() -> Self {
        let (tx, rx) = channel::<SaveJob>();
        let in_flight: Arc<Mutex<HashSet<PathBuf>>> = Arc::default();
        let registry = Arc::clone(&in_flight);
        let join = std::thread::Builder::new()
            .name("ckpt-saver".into())
            .spawn(move || {
                let mut totals = SaveTotals::default();
                let mut first_err: Option<anyhow::Error> = None;
                while let Ok(job) = rx.recv() {
                    let res = format::save_sharded(&job.path, &job.ck, job.shards)
                        .with_context(|| {
                            format!("background save of {:?}", job.path)
                        });
                    // deregister only after the final rename: prune must
                    // keep its hands off until the snapshot is committed
                    lock(&registry).remove(&job.path);
                    match res {
                        Ok(io) => {
                            totals.snapshots += 1;
                            totals.bytes += io.bytes;
                            totals.secs += io.secs;
                        }
                        Err(e) => {
                            if first_err.is_none() {
                                first_err = Some(e);
                            }
                        }
                    }
                }
                match first_err {
                    Some(e) => Err(e),
                    None => Ok(totals),
                }
            })
            // OS thread-spawn failure at run setup is unrecoverable, and
            // this saver is not a connection thread:
            // lint:allow(no-panic-path): unrecoverable at startup
            .expect("spawning the ckpt-saver thread");
        Self { tx: Some(tx), join: Some(join), in_flight }
    }

    /// Queue one snapshot.  Registers `path` as in-flight *before* the
    /// job is visible to the saver thread, so a prune between enqueue and
    /// write cannot delete the predecessor it is about to replace — or
    /// the snapshot itself once it lands.
    pub fn enqueue(&self, path: PathBuf, ck: TrainCheckpoint, shards: usize) {
        lock(&self.in_flight).insert(path.clone());
        if let Some(tx) = &self.tx {
            // a send error means the saver thread already exited (it only
            // does so on channel close, so this is unreachable in
            // practice); the failure surfaces at finish() via join
            let _ = tx.send(SaveJob { path, ck, shards });
        }
    }

    /// Snapshot of the in-flight registry — feed it to
    /// [`super::prune_snapshots_guarded`] on every retention pass.
    pub fn in_flight(&self) -> HashSet<PathBuf> {
        lock(&self.in_flight).clone()
    }

    /// Queued-or-writing save count (0 ⇒ every enqueued snapshot is on
    /// disk).
    pub fn pending(&self) -> usize {
        lock(&self.in_flight).len()
    }

    /// Close the queue, drain every pending save, join the thread and
    /// return the accumulated totals — or the first save error.  This is
    /// the join-on-exit guard the trainer calls before reporting a run
    /// complete.
    pub fn finish(mut self) -> Result<SaveTotals> {
        self.tx.take(); // close the channel: the worker drains then exits
        let Some(join) = self.join.take() else {
            bail!("ckpt-saver thread already joined");
        };
        join.join()
            .map_err(|_| anyhow!("the ckpt-saver thread panicked"))?
    }
}

impl Drop for AsyncSaver {
    /// Last-resort join (e.g. the run errored out mid-loop): still drain
    /// the queue so no snapshot is left half-written, but swallow the
    /// outcome — an error path is already unwinding.
    fn drop(&mut self) {
        self.tx.take();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::format::tests::sample_ckpt;
    use super::super::{load, prune_snapshots_guarded, snapshot_path};
    use super::*;

    #[test]
    fn async_saves_commit_and_match_sync_bytes() {
        let dir = std::env::temp_dir().join("sbck_async_saver_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let ck = sample_ckpt();
        let saver = AsyncSaver::spawn();
        saver.enqueue(snapshot_path(&dir, 1), ck.clone(), 1); // v1
        saver.enqueue(snapshot_path(&dir, 2), ck.clone(), 3); // v2
        saver.enqueue(snapshot_path(&dir, 3), ck.clone(), 3);
        let totals = saver.finish().unwrap();
        assert_eq!(totals.snapshots, 3);
        assert!(totals.bytes > 0 && totals.secs >= 0.0);

        // every snapshot is committed, loadable, and bit-identical to the
        // synchronous save of the same capture
        let sync_v1 = dir.join("sync1.sbck");
        format::save(&sync_v1, &ck).unwrap();
        assert_eq!(
            std::fs::read(snapshot_path(&dir, 1)).unwrap(),
            std::fs::read(&sync_v1).unwrap(),
            "async v1 bytes must equal the sync save"
        );
        let (a, _) = load(&snapshot_path(&dir, 2)).unwrap();
        assert_eq!(a.params, ck.params);
        assert_eq!(a.opt, ck.opt);
        let sync_v2 = dir.join("sync2.sbck");
        format::save_sharded(&sync_v2, &ck, 3).unwrap();
        for s in 0..3 {
            assert_eq!(
                std::fs::read(snapshot_path(&dir, 2).join(format::shard_filename(s)))
                    .unwrap(),
                std::fs::read(sync_v2.join(format::shard_filename(s))).unwrap(),
                "async shard {s} bytes must equal the sync save"
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn drop_without_finish_still_drains_the_queue() {
        let dir = std::env::temp_dir().join("sbck_async_drop_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let ck = sample_ckpt();
        {
            let saver = AsyncSaver::spawn();
            for step in 1..=4u64 {
                saver.enqueue(snapshot_path(&dir, step), ck.clone(), 2);
            }
            // dropped here: the guard must join, not abandon the queue
        }
        for step in 1..=4u64 {
            load(&snapshot_path(&dir, step)).unwrap();
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn finish_surfaces_the_first_save_error() {
        let ck = sample_ckpt();
        let saver = AsyncSaver::spawn();
        // an unwritable destination: the parent is a *file*
        let junk = std::env::temp_dir().join("sbck_async_err_test_file");
        std::fs::write(&junk, b"x").unwrap();
        saver.enqueue(junk.join("ckpt-00000001.sbck"), ck, 2);
        let err = saver.finish().unwrap_err().to_string();
        assert!(err.contains("background save"), "{err}");
        std::fs::remove_file(&junk).ok();
    }

    /// The registry window covers enqueue → committed: a prune issued
    /// while a save is queued can never delete that snapshot's path.
    #[test]
    fn in_flight_registry_guards_prune_until_commit() {
        let dir = std::env::temp_dir().join("sbck_async_prune_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let ck = sample_ckpt();
        let saver = AsyncSaver::spawn();
        let path = snapshot_path(&dir, 5);
        saver.enqueue(path.clone(), ck, 2);
        // regardless of whether the save already landed, the guarded
        // prune consults the registry snapshot taken *now*
        let guard = saver.in_flight();
        assert!(guard.is_empty() || guard.contains(&path));
        assert_eq!(prune_snapshots_guarded(&dir, 1, &guard), 0);
        saver.finish().unwrap();
        assert!(path.exists(), "the guarded snapshot must survive");
        load(&path).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }
}
