//! The on-disk checkpoint formats.
//!
//! **Version 1 — single file** (`ckpt-<step>.sbck`):
//!
//! ```text
//! bytes 0..4   magic  b"SBCK"
//! bytes 4..8   format version, u32 LE  (1)
//! bytes 8..16  manifest length M, u64 LE
//! bytes 16..16+M  JSON manifest (util::json writer; human-inspectable)
//! then         raw tensor blobs: little-endian f32, contiguous, at the
//!              offsets recorded in the manifest (relative to blob base),
//!              each CRC-32-checked on load
//! ```
//!
//! **Version 2 — manifest-of-shards** (`ckpt-<step>.sbck/` is a
//! *directory*):
//!
//! ```text
//! ckpt-<step>.sbck/
//!   shard-000.sbsh     contiguous LE-f32 blobs of its tensor group
//!   shard-001.sbsh     ...
//!   MANIFEST.sbck      magic + version 2 + manifest length + JSON
//! ```
//!
//! The v2 manifest carries a `shards` array (file name, byte length,
//! CRC-32 of the whole shard file) and per-tensor `(shard, offset)`
//! coordinates.  Shards are written and read **in parallel**
//! ([`crate::util::threads::par_try_map`]) — the streaming path a
//! ViT-Huge-sized snapshot needs so saves/loads scale with spindle and
//! core count instead of a single pass.
//!
//! Commit protocol (v2): shards are written first, each through its own
//! `*.tmp` + rename; the root manifest is written **last** (also
//! temp+rename), and the whole staging directory is renamed into place
//! only after that.  A reader therefore never sees a manifest that
//! promises shards which were not fully written by the producer — and a
//! *non-atomic copy* of a snapshot directory (e.g. `cp -r` into a watch
//! directory) is detected by [`peek`]'s per-shard size check
//! ([`CkptPeek::is_complete`]), generalizing the v1 blob-size retry.
//!
//! Blob order (both versions): the model parameters in
//! `ClipTrainModel::collect_params` layout order, then one run of
//! per-tensor buffers per optimizer slot (`opt.<slot>.<tensor>`).
//! Exactness rules: full-range integers (seeds, RNG words, step counters)
//! are serialized as decimal *strings* — JSON numbers are f64 and
//! silently lose u64 precision; scalar f32 state the resume math depends
//! on (data gain, Box–Muller spare, hyper floats) is serialized twice,
//! display value for humans plus `*_bits` (the IEEE bit pattern) for
//! exact reload.
//!
//! The two formats hold the same bytes per tensor: a v2 snapshot of a
//! [`TrainCheckpoint`] loads bit-identically to the v1 file of the same
//! checkpoint (tested below), so every consumer — resume, serve boot,
//! standby promotion, `ckpt diff` — accepts either interchangeably.

use crate::config::{OptimizerKind, TrainHyper};
use crate::data::{DataCursor, Shift};
use crate::nn::LinearKind;
use crate::optim::OptimizerState;
use crate::serve::EncoderConfig;
use crate::trace;
use crate::util::crc32::crc32;
use crate::util::json::{self, ObjWriter, Value};
use crate::util::threads::{par_map, par_try_map};
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// File magic: the first four bytes of every checkpoint (and of a v2
/// snapshot directory's root manifest).
pub const MAGIC: &[u8; 4] = b"SBCK";
/// Single-file format version.
pub const FORMAT_VERSION: u32 = 1;
/// Manifest-of-shards format version (directory snapshots).
pub const FORMAT_VERSION_V2: u32 = 2;
/// Root-manifest filename inside a v2 snapshot directory.  Committed
/// last, so its presence is the snapshot's producer-side commit marker.
pub const MANIFEST_FILE: &str = "MANIFEST.sbck";

/// Canonical shard filename inside a v2 snapshot directory.
pub fn shard_filename(index: usize) -> String {
    format!("shard-{index:03}.sbsh")
}

/// Everything a resumed run needs to continue bit-identically (see the
/// module docs of [`crate::ckpt`] for the inventory).
#[derive(Debug, Clone)]
pub struct TrainCheckpoint {
    /// training step this snapshot was taken *after* (0 = pre-training)
    pub step: u64,
    /// model shape + precision kind + init seed
    pub encoder: EncoderConfig,
    /// optimizer/schedule hyperparameters of the run being snapshotted
    pub hyper: TrainHyper,
    /// the run's scheduled distribution shifts (the un-fired tail matters)
    pub shifts: Vec<Shift>,
    /// examples per step — changes the data draws, so resume validates it
    pub batch: usize,
    /// gradient-accumulation shard count — changes summation order ditto
    pub grad_shards: usize,
    /// tensor names, index-aligned with `params` (the train model layout)
    pub param_names: Vec<String>,
    pub params: Vec<Vec<f32>>,
    pub opt: OptimizerState,
    pub data: DataCursor,
}

/// Bytes moved and wall time of one save/load (the BENCH_ckpt numbers).
#[derive(Debug, Clone, Copy)]
pub struct IoStats {
    pub bytes: u64,
    pub secs: f64,
}

impl IoStats {
    /// Throughput of the save/load this measures.
    pub fn mb_per_s(&self) -> f64 {
        self.bytes as f64 / 1e6 / self.secs.max(1e-9)
    }
}

fn write_f32_exact(w: &mut ObjWriter, key: &str, v: f32) {
    w.field_f32(key, v);
    w.field_u64(&format!("{key}_bits"), v.to_bits() as u64);
}

fn read_f32_exact(v: &Value, key: &str) -> Result<f32> {
    if let Some(b) = v.get(&format!("{key}_bits")).and_then(Value::as_usize) {
        let bits = u32::try_from(b)
            .map_err(|_| anyhow!("manifest {key}_bits {b} out of u32 range"))?;
        return Ok(f32::from_bits(bits));
    }
    v.get(key)
        .and_then(Value::as_f64)
        .map(|x| x as f32)
        .ok_or_else(|| anyhow!("manifest missing {key}"))
}

fn read_opt_f32_exact(v: &Value, key: &str) -> Result<Option<f32>> {
    match v.get(key) {
        None | Some(Value::Null) => Ok(None),
        Some(_) => read_f32_exact(v, key).map(Some),
    }
}

fn write_u64_str(w: &mut ObjWriter, key: &str, v: u64) {
    w.field_str(key, &v.to_string());
}

fn read_u64_str(v: &Value, key: &str) -> Result<u64> {
    v.get(key)
        .and_then(Value::as_str)
        .ok_or_else(|| anyhow!("manifest missing {key}"))?
        .parse::<u64>()
        .map_err(|_| anyhow!("manifest {key} is not a u64"))
}

fn read_u64_num(v: &Value, key: &str) -> Result<u64> {
    v.get(key)
        .and_then(Value::as_f64)
        .map(|x| x as u64)
        .ok_or_else(|| anyhow!("manifest missing {key}"))
}

fn read_usize(v: &Value, key: &str) -> Result<usize> {
    v.get(key)
        .and_then(Value::as_usize)
        .ok_or_else(|| anyhow!("manifest missing {key}"))
}

fn read_str<'a>(v: &'a Value, key: &str) -> Result<&'a str> {
    v.get(key)
        .and_then(Value::as_str)
        .ok_or_else(|| anyhow!("manifest missing {key}"))
}

/// The manifest sections shared by both on-disk versions, pre-rendered.
struct CommonSections {
    model: String,
    hyper: String,
    shifts: String,
    data: String,
    opt: String,
}

fn common_sections(ck: &TrainCheckpoint) -> CommonSections {
    let e = &ck.encoder;
    let mut model = ObjWriter::new();
    model
        .field_str("kind", e.kind.label())
        .field_u64("dim", e.dim as u64)
        .field_u64("heads", e.heads as u64)
        .field_u64("blocks", e.blocks as u64)
        .field_u64("embed_dim", e.embed_dim as u64)
        .field_u64("patches", e.patches as u64)
        .field_u64("patch_dim", e.patch_dim as u64)
        .field_u64("text_seq", e.text_seq as u64)
        .field_u64("vocab", e.vocab as u64);
    write_u64_str(&mut model, "seed", e.seed);

    let h = &ck.hyper;
    let mut hyper = ObjWriter::new();
    hyper.field_u64("steps", h.steps).field_u64("warmup", h.warmup);
    write_f32_exact(&mut hyper, "lr", h.lr);
    write_f32_exact(&mut hyper, "weight_decay", h.weight_decay);
    write_f32_exact(&mut hyper, "beta1", h.beta1);
    write_f32_exact(&mut hyper, "beta2", h.beta2);
    hyper.field_str("optimizer", h.optimizer.label());
    if let Some(l) = h.beta2_lambda {
        write_f32_exact(&mut hyper, "beta2_lambda", l);
    }
    if let Some(c) = h.grad_clip {
        write_f32_exact(&mut hyper, "grad_clip", c);
    }
    write_u64_str(&mut hyper, "seed", h.seed);

    let shifts: Vec<String> = ck
        .shifts
        .iter()
        .map(|s| {
            let mut w = ObjWriter::new();
            w.field_u64("at_step", s.at_step);
            write_f32_exact(&mut w, "image_gain", s.image_gain);
            w.field_bool("remap_concepts", s.remap_concepts);
            w.finish()
        })
        .collect();

    let d = &ck.data;
    let mut data = ObjWriter::new();
    write_u64_str(&mut data, "step", d.step);
    write_f32_exact(&mut data, "gain", d.gain);
    let mapping: Vec<String> = d.mapping.iter().map(|m| m.to_string()).collect();
    data.field_raw("mapping", &format!("[{}]", mapping.join(",")));
    let rng: Vec<String> = d.rng.iter().map(|w| json::quote(&w.to_string())).collect();
    data.field_raw("rng", &format!("[{}]", rng.join(",")));
    if let Some(s) = d.rng_spare {
        write_f32_exact(&mut data, "rng_spare", s);
    } else {
        data.field_raw("rng_spare", "null");
    }

    let mut opt = ObjWriter::new();
    opt.field_str("name", &ck.opt.name);
    write_u64_str(&mut opt, "t", ck.opt.t);
    let slots: Vec<String> =
        ck.opt.slots.iter().map(|(label, _)| json::quote(label)).collect();
    opt.field_raw("slots", &format!("[{}]", slots.join(",")));

    CommonSections {
        model: model.finish(),
        hyper: hyper.finish(),
        shifts: format!("[{}]", shifts.join(",")),
        data: data.finish(),
        opt: opt.finish(),
    }
}

/// Assemble a manifest document: the common sections plus the
/// version-specific blob index (`tensors_json`, and for v2 `shards_json`).
fn manifest_json(
    ck: &TrainCheckpoint,
    version: u32,
    tensors_json: &str,
    shards_json: Option<&str>,
) -> String {
    let c = common_sections(ck);
    let mut top = ObjWriter::new();
    top.field_str("format", "switchback-ckpt")
        .field_u64("version", version as u64)
        .field_u64("step", ck.step)
        .field_u64("batch", ck.batch as u64)
        .field_u64("grad_shards", ck.grad_shards as u64)
        .field_raw("model", &c.model)
        .field_raw("hyper", &c.hyper)
        .field_raw("shifts", &c.shifts)
        .field_raw("data", &c.data)
        .field_raw("opt", &c.opt)
        .field_u64("n_params", ck.params.len() as u64);
    if let Some(s) = shards_json {
        top.field_raw("shards", s);
    }
    top.field_raw("tensors", tensors_json);
    top.finish()
}

/// Shard index → span counter for `span_n`, saturating instead of
/// wrapping (shard counts are tiny; the id is display-only).
fn span_id(s: usize) -> u32 {
    u32::try_from(s).unwrap_or(u32::MAX)
}

fn f32s_to_le_bytes(data: &[f32]) -> Vec<u8> {
    let mut out = vec![0u8; data.len() * 4];
    for (chunk, v) in out.chunks_exact_mut(4).zip(data) {
        chunk.copy_from_slice(&v.to_le_bytes());
    }
    out
}

fn le_bytes_to_f32s(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Validate the 16-byte header; returns `(version, manifest length)`.
/// Accepts both known versions — the caller decides which one its
/// container (raw file vs `MANIFEST.sbck`) permits.
fn parse_header(head: &[u8; 16], path: &Path) -> Result<(u32, usize)> {
    if &head[0..4] != MAGIC {
        bail!("{path:?} is not a switchback checkpoint (bad magic)");
    }
    let version = u32::from_le_bytes([head[4], head[5], head[6], head[7]]);
    if version != FORMAT_VERSION && version != FORMAT_VERSION_V2 {
        bail!(
            "{path:?} has format version {version}, this build reads \
             {FORMAT_VERSION} and {FORMAT_VERSION_V2}"
        );
    }
    let mlen = u64::from_le_bytes([
        head[8], head[9], head[10], head[11], head[12], head[13], head[14], head[15],
    ]);
    Ok((version, mlen as usize))
}

/// Rebuild the [`EncoderConfig`] echo from a parsed manifest.
fn encoder_from_manifest(m: &Value) -> Result<EncoderConfig> {
    let model = m.get("model").ok_or_else(|| anyhow!("manifest missing model"))?;
    let kind_s = read_str(model, "kind")?;
    let kind = LinearKind::parse(kind_s)
        .ok_or_else(|| anyhow!("unknown precision kind {kind_s:?}"))?;
    Ok(EncoderConfig {
        kind,
        dim: read_usize(model, "dim")?,
        heads: read_usize(model, "heads")?,
        blocks: read_usize(model, "blocks")?,
        embed_dim: read_usize(model, "embed_dim")?,
        patches: read_usize(model, "patches")?,
        patch_dim: read_usize(model, "patch_dim")?,
        text_seq: read_usize(model, "text_seq")?,
        vocab: read_usize(model, "vocab")?,
        seed: read_u64_str(model, "seed")?,
    })
}

/// Everything a manifest describes apart from the tensor bytes — shared
/// by the v1 and v2 load paths.
struct ManifestCore {
    step: u64,
    encoder: EncoderConfig,
    hyper: TrainHyper,
    shifts: Vec<Shift>,
    batch: usize,
    grad_shards: usize,
    data: DataCursor,
    opt_name: String,
    opt_t: u64,
    slot_labels: Vec<String>,
    n_params: usize,
}

fn manifest_core(m: &Value) -> Result<ManifestCore> {
    let encoder = encoder_from_manifest(m)?;

    let hv = m.get("hyper").ok_or_else(|| anyhow!("manifest missing hyper"))?;
    let opt_s = read_str(hv, "optimizer")?;
    let hyper = TrainHyper {
        steps: read_u64_num(hv, "steps")?,
        warmup: read_u64_num(hv, "warmup")?,
        lr: read_f32_exact(hv, "lr")?,
        weight_decay: read_f32_exact(hv, "weight_decay")?,
        beta1: read_f32_exact(hv, "beta1")?,
        beta2: read_f32_exact(hv, "beta2")?,
        optimizer: OptimizerKind::parse(opt_s)
            .ok_or_else(|| anyhow!("unknown optimizer {opt_s:?}"))?,
        beta2_lambda: read_opt_f32_exact(hv, "beta2_lambda")?,
        grad_clip: read_opt_f32_exact(hv, "grad_clip")?,
        seed: read_u64_str(hv, "seed")?,
    };

    let shifts = m
        .get("shifts")
        .and_then(Value::as_arr)
        .unwrap_or(&[])
        .iter()
        .map(|s| {
            Ok(Shift {
                at_step: read_u64_num(s, "at_step")?,
                image_gain: read_f32_exact(s, "image_gain")?,
                remap_concepts: s
                    .get("remap_concepts")
                    .and_then(Value::as_bool)
                    .unwrap_or(false),
            })
        })
        .collect::<Result<Vec<Shift>>>()?;

    let dv = m.get("data").ok_or_else(|| anyhow!("manifest missing data"))?;
    let rng_words = dv
        .get("rng")
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow!("manifest missing data.rng"))?;
    if rng_words.len() != 4 {
        bail!("data.rng must have 4 words, got {}", rng_words.len());
    }
    let mut rng = [0u64; 4];
    for (dst, w) in rng.iter_mut().zip(rng_words) {
        *dst = w
            .as_str()
            .and_then(|s| s.parse::<u64>().ok())
            .ok_or_else(|| anyhow!("data.rng word is not a u64 string"))?;
    }
    let data = DataCursor {
        step: read_u64_str(dv, "step")?,
        gain: read_f32_exact(dv, "gain")?,
        mapping: dv
            .get("mapping")
            .and_then(Value::as_usize_vec)
            .ok_or_else(|| anyhow!("manifest missing data.mapping"))?,
        rng,
        rng_spare: read_opt_f32_exact(dv, "rng_spare")?,
    };

    let ov = m.get("opt").ok_or_else(|| anyhow!("manifest missing opt"))?;
    let opt_name = read_str(ov, "name")?.to_string();
    let opt_t = read_u64_str(ov, "t")?;
    let slot_labels: Vec<String> = ov
        .get("slots")
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow!("manifest missing opt.slots"))?
        .iter()
        .map(|s| s.as_str().map(str::to_string).ok_or_else(|| anyhow!("bad slot label")))
        .collect::<Result<_>>()?;

    Ok(ManifestCore {
        step: read_u64_num(m, "step")?,
        encoder,
        hyper,
        shifts,
        batch: read_usize(m, "batch")?,
        grad_shards: read_usize(m, "grad_shards")?,
        data,
        opt_name,
        opt_t,
        slot_labels,
        n_params: read_usize(m, "n_params")?,
    })
}

/// Rebuild a [`TrainCheckpoint`] from a decoded core + the tensor blobs
/// in manifest order (params first, then one run per optimizer slot).
fn assemble(core: ManifestCore, names: Vec<String>, mut blobs: Vec<Vec<f32>>) -> TrainCheckpoint {
    let n = core.n_params;
    let params: Vec<Vec<f32>> = blobs.drain(..n).collect();
    let param_names: Vec<String> = names[..n].to_vec();
    let mut slots = Vec::with_capacity(core.slot_labels.len());
    for label in core.slot_labels {
        let bufs: Vec<Vec<f32>> = blobs.drain(..n).collect();
        slots.push((label, bufs));
    }
    TrainCheckpoint {
        step: core.step,
        encoder: core.encoder,
        hyper: core.hyper,
        shifts: core.shifts,
        batch: core.batch,
        grad_shards: core.grad_shards,
        param_names,
        params,
        opt: OptimizerState { name: core.opt_name, t: core.opt_t, slots },
        data: core.data,
    }
}

/// What [`peek`] reads out of a checkpoint without touching its tensor
/// blobs: enough for a watcher to decide whether a snapshot is newer and
/// shape-compatible before paying for the full CRC-checked load.
#[derive(Debug, Clone)]
pub struct CkptPeek {
    /// training step the snapshot was taken after (the freshness key)
    pub step: u64,
    /// model shape + precision kind + init seed echo
    pub encoder: EncoderConfig,
    /// model tensors in the file (excluding optimizer slots)
    pub n_params: usize,
    /// manifest length in bytes (all that was read past the header)
    pub manifest_bytes: usize,
    /// bytes a complete snapshot holds (header + manifest + every tensor
    /// blob; for v2, header + manifest + every shard file)
    pub expected_bytes: u64,
    /// bytes actually on disk right now — `< expected_bytes` means the
    /// blobs are still being written (e.g. a non-atomic copy in flight):
    /// a full [`load`] would fail *now* but may succeed later
    pub file_bytes: u64,
    /// on-disk format version (1 = single file, 2 = sharded directory)
    pub version: u32,
    /// shard-file count (0 for a v1 single-file snapshot)
    pub shards: usize,
    /// completeness verdict: v1 compares file size against the manifest's
    /// blob extent; v2 requires every shard file to exist at (at least)
    /// its declared size
    complete: bool,
}

impl CkptPeek {
    /// Does the on-disk state match what the manifest promises?  (Content
    /// integrity still needs [`load`]'s CRC pass.)  `false` usually means
    /// a non-atomic copy is still in flight — retry later.
    pub fn is_complete(&self) -> bool {
        self.complete
    }
}

/// Read a checkpoint's header + JSON manifest **without loading the
/// tensor blobs** — a few KiB of I/O regardless of model size.  The
/// serve-side standby watcher ([`crate::serve::standby`]) uses this to
/// pick the newest compatible snapshot (newest-manifest-wins) before
/// committing to a full [`load`].  Integrity of the blobs is *not*
/// checked here; that is `load`'s job.
///
/// Dispatches on the path: a directory is peeked through its
/// [`MANIFEST_FILE`] (v2), a file through its own header (v1).  For v2
/// the shard files are only `stat`ed, never read.
pub fn peek(path: &Path) -> Result<CkptPeek> {
    if path.is_dir() {
        return peek_dir(path);
    }
    use std::io::Read;
    let mut f =
        std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut head = [0u8; 16];
    f.read_exact(&mut head)
        .map_err(|_| anyhow!("{path:?} is truncated inside the header"))?;
    let (version, mlen) = parse_header(&head, path)?;
    if version != FORMAT_VERSION {
        bail!(
            "{path:?} is a v{version} shard manifest — peek the snapshot \
             directory that contains it"
        );
    }
    // the length field is untrusted bytes: bound it by the file size
    // before allocating, or a torn header could ask for a huge buffer
    let file_len = f
        .metadata()
        .with_context(|| format!("stat {path:?}"))?
        .len();
    if (mlen as u64).saturating_add(16) > file_len {
        bail!("{path:?} is truncated inside the manifest");
    }
    let mut mbytes = vec![0u8; mlen];
    f.read_exact(&mut mbytes)
        .map_err(|_| anyhow!("{path:?} is truncated inside the manifest"))?;
    let manifest = std::str::from_utf8(&mbytes)
        .map_err(|_| anyhow!("manifest is not UTF-8"))?;
    let m = json::parse(manifest).map_err(|e| anyhow!("bad manifest JSON: {e}"))?;
    // end of the furthest blob per the manifest → the complete file size
    let blob_end: u64 = m
        .get("tensors")
        .and_then(Value::as_arr)
        .map(|ts| {
            ts.iter()
                .filter_map(|t| {
                    let off = t.get("offset").and_then(Value::as_f64)? as u64;
                    let len = t.get("len").and_then(Value::as_f64)? as u64;
                    Some(off.saturating_add(len.saturating_mul(4)))
                })
                .max()
                .unwrap_or(0)
        })
        .unwrap_or(0);
    let expected_bytes = (16 + mlen as u64).saturating_add(blob_end);
    Ok(CkptPeek {
        step: read_u64_num(&m, "step")?,
        encoder: encoder_from_manifest(&m)?,
        n_params: read_usize(&m, "n_params")?,
        manifest_bytes: mlen,
        expected_bytes,
        file_bytes: file_len,
        version: FORMAT_VERSION,
        shards: 0,
        complete: file_len >= expected_bytes,
    })
}

/// The `shards` array of a v2 manifest: `(file, bytes, crc32)` per shard.
fn shard_list(m: &Value) -> Result<Vec<(String, u64, u32)>> {
    m.get("shards")
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow!("manifest missing shards"))?
        .iter()
        .map(|s| {
            Ok((
                read_str(s, "file")?.to_string(),
                read_u64_num(s, "bytes")?,
                u32::try_from(read_u64_num(s, "crc")?)
                    .map_err(|_| anyhow!("manifest shard crc out of u32 range"))?,
            ))
        })
        .collect()
}

/// Read a v2 snapshot directory's root manifest (header-validated,
/// length-bounded).  Returns the parsed document and the manifest byte
/// length.
fn read_dir_manifest(dir: &Path) -> Result<(Value, usize, u64)> {
    let mpath = dir.join(MANIFEST_FILE);
    let raw = std::fs::read(&mpath).with_context(|| format!("reading {mpath:?}"))?;
    if raw.len() < 16 {
        bail!("{mpath:?} is not a switchback checkpoint (bad magic)");
    }
    let Ok(head) = <&[u8; 16]>::try_from(&raw[0..16]) else {
        bail!("{mpath:?} is not a switchback checkpoint (bad magic)");
    };
    let (version, mlen) = parse_header(head, &mpath)?;
    if version != FORMAT_VERSION_V2 {
        bail!(
            "{mpath:?} has format version {version}, a snapshot directory's \
             root manifest must be v{FORMAT_VERSION_V2}"
        );
    }
    let blob_base = match 16usize.checked_add(mlen) {
        Some(b) if b <= raw.len() => b,
        _ => bail!("{mpath:?} is truncated inside the manifest"),
    };
    let manifest = std::str::from_utf8(&raw[16..blob_base])
        .map_err(|_| anyhow!("manifest is not UTF-8"))?;
    let m = json::parse(manifest).map_err(|e| anyhow!("bad manifest JSON: {e}"))?;
    Ok((m, mlen, raw.len() as u64))
}

fn peek_dir(dir: &Path) -> Result<CkptPeek> {
    let (m, mlen, manifest_file_bytes) = read_dir_manifest(dir)?;
    let shards = shard_list(&m)?;
    let mut expected_bytes = 16 + mlen as u64;
    let mut file_bytes = manifest_file_bytes;
    let mut complete = true;
    for (file, bytes, _crc) in &shards {
        expected_bytes = expected_bytes.saturating_add(*bytes);
        match std::fs::metadata(dir.join(file)) {
            // a shard shorter than the manifest promises is a copy still
            // in flight; longer would CRC-fail, but is "present"
            Ok(md) => {
                file_bytes += md.len();
                if md.len() < *bytes {
                    complete = false;
                }
            }
            Err(_) => complete = false,
        }
    }
    Ok(CkptPeek {
        step: read_u64_num(&m, "step")?,
        encoder: encoder_from_manifest(&m)?,
        n_params: read_usize(&m, "n_params")?,
        manifest_bytes: mlen,
        expected_bytes,
        file_bytes,
        version: FORMAT_VERSION_V2,
        shards: shards.len(),
        complete,
    })
}

/// Flat `(name, data)` blob list in the canonical layout order: the model
/// parameters, then one run of per-tensor buffers per optimizer slot.
/// Carries the save-side consistency validation shared by both formats.
fn blob_entries(ck: &TrainCheckpoint) -> Result<Vec<(String, &[f32])>> {
    if ck.param_names.len() != ck.params.len() {
        bail!(
            "param_names ({}) and params ({}) disagree",
            ck.param_names.len(),
            ck.params.len()
        );
    }
    for (label, bufs) in &ck.opt.slots {
        if bufs.len() != ck.params.len() {
            bail!("opt slot {label:?} has {} tensors, model has {}", bufs.len(), ck.params.len());
        }
    }
    let mut out: Vec<(String, &[f32])> =
        Vec::with_capacity(ck.params.len() * (1 + ck.opt.slots.len()));
    for (name, p) in ck.param_names.iter().zip(&ck.params) {
        out.push((name.clone(), p.as_slice()));
    }
    for (label, bufs) in &ck.opt.slots {
        for (name, b) in ck.param_names.iter().zip(bufs) {
            out.push((format!("opt.{label}.{name}"), b.as_slice()));
        }
    }
    Ok(out)
}

/// Contiguous tensor ranges per shard, balanced by byte size — a pure
/// function of `(sizes, shards)`, so the grouping (and therefore the
/// on-disk bytes) is deterministic regardless of worker count.  Every
/// shard gets at least one tensor; the shard count is clamped to the
/// tensor count.
fn shard_plan(sizes: &[usize], shards: usize) -> Vec<std::ops::Range<usize>> {
    let n_t = sizes.len();
    let n = shards.clamp(1, n_t.max(1));
    let total: u64 = sizes.iter().map(|&s| s as u64).sum();
    let mut out = Vec::with_capacity(n);
    let mut start = 0usize;
    let mut cum = 0u64;
    for k in 0..n {
        let target = total * (k as u64 + 1) / n as u64;
        let mut end = start;
        // take tensors until the cumulative size reaches this shard's
        // boundary, but always at least one, and always leave one per
        // remaining shard
        while let Some(&sz) = sizes.get(end) {
            if !((cum < target || end == start) && (n_t - end) > (n - k - 1)) {
                break;
            }
            cum += sz as u64;
            end += 1;
        }
        out.push(start..end);
        start = end;
    }
    out
}

/// Remove whatever is at `p` — file or directory — ignoring "not found".
pub(crate) fn remove_path(p: &Path) -> Result<()> {
    let res = if p.is_dir() { std::fs::remove_dir_all(p) } else { std::fs::remove_file(p) };
    match res {
        Ok(()) => Ok(()),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
        Err(e) => Err(anyhow!("removing {p:?}: {e}")),
    }
}

/// Rename `from` into place at `to`.  A plain rename is atomic and is
/// always tried first (file-over-file overwrites atomically; a fresh
/// name succeeds outright).  Only when that fails — the target is an
/// existing *directory* snapshot, which rename cannot replace — is the
/// old snapshot cleared and the rename retried: the non-atomic window
/// exists solely when overwriting a same-name directory snapshot, never
/// for a sibling and never on the common fresh-name path.
fn rename_over(from: &Path, to: &Path) -> Result<()> {
    if std::fs::rename(from, to).is_ok() {
        return Ok(());
    }
    remove_path(to)?;
    std::fs::rename(from, to).with_context(|| format!("renaming to {to:?}"))
}

fn ensure_parent(path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
        }
    }
    Ok(())
}

/// Serialize `ck` to `path` as a **v1 single file** (atomic: temp file +
/// rename).  Returns bytes written and wall time (save MB/s in
/// BENCH_ckpt.json).  For the sharded v2 layout use [`save_sharded`].
///
/// Round trip (every blob CRC-32-checked on [`load`]; [`peek`] reads the
/// manifest without touching the blobs):
///
/// ```
/// use switchback::ckpt::{load, peek, save, TrainCheckpoint};
/// use switchback::config::TrainHyper;
/// use switchback::data::DataCursor;
/// use switchback::nn::LinearKind;
/// use switchback::optim::OptimizerState;
/// use switchback::serve::EncoderConfig;
///
/// let ck = TrainCheckpoint {
///     step: 3,
///     encoder: EncoderConfig {
///         kind: LinearKind::SwitchBack,
///         dim: 4, heads: 2, blocks: 1, embed_dim: 2,
///         patches: 2, patch_dim: 3, text_seq: 2, vocab: 8, seed: 7,
///     },
///     hyper: TrainHyper::preset(4),
///     shifts: vec![],
///     batch: 2,
///     grad_shards: 1,
///     param_names: vec!["w".into()],
///     params: vec![vec![1.0, -2.5]],
///     opt: OptimizerState {
///         name: "lion".into(),
///         t: 3,
///         slots: vec![("m".into(), vec![vec![0.5, 0.25]])],
///     },
///     data: DataCursor {
///         step: 3, gain: 1.0, mapping: vec![0, 1],
///         rng: [1, 2, 3, 4], rng_spare: None,
///     },
/// };
/// let path = std::env::temp_dir().join("sbck_doctest_roundtrip.sbck");
/// save(&path, &ck)?;
/// let (back, _io) = load(&path)?; // fails closed on any CRC mismatch
/// assert_eq!(back.params, ck.params);
/// assert_eq!(back.opt, ck.opt);
/// assert_eq!(peek(&path)?.step, 3); // manifest only, no tensor load
/// # std::fs::remove_file(&path).ok();
/// # Ok::<(), anyhow::Error>(())
/// ```
pub fn save(path: &Path, ck: &TrainCheckpoint) -> Result<IoStats> {
    let _sp = trace::span("ckpt.save", "ckpt");
    let entries = blob_entries(ck)?;
    let t0 = trace::clock();
    // encode every blob once; offsets/crcs feed the manifest, bytes the file
    let mut blob_meta: Vec<(String, usize, u64, u32)> = vec![];
    let mut blob_bytes: Vec<Vec<u8>> = vec![];
    let mut offset = 0u64;
    {
        let _enc = trace::span("ckpt.encode", "ckpt");
        for (name, data) in &entries {
            let b = f32s_to_le_bytes(data);
            blob_meta.push((name.clone(), data.len(), offset, crc32(&b)));
            offset += b.len() as u64;
            blob_bytes.push(b);
        }
    }
    let tensors: Vec<String> = blob_meta
        .iter()
        .map(|(name, len, off, crc)| {
            let mut w = ObjWriter::new();
            w.field_str("name", name)
                .field_u64("len", *len as u64)
                .field_u64("offset", *off)
                .field_u64("crc", *crc as u64);
            w.finish()
        })
        .collect();
    let manifest = manifest_json(
        ck,
        FORMAT_VERSION,
        &format!("[{}]", tensors.join(",")),
        None,
    );
    debug_assert!(json::parse(&manifest).is_ok(), "invalid ckpt manifest");

    let mut out: Vec<u8> =
        Vec::with_capacity(16 + manifest.len() + offset as usize);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    out.extend_from_slice(&(manifest.len() as u64).to_le_bytes());
    out.extend_from_slice(manifest.as_bytes());
    for b in &blob_bytes {
        out.extend_from_slice(b);
    }
    ensure_parent(path)?;
    let tmp = path.with_extension("sbck.tmp");
    remove_path(&tmp)?; // a crashed v2 staging dir may squat on the name
    {
        let _wr = trace::span("ckpt.write", "ckpt");
        std::fs::write(&tmp, &out).with_context(|| format!("writing {tmp:?}"))?;
        rename_over(&tmp, path)?;
    }
    let secs = t0.elapsed().as_secs_f64();
    trace::global().histogram("ckpt.save_ns").record((secs * 1e9) as u64);
    Ok(IoStats { bytes: out.len() as u64, secs })
}

/// Serialize `ck` to `path` as a **v2 manifest-of-shards directory**:
/// tensors are grouped into `shards` balanced-by-bytes blob files,
/// encoded + CRC'd + written in parallel.  `shards <= 1` falls back to
/// the v1 single-file [`save`].
///
/// Commit protocol: everything lands in a `<path>.tmp` staging directory
/// — each shard via its own temp+rename, the root [`MANIFEST_FILE`]
/// *last* — and the staging directory is renamed into place only once
/// the manifest is down.  An interrupted save therefore never produces a
/// visible snapshot, complete or otherwise.
pub fn save_sharded(path: &Path, ck: &TrainCheckpoint, shards: usize) -> Result<IoStats> {
    if shards <= 1 {
        return save(path, ck);
    }
    let _sp = trace::span("ckpt.save", "ckpt");
    let entries = blob_entries(ck)?;
    let t0 = trace::clock();
    let sizes: Vec<usize> = entries.iter().map(|(_, d)| d.len() * 4).collect();
    let plan = shard_plan(&sizes, shards);
    // encode + CRC every shard in parallel (the compute half of a save)
    let encoded: Vec<(Vec<u8>, u32)> = par_map(plan.len(), |s| {
        // `s < plan.len()` by the par_map contract, and shard_plan built
        // the ranges over these same entries — `.get()` keeps the worker
        // panic-free anyway.
        let range = plan.get(s).cloned().unwrap_or_default();
        let shard_entries = entries.get(range).unwrap_or(&[]);
        let bytes = {
            let _enc = trace::span_n("ckpt.shard_encode", "ckpt", span_id(s));
            let cap = shard_entries.iter().map(|(_, d)| d.len() * 4).sum::<usize>();
            let mut bytes = Vec::with_capacity(cap);
            for (_, data) in shard_entries {
                bytes.extend_from_slice(&f32s_to_le_bytes(data));
            }
            bytes
        };
        let crc = {
            let _crc = trace::span_n("ckpt.shard_crc", "ckpt", span_id(s));
            crc32(&bytes)
        };
        (bytes, crc)
    });

    // manifest index: per-tensor (shard, offset-within-shard), per-shard
    // (file, bytes, crc)
    let mut tensors: Vec<String> = Vec::with_capacity(entries.len());
    for (s, range) in plan.iter().enumerate() {
        let mut off = 0u64;
        for (name, data) in entries.get(range.clone()).unwrap_or(&[]) {
            let mut w = ObjWriter::new();
            w.field_str("name", name)
                .field_u64("len", data.len() as u64)
                .field_u64("shard", s as u64)
                .field_u64("offset", off);
            tensors.push(w.finish());
            off += (data.len() * 4) as u64;
        }
    }
    let shard_entries: Vec<String> = encoded
        .iter()
        .enumerate()
        .map(|(s, (bytes, crc))| {
            let mut w = ObjWriter::new();
            w.field_str("file", &shard_filename(s))
                .field_u64("bytes", bytes.len() as u64)
                .field_u64("crc", *crc as u64);
            w.finish()
        })
        .collect();
    let manifest = manifest_json(
        ck,
        FORMAT_VERSION_V2,
        &format!("[{}]", tensors.join(",")),
        Some(&format!("[{}]", shard_entries.join(","))),
    );
    debug_assert!(json::parse(&manifest).is_ok(), "invalid ckpt manifest");

    ensure_parent(path)?;
    let staging = path.with_extension("sbck.tmp");
    remove_path(&staging)?;
    std::fs::create_dir_all(&staging)
        .with_context(|| format!("creating {staging:?}"))?;
    // shards first, in parallel, each atomically (temp + rename)
    par_try_map(encoded.len(), |s| -> Result<()> {
        let _wr = trace::span_n("ckpt.shard_write", "ckpt", span_id(s));
        let tmp = staging.join(format!("{}.tmp", shard_filename(s)));
        let dst = staging.join(shard_filename(s));
        let shard = encoded.get(s).ok_or_else(|| anyhow!("shard {s} out of range"))?;
        std::fs::write(&tmp, &shard.0).with_context(|| format!("writing {tmp:?}"))?;
        std::fs::rename(&tmp, &dst).with_context(|| format!("renaming to {dst:?}"))?;
        Ok(())
    })?;
    // the root manifest commits the snapshot — written only after every
    // shard is fully down
    let mut head: Vec<u8> = Vec::with_capacity(16 + manifest.len());
    head.extend_from_slice(MAGIC);
    head.extend_from_slice(&FORMAT_VERSION_V2.to_le_bytes());
    head.extend_from_slice(&(manifest.len() as u64).to_le_bytes());
    head.extend_from_slice(manifest.as_bytes());
    let mtmp = staging.join(format!("{MANIFEST_FILE}.tmp"));
    {
        let _mf = trace::span("ckpt.manifest", "ckpt");
        std::fs::write(&mtmp, &head).with_context(|| format!("writing {mtmp:?}"))?;
        std::fs::rename(&mtmp, staging.join(MANIFEST_FILE))
            .with_context(|| format!("committing {MANIFEST_FILE} in {staging:?}"))?;
        rename_over(&staging, path)?;
    }
    let bytes =
        head.len() as u64 + encoded.iter().map(|(b, _)| b.len() as u64).sum::<u64>();
    let secs = t0.elapsed().as_secs_f64();
    trace::global().histogram("ckpt.save_ns").record((secs * 1e9) as u64);
    Ok(IoStats { bytes, secs })
}

/// Deserialize and integrity-check a checkpoint — v1 single file or v2
/// shard directory, dispatched on the path.  Fails closed on a bad
/// magic/version, a truncated file, a missing/short shard, or any
/// blob/shard whose CRC-32 disagrees with the manifest.
pub fn load(path: &Path) -> Result<(TrainCheckpoint, IoStats)> {
    if path.is_dir() {
        return load_dir(path);
    }
    let _sp = trace::span("ckpt.load", "ckpt");
    let t0 = trace::clock();
    let raw = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    let bytes = raw.len() as u64;
    // fail closed on anything shorter than a header — a 0/8/15-byte junk
    // file must return Err, never slice out of bounds
    if raw.len() < 16 {
        bail!("{path:?} is not a switchback checkpoint (bad magic)");
    }
    let Ok(head) = <&[u8; 16]>::try_from(&raw[0..16]) else {
        bail!("{path:?} is not a switchback checkpoint (bad magic)");
    };
    let (version, mlen) = parse_header(head, path)?;
    if version != FORMAT_VERSION {
        bail!(
            "{path:?} is a v{version} shard manifest — load the snapshot \
             directory that contains it"
        );
    }
    // untrusted length field: checked add, or a torn header whose length
    // wraps usize would index past (or before) the buffer
    let blob_base = match 16usize.checked_add(mlen) {
        Some(b) if b <= raw.len() => b,
        _ => bail!("{path:?} is truncated inside the manifest"),
    };
    let manifest = std::str::from_utf8(&raw[16..blob_base])
        .map_err(|_| anyhow!("manifest is not UTF-8"))?;
    let m = json::parse(manifest).map_err(|e| anyhow!("bad manifest JSON: {e}"))?;
    let core = manifest_core(&m)?;

    let tensors = m
        .get("tensors")
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow!("manifest missing tensors"))?;
    let expected = core.n_params * (1 + core.slot_labels.len());
    if tensors.len() != expected {
        bail!("manifest lists {} tensors, expected {expected}", tensors.len());
    }

    let mut names = Vec::with_capacity(tensors.len());
    let mut blobs: Vec<Vec<f32>> = Vec::with_capacity(tensors.len());
    for t in tensors {
        let name = read_str(t, "name")?;
        let len = read_usize(t, "len")?;
        let off = read_usize(t, "offset")?;
        let crc = u32::try_from(read_u64_num(t, "crc")?)
            .map_err(|_| anyhow!("tensor {name:?} crc out of u32 range"))?;
        // len/offset are untrusted manifest values: checked arithmetic,
        // or a corrupt manifest could wrap the bounds math and either
        // panic or slice the wrong bytes instead of failing closed
        let hi = len
            .checked_mul(4)
            .and_then(|b| blob_base.checked_add(off)?.checked_add(b))
            .filter(|&hi| hi <= raw.len())
            .ok_or_else(|| {
                anyhow!("tensor {name:?} extends past end of file (truncated?)")
            })?;
        let lo = blob_base + off;
        let chunk = &raw[lo..hi];
        let got = crc32(chunk);
        if got != crc {
            bail!(
                "tensor {name:?} failed its CRC-32 check \
                 (stored {crc:#010x}, computed {got:#010x}) — corrupt checkpoint"
            );
        }
        names.push(name.to_string());
        blobs.push(le_bytes_to_f32s(chunk));
    }

    let ck = assemble(core, names, blobs);
    let secs = t0.elapsed().as_secs_f64();
    trace::global().histogram("ckpt.load_ns").record((secs * 1e9) as u64);
    Ok((ck, IoStats { bytes, secs }))
}

/// The v2 read path: parse the root manifest, then read + CRC-check every
/// shard file in parallel and slice the tensors out of their shards.
fn load_dir(dir: &Path) -> Result<(TrainCheckpoint, IoStats)> {
    let _sp = trace::span("ckpt.load", "ckpt");
    let t0 = trace::clock();
    let (m, _mlen, manifest_bytes) = read_dir_manifest(dir)?;
    let core = manifest_core(&m)?;
    let shards = shard_list(&m)?;

    // parallel streaming read: each worker reads and CRC-checks one shard
    let shard_bufs: Vec<Vec<u8>> = par_try_map(shards.len(), |s| -> Result<Vec<u8>> {
        let _rd = trace::span_n("ckpt.shard_read", "ckpt", span_id(s));
        let (file, bytes, crc) =
            shards.get(s).ok_or_else(|| anyhow!("shard {s} out of range"))?;
        let p = dir.join(file);
        let b = std::fs::read(&p).with_context(|| format!("reading shard {p:?}"))?;
        if b.len() as u64 != *bytes {
            bail!(
                "shard {file:?} is {} bytes, manifest promises {bytes} \
                 (incomplete copy?)",
                b.len()
            );
        }
        let got = crc32(&b);
        if got != *crc {
            bail!(
                "shard {file:?} failed its CRC-32 check \
                 (stored {crc:#010x}, computed {got:#010x}) — corrupt checkpoint"
            );
        }
        Ok(b)
    })?;

    let tensors = m
        .get("tensors")
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow!("manifest missing tensors"))?;
    let expected = core.n_params * (1 + core.slot_labels.len());
    if tensors.len() != expected {
        bail!("manifest lists {} tensors, expected {expected}", tensors.len());
    }
    let mut names = Vec::with_capacity(tensors.len());
    let mut blobs: Vec<Vec<f32>> = Vec::with_capacity(tensors.len());
    for t in tensors {
        let name = read_str(t, "name")?;
        let len = read_usize(t, "len")?;
        let shard = read_usize(t, "shard")?;
        let off = read_usize(t, "offset")?;
        let buf = shard_bufs.get(shard).ok_or_else(|| {
            anyhow!("tensor {name:?} names shard {shard}, only {} exist", shard_bufs.len())
        })?;
        // untrusted manifest values: checked multiply + add, same
        // fail-closed rule as the v1 tensor bounds above
        let hi = len
            .checked_mul(4)
            .and_then(|b| off.checked_add(b))
            .filter(|&hi| hi <= buf.len())
            .ok_or_else(|| anyhow!("tensor {name:?} extends past end of its shard"))?;
        names.push(name.to_string());
        blobs.push(le_bytes_to_f32s(&buf[off..hi]));
    }
    let ck = assemble(core, names, blobs);
    let bytes = manifest_bytes + shard_bufs.iter().map(|b| b.len() as u64).sum::<u64>();
    let secs = t0.elapsed().as_secs_f64();
    trace::global().histogram("ckpt.load_ns").record((secs * 1e9) as u64);
    Ok((ck, IoStats { bytes, secs }))
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::nn::LinearKind;

    pub(crate) fn sample_ckpt() -> TrainCheckpoint {
        let mut hyper = TrainHyper::preset(40);
        hyper.seed = u64::MAX - 3; // exercise full-range u64 round-trip
        hyper.lr = 0.1; // not exactly representable — exercises *_bits
        hyper.grad_clip = Some(1.0);
        TrainCheckpoint {
            step: 17,
            encoder: EncoderConfig {
                kind: LinearKind::SwitchBack,
                dim: 8,
                heads: 2,
                blocks: 1,
                embed_dim: 4,
                patches: 3,
                patch_dim: 5,
                text_seq: 3,
                vocab: 16,
                seed: 0xDEAD_BEEF_CAFE_F00D,
            },
            hyper,
            shifts: vec![Shift { at_step: 22, image_gain: 6.0, remap_concepts: true }],
            batch: 8,
            grad_shards: 3,
            param_names: vec!["a".into(), "b".into()],
            params: vec![vec![1.0, -2.5, 3.25], vec![0.5]],
            opt: OptimizerState {
                name: "stable_adamw".into(),
                t: 17,
                slots: vec![
                    ("v".into(), vec![vec![0.1, 0.2, 0.3], vec![0.4]]),
                    ("u".into(), vec![vec![1e-9, 2e-9, 3e-9], vec![4e-9]]),
                ],
            },
            data: DataCursor {
                step: 17,
                gain: 6.0,
                mapping: vec![2, 0, 1],
                rng: [u64::MAX, 1, 0x0123_4567_89AB_CDEF, 42],
                rng_spare: Some(0.123_456_79),
            },
        }
    }

    fn assert_ckpt_eq(back: &TrainCheckpoint, ck: &TrainCheckpoint, what: &str) {
        assert_eq!(back.step, ck.step, "{what}: step");
        assert_eq!(back.encoder.kind, ck.encoder.kind, "{what}: kind");
        assert_eq!(back.encoder.seed, ck.encoder.seed, "{what}: model seed");
        assert_eq!(back.hyper.seed, ck.hyper.seed, "{what}: hyper seed");
        assert_eq!(back.hyper.lr.to_bits(), ck.hyper.lr.to_bits(), "{what}: lr bits");
        assert_eq!(back.hyper.grad_clip, ck.hyper.grad_clip, "{what}: clip");
        assert_eq!(back.hyper.optimizer, ck.hyper.optimizer, "{what}: optimizer");
        assert_eq!(back.shifts.len(), ck.shifts.len(), "{what}: shifts");
        assert_eq!((back.batch, back.grad_shards), (ck.batch, ck.grad_shards));
        assert_eq!(back.param_names, ck.param_names, "{what}: names");
        assert_eq!(back.params, ck.params, "{what}: params");
        assert_eq!(back.opt, ck.opt, "{what}: optimizer state");
        assert_eq!(back.data, ck.data, "{what}: data cursor");
    }

    #[test]
    fn save_load_roundtrip_is_exact() {
        let dir = std::env::temp_dir().join("sbck_fmt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.sbck");
        let ck = sample_ckpt();
        let saved = save(&path, &ck).unwrap();
        assert!(saved.bytes > 0 && saved.secs >= 0.0);
        let (back, loaded) = load(&path).unwrap();
        assert_eq!(loaded.bytes, saved.bytes);
        assert_ckpt_eq(&back, &ck, "v1 roundtrip");
        assert_eq!(back.shifts[0].at_step, 22);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corruption_and_bad_headers_fail_closed() {
        let dir = std::env::temp_dir().join("sbck_fmt_corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.sbck");
        let ck = sample_ckpt();
        save(&path, &ck).unwrap();

        // flip one bit inside the last tensor blob
        let mut raw = std::fs::read(&path).unwrap();
        let n = raw.len();
        raw[n - 2] ^= 0x40;
        let bad = dir.join("bitflip.sbck");
        std::fs::write(&bad, &raw).unwrap();
        let err = load(&bad).unwrap_err().to_string();
        assert!(err.contains("CRC-32"), "{err}");

        // truncation inside the blobs
        let trunc = dir.join("trunc.sbck");
        std::fs::write(&trunc, &std::fs::read(&path).unwrap()[..n - 3]).unwrap();
        assert!(load(&trunc).is_err());

        // wrong magic
        let junk = dir.join("junk.sbck");
        std::fs::write(&junk, b"NOPE....rest").unwrap();
        let err = load(&junk).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");

        // future version
        let mut raw = std::fs::read(&path).unwrap();
        raw[4] = 99;
        let vfile = dir.join("v99.sbck");
        std::fs::write(&vfile, &raw).unwrap();
        let err = load(&vfile).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The short-file regression (ISSUE 5 satellite): `load` on a file
    /// shorter than the 16-byte header must return the fail-closed `Err`
    /// path — never slice out of bounds — exactly like `peek` already
    /// does.  Covers 0-, 8- and 15-byte junk for both entry points.
    #[test]
    fn load_and_peek_fail_closed_on_short_files() {
        let dir = std::env::temp_dir().join("sbck_fmt_short");
        std::fs::create_dir_all(&dir).unwrap();
        for n in [0usize, 8, 15] {
            let p = dir.join(format!("short{n}.sbck"));
            // 8/15-byte prefixes of a real header: the nastiest torn writes
            let mut bytes = Vec::new();
            bytes.extend_from_slice(MAGIC);
            bytes.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
            bytes.extend_from_slice(&u64::MAX.to_le_bytes());
            bytes.truncate(n);
            std::fs::write(&p, &bytes).unwrap();
            let err = load(&p).unwrap_err().to_string();
            assert!(
                err.contains("magic") || err.contains("truncated"),
                "{n}-byte file: {err}"
            );
            let err = peek(&p).unwrap_err().to_string();
            assert!(err.contains("truncated"), "{n}-byte peek: {err}");
        }
        // same torn prefixes as a v2 root manifest: the directory loader
        // must fail closed identically
        let snap = dir.join("ckpt-00000001.sbck");
        std::fs::create_dir_all(&snap).unwrap();
        std::fs::write(snap.join(MANIFEST_FILE), b"SBCK").unwrap();
        assert!(load(&snap).is_err());
        assert!(peek(&snap).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// `peek` reads only the header + manifest: it must succeed — and
    /// agree with the manifest — even on a file whose tensor blobs are
    /// truncated (which `load` correctly rejects).
    #[test]
    fn peek_reads_manifest_without_touching_blobs() {
        let dir = std::env::temp_dir().join("sbck_fmt_peek");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.sbck");
        let ck = sample_ckpt();
        save(&path, &ck).unwrap();
        let p = peek(&path).unwrap();
        assert_eq!(p.step, ck.step);
        assert_eq!(p.n_params, ck.params.len());
        assert_eq!(p.encoder.kind, ck.encoder.kind);
        assert_eq!(p.encoder.seed, ck.encoder.seed);
        assert_eq!(p.encoder.dim, ck.encoder.dim);
        assert!(p.manifest_bytes > 0);
        assert_eq!((p.version, p.shards), (FORMAT_VERSION, 0));
        assert!(p.is_complete(), "a finished save must peek complete");
        assert_eq!(p.expected_bytes, p.file_bytes, "save writes exactly the blobs");

        // drop the last tensor bytes: load fails closed, peek still works
        // — and reports the file as incomplete (a copy still in flight)
        let raw = std::fs::read(&path).unwrap();
        let trunc = dir.join("trunc.sbck");
        std::fs::write(&trunc, &raw[..raw.len() - 3]).unwrap();
        assert!(load(&trunc).is_err(), "truncated blobs must fail load");
        let tp = peek(&trunc).unwrap();
        assert_eq!(tp.step, ck.step);
        assert!(!tp.is_complete(), "missing blob bytes must show as incomplete");

        // header/manifest damage still fails peek closed: a full 16-byte
        // header with a wrong magic, a short file, and a header whose
        // manifest-length field asks for more bytes than the file holds
        let junk = dir.join("junk.sbck");
        std::fs::write(&junk, b"NOPE....0123456789ab").unwrap();
        assert!(peek(&junk).unwrap_err().to_string().contains("magic"));
        let short = dir.join("short.sbck");
        std::fs::write(&short, b"SBCK").unwrap();
        assert!(peek(&short).unwrap_err().to_string().contains("truncated"));
        let mut lying = Vec::new();
        lying.extend_from_slice(MAGIC);
        lying.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        lying.extend_from_slice(&u64::MAX.to_le_bytes()); // absurd manifest len
        let huge = dir.join("huge.sbck");
        std::fs::write(&huge, &lying).unwrap();
        let err = peek(&huge).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_is_atomic_no_tmp_left_behind() {
        let dir = std::env::temp_dir().join("sbck_fmt_atomic");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.sbck");
        save(&path, &sample_ckpt()).unwrap();
        save_sharded(&dir.join("b.sbck"), &sample_ckpt(), 3).unwrap();
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().contains("tmp"))
            .collect();
        assert!(leftovers.is_empty(), "temp file left behind: {leftovers:?}");
        // and none inside the committed shard directory either
        let inner: Vec<_> = std::fs::read_dir(dir.join("b.sbck"))
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().contains("tmp"))
            .collect();
        assert!(inner.is_empty(), "shard temp left behind: {inner:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_plan_covers_all_tensors_contiguously() {
        for (sizes, shards) in [
            (vec![10usize, 20, 30, 40, 50, 60], 4usize),
            (vec![1000, 1, 1, 1], 4),
            (vec![4], 4),
            (vec![8, 8], 1),
            (vec![], 3),
            (vec![5; 29], 4), // the pipeline's 29-tensor model
        ] {
            let plan = shard_plan(&sizes, shards);
            let n = shards.clamp(1, sizes.len().max(1));
            assert_eq!(plan.len(), n, "{sizes:?}/{shards}");
            assert_eq!(plan[0].start, 0);
            assert_eq!(plan.last().unwrap().end, sizes.len());
            for w in plan.windows(2) {
                assert_eq!(w[0].end, w[1].start, "contiguous");
            }
            if !sizes.is_empty() {
                assert!(plan.iter().all(|r| !r.is_empty()), "{plan:?}");
            }
            // deterministic
            assert_eq!(plan, shard_plan(&sizes, shards));
        }
    }

    /// The v2 tentpole contract: a sharded save round-trips to the exact
    /// same [`TrainCheckpoint`] as the v1 single file — params, optimizer
    /// moments, cursor, hyper bits — and `peek` understands the directory
    /// without reading a shard.
    #[test]
    fn sharded_roundtrip_is_bit_identical_to_v1() {
        let dir = std::env::temp_dir().join("sbck_fmt_v2_rt");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let ck = sample_ckpt();
        let v1 = dir.join("one.sbck");
        save(&v1, &ck).unwrap();
        let (from_v1, _) = load(&v1).unwrap();

        for shards in [2usize, 4, 64 /* clamps to the 6 tensors */] {
            let v2 = dir.join(format!("sharded{shards}.sbck"));
            let io = save_sharded(&v2, &ck, shards).unwrap();
            assert!(v2.is_dir(), "v2 snapshot must be a directory");
            assert!(io.bytes > 0);
            let (back, lio) = load(&v2).unwrap();
            assert_eq!(lio.bytes, io.bytes, "load must see what save wrote");
            assert_ckpt_eq(&back, &ck, "v2 roundtrip");
            assert_ckpt_eq(&back, &from_v1, "v2 vs v1");

            let p = peek(&v2).unwrap();
            assert_eq!(p.step, ck.step);
            assert_eq!(p.version, FORMAT_VERSION_V2);
            assert_eq!(p.shards, shards.min(6), "6 tensors cap the shard count");
            assert_eq!(p.n_params, ck.params.len());
            assert!(p.is_complete());
            assert_eq!(p.expected_bytes, p.file_bytes);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Incomplete-shard detection (the generalized blob-size retry) and
    /// per-shard CRC enforcement.
    #[test]
    fn sharded_corruption_and_incomplete_copies_fail_closed() {
        let dir = std::env::temp_dir().join("sbck_fmt_v2_bad");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let ck = sample_ckpt();
        let snap = dir.join("s.sbck");
        save_sharded(&snap, &ck, 3).unwrap();

        // bit-flip inside a shard: the shard CRC catches it
        let s1 = snap.join(shard_filename(1));
        let mut raw = std::fs::read(&s1).unwrap();
        raw[0] ^= 0x01;
        std::fs::write(&s1, &raw).unwrap();
        let err = load(&snap).unwrap_err().to_string();
        assert!(err.contains("CRC-32"), "{err}");
        raw[0] ^= 0x01;
        std::fs::write(&s1, &raw).unwrap();
        load(&snap).unwrap();

        // truncate a shard: peek flags incomplete (copy in flight), load
        // fails closed naming the shard
        let full = std::fs::read(&s1).unwrap();
        std::fs::write(&s1, &full[..full.len() - 4]).unwrap();
        let p = peek(&snap).unwrap();
        assert!(!p.is_complete(), "short shard must peek incomplete");
        let err = load(&snap).unwrap_err().to_string();
        assert!(err.contains("incomplete") || err.contains("bytes"), "{err}");
        std::fs::write(&s1, &full).unwrap();

        // delete a shard entirely: same story
        std::fs::remove_file(&s1).unwrap();
        assert!(!peek(&snap).unwrap().is_complete());
        assert!(load(&snap).is_err());
        std::fs::write(&s1, &full).unwrap();
        load(&snap).unwrap();

        // no manifest at all (producer crashed pre-commit, or a copy that
        // has not reached it yet): peek and load both fail closed
        let uncommitted = dir.join("u.sbck");
        std::fs::create_dir_all(&uncommitted).unwrap();
        std::fs::write(uncommitted.join(shard_filename(0)), b"data").unwrap();
        assert!(peek(&uncommitted).is_err());
        assert!(load(&uncommitted).is_err());

        // a v2 manifest fed to the flat-file loader is redirected, not
        // misparsed
        let err = load(&snap.join(MANIFEST_FILE)).unwrap_err().to_string();
        assert!(err.contains("directory"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Rewrite a header+manifest container with a tampered manifest
    /// (fixing up the length field), keeping any trailing bytes.
    fn retampered(raw: &[u8], from: &str, to: &str) -> Vec<u8> {
        let mlen = u64::from_le_bytes(raw[8..16].try_into().unwrap()) as usize;
        let manifest = std::str::from_utf8(&raw[16..16 + mlen]).unwrap();
        let tampered = manifest.replacen(from, to, 1);
        assert_ne!(manifest, tampered, "tamper target {from:?} not found");
        let mut out = Vec::new();
        out.extend_from_slice(&raw[0..8]);
        out.extend_from_slice(&(tampered.len() as u64).to_le_bytes());
        out.extend_from_slice(tampered.as_bytes());
        out.extend_from_slice(&raw[16 + mlen..]);
        out
    }

    /// Untrusted-manifest arithmetic must fail closed, never wrap or
    /// panic: a tensor `len` of 2^62 (exactly representable as a JSON
    /// f64; `len * 4` would wrap to 0 in release and panic in debug)
    /// makes `load` return Err on both on-disk versions.
    #[test]
    fn absurd_manifest_tensor_lengths_fail_closed() {
        let dir = std::env::temp_dir().join("sbck_fmt_absurd_len");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let ck = sample_ckpt();
        let huge = "4611686018427387904"; // 2^62

        let v1 = dir.join("a.sbck");
        save(&v1, &ck).unwrap();
        let raw = std::fs::read(&v1).unwrap();
        let bad = dir.join("bad.sbck");
        std::fs::write(&bad, retampered(&raw, "\"len\":3", &format!("\"len\":{huge}")))
            .unwrap();
        let err = load(&bad).unwrap_err().to_string();
        assert!(err.contains("extends past"), "{err}");

        let v2 = dir.join("s.sbck");
        save_sharded(&v2, &ck, 3).unwrap();
        let mpath = v2.join(MANIFEST_FILE);
        let raw = std::fs::read(&mpath).unwrap();
        std::fs::write(&mpath, retampered(&raw, "\"len\":3", &format!("\"len\":{huge}")))
            .unwrap();
        let err = load(&v2).unwrap_err().to_string();
        assert!(err.contains("extends past"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Overwriting a same-name snapshot works across every version pair
    /// — the clear-and-retry rename replaces dir targets that a plain
    /// rename cannot.
    #[test]
    fn saves_replace_same_name_snapshots_across_versions() {
        let dir = std::env::temp_dir().join("sbck_fmt_overwrite");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let ck = sample_ckpt();
        let p = dir.join("x.sbck");
        save_sharded(&p, &ck, 2).unwrap();
        save_sharded(&p, &ck, 3).unwrap(); // dir over dir
        assert_eq!(peek(&p).unwrap().shards, 3, "old shards must not linger");
        load(&p).unwrap();
        save(&p, &ck).unwrap(); // file over dir
        assert!(p.is_file());
        load(&p).unwrap();
        save_sharded(&p, &ck, 2).unwrap(); // dir over file
        assert!(p.is_dir());
        load(&p).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Sharded save bytes are deterministic under any worker count — the
    /// foundation of the async-save bit-identity guarantee.
    #[test]
    fn sharded_save_bytes_identical_across_thread_counts() {
        let dir = std::env::temp_dir().join("sbck_fmt_v2_threads");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let ck = sample_ckpt();
        let mut trees: Vec<Vec<(String, Vec<u8>)>> = vec![];
        for threads in ["1", "4"] {
            let _lock = crate::util::threads::THREADS_ENV_TEST_LOCK
                .lock()
                .unwrap_or_else(|e| e.into_inner());
            std::env::set_var("SWITCHBACK_THREADS", threads);
            let snap = dir.join(format!("t{threads}.sbck"));
            save_sharded(&snap, &ck, 3).unwrap();
            std::env::remove_var("SWITCHBACK_THREADS");
            let mut files: Vec<(String, Vec<u8>)> = std::fs::read_dir(&snap)
                .unwrap()
                .flatten()
                .map(|e| {
                    (
                        e.file_name().to_string_lossy().into_owned(),
                        std::fs::read(e.path()).unwrap(),
                    )
                })
                .collect();
            files.sort();
            trees.push(files);
        }
        assert_eq!(
            trees[0], trees[1],
            "sharded snapshot bytes must not depend on SWITCHBACK_THREADS"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
