//! The on-disk checkpoint format (version 1).
//!
//! ```text
//! bytes 0..4   magic  b"SBCK"
//! bytes 4..8   format version, u32 LE  (currently 1)
//! bytes 8..16  manifest length M, u64 LE
//! bytes 16..16+M  JSON manifest (util::json writer; human-inspectable)
//! then         raw tensor blobs: little-endian f32, contiguous, at the
//!              offsets recorded in the manifest (relative to blob base),
//!              each CRC-32-checked on load
//! ```
//!
//! Blob order: the model parameters in `ClipTrainModel::collect_params`
//! layout order, then one run of per-tensor buffers per optimizer slot
//! (`opt.<slot>.<tensor>`).  Exactness rules: full-range integers (seeds,
//! RNG words, step counters) are serialized as decimal *strings* — JSON
//! numbers are f64 and silently lose u64 precision; scalar f32 state the
//! resume math depends on (data gain, Box–Muller spare, hyper floats) is
//! serialized twice, display value for humans plus `*_bits` (the IEEE bit
//! pattern) for exact reload.
//!
//! Saves write `<path>.tmp` then rename, so an interrupted snapshot never
//! corrupts an existing file.

use crate::config::{OptimizerKind, TrainHyper};
use crate::data::{DataCursor, Shift};
use crate::nn::LinearKind;
use crate::optim::OptimizerState;
use crate::serve::EncoderConfig;
use crate::util::crc32::crc32;
use crate::util::json::{self, ObjWriter, Value};
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;
use std::time::Instant;

/// File magic: the first four bytes of every checkpoint.
pub const MAGIC: &[u8; 4] = b"SBCK";
/// On-disk format version this build writes and reads.
pub const FORMAT_VERSION: u32 = 1;

/// Everything a resumed run needs to continue bit-identically (see the
/// module docs of [`crate::ckpt`] for the inventory).
#[derive(Debug, Clone)]
pub struct TrainCheckpoint {
    /// training step this snapshot was taken *after* (0 = pre-training)
    pub step: u64,
    /// model shape + precision kind + init seed
    pub encoder: EncoderConfig,
    /// optimizer/schedule hyperparameters of the run being snapshotted
    pub hyper: TrainHyper,
    /// the run's scheduled distribution shifts (the un-fired tail matters)
    pub shifts: Vec<Shift>,
    /// examples per step — changes the data draws, so resume validates it
    pub batch: usize,
    /// gradient-accumulation shard count — changes summation order ditto
    pub grad_shards: usize,
    /// tensor names, index-aligned with `params` (the train model layout)
    pub param_names: Vec<String>,
    pub params: Vec<Vec<f32>>,
    pub opt: OptimizerState,
    pub data: DataCursor,
}

/// Bytes moved and wall time of one save/load (the BENCH_ckpt numbers).
#[derive(Debug, Clone, Copy)]
pub struct IoStats {
    pub bytes: u64,
    pub secs: f64,
}

impl IoStats {
    /// Throughput of the save/load this measures.
    pub fn mb_per_s(&self) -> f64 {
        self.bytes as f64 / 1e6 / self.secs.max(1e-9)
    }
}

fn write_f32_exact(w: &mut ObjWriter, key: &str, v: f32) {
    w.field_f32(key, v);
    w.field_u64(&format!("{key}_bits"), v.to_bits() as u64);
}

fn read_f32_exact(v: &Value, key: &str) -> Result<f32> {
    if let Some(b) = v.get(&format!("{key}_bits")).and_then(Value::as_f64) {
        return Ok(f32::from_bits(b as u32));
    }
    v.get(key)
        .and_then(Value::as_f64)
        .map(|x| x as f32)
        .ok_or_else(|| anyhow!("manifest missing {key}"))
}

fn read_opt_f32_exact(v: &Value, key: &str) -> Result<Option<f32>> {
    match v.get(key) {
        None | Some(Value::Null) => Ok(None),
        Some(_) => read_f32_exact(v, key).map(Some),
    }
}

fn write_u64_str(w: &mut ObjWriter, key: &str, v: u64) {
    w.field_str(key, &v.to_string());
}

fn read_u64_str(v: &Value, key: &str) -> Result<u64> {
    v.get(key)
        .and_then(Value::as_str)
        .ok_or_else(|| anyhow!("manifest missing {key}"))?
        .parse::<u64>()
        .map_err(|_| anyhow!("manifest {key} is not a u64"))
}

fn read_u64_num(v: &Value, key: &str) -> Result<u64> {
    v.get(key)
        .and_then(Value::as_f64)
        .map(|x| x as u64)
        .ok_or_else(|| anyhow!("manifest missing {key}"))
}

fn read_usize(v: &Value, key: &str) -> Result<usize> {
    v.get(key)
        .and_then(Value::as_usize)
        .ok_or_else(|| anyhow!("manifest missing {key}"))
}

fn read_str<'a>(v: &'a Value, key: &str) -> Result<&'a str> {
    v.get(key)
        .and_then(Value::as_str)
        .ok_or_else(|| anyhow!("manifest missing {key}"))
}

fn manifest_json(ck: &TrainCheckpoint, blobs: &[(String, usize, u64, u32)]) -> String {
    let e = &ck.encoder;
    let mut model = ObjWriter::new();
    model
        .field_str("kind", e.kind.label())
        .field_u64("dim", e.dim as u64)
        .field_u64("heads", e.heads as u64)
        .field_u64("blocks", e.blocks as u64)
        .field_u64("embed_dim", e.embed_dim as u64)
        .field_u64("patches", e.patches as u64)
        .field_u64("patch_dim", e.patch_dim as u64)
        .field_u64("text_seq", e.text_seq as u64)
        .field_u64("vocab", e.vocab as u64);
    write_u64_str(&mut model, "seed", e.seed);

    let h = &ck.hyper;
    let mut hyper = ObjWriter::new();
    hyper.field_u64("steps", h.steps).field_u64("warmup", h.warmup);
    write_f32_exact(&mut hyper, "lr", h.lr);
    write_f32_exact(&mut hyper, "weight_decay", h.weight_decay);
    write_f32_exact(&mut hyper, "beta1", h.beta1);
    write_f32_exact(&mut hyper, "beta2", h.beta2);
    hyper.field_str("optimizer", h.optimizer.label());
    if let Some(l) = h.beta2_lambda {
        write_f32_exact(&mut hyper, "beta2_lambda", l);
    }
    if let Some(c) = h.grad_clip {
        write_f32_exact(&mut hyper, "grad_clip", c);
    }
    write_u64_str(&mut hyper, "seed", h.seed);

    let shifts: Vec<String> = ck
        .shifts
        .iter()
        .map(|s| {
            let mut w = ObjWriter::new();
            w.field_u64("at_step", s.at_step);
            write_f32_exact(&mut w, "image_gain", s.image_gain);
            w.field_bool("remap_concepts", s.remap_concepts);
            w.finish()
        })
        .collect();

    let d = &ck.data;
    let mut data = ObjWriter::new();
    write_u64_str(&mut data, "step", d.step);
    write_f32_exact(&mut data, "gain", d.gain);
    let mapping: Vec<String> = d.mapping.iter().map(|m| m.to_string()).collect();
    data.field_raw("mapping", &format!("[{}]", mapping.join(",")));
    let rng: Vec<String> = d.rng.iter().map(|w| json::quote(&w.to_string())).collect();
    data.field_raw("rng", &format!("[{}]", rng.join(",")));
    if let Some(s) = d.rng_spare {
        write_f32_exact(&mut data, "rng_spare", s);
    } else {
        data.field_raw("rng_spare", "null");
    }

    let mut opt = ObjWriter::new();
    opt.field_str("name", &ck.opt.name);
    write_u64_str(&mut opt, "t", ck.opt.t);
    let slots: Vec<String> =
        ck.opt.slots.iter().map(|(label, _)| json::quote(label)).collect();
    opt.field_raw("slots", &format!("[{}]", slots.join(",")));

    let tensors: Vec<String> = blobs
        .iter()
        .map(|(name, len, offset, crc)| {
            let mut w = ObjWriter::new();
            w.field_str("name", name)
                .field_u64("len", *len as u64)
                .field_u64("offset", *offset)
                .field_u64("crc", *crc as u64);
            w.finish()
        })
        .collect();

    let mut top = ObjWriter::new();
    top.field_str("format", "switchback-ckpt")
        .field_u64("version", FORMAT_VERSION as u64)
        .field_u64("step", ck.step)
        .field_u64("batch", ck.batch as u64)
        .field_u64("grad_shards", ck.grad_shards as u64)
        .field_raw("model", &model.finish())
        .field_raw("hyper", &hyper.finish())
        .field_raw("shifts", &format!("[{}]", shifts.join(",")))
        .field_raw("data", &data.finish())
        .field_raw("opt", &opt.finish())
        .field_u64("n_params", ck.params.len() as u64)
        .field_raw("tensors", &format!("[{}]", tensors.join(",")));
    top.finish()
}

fn f32s_to_le_bytes(data: &[f32]) -> Vec<u8> {
    let mut out = vec![0u8; data.len() * 4];
    for (chunk, v) in out.chunks_exact_mut(4).zip(data) {
        chunk.copy_from_slice(&v.to_le_bytes());
    }
    out
}

fn le_bytes_to_f32s(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Validate the 16-byte header; returns the manifest length in bytes.
fn parse_header(head: &[u8; 16], path: &Path) -> Result<usize> {
    if &head[0..4] != MAGIC {
        bail!("{path:?} is not a switchback checkpoint (bad magic)");
    }
    let version = u32::from_le_bytes([head[4], head[5], head[6], head[7]]);
    if version != FORMAT_VERSION {
        bail!("{path:?} has format version {version}, this build reads {FORMAT_VERSION}");
    }
    Ok(u64::from_le_bytes(head[8..16].try_into().unwrap()) as usize)
}

/// Rebuild the [`EncoderConfig`] echo from a parsed manifest.
fn encoder_from_manifest(m: &Value) -> Result<EncoderConfig> {
    let model = m.get("model").ok_or_else(|| anyhow!("manifest missing model"))?;
    let kind_s = read_str(model, "kind")?;
    let kind = LinearKind::parse(kind_s)
        .ok_or_else(|| anyhow!("unknown precision kind {kind_s:?}"))?;
    Ok(EncoderConfig {
        kind,
        dim: read_usize(model, "dim")?,
        heads: read_usize(model, "heads")?,
        blocks: read_usize(model, "blocks")?,
        embed_dim: read_usize(model, "embed_dim")?,
        patches: read_usize(model, "patches")?,
        patch_dim: read_usize(model, "patch_dim")?,
        text_seq: read_usize(model, "text_seq")?,
        vocab: read_usize(model, "vocab")?,
        seed: read_u64_str(model, "seed")?,
    })
}

/// What [`peek`] reads out of a checkpoint without touching its tensor
/// blobs: enough for a watcher to decide whether a snapshot is newer and
/// shape-compatible before paying for the full CRC-checked load.
#[derive(Debug, Clone)]
pub struct CkptPeek {
    /// training step the snapshot was taken after (the freshness key)
    pub step: u64,
    /// model shape + precision kind + init seed echo
    pub encoder: EncoderConfig,
    /// model tensors in the file (excluding optimizer slots)
    pub n_params: usize,
    /// manifest length in bytes (all that was read past the header)
    pub manifest_bytes: usize,
    /// bytes the manifest says a complete file holds (header + manifest
    /// + every tensor blob)
    pub expected_bytes: u64,
    /// bytes actually on disk right now — `< expected_bytes` means the
    /// blobs are still being written (e.g. a non-atomic copy in flight):
    /// a full [`load`] would fail *now* but may succeed later
    pub file_bytes: u64,
}

impl CkptPeek {
    /// Does the on-disk size match what the manifest promises?  (Content
    /// integrity still needs [`load`]'s CRC pass.)
    pub fn is_complete(&self) -> bool {
        self.file_bytes >= self.expected_bytes
    }
}

/// Read a checkpoint's header + JSON manifest **without loading the
/// tensor blobs** — a few KiB of I/O regardless of model size.  The
/// serve-side standby watcher ([`crate::serve::standby`]) uses this to
/// pick the newest compatible snapshot (newest-manifest-wins) before
/// committing to a full [`load`].  Integrity of the blobs is *not*
/// checked here; that is `load`'s job.
pub fn peek(path: &Path) -> Result<CkptPeek> {
    use std::io::Read;
    let mut f =
        std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut head = [0u8; 16];
    f.read_exact(&mut head)
        .map_err(|_| anyhow!("{path:?} is truncated inside the header"))?;
    let mlen = parse_header(&head, path)?;
    // the length field is untrusted bytes: bound it by the file size
    // before allocating, or a torn header could ask for a huge buffer
    let file_len = f
        .metadata()
        .with_context(|| format!("stat {path:?}"))?
        .len();
    if (mlen as u64).saturating_add(16) > file_len {
        bail!("{path:?} is truncated inside the manifest");
    }
    let mut mbytes = vec![0u8; mlen];
    f.read_exact(&mut mbytes)
        .map_err(|_| anyhow!("{path:?} is truncated inside the manifest"))?;
    let manifest = std::str::from_utf8(&mbytes)
        .map_err(|_| anyhow!("manifest is not UTF-8"))?;
    let m = json::parse(manifest).map_err(|e| anyhow!("bad manifest JSON: {e}"))?;
    // end of the furthest blob per the manifest → the complete file size
    let blob_end: u64 = m
        .get("tensors")
        .and_then(Value::as_arr)
        .map(|ts| {
            ts.iter()
                .filter_map(|t| {
                    let off = t.get("offset").and_then(Value::as_f64)? as u64;
                    let len = t.get("len").and_then(Value::as_f64)? as u64;
                    Some(off.saturating_add(len.saturating_mul(4)))
                })
                .max()
                .unwrap_or(0)
        })
        .unwrap_or(0);
    Ok(CkptPeek {
        step: read_u64_num(&m, "step")?,
        encoder: encoder_from_manifest(&m)?,
        n_params: read_usize(&m, "n_params")?,
        manifest_bytes: mlen,
        expected_bytes: (16 + mlen as u64).saturating_add(blob_end),
        file_bytes: file_len,
    })
}

/// Serialize `ck` to `path` (atomic: temp file + rename).  Returns bytes
/// written and wall time (save MB/s in BENCH_ckpt.json).
///
/// Round trip (every blob CRC-32-checked on [`load`]; [`peek`] reads the
/// manifest without touching the blobs):
///
/// ```
/// use switchback::ckpt::{load, peek, save, TrainCheckpoint};
/// use switchback::config::TrainHyper;
/// use switchback::data::DataCursor;
/// use switchback::nn::LinearKind;
/// use switchback::optim::OptimizerState;
/// use switchback::serve::EncoderConfig;
///
/// let ck = TrainCheckpoint {
///     step: 3,
///     encoder: EncoderConfig {
///         kind: LinearKind::SwitchBack,
///         dim: 4, heads: 2, blocks: 1, embed_dim: 2,
///         patches: 2, patch_dim: 3, text_seq: 2, vocab: 8, seed: 7,
///     },
///     hyper: TrainHyper::preset(4),
///     shifts: vec![],
///     batch: 2,
///     grad_shards: 1,
///     param_names: vec!["w".into()],
///     params: vec![vec![1.0, -2.5]],
///     opt: OptimizerState {
///         name: "lion".into(),
///         t: 3,
///         slots: vec![("m".into(), vec![vec![0.5, 0.25]])],
///     },
///     data: DataCursor {
///         step: 3, gain: 1.0, mapping: vec![0, 1],
///         rng: [1, 2, 3, 4], rng_spare: None,
///     },
/// };
/// let path = std::env::temp_dir().join("sbck_doctest_roundtrip.sbck");
/// save(&path, &ck)?;
/// let (back, _io) = load(&path)?; // fails closed on any CRC mismatch
/// assert_eq!(back.params, ck.params);
/// assert_eq!(back.opt, ck.opt);
/// assert_eq!(peek(&path)?.step, 3); // manifest only, no tensor load
/// # std::fs::remove_file(&path).ok();
/// # Ok::<(), anyhow::Error>(())
/// ```
pub fn save(path: &Path, ck: &TrainCheckpoint) -> Result<IoStats> {
    if ck.param_names.len() != ck.params.len() {
        bail!(
            "param_names ({}) and params ({}) disagree",
            ck.param_names.len(),
            ck.params.len()
        );
    }
    for (label, bufs) in &ck.opt.slots {
        if bufs.len() != ck.params.len() {
            bail!("opt slot {label:?} has {} tensors, model has {}", bufs.len(), ck.params.len());
        }
    }
    let t0 = Instant::now();
    // encode every blob once; offsets/crcs feed the manifest, bytes the file
    let mut blob_meta: Vec<(String, usize, u64, u32)> = vec![];
    let mut blob_bytes: Vec<Vec<u8>> = vec![];
    let mut offset = 0u64;
    let mut push = |name: String, data: &[f32], meta: &mut Vec<_>, bytes: &mut Vec<Vec<u8>>| {
        let b = f32s_to_le_bytes(data);
        meta.push((name, data.len(), offset, crc32(&b)));
        offset += b.len() as u64;
        bytes.push(b);
    };
    for (name, p) in ck.param_names.iter().zip(&ck.params) {
        push(name.clone(), p, &mut blob_meta, &mut blob_bytes);
    }
    for (label, bufs) in &ck.opt.slots {
        for (name, b) in ck.param_names.iter().zip(bufs) {
            push(format!("opt.{label}.{name}"), b, &mut blob_meta, &mut blob_bytes);
        }
    }
    let manifest = manifest_json(ck, &blob_meta);
    debug_assert!(json::parse(&manifest).is_ok(), "invalid ckpt manifest");

    let mut out: Vec<u8> =
        Vec::with_capacity(16 + manifest.len() + offset as usize);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    out.extend_from_slice(&(manifest.len() as u64).to_le_bytes());
    out.extend_from_slice(manifest.as_bytes());
    for b in &blob_bytes {
        out.extend_from_slice(b);
    }
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {dir:?}"))?;
        }
    }
    let tmp = path.with_extension("sbck.tmp");
    std::fs::write(&tmp, &out).with_context(|| format!("writing {tmp:?}"))?;
    std::fs::rename(&tmp, path).with_context(|| format!("renaming to {path:?}"))?;
    Ok(IoStats { bytes: out.len() as u64, secs: t0.elapsed().as_secs_f64() })
}

/// Deserialize and integrity-check a checkpoint.  Fails closed on a bad
/// magic/version, a truncated file, or any blob whose CRC-32 disagrees
/// with the manifest.
pub fn load(path: &Path) -> Result<(TrainCheckpoint, IoStats)> {
    let t0 = Instant::now();
    let raw = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    let bytes = raw.len() as u64;
    if raw.len() < 16 {
        bail!("{path:?} is not a switchback checkpoint (bad magic)");
    }
    let mlen = parse_header(raw[0..16].try_into().unwrap(), path)?;
    // untrusted length field: checked add, or a torn header whose length
    // wraps usize would index past (or before) the buffer
    let blob_base = match 16usize.checked_add(mlen) {
        Some(b) if b <= raw.len() => b,
        _ => bail!("{path:?} is truncated inside the manifest"),
    };
    let manifest = std::str::from_utf8(&raw[16..blob_base])
        .map_err(|_| anyhow!("manifest is not UTF-8"))?;
    let m = json::parse(manifest).map_err(|e| anyhow!("bad manifest JSON: {e}"))?;
    let encoder = encoder_from_manifest(&m)?;

    let hv = m.get("hyper").ok_or_else(|| anyhow!("manifest missing hyper"))?;
    let opt_s = read_str(hv, "optimizer")?;
    let hyper = TrainHyper {
        steps: read_u64_num(hv, "steps")?,
        warmup: read_u64_num(hv, "warmup")?,
        lr: read_f32_exact(hv, "lr")?,
        weight_decay: read_f32_exact(hv, "weight_decay")?,
        beta1: read_f32_exact(hv, "beta1")?,
        beta2: read_f32_exact(hv, "beta2")?,
        optimizer: OptimizerKind::parse(opt_s)
            .ok_or_else(|| anyhow!("unknown optimizer {opt_s:?}"))?,
        beta2_lambda: read_opt_f32_exact(hv, "beta2_lambda")?,
        grad_clip: read_opt_f32_exact(hv, "grad_clip")?,
        seed: read_u64_str(hv, "seed")?,
    };

    let shifts = m
        .get("shifts")
        .and_then(Value::as_arr)
        .unwrap_or(&[])
        .iter()
        .map(|s| {
            Ok(Shift {
                at_step: read_u64_num(s, "at_step")?,
                image_gain: read_f32_exact(s, "image_gain")?,
                remap_concepts: s
                    .get("remap_concepts")
                    .and_then(Value::as_bool)
                    .unwrap_or(false),
            })
        })
        .collect::<Result<Vec<Shift>>>()?;

    let dv = m.get("data").ok_or_else(|| anyhow!("manifest missing data"))?;
    let rng_words = dv
        .get("rng")
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow!("manifest missing data.rng"))?;
    if rng_words.len() != 4 {
        bail!("data.rng must have 4 words, got {}", rng_words.len());
    }
    let mut rng = [0u64; 4];
    for (dst, w) in rng.iter_mut().zip(rng_words) {
        *dst = w
            .as_str()
            .and_then(|s| s.parse::<u64>().ok())
            .ok_or_else(|| anyhow!("data.rng word is not a u64 string"))?;
    }
    let data = DataCursor {
        step: read_u64_str(dv, "step")?,
        gain: read_f32_exact(dv, "gain")?,
        mapping: dv
            .get("mapping")
            .and_then(Value::as_usize_vec)
            .ok_or_else(|| anyhow!("manifest missing data.mapping"))?,
        rng,
        rng_spare: read_opt_f32_exact(dv, "rng_spare")?,
    };

    let ov = m.get("opt").ok_or_else(|| anyhow!("manifest missing opt"))?;
    let opt_name = read_str(ov, "name")?.to_string();
    let opt_t = read_u64_str(ov, "t")?;
    let slot_labels: Vec<String> = ov
        .get("slots")
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow!("manifest missing opt.slots"))?
        .iter()
        .map(|s| s.as_str().map(str::to_string).ok_or_else(|| anyhow!("bad slot label")))
        .collect::<Result<_>>()?;

    let n_params = read_usize(&m, "n_params")?;
    let tensors = m
        .get("tensors")
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow!("manifest missing tensors"))?;
    let expected = n_params * (1 + slot_labels.len());
    if tensors.len() != expected {
        bail!("manifest lists {} tensors, expected {expected}", tensors.len());
    }

    let mut names = Vec::with_capacity(tensors.len());
    let mut blobs: Vec<Vec<f32>> = Vec::with_capacity(tensors.len());
    for t in tensors {
        let name = read_str(t, "name")?;
        let len = read_usize(t, "len")?;
        let off = read_usize(t, "offset")?;
        let crc = read_u64_num(t, "crc")? as u32;
        let lo = blob_base + off;
        let hi = lo + len * 4;
        if hi > raw.len() {
            bail!("tensor {name:?} extends past end of file (truncated?)");
        }
        let chunk = &raw[lo..hi];
        let got = crc32(chunk);
        if got != crc {
            bail!(
                "tensor {name:?} failed its CRC-32 check \
                 (stored {crc:#010x}, computed {got:#010x}) — corrupt checkpoint"
            );
        }
        names.push(name.to_string());
        blobs.push(le_bytes_to_f32s(chunk));
    }

    let params: Vec<Vec<f32>> = blobs.drain(..n_params).collect();
    let param_names: Vec<String> = names[..n_params].to_vec();
    let mut slots = Vec::with_capacity(slot_labels.len());
    for label in slot_labels {
        let bufs: Vec<Vec<f32>> = blobs.drain(..n_params).collect();
        slots.push((label, bufs));
    }

    let ck = TrainCheckpoint {
        step: read_u64_num(&m, "step")?,
        encoder,
        hyper,
        shifts,
        batch: read_usize(&m, "batch")?,
        grad_shards: read_usize(&m, "grad_shards")?,
        param_names,
        params,
        opt: OptimizerState { name: opt_name, t: opt_t, slots },
        data,
    };
    Ok((ck, IoStats { bytes, secs: t0.elapsed().as_secs_f64() }))
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::nn::LinearKind;

    pub(crate) fn sample_ckpt() -> TrainCheckpoint {
        let mut hyper = TrainHyper::preset(40);
        hyper.seed = u64::MAX - 3; // exercise full-range u64 round-trip
        hyper.lr = 0.1; // not exactly representable — exercises *_bits
        hyper.grad_clip = Some(1.0);
        TrainCheckpoint {
            step: 17,
            encoder: EncoderConfig {
                kind: LinearKind::SwitchBack,
                dim: 8,
                heads: 2,
                blocks: 1,
                embed_dim: 4,
                patches: 3,
                patch_dim: 5,
                text_seq: 3,
                vocab: 16,
                seed: 0xDEAD_BEEF_CAFE_F00D,
            },
            hyper,
            shifts: vec![Shift { at_step: 22, image_gain: 6.0, remap_concepts: true }],
            batch: 8,
            grad_shards: 3,
            param_names: vec!["a".into(), "b".into()],
            params: vec![vec![1.0, -2.5, 3.25], vec![0.5]],
            opt: OptimizerState {
                name: "stable_adamw".into(),
                t: 17,
                slots: vec![
                    ("v".into(), vec![vec![0.1, 0.2, 0.3], vec![0.4]]),
                    ("u".into(), vec![vec![1e-9, 2e-9, 3e-9], vec![4e-9]]),
                ],
            },
            data: DataCursor {
                step: 17,
                gain: 6.0,
                mapping: vec![2, 0, 1],
                rng: [u64::MAX, 1, 0x0123_4567_89AB_CDEF, 42],
                rng_spare: Some(0.123_456_79),
            },
        }
    }

    #[test]
    fn save_load_roundtrip_is_exact() {
        let dir = std::env::temp_dir().join("sbck_fmt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.sbck");
        let ck = sample_ckpt();
        let saved = save(&path, &ck).unwrap();
        assert!(saved.bytes > 0 && saved.secs >= 0.0);
        let (back, loaded) = load(&path).unwrap();
        assert_eq!(loaded.bytes, saved.bytes);
        assert_eq!(back.step, ck.step);
        assert_eq!(back.encoder.kind, ck.encoder.kind);
        assert_eq!(back.encoder.seed, ck.encoder.seed);
        assert_eq!(back.hyper.seed, ck.hyper.seed);
        assert_eq!(back.hyper.lr.to_bits(), ck.hyper.lr.to_bits());
        assert_eq!(back.hyper.grad_clip, ck.hyper.grad_clip);
        assert_eq!(back.hyper.optimizer, ck.hyper.optimizer);
        assert_eq!(back.shifts.len(), 1);
        assert_eq!(back.shifts[0].at_step, 22);
        assert_eq!((back.batch, back.grad_shards), (8, 3));
        assert_eq!(back.param_names, ck.param_names);
        assert_eq!(back.params, ck.params);
        assert_eq!(back.opt, ck.opt);
        assert_eq!(back.data, ck.data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corruption_and_bad_headers_fail_closed() {
        let dir = std::env::temp_dir().join("sbck_fmt_corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.sbck");
        let ck = sample_ckpt();
        save(&path, &ck).unwrap();

        // flip one bit inside the last tensor blob
        let mut raw = std::fs::read(&path).unwrap();
        let n = raw.len();
        raw[n - 2] ^= 0x40;
        let bad = dir.join("bitflip.sbck");
        std::fs::write(&bad, &raw).unwrap();
        let err = load(&bad).unwrap_err().to_string();
        assert!(err.contains("CRC-32"), "{err}");

        // truncation inside the blobs
        let trunc = dir.join("trunc.sbck");
        std::fs::write(&trunc, &std::fs::read(&path).unwrap()[..n - 3]).unwrap();
        assert!(load(&trunc).is_err());

        // wrong magic
        let junk = dir.join("junk.sbck");
        std::fs::write(&junk, b"NOPE....rest").unwrap();
        let err = load(&junk).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");

        // future version
        let mut raw = std::fs::read(&path).unwrap();
        raw[4] = 99;
        let vfile = dir.join("v99.sbck");
        std::fs::write(&vfile, &raw).unwrap();
        let err = load(&vfile).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// `peek` reads only the header + manifest: it must succeed — and
    /// agree with the manifest — even on a file whose tensor blobs are
    /// truncated (which `load` correctly rejects).
    #[test]
    fn peek_reads_manifest_without_touching_blobs() {
        let dir = std::env::temp_dir().join("sbck_fmt_peek");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.sbck");
        let ck = sample_ckpt();
        save(&path, &ck).unwrap();
        let p = peek(&path).unwrap();
        assert_eq!(p.step, ck.step);
        assert_eq!(p.n_params, ck.params.len());
        assert_eq!(p.encoder.kind, ck.encoder.kind);
        assert_eq!(p.encoder.seed, ck.encoder.seed);
        assert_eq!(p.encoder.dim, ck.encoder.dim);
        assert!(p.manifest_bytes > 0);
        assert!(p.is_complete(), "a finished save must peek complete");
        assert_eq!(p.expected_bytes, p.file_bytes, "save writes exactly the blobs");

        // drop the last tensor bytes: load fails closed, peek still works
        // — and reports the file as incomplete (a copy still in flight)
        let raw = std::fs::read(&path).unwrap();
        let trunc = dir.join("trunc.sbck");
        std::fs::write(&trunc, &raw[..raw.len() - 3]).unwrap();
        assert!(load(&trunc).is_err(), "truncated blobs must fail load");
        let tp = peek(&trunc).unwrap();
        assert_eq!(tp.step, ck.step);
        assert!(!tp.is_complete(), "missing blob bytes must show as incomplete");

        // header/manifest damage still fails peek closed: a full 16-byte
        // header with a wrong magic, a short file, and a header whose
        // manifest-length field asks for more bytes than the file holds
        let junk = dir.join("junk.sbck");
        std::fs::write(&junk, b"NOPE....0123456789ab").unwrap();
        assert!(peek(&junk).unwrap_err().to_string().contains("magic"));
        let short = dir.join("short.sbck");
        std::fs::write(&short, b"SBCK").unwrap();
        assert!(peek(&short).unwrap_err().to_string().contains("truncated"));
        let mut lying = Vec::new();
        lying.extend_from_slice(MAGIC);
        lying.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        lying.extend_from_slice(&u64::MAX.to_le_bytes()); // absurd manifest len
        let huge = dir.join("huge.sbck");
        std::fs::write(&huge, &lying).unwrap();
        let err = peek(&huge).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_is_atomic_no_tmp_left_behind() {
        let dir = std::env::temp_dir().join("sbck_fmt_atomic");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.sbck");
        save(&path, &sample_ckpt()).unwrap();
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().contains("tmp"))
            .collect();
        assert!(leftovers.is_empty(), "temp file left behind");
        std::fs::remove_dir_all(&dir).ok();
    }
}
