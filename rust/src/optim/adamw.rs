//! AdamW and StableAdamW (paper Algorithm 2).
//!
//! Algorithm 2 writes Adam in the AdaFactor §7.1 form: the bias correction
//! is folded into the decay rates,
//! `β̂₁(t) = β₁ (1−β₁^{t−1})/(1−β₁^t)`, `β̂₂(t)` analogously — equivalent to
//! the usual `v̂ = v/(1−β^t)` debiasing [54].  With `update_clipping` on,
//! the per-tensor learning rate becomes `α / max(1, RMS_t)` where
//! `RMS_t = sqrt(mean(g²/max(u, ε²)))` — AdaFactor's update clipping with
//! d = 1, computed **independently per tensor** ("for implementation
//! convenience", §3.5; that choice is load-bearing: it is what lets the
//! patch embedding be slowed without touching healthy layers).
//!
//! The ε inside the max follows Appendix E.2 exactly (divide-by-zero
//! guard: `g²/maximum(u, ε²)`).

use super::{Optimizer, OptimizerState, ParamMeta, StepStats};
use crate::util::threads::num_threads;

/// Hyperparameters for [`AdamW`] / StableAdamW.
#[derive(Debug, Clone)]
pub struct AdamWConfig {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    /// `true` ⇒ StableAdamW (Algorithm 2); `false` ⇒ plain AdamW.
    pub update_clipping: bool,
    /// Optional β₂ schedule `1 − t^{−λ}` (Fig 15); overrides `beta2`.
    pub beta2_schedule_lambda: Option<f32>,
}

impl Default for AdamWConfig {
    fn default() -> Self {
        Self {
            beta1: 0.9,
            beta2: 0.999, // the PyTorch default the paper shows is spike-prone
            eps: 1e-6,    // Appendix E.2 uses 1e-6
            weight_decay: 0.2,
            update_clipping: false,
            beta2_schedule_lambda: None,
        }
    }
}

impl AdamWConfig {
    /// StableAdamW (Algorithm 2): AdaFactor update clipping on.
    pub fn stable(beta2: f32) -> Self {
        Self { beta2, update_clipping: true, ..Self::default() }
    }

    /// Plain AdamW: no update clipping (the Fig 6-8 baseline).
    pub fn plain(beta2: f32) -> Self {
        Self { beta2, update_clipping: false, ..Self::default() }
    }
}

struct TensorState {
    v: Vec<f32>, // first moment
    u: Vec<f32>, // second moment
    decay: bool,
}

/// AdamW / StableAdamW over flat per-tensor buffers.
pub struct AdamW {
    cfg: AdamWConfig,
    state: Vec<TensorState>,
    t: u64,
}

impl AdamW {
    /// Zero-moment optimizer over `sizes`-shaped flat tensors; `metas`
    /// decides which tensors receive weight decay.
    pub fn new(cfg: AdamWConfig, metas: &[ParamMeta], sizes: &[usize]) -> Self {
        assert_eq!(metas.len(), sizes.len());
        let state = metas
            .iter()
            .zip(sizes)
            .map(|(m, &n)| TensorState {
                v: vec![0.0; n],
                u: vec![0.0; n],
                decay: m.decay,
            })
            .collect();
        Self { cfg, state, t: 0 }
    }

    /// Effective β₂ at step `t` (≥1): scheduled or constant.
    fn beta2_at(&self, t: u64) -> f32 {
        match self.cfg.beta2_schedule_lambda {
            Some(lambda) => 1.0 - (t as f32).powf(-lambda),
            None => self.cfg.beta2,
        }
    }

    /// Second-moment view for a tensor (telemetry / tests).
    pub fn second_moment(&self, i: usize) -> &[f32] {
        &self.state[i].u
    }
}

impl Optimizer for AdamW {
    fn step(
        &mut self,
        params: &mut [Vec<f32>],
        grads: &[Vec<f32>],
        lr: f32,
        skip_mask: Option<&[bool]>,
    ) -> StepStats {
        self.t += 1;
        let t = self.t;
        let b1 = self.cfg.beta1;
        let b2 = self.beta2_at(t);
        // Correction folded into the betas (Algorithm 2 / AdaFactor §7.1).
        let b1_hat = if t == 1 {
            0.0
        } else {
            b1 * (1.0 - b1.powi(t as i32 - 1)) / (1.0 - b1.powi(t as i32))
        };
        let b2_hat = if t == 1 {
            0.0
        } else {
            b2 * (1.0 - b2.powi(t as i32 - 1)) / (1.0 - b2.powi(t as i32))
        };
        let eps = self.cfg.eps;
        let eps2 = eps * eps;
        let wd = self.cfg.weight_decay;
        let clip = self.cfg.update_clipping;

        // Per-tensor update body (runs on worker threads below).
        let update_one = |i: usize, p: &mut Vec<f32>, st: &mut TensorState,
                          g: &Vec<f32>| -> (f32, f32) {
            if skip_mask.map(|m| m[i]).unwrap_or(false) {
                return (1.0, 1.0); // tensor-level skip: freeze moments too
            }
            // Moving averages + RMS_t in one pass.
            let mut ratio_sum = 0.0f64;
            for j in 0..p.len() {
                let gj = g[j];
                let g2 = gj * gj;
                st.v[j] = b1_hat * st.v[j] + (1.0 - b1_hat) * gj;
                st.u[j] = b2_hat * st.u[j] + (1.0 - b2_hat) * g2;
                ratio_sum += (g2 / st.u[j].max(eps2)) as f64;
            }
            let rms = if p.is_empty() {
                1.0
            } else {
                (ratio_sum / p.len() as f64).sqrt() as f32
            };
            // Update clipping: η = α / max(1, RMS_t)  (per tensor).
            let lr_mult = if clip { 1.0 / rms.max(1.0) } else { 1.0 };
            let eta = lr * lr_mult;
            let decay = if st.decay { eta * wd } else { 0.0 };
            for j in 0..p.len() {
                let upd = st.v[j] / (st.u[j].sqrt() + eps);
                p[j] -= decay * p[j] + eta * upd;
            }
            (rms, lr_mult)
        };

        let n = params.len();
        let mut results = vec![(1.0f32, 1.0f32); n];
        let workers = num_threads().min(n.max(1));
        let per = n.div_ceil(workers.max(1));
        std::thread::scope(|scope| {
            let mut p_rest: &mut [Vec<f32>] = params;
            let mut s_rest: &mut [TensorState] = &mut self.state;
            let mut r_rest: &mut [(f32, f32)] = &mut results;
            let mut g_rest: &[Vec<f32>] = grads;
            let mut idx0 = 0usize;
            let body = &update_one;
            while !p_rest.is_empty() {
                let take = per.min(p_rest.len());
                let (pc, pt) = p_rest.split_at_mut(take);
                p_rest = pt;
                let (sc, st_) = s_rest.split_at_mut(take);
                s_rest = st_;
                let (rc, rt) = r_rest.split_at_mut(take);
                r_rest = rt;
                let (gc, gt) = g_rest.split_at(take);
                g_rest = gt;
                let my_idx0 = idx0;
                idx0 += take;
                scope.spawn(move || {
                    for j in 0..take {
                        rc[j] = body(my_idx0 + j, &mut pc[j], &mut sc[j], &gc[j]);
                    }
                });
            }
        });
        let (rms, lr_mult): (Vec<f32>, Vec<f32>) = results.into_iter().unzip();
        let skipped_tensors =
            skip_mask.map(|m| m.iter().filter(|&&s| s).count()).unwrap_or(0);
        StepStats { rms, lr_mult, skipped_tensors, skipped_step: false }
    }

    fn state_floats_per_param(&self) -> usize {
        2 // v and u
    }

    fn name(&self) -> &'static str {
        if self.cfg.update_clipping {
            "stable_adamw"
        } else {
            "adamw"
        }
    }

    fn export_state(&self) -> OptimizerState {
        OptimizerState {
            name: self.name().to_string(),
            t: self.t,
            slots: vec![
                ("v".into(), self.state.iter().map(|s| s.v.clone()).collect()),
                ("u".into(), self.state.iter().map(|s| s.u.clone()).collect()),
            ],
        }
    }

    fn import_state(&mut self, st: &OptimizerState) -> Result<(), String> {
        let sizes: Vec<usize> = self.state.iter().map(|s| s.v.len()).collect();
        st.check_shape(self.name(), &["v", "u"], &sizes)?;
        self.t = st.t;
        for (dst, src) in self.state.iter_mut().zip(&st.slots[0].1) {
            dst.v.copy_from_slice(src);
        }
        for (dst, src) in self.state.iter_mut().zip(&st.slots[1].1) {
            dst.u.copy_from_slice(src);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(n: usize) -> Vec<ParamMeta> {
        (0..n)
            .map(|i| ParamMeta {
                name: format!("p{i}"),
                decay: false,
                kind: "weight".into(),
            })
            .collect()
    }

    /// On a constant gradient, debiased Adam's first step is
    /// θ ← θ − lr · g/(|g| + ε): the moments debias to exactly g and g².
    #[test]
    fn first_step_is_sign_times_lr() {
        let mut opt = AdamW::new(AdamWConfig::plain(0.999), &meta(1), &[2]);
        let mut p = vec![vec![1.0f32, -1.0]];
        let g = vec![vec![0.5f32, -2.0]];
        opt.step(&mut p, &g, 0.1, None);
        assert!((p[0][0] - (1.0 - 0.1)).abs() < 1e-3, "{}", p[0][0]);
        assert!((p[0][1] - (-1.0 + 0.1)).abs() < 1e-3);
    }

    /// Quadratic convergence sanity: minimize 0.5*x².
    #[test]
    fn converges_on_quadratic() {
        let mut opt = AdamW::new(AdamWConfig::plain(0.99), &meta(1), &[1]);
        let mut p = vec![vec![5.0f32]];
        for _ in 0..500 {
            let g = vec![vec![p[0][0]]];
            opt.step(&mut p, &g, 0.05, None);
        }
        assert!(p[0][0].abs() < 0.05, "did not converge: {}", p[0][0]);
    }

    /// The stuck-in-the-past scenario (§3.4): after a long quiet phase, a
    /// sudden large gradient must produce RMS ≫ 1, and StableAdamW must
    /// shrink the applied update relative to plain AdamW.
    #[test]
    fn update_clipping_tames_stale_second_moment() {
        let metas = meta(1);
        let mk = |clip: bool| AdamW::new(
            AdamWConfig { update_clipping: clip, beta2: 0.999, ..Default::default() },
            &metas,
            &[1],
        );
        let run = |mut opt: AdamW| {
            let mut p = vec![vec![0.0f32]];
            // quiet phase: tiny gradients
            let quiet = [vec![1e-4f32]];
            for _ in 0..300 {
                opt.step(&mut p, &quiet, 1e-3, None);
            }
            let before = p[0][0];
            // signal change: gradient jumps 4 orders of magnitude
            let stats = opt.step(&mut p, &[vec![1.0f32]], 1e-3, None);
            ((p[0][0] - before).abs(), stats.rms[0])
        };
        let (jump_plain, rms_plain) = run(mk(false));
        let (jump_stable, rms_stable) = run(mk(true));
        assert!(rms_plain > 10.0, "RMS should spike, got {rms_plain}");
        assert!((rms_stable - rms_plain).abs() < 1e-3);
        assert!(
            jump_stable < jump_plain / 5.0,
            "clipped update {jump_stable} not ≪ unclipped {jump_plain}"
        );
    }

    /// RMS_t ≈ 1 when the gradient distribution is stationary.
    #[test]
    fn rms_near_one_when_stationary() {
        let mut opt = AdamW::new(AdamWConfig::stable(0.99), &meta(1), &[64]);
        let mut p = vec![vec![0.0f32; 64]];
        let mut rng = crate::tensor::Rng::seed(44);
        let mut last = 0.0;
        for _ in 0..200 {
            let mut g = vec![0.0f32; 64];
            rng.fill_normal(&mut g, 1.0);
            let stats = opt.step(&mut p, &[g], 1e-4, None);
            last = stats.rms[0];
        }
        assert!(last > 0.5 && last < 2.3, "stationary RMS should hover near 1: {last}");
    }

    #[test]
    fn weight_decay_respects_mask() {
        let metas = vec![
            ParamMeta { name: "w".into(), decay: true, kind: "weight".into() },
            ParamMeta { name: "ln".into(), decay: false, kind: "norm".into() },
        ];
        let mut opt = AdamW::new(
            AdamWConfig { weight_decay: 0.5, ..AdamWConfig::plain(0.999) },
            &metas,
            &[1, 1],
        );
        let mut p = vec![vec![1.0f32], vec![1.0f32]];
        // zero gradient: only decay should act
        opt.step(&mut p, &[vec![0.0], vec![0.0]], 0.1, None);
        assert!(p[0][0] < 1.0, "decayed tensor should shrink");
        assert_eq!(p[1][0], 1.0, "no-decay tensor must not shrink");
    }

    #[test]
    fn skip_mask_freezes_tensor_and_moments() {
        let mut opt = AdamW::new(AdamWConfig::plain(0.999), &meta(2), &[1, 1]);
        let mut p = vec![vec![1.0f32], vec![1.0f32]];
        let g = vec![vec![1.0f32], vec![1.0f32]];
        let stats = opt.step(&mut p, &g, 0.1, Some(&[true, false]));
        assert_eq!(p[0][0], 1.0);
        assert!(p[1][0] < 1.0);
        assert_eq!(stats.skipped_tensors, 1);
        assert_eq!(opt.second_moment(0)[0], 0.0, "skipped moments must not advance");
        assert!(opt.second_moment(1)[0] > 0.0);
    }

    #[test]
    fn beta2_schedule_takes_over() {
        let cfg = AdamWConfig {
            beta2_schedule_lambda: Some(0.5),
            ..AdamWConfig::plain(0.999)
        };
        let opt = AdamW::new(cfg, &meta(1), &[1]);
        assert!((opt.beta2_at(4) - 0.5).abs() < 1e-6); // 1 - 4^-0.5
        assert!((opt.beta2_at(100) - 0.9).abs() < 1e-6); // 1 - 100^-0.5
    }
}
