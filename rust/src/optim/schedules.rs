//! Learning-rate schedule: linear warmup then cosine decay (paper §2.2.2 /
//! §3.2 — 5k warmup of 20k total in the paper; scaled by config here).

/// Warmup + cosine decay to zero.
#[derive(Debug, Clone)]
pub struct LrSchedule {
    pub base_lr: f32,
    pub warmup_steps: u64,
    pub total_steps: u64,
}

impl LrSchedule {
    /// Linear warmup over `warmup_steps`, cosine decay to zero at
    /// `total_steps`.
    pub fn new(base_lr: f32, warmup_steps: u64, total_steps: u64) -> Self {
        assert!(warmup_steps <= total_steps);
        Self { base_lr, warmup_steps, total_steps }
    }

    /// LR at (1-based) iteration `t`.
    pub fn at(&self, t: u64) -> f32 {
        if self.total_steps == 0 {
            return self.base_lr;
        }
        if t <= self.warmup_steps && self.warmup_steps > 0 {
            return self.base_lr * (t as f32) / (self.warmup_steps as f32);
        }
        let t = t.min(self.total_steps);
        let progress = (t - self.warmup_steps) as f32
            / (self.total_steps - self.warmup_steps).max(1) as f32;
        0.5 * self.base_lr * (1.0 + (std::f32::consts::PI * progress).cos())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_is_linear() {
        let s = LrSchedule::new(1.0, 10, 100);
        assert!((s.at(1) - 0.1).abs() < 1e-6);
        assert!((s.at(5) - 0.5).abs() < 1e-6);
        assert!((s.at(10) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cosine_decays_to_zero() {
        let s = LrSchedule::new(2e-3, 5, 20);
        assert!(s.at(20) < 1e-9);
        assert!(s.at(12) < s.at(11));
        // midpoint of decay ≈ half the base lr
        let mid = s.at(5 + (20 - 5) / 2);
        assert!((mid / 2e-3 - 0.5).abs() < 0.1, "mid {mid}");
    }

    #[test]
    fn clamped_after_total() {
        let s = LrSchedule::new(1.0, 0, 10);
        assert_eq!(s.at(10), s.at(999));
    }
}
