//! Loss scalers (paper §3.6: "Loss spikes and the loss scalar").
//!
//! The paper observes that spikes make gradients overflow fp16 range in a
//! *few specific tensors* (chiefly the patch embedding), yet the PyTorch
//! default scaler reacts globally: it skips the whole update and halves the
//! scalar, taking thousands of iterations to recover.  Their fix:
//!
//! 1. check Inf/NaN **per tensor** and skip only the offending tensors,
//! 2. keep the scalar **fixed** at its initial value.
//!
//! We implement both policies.  Since the runtime computes f32 gradients,
//! fp16 overflow is *simulated* faithfully: a gradient tensor "overflows"
//! when `|g| * scale` exceeds fp16 max (65504) — exactly the condition that
//! produces Inf in a real fp16 backward pass — or when it is already
//! non-finite.

/// fp16 largest finite value.
pub const FP16_MAX: f32 = 65504.0;

/// Decision returned by a scaler for the current step.
#[derive(Debug, Clone, PartialEq)]
pub enum ScaleDecision {
    /// Apply the full update.
    Proceed,
    /// Skip the whole update (global scaler saw Inf/NaN).
    SkipStep,
    /// Skip only these tensors (tensor-level scaler).
    SkipTensors(Vec<bool>),
}

/// Would this tensor's fp16 gradient overflow at the given loss scale?
pub fn tensor_overflows(grad: &[f32], scale: f32) -> bool {
    grad.iter().any(|&g| !g.is_finite() || (g * scale).abs() > FP16_MAX)
}

/// PyTorch-style **dynamic global** scaler (§2.1): init 65536; on Inf/NaN
/// skip the update and halve; after `growth_interval` clean steps, double.
#[derive(Debug, Clone)]
pub struct DynamicGlobalScaler {
    pub scale: f32,
    pub growth_interval: u64,
    clean_steps: u64,
    /// telemetry: how many times the scale dropped (Fig 11's bottom panel)
    pub drops: u64,
}

impl DynamicGlobalScaler {
    /// PyTorch-shaped defaults: scale 2^16, growth interval 2000.
    pub fn new() -> Self {
        Self { scale: 65536.0, growth_interval: 2000, clean_steps: 0, drops: 0 }
    }

    /// Inspect a step's gradients: any overflow halves the scale and
    /// skips the whole step; enough clean steps double it.
    pub fn inspect(&mut self, grads: &[Vec<f32>]) -> ScaleDecision {
        let overflow = grads.iter().any(|g| tensor_overflows(g, self.scale));
        if overflow {
            self.scale *= 0.5;
            self.clean_steps = 0;
            self.drops += 1;
            ScaleDecision::SkipStep
        } else {
            self.clean_steps += 1;
            if self.clean_steps >= self.growth_interval {
                self.scale *= 2.0;
                self.clean_steps = 0;
            }
            ScaleDecision::Proceed
        }
    }
}

impl Default for DynamicGlobalScaler {
    fn default() -> Self {
        Self::new()
    }
}

/// The paper's **fixed tensor-level** scaler (§3.6): scale never changes;
/// Inf/NaN is checked per tensor and only those tensors are skipped.  When
/// overflows concentrate in the patch embedding (as the paper observes),
/// this degenerates gracefully into Chen et al. [8]'s "freeze the embedding
/// layer" — without freezing anything else.
#[derive(Debug, Clone)]
pub struct FixedTensorScaler {
    pub scale: f32,
    /// telemetry: per-tensor skip counts (which layers overflow — Fig 11)
    pub skip_counts: Vec<u64>,
}

impl FixedTensorScaler {
    /// Fixed scale over `n_tensors` per-tensor skip counters.
    pub fn new(scale: f32, n_tensors: usize) -> Self {
        Self { scale, skip_counts: vec![0; n_tensors] }
    }

    /// Inspect a step's gradients: overflowing tensors are skipped
    /// individually (the scale never moves).
    pub fn inspect(&mut self, grads: &[Vec<f32>]) -> ScaleDecision {
        let mask: Vec<bool> = grads
            .iter()
            .map(|g| tensor_overflows(g, self.scale))
            .collect();
        if mask.iter().any(|&b| b) {
            for (c, &m) in self.skip_counts.iter_mut().zip(&mask) {
                if m {
                    *c += 1;
                }
            }
            ScaleDecision::SkipTensors(mask)
        } else {
            ScaleDecision::Proceed
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dynamic_halves_on_overflow_and_recovers_slowly() {
        let mut s = DynamicGlobalScaler::new();
        s.growth_interval = 3;
        let huge = vec![vec![10.0f32]]; // 10 * 65536 > 65504 → overflow
        assert_eq!(s.inspect(&huge), ScaleDecision::SkipStep);
        assert_eq!(s.scale, 32768.0);
        assert_eq!(s.drops, 1);
        let ok = vec![vec![1e-3f32]];
        for _ in 0..3 {
            assert_eq!(s.inspect(&ok), ScaleDecision::Proceed);
        }
        assert_eq!(s.scale, 65536.0, "doubles after growth_interval clean steps");
    }

    #[test]
    fn dynamic_skips_on_nan_even_without_scale() {
        let mut s = DynamicGlobalScaler::new();
        let g = vec![vec![f32::NAN]];
        assert_eq!(s.inspect(&g), ScaleDecision::SkipStep);
    }

    #[test]
    fn tensor_level_skips_only_offenders() {
        let mut s = FixedTensorScaler::new(65536.0, 3);
        let grads = vec![vec![1e-3f32], vec![100.0], vec![1e-3]];
        match s.inspect(&grads) {
            ScaleDecision::SkipTensors(mask) => {
                assert_eq!(mask, vec![false, true, false]);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(s.skip_counts, vec![0, 1, 0]);
        assert_eq!(s.scale, 65536.0, "scale stays fixed");
    }

    #[test]
    fn tensor_level_proceeds_when_clean() {
        let mut s = FixedTensorScaler::new(65536.0, 2);
        let grads = vec![vec![1e-4f32], vec![1e-4]];
        assert_eq!(s.inspect(&grads), ScaleDecision::Proceed);
    }

    #[test]
    fn overflow_threshold_is_fp16_max() {
        // just below: 65504/65536 ≈ 0.9995
        assert!(!tensor_overflows(&[0.999], 65536.0));
        assert!(tensor_overflows(&[1.1], 65536.0));
    }
}
