//! Optimizers + stability interventions (paper §3).
//!
//! The paper's stability contribution — **StableAdamW** (Algorithm 2:
//! AdamW + AdaFactor update clipping) — lives here, on the rust training
//! path, consuming gradients computed by the AOT'd L2 model every step.
//!
//! * [`AdamW`] — the de-facto baseline, written in the AdaFactor §7.1 form
//!   (bias correction folded into the βs) exactly as Algorithm 2 does.
//! * [`AdamW`] with `update_clipping = true` — **StableAdamW**: per-tensor
//!   `RMS_t = sqrt(mean(g²/max(u, ε²)))` divides the learning rate via
//!   `1/max(1, RMS_t)`.
//! * [`Lion`] — the sign-update optimizer discussed in Appendix E (immune
//!   to the stuck-in-the-past scenario by construction).
//! * [`clip_global_norm`] — the gradient-clipping intervention StableAdamW
//!   is compared against in Fig 10.
//! * [`scaler`] — the §3.6 loss scalers (PyTorch-style dynamic global vs
//!   the paper's fixed tensor-level scaler).
//! * [`schedules`] — warmup+cosine LR and the `1 − t^{−λ}` β₂ schedule
//!   (Fig 15).

mod adamw;
mod lion;
pub mod scaler;
pub mod schedules;

pub use adamw::{AdamW, AdamWConfig};
pub use lion::{Lion, LionConfig};

/// Per-tensor optimizer metadata (from the artifact manifest, or built by
/// the native trainer's parameter registry).
#[derive(Debug, Clone)]
pub struct ParamMeta {
    pub name: String,
    /// weight decay applies (weight matrices only, not LN/bias/embeddings)
    pub decay: bool,
    /// "patch_embed" | "embedding" | "weight" | "norm" | ... (telemetry tag)
    pub kind: String,
}

impl ParamMeta {
    /// A decayed weight matrix.
    pub fn weight(name: &str) -> Self {
        Self { name: name.to_string(), decay: true, kind: "weight".into() }
    }

    /// A non-decayed tensor tagged `kind` (embeddings, norms, scalars).
    pub fn no_decay(name: &str, kind: &str) -> Self {
        Self { name: name.to_string(), decay: false, kind: kind.into() }
    }
}

/// What a step reports back to telemetry.
#[derive(Debug, Clone)]
pub struct StepStats {
    /// Per-tensor `RMS_t` (1.0 for non-adaptive optimizers).  This is the
    /// quantity whose spikes *precede* loss spikes (paper §3.4, Fig 9).
    pub rms: Vec<f32>,
    /// Per-tensor lr multiplier actually applied (`1/max(1, RMS_t)` for
    /// StableAdamW, 1 otherwise).
    pub lr_mult: Vec<f32>,
    /// Tensors whose update was skipped by the tensor-level scaler.
    pub skipped_tensors: usize,
    /// Whole update skipped (global scaler saw Inf/NaN).
    pub skipped_step: bool,
}

impl StepStats {
    /// Neutral stats for a step that applied no update (skipped or
    /// rolled back): RMS 1.0, lr multiplier 1.0, nothing skipped.
    pub fn empty(n: usize) -> Self {
        Self {
            rms: vec![1.0; n],
            lr_mult: vec![1.0; n],
            skipped_tensors: 0,
            skipped_step: false,
        }
    }
}

/// A snapshot of an optimizer's mutable state — the checkpoint payload
/// that makes `train --resume` bit-identical ([`crate::ckpt`]).  Slots are
/// the optimizer's moment buffers (AdamW: `v`+`u`; Lion: `m`), each a
/// per-tensor list index-aligned with the parameter layout.
#[derive(Debug, Clone, PartialEq)]
pub struct OptimizerState {
    /// [`Optimizer::name`] of the exporter (validated on import)
    pub name: String,
    /// debiasing step counter (0 for optimizers without one)
    pub t: u64,
    /// `(slot label, per-tensor flat buffers)`
    pub slots: Vec<(String, Vec<Vec<f32>>)>,
}

impl OptimizerState {
    /// Validate that `slots` matches the expected labels and per-tensor
    /// buffer sizes (shared import precondition of every optimizer).
    fn check_shape(&self, name: &str, labels: &[&str], sizes: &[usize]) -> Result<(), String> {
        if self.name != name {
            return Err(format!(
                "optimizer state is for {:?}, cannot import into {name:?}",
                self.name
            ));
        }
        if self.slots.len() != labels.len() {
            return Err(format!(
                "{name}: expected {} state slots, got {}",
                labels.len(),
                self.slots.len()
            ));
        }
        for ((slot, bufs), &label) in self.slots.iter().zip(labels) {
            if slot != label {
                return Err(format!("{name}: expected slot {label:?}, got {slot:?}"));
            }
            if bufs.len() != sizes.len() {
                return Err(format!(
                    "{name}.{label}: {} tensors, optimizer has {}",
                    bufs.len(),
                    sizes.len()
                ));
            }
            for (i, (b, &n)) in bufs.iter().zip(sizes).enumerate() {
                if b.len() != n {
                    return Err(format!(
                        "{name}.{label}[{i}]: {} floats, tensor has {n}",
                        b.len()
                    ));
                }
            }
        }
        Ok(())
    }
}

/// A first-order optimizer over flat per-tensor f32 buffers.
pub trait Optimizer: Send {
    /// One update step.  `lr` is the *scheduled* learning rate for this
    /// iteration; implementations may further scale it per tensor (update
    /// clipping).  `skip_mask[i] == true` means "do not apply tensor i's
    /// update this step" (tensor-level loss scaler, §3.6) — moments are
    /// not advanced for skipped tensors either.
    fn step(
        &mut self,
        params: &mut [Vec<f32>],
        grads: &[Vec<f32>],
        lr: f32,
        skip_mask: Option<&[bool]>,
    ) -> StepStats;

    /// Number of optimizer-state floats per parameter (memory accounting).
    fn state_floats_per_param(&self) -> usize;

    fn name(&self) -> &'static str;

    /// Snapshot the mutable state (moments + step counter) for a
    /// checkpoint.
    fn export_state(&self) -> OptimizerState;

    /// Restore a snapshot taken by [`Self::export_state`].  Fails closed
    /// on optimizer/slot/shape mismatch — a silently mis-shaped import
    /// would corrupt the resumed run.
    fn import_state(&mut self, st: &OptimizerState) -> Result<(), String>;
}

/// The paper's spike predictor (§3.3–3.4): the mean **under-estimation
/// ratio** `mean(g² / max(u, ε²))` of tensor `tensor`, computed against
/// the second-moment slot (`"u"`) of an exported [`OptimizerState`].
/// Values ≫ 1 mean the second moment under-estimates the current squared
/// gradients — exactly the condition the paper shows precedes loss spikes
/// by 1–8 iterations.  Equals `RMS_t²` when `st` was exported right after
/// the step that consumed `g`.
///
/// Returns `None` for optimizers without a second moment (Lion), an
/// out-of-range tensor index, or a gradient/buffer length mismatch.
pub fn under_estimation_ratio(
    st: &OptimizerState,
    tensor: usize,
    g: &[f32],
    eps: f32,
) -> Option<f32> {
    let (_, bufs) = st.slots.iter().find(|(label, _)| label == "u")?;
    let u = bufs.get(tensor)?;
    if u.len() != g.len() || g.is_empty() {
        return None;
    }
    // f32 division accumulated in f64 — bit-matching AdamW's in-step
    // RMS_t computation so ratio == rms² exactly.
    let eps2 = eps * eps;
    let mut sum = 0.0f64;
    for (&gj, &uj) in g.iter().zip(u) {
        sum += ((gj * gj) / uj.max(eps2)) as f64;
    }
    Some((sum / g.len() as f64) as f32)
}

/// Global-norm gradient clipping (the Fig 10 comparison baseline; the paper
/// clips at norm 1.0, "standard in e.g. PaLM").  Returns the pre-clip norm.
pub fn clip_global_norm(grads: &mut [Vec<f32>], max_norm: f32) -> f32 {
    let mut ss = 0.0f64;
    for g in grads.iter() {
        for &v in g {
            ss += (v as f64) * (v as f64);
        }
    }
    let norm = ss.sqrt() as f32;
    if norm > max_norm && norm > 0.0 {
        let scale = max_norm / norm;
        for g in grads.iter_mut() {
            for v in g.iter_mut() {
                *v *= scale;
            }
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clip_reduces_norm_to_max() {
        let mut grads = vec![vec![3.0, 4.0]]; // norm 5
        let pre = clip_global_norm(&mut grads, 1.0);
        assert!((pre - 5.0).abs() < 1e-6);
        let post: f32 = grads[0].iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!((post - 1.0).abs() < 1e-6);
    }

    #[test]
    fn clip_noop_below_max() {
        let mut grads = vec![vec![0.3, 0.4]];
        clip_global_norm(&mut grads, 1.0);
        assert_eq!(grads[0], vec![0.3, 0.4]);
    }

    #[test]
    fn clip_handles_zero() {
        let mut grads = vec![vec![0.0; 4]];
        let pre = clip_global_norm(&mut grads, 1.0);
        assert_eq!(pre, 0.0);
    }

    fn metas(n: usize) -> Vec<ParamMeta> {
        (0..n).map(|i| ParamMeta::weight(&format!("p{i}"))).collect()
    }

    /// Export mid-run, import into a fresh optimizer, continue both:
    /// every subsequent update is bit-identical (the resume contract).
    #[test]
    fn state_roundtrip_continues_bit_identically() {
        let sizes = [3usize, 5];
        let grad_at = |t: u64| -> Vec<Vec<f32>> {
            let elem = |i: usize, j: usize| {
                ((t + 1) as f32) * 0.1 + i as f32 + j as f32 * 0.01
            };
            sizes
                .iter()
                .enumerate()
                .map(|(i, &n)| (0..n).map(|j| elem(i, j)).collect())
                .collect()
        };
        for kind in ["adamw", "stable_adamw", "lion"] {
            let mk = || -> Box<dyn Optimizer> {
                match kind {
                    "adamw" => Box::new(AdamW::new(AdamWConfig::plain(0.99), &metas(2), &sizes)),
                    "stable_adamw" => {
                        Box::new(AdamW::new(AdamWConfig::stable(0.99), &metas(2), &sizes))
                    }
                    _ => Box::new(Lion::new(LionConfig::default(), &metas(2), &sizes)),
                }
            };
            let mut a = mk();
            let mut pa: Vec<Vec<f32>> = sizes.iter().map(|&n| vec![1.0; n]).collect();
            for t in 0..7 {
                a.step(&mut pa, &grad_at(t), 1e-2, None);
            }
            let st = a.export_state();
            assert_eq!(st.name, kind);
            let mut b = mk();
            let mut pb = pa.clone();
            b.import_state(&st).unwrap();
            for t in 7..14 {
                a.step(&mut pa, &grad_at(t), 1e-2, None);
                b.step(&mut pb, &grad_at(t), 1e-2, None);
            }
            assert_eq!(pa, pb, "{kind}: resumed updates diverged");
            assert_eq!(a.export_state(), b.export_state(), "{kind}: moments diverged");
        }
    }

    /// Pin `under_estimation_ratio` on a hand-computed AdamW trajectory
    /// (β₂ = 0.9, one scalar parameter, gradients 1 then 2):
    ///
    /// * t=1: β̂₂ = 0 ⇒ u₁ = g₁² = 1, ratio = 1²/1 = **1.0**
    /// * t=2: β̂₂ = 0.9·(1−0.9)/(1−0.81) = 9/19
    ///   ⇒ u₂ = (9/19)·1 + (10/19)·4 = 49/19 ≈ 2.5789
    ///   ratio = 4/(49/19) = 76/49 ≈ **1.5510**
    #[test]
    fn under_estimation_ratio_matches_hand_computed_adamw() {
        let metas = vec![ParamMeta::no_decay("w", "weight")];
        let mut opt = AdamW::new(AdamWConfig::plain(0.9), &metas, &[1]);
        let mut p = vec![vec![0.0f32]];
        let eps = AdamWConfig::default().eps;

        let g1 = vec![vec![1.0f32]];
        let stats1 = opt.step(&mut p, &g1, 1e-3, None);
        let r1 = under_estimation_ratio(&opt.export_state(), 0, &g1[0], eps)
            .expect("adamw exports a second moment");
        assert!((r1 - 1.0).abs() < 1e-6, "t=1 ratio {r1}");

        let g2 = vec![vec![2.0f32]];
        let stats2 = opt.step(&mut p, &g2, 1e-3, None);
        let r2 = under_estimation_ratio(&opt.export_state(), 0, &g2[0], eps)
            .expect("adamw exports a second moment");
        assert!((r2 - 76.0 / 49.0).abs() < 1e-5, "t=2 ratio {r2}");

        // the ratio is RMS_t² — the same quantity StepStats reports
        assert!((r1 - stats1.rms[0] * stats1.rms[0]).abs() < 1e-6);
        assert!((r2 - stats2.rms[0] * stats2.rms[0]).abs() < 1e-6);
    }

    /// No second moment (Lion) or shape mismatch ⇒ `None`, never a bogus
    /// number.
    #[test]
    fn under_estimation_ratio_rejects_bad_inputs() {
        let metas = vec![ParamMeta::weight("w")];
        let lion = Lion::new(LionConfig::default(), &metas, &[2]);
        assert!(under_estimation_ratio(&lion.export_state(), 0, &[1.0, 1.0], 1e-6).is_none());
        let adam = AdamW::new(AdamWConfig::plain(0.9), &metas, &[2]);
        let st = adam.export_state();
        assert!(under_estimation_ratio(&st, 1, &[1.0, 1.0], 1e-6).is_none(), "bad index");
        assert!(under_estimation_ratio(&st, 0, &[1.0], 1e-6).is_none(), "length mismatch");
        assert!(under_estimation_ratio(&st, 0, &[], 1e-6).is_none(), "empty gradient");
    }

    /// Mis-shaped or cross-optimizer imports fail closed.
    #[test]
    fn state_import_rejects_mismatch() {
        let mut adam = AdamW::new(AdamWConfig::plain(0.99), &metas(1), &[4]);
        let lion = Lion::new(LionConfig::default(), &metas(1), &[4]);
        let err = adam.import_state(&lion.export_state()).unwrap_err();
        assert!(err.contains("lion"), "{err}");
        let mut st = adam.export_state();
        st.slots[1].1[0].pop(); // wrong buffer length
        assert!(adam.import_state(&st).is_err());
        let mut st = adam.export_state();
        st.slots.swap(0, 1); // wrong slot order
        assert!(adam.import_state(&st).is_err());
    }
}
