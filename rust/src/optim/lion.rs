//! Lion [7] — the sign-update optimizer from Appendix E's Q&A.
//!
//! Lion never divides by a second-moment estimate, so it is structurally
//! immune to the stuck-in-the-past scenario; the paper notes it slightly
//! under-performs AdamW at ViT-Huge scale.  Included as a comparison
//! baseline for the stability experiments.

use super::{Optimizer, OptimizerState, ParamMeta, StepStats};

/// Lion hyperparameters (β₁ interpolation, β₂ momentum, decoupled
/// weight decay).
#[derive(Debug, Clone)]
pub struct LionConfig {
    pub beta1: f32,
    pub beta2: f32,
    pub weight_decay: f32,
}

impl Default for LionConfig {
    fn default() -> Self {
        Self { beta1: 0.9, beta2: 0.99, weight_decay: 0.2 }
    }
}

/// The Lion optimizer over flat per-tensor buffers (momentum only —
/// no second moment, hence no RMS_t and no update clipping to do).
pub struct Lion {
    cfg: LionConfig,
    m: Vec<Vec<f32>>,
    decay: Vec<bool>,
}

impl Lion {
    /// Zero-momentum optimizer over `sizes`-shaped flat tensors.
    pub fn new(cfg: LionConfig, metas: &[ParamMeta], sizes: &[usize]) -> Self {
        Self {
            cfg,
            m: sizes.iter().map(|&n| vec![0.0; n]).collect(),
            decay: metas.iter().map(|m| m.decay).collect(),
        }
    }
}

impl Optimizer for Lion {
    fn step(
        &mut self,
        params: &mut [Vec<f32>],
        grads: &[Vec<f32>],
        lr: f32,
        skip_mask: Option<&[bool]>,
    ) -> StepStats {
        let (b1, b2, wd) = (self.cfg.beta1, self.cfg.beta2, self.cfg.weight_decay);
        for (i, ((p, m), g)) in params
            .iter_mut()
            .zip(self.m.iter_mut())
            .zip(grads.iter())
            .enumerate()
        {
            if skip_mask.map(|s| s[i]).unwrap_or(false) {
                continue;
            }
            let decay = if self.decay[i] { lr * wd } else { 0.0 };
            for j in 0..p.len() {
                // update direction: sign of interpolated momentum
                let c = b1 * m[j] + (1.0 - b1) * g[j];
                p[j] -= decay * p[j] + lr * c.signum();
                // momentum EMA
                m[j] = b2 * m[j] + (1.0 - b2) * g[j];
            }
        }
        let skipped =
            skip_mask.map(|m| m.iter().filter(|&&s| s).count()).unwrap_or(0);
        StepStats { skipped_tensors: skipped, ..StepStats::empty(params.len()) }
    }

    fn state_floats_per_param(&self) -> usize {
        1
    }

    fn name(&self) -> &'static str {
        "lion"
    }

    fn export_state(&self) -> OptimizerState {
        OptimizerState {
            name: self.name().to_string(),
            t: 0, // Lion carries no debiasing counter
            slots: vec![("m".into(), self.m.clone())],
        }
    }

    fn import_state(&mut self, st: &OptimizerState) -> Result<(), String> {
        let sizes: Vec<usize> = self.m.iter().map(Vec::len).collect();
        st.check_shape(self.name(), &["m"], &sizes)?;
        for (dst, src) in self.m.iter_mut().zip(&st.slots[0].1) {
            dst.copy_from_slice(src);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(n: usize) -> Vec<ParamMeta> {
        (0..n)
            .map(|i| ParamMeta { name: format!("p{i}"), decay: false, kind: "w".into() })
            .collect()
    }

    #[test]
    fn updates_are_bounded_by_lr() {
        let mut opt = Lion::new(LionConfig::default(), &meta(1), &[2]);
        let mut p = vec![vec![0.0f32, 0.0]];
        // enormous gradient — update magnitude must still be exactly lr
        opt.step(&mut p, &[vec![1e8, -1e8]], 0.01, None);
        assert!((p[0][0] + 0.01).abs() < 1e-7);
        assert!((p[0][1] - 0.01).abs() < 1e-7);
    }

    #[test]
    fn immune_to_stale_history() {
        // Same scenario as AdamW's stuck-in-the-past test: the jump after a
        // signal change is the same size as any other step.
        let mut opt = Lion::new(LionConfig::default(), &meta(1), &[1]);
        let mut p = vec![vec![0.0f32]];
        let quiet = [vec![1e-4f32]];
        for _ in 0..300 {
            opt.step(&mut p, &quiet, 1e-3, None);
        }
        let before = p[0][0];
        opt.step(&mut p, &[vec![1.0]], 1e-3, None);
        assert!((p[0][0] - before).abs() <= 1e-3 + 1e-7);
    }

    #[test]
    fn converges_on_quadratic() {
        let mut opt = Lion::new(
            LionConfig { weight_decay: 0.0, ..Default::default() },
            &meta(1),
            &[1],
        );
        let mut p = vec![vec![3.0f32]];
        for _ in 0..2000 {
            let g = vec![vec![p[0][0]]];
            opt.step(&mut p, &g, 0.01, None);
        }
        assert!(p[0][0].abs() < 0.05);
    }
}
