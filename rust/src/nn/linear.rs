//! Linear-layer variants with explicit forward/backward (the native mirror
//! of `python/compile/layers.py`), including the memory-efficient
//! **SwitchBackM** (Algorithm 3) whose backward dequantizes the saved int8
//! activations instead of keeping f32 around.
//!
//! Every variant's numerics live in one [`MatmulPlan`] (weight form +
//! which matmuls run int8 + what the cache holds); this file only maps
//! `LinearKind` → plan and threads the cache through the backward.

use crate::gemm::MatmulPlan;
pub use crate::gemm::PreparedWeight;
use crate::quant::{dequant_rowwise, rowwise_quant, QuantizedRow};
use crate::tensor::{Matrix, Rng};

/// Which precision scheme the layer uses (paper §2.2 + Appendix B).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinearKind {
    /// Algorithm 5: all three matmuls full precision.
    Standard,
    /// Algorithm 1: int8 fwd + dgrad, f32 wgrad; saves f32 X for backward.
    SwitchBack,
    /// Algorithm 3: as SwitchBack but saves only int8 X (4× less memory),
    /// paying one dequantize in the backward.
    SwitchBackM,
    /// All three matmuls int8 (LLM.int8()-style).
    LlmInt8,
}

impl LinearKind {
    /// Inverse of [`Self::label`] (CLI / config parsing).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "standard" => Some(Self::Standard),
            "switchback" => Some(Self::SwitchBack),
            "switchback_m" => Some(Self::SwitchBackM),
            "llmint8" => Some(Self::LlmInt8),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::Standard => "standard",
            Self::SwitchBack => "switchback",
            Self::SwitchBackM => "switchback_m",
            Self::LlmInt8 => "llmint8",
        }
    }

    /// The kind's numerics as data — the single dispatch point every
    /// forward/backward/infer/prepare path funnels through.
    pub const fn plan(&self) -> MatmulPlan {
        match self {
            Self::Standard => MatmulPlan::standard(),
            Self::SwitchBack => MatmulPlan::switchback(false),
            Self::SwitchBackM => MatmulPlan::switchback(true),
            Self::LlmInt8 => MatmulPlan::llm_int8(),
        }
    }
}

impl std::str::FromStr for LinearKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Self::parse(s).ok_or_else(|| format!("unknown linear kind {s:?}"))
    }
}

/// What the forward pass saves for the backward pass.
pub enum LinearCache {
    /// f32 activations (Standard / SwitchBack / LlmInt8)
    Full(Matrix),
    /// int8 activations + state (SwitchBackM)
    Quantized(QuantizedRow),
}

impl LinearCache {
    /// Bytes retained for the backward pass — the Algorithm 3 selling point.
    pub fn retained_bytes(&self) -> usize {
        match self {
            Self::Full(m) => m.data.len() * 4,
            Self::Quantized(q) => q.codes.data.len() + q.state.len() * 4,
        }
    }
}

/// A bias-free linear layer `y = x Wᵀ` with pluggable precision.
pub struct Linear {
    pub w: Matrix, // [out, in]
    pub kind: LinearKind,
}

impl Linear {
    pub fn new(out_dim: usize, in_dim: usize, kind: LinearKind, rng: &mut Rng) -> Self {
        let std = (2.0 / (in_dim + out_dim) as f32).sqrt();
        Self { w: Matrix::randn(out_dim, in_dim, std, rng), kind }
    }

    /// Forward: `x [b, in] → [b, out]`, plus the backward cache.
    pub fn forward(&self, x: &Matrix) -> (Matrix, LinearCache) {
        let plan = self.kind.plan();
        if plan.cache_codes {
            // Algorithm 3: quantize once, reuse the codes for both the
            // matmul and the (4×-smaller) backward cache.
            let xq = rowwise_quant(x);
            let y = plan.forward_quantized(&xq, &self.w);
            (y, LinearCache::Quantized(xq))
        } else {
            (plan.forward(x, &self.w), LinearCache::Full(x.clone()))
        }
    }

    /// Backward: upstream `g [b, out]` → `(dx [b, in], dw [out, in])`.
    pub fn backward(&self, cache: &LinearCache, g: &Matrix) -> (Matrix, Matrix) {
        let plan = self.kind.plan();
        let dx = plan.dgrad(g, &self.w);
        let dw = match cache {
            LinearCache::Full(x) => plan.wgrad(g, x),
            // Algorithm 3: dequantize X from int8, then (exact f32) wgrad.
            LinearCache::Quantized(xq) => plan.wgrad(g, &dequant_rowwise(xq)),
        };
        (dx, dw)
    }

    /// Inference-mode forward: identical numerics to [`Linear::forward`]'s
    /// output but no [`LinearCache`] is materialized (serving never runs a
    /// backward pass).  SwitchBackM shares SwitchBack's forward — the
    /// variants only differ in what they *save*, which is nothing here.
    pub fn forward_infer(&self, x: &Matrix) -> Matrix {
        self.kind.plan().forward(x, &self.w)
    }

    /// Pack the weight once for forward-only serving (the serve
    /// subsystem's quantize-on-load path): int8 kinds keep only packed
    /// tile-major codes + state, ready for the blocked kernel.
    pub fn prepare(&self) -> PreparedLinear {
        PreparedLinear {
            kind: self.kind,
            out_dim: self.w.rows,
            in_dim: self.w.cols,
            weight: self.kind.plan().prepare(&self.w),
        }
    }
}

/// A forward-only linear layer with its weight pre-quantized **and
/// pre-packed** into the blocked kernel's panel layout at load time.
///
/// Per call only the *activations* are quantized (row-wise, O(b·n) against
/// the matmul's O(b·m·n), into per-thread scratch); the weight-side
/// quantize+pack — O(m·n), the dominant quantize cost in
/// [`Linear::forward`] — is already paid.
pub struct PreparedLinear {
    pub kind: LinearKind,
    pub out_dim: usize,
    pub in_dim: usize,
    weight: PreparedWeight,
}

impl PreparedLinear {
    /// `x [b, in] → [b, out]`, no cache, weight already packed.
    pub fn forward(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.in_dim, "input dim mismatch");
        self.weight.forward(x)
    }

    /// Forward from shared, already-quantized activations (int8 kinds):
    /// one row-quantize of a block input feeds Q, K and V.
    pub fn forward_quant(&self, xq: &QuantizedRow) -> Matrix {
        assert_eq!(xq.codes.cols, self.in_dim, "input dim mismatch");
        self.weight.forward_quant(xq)
    }

    /// Forward with the fused map+quantize epilogue: the output rows are
    /// mapped (e.g. gelu) and re-quantized inside the GEMM's dequant
    /// epilogue — the next layer's int8 input without an f32 round-trip.
    pub fn forward_fused_quant(
        &self,
        xq: &QuantizedRow,
        map: Option<fn(f32) -> f32>,
    ) -> QuantizedRow {
        assert_eq!(xq.codes.cols, self.in_dim, "input dim mismatch");
        self.weight.forward_fused_quant(xq, map)
    }

    /// Whether this layer consumes quantized activations (int8 kinds).
    pub fn quantizes_input(&self) -> bool {
        self.weight.is_quantized()
    }

    /// Resident weight bytes (codes + state) — the serving-memory analogue
    /// of [`LinearCache::retained_bytes`].
    pub fn weight_bytes(&self) -> usize {
        self.weight.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rel_err(a: &Matrix, b: &Matrix) -> f32 {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (x, y) in a.data.iter().zip(&b.data) {
            num += ((x - y) as f64).powi(2);
            den += (*y as f64).powi(2);
        }
        (num / den.max(1e-30)).sqrt() as f32
    }

    /// Analytic gradients of the Standard layer vs finite differences on a
    /// scalar loss L = sum(y ⊙ r).
    #[test]
    fn standard_backward_matches_finite_difference() {
        let mut rng = Rng::seed(77);
        let lin = Linear::new(3, 4, LinearKind::Standard, &mut rng);
        let x = Matrix::randn(2, 4, 1.0, &mut rng);
        let r = Matrix::randn(2, 3, 1.0, &mut rng);
        let (_, cache) = lin.forward(&x);
        let (dx, dw) = lin.backward(&cache, &r);
        let h = 1e-3;
        for i in 0..x.data.len() {
            let mut xp = x.clone();
            xp.data[i] += h;
            let mut xm = x.clone();
            xm.data[i] -= h;
            let lp: f32 = lin.forward(&xp).0.data.iter().zip(&r.data).map(|(a, b)| a * b).sum();
            let lm: f32 = lin.forward(&xm).0.data.iter().zip(&r.data).map(|(a, b)| a * b).sum();
            assert!((dx.data[i] - (lp - lm) / (2.0 * h)).abs() < 1e-2);
        }
        for i in 0..lin.w.data.len() {
            let mut lp_lin = Linear { w: lin.w.clone(), kind: lin.kind };
            lp_lin.w.data[i] += h;
            let mut lm_lin = Linear { w: lin.w.clone(), kind: lin.kind };
            lm_lin.w.data[i] -= h;
            let lp: f32 =
                lp_lin.forward(&x).0.data.iter().zip(&r.data).map(|(a, b)| a * b).sum();
            let lm: f32 =
                lm_lin.forward(&x).0.data.iter().zip(&r.data).map(|(a, b)| a * b).sum();
            assert!((dw.data[i] - (lp - lm) / (2.0 * h)).abs() < 1e-2);
        }
    }

    #[test]
    fn switchback_close_to_standard() {
        let mut rng = Rng::seed(78);
        let w = Matrix::randn(32, 48, 0.1, &mut rng);
        let sb = Linear { w: w.clone(), kind: LinearKind::SwitchBack };
        let st = Linear { w, kind: LinearKind::Standard };
        let x = Matrix::randn(64, 48, 1.0, &mut rng);
        let g = Matrix::randn(64, 32, 1.0, &mut rng);
        let (ysb, csb) = sb.forward(&x);
        let (yst, cst) = st.forward(&x);
        assert!(rel_err(&ysb, &yst) < 0.03);
        let (dxsb, dwsb) = sb.backward(&csb, &g);
        let (dxst, dwst) = st.backward(&cst, &g);
        assert!(rel_err(&dxsb, &dxst) < 0.03);
        // wgrad identical: both are exact f32
        assert_eq!(dwsb.max_abs_diff(&dwst), 0.0);
    }

    #[test]
    fn switchbackm_saves_memory_and_stays_close() {
        let mut rng = Rng::seed(79);
        let w = Matrix::randn(32, 48, 0.1, &mut rng);
        let sbm = Linear { w: w.clone(), kind: LinearKind::SwitchBackM };
        let sb = Linear { w, kind: LinearKind::SwitchBack };
        let x = Matrix::randn(64, 48, 1.0, &mut rng);
        let g = Matrix::randn(64, 32, 1.0, &mut rng);
        let (ym, cm) = sbm.forward(&x);
        let (yf, cf) = sb.forward(&x);
        assert_eq!(ym.max_abs_diff(&yf), 0.0, "same int8 forward");
        assert!(cm.retained_bytes() * 3 < cf.retained_bytes(), "≈4× smaller cache");
        let (dxm, dwm) = sbm.backward(&cm, &g);
        let (dxf, dwf) = sb.backward(&cf, &g);
        assert_eq!(dxm.max_abs_diff(&dxf), 0.0);
        // wgrad differs only by the int8 round-trip of X
        assert!(rel_err(&dwm, &dwf) < 0.03);
    }

    /// The inference path must be bit-identical to the training forward for
    /// every kind — serving reuses the exact same GEMM substrate, packed.
    #[test]
    fn forward_infer_and_prepared_match_training_forward() {
        let mut rng = Rng::seed(83);
        for kind in [
            LinearKind::Standard,
            LinearKind::SwitchBack,
            LinearKind::SwitchBackM,
            LinearKind::LlmInt8,
        ] {
            let lin = Linear::new(24, 40, kind, &mut rng);
            let x = Matrix::randn(16, 40, 1.0, &mut rng);
            let (y_train, _) = lin.forward(&x);
            let y_infer = lin.forward_infer(&x);
            let y_prep = lin.prepare().forward(&x);
            assert_eq!(
                y_train.max_abs_diff(&y_infer),
                0.0,
                "{kind:?}: infer != train fwd"
            );
            assert_eq!(
                y_train.max_abs_diff(&y_prep),
                0.0,
                "{kind:?}: prepared != train fwd"
            );
        }
    }

    /// The shared-codes and fused-epilogue prepared paths are bit-identical
    /// to quantize-then-forward (the fusion contract, per kind).
    #[test]
    fn prepared_quant_paths_match_unfused() {
        let mut rng = Rng::seed(85);
        for kind in [LinearKind::SwitchBack, LinearKind::LlmInt8] {
            let lin = Linear::new(24, 40, kind, &mut rng);
            let prep = lin.prepare();
            assert!(prep.quantizes_input());
            let x = Matrix::randn(7, 40, 1.0, &mut rng);
            let xq = rowwise_quant(&x);
            let y = prep.forward(&x);
            assert_eq!(prep.forward_quant(&xq).max_abs_diff(&y), 0.0, "{kind:?}");
            let fused = prep.forward_fused_quant(&xq, Some(crate::nn::gelu));
            let mut mapped = y.clone();
            for v in mapped.data.iter_mut() {
                *v = crate::nn::gelu(*v);
            }
            let want = rowwise_quant(&mapped);
            assert_eq!(fused.codes.data, want.codes.data, "{kind:?}");
            assert_eq!(fused.state, want.state, "{kind:?}");
        }
    }

    /// Pre-packed int8 weights hold ≈4× less memory than f32 weights.
    #[test]
    fn prepared_weight_bytes_quartered_for_int8_kinds() {
        let mut rng = Rng::seed(84);
        let std = Linear::new(64, 256, LinearKind::Standard, &mut rng).prepare();
        let sb = Linear::new(64, 256, LinearKind::SwitchBack, &mut rng).prepare();
        let llm = Linear::new(64, 256, LinearKind::LlmInt8, &mut rng).prepare();
        assert_eq!(std.weight_bytes(), 64 * 256 * 4);
        assert!(sb.weight_bytes() * 3 < std.weight_bytes());
        assert!(llm.weight_bytes() * 3 < std.weight_bytes());
    }

    #[test]
    fn llmint8_wgrad_noise_variance_grows_with_inner_dim() {
        // Appendix C, measured: the *absolute* quantization-noise variance of
        // the int8 wgrad grows ∝ the inner dimension (= batch×seq), eq. (14).
        let mut rng = Rng::seed(80);
        let w = Matrix::randn(16, 24, 0.1, &mut rng);
        let noise_var = |b: usize, rng: &mut Rng| {
            let llm = Linear { w: w.clone(), kind: LinearKind::LlmInt8 };
            let st = Linear { w: w.clone(), kind: LinearKind::Standard };
            let x = Matrix::randn(b, 24, 1.0, rng);
            let g = Matrix::randn(b, 16, 1.0, rng);
            let (_, cl) = llm.forward(&x);
            let (_, cs) = st.forward(&x);
            let (_, dwl) = llm.backward(&cl, &g);
            let (_, dws) = st.backward(&cs, &g);
            let n = dwl.data.len() as f64;
            dwl.data
                .iter()
                .zip(&dws.data)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                / n
        };
        let v_small = noise_var(64, &mut rng);
        let v_big = noise_var(4096, &mut rng);
        assert!(
            v_big > 8.0 * v_small,
            "noise variance should scale ~linearly with inner dim (64→4096): \
             {v_small} vs {v_big}"
        );
    }
}
