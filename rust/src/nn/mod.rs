//! Native neural-network substrate: hand-written forward/backward for the
//! linear-layer variants and a full transformer block.
//!
//! This is the *measured-speed* half of the reproduction (the accuracy
//! experiments run through the AOT'd JAX model — see `crate::runtime`,
//! feature `pjrt`).
//! The paper's Fig 3/4/13 compare wall-clock of SwitchBack vs standard vs
//! LLM.int8() linear layers inside real training steps; those comparisons
//! need kernels that actually run at different speeds, which the
//! interpret-mode Pallas path cannot provide on CPU.  Here every variant's
//! three matmuls run on the native [`crate::gemm`] kernels with real int8
//! arithmetic.
//!
//! Numerics are cross-checked against the [`crate::quant`] +
//! finite-difference oracles in the tests.

mod block;
mod linear;

pub use block::{BlockCache, BlockGrads, PreparedBlock, TransformerBlock};
pub use linear::{Linear, LinearCache, LinearKind, PreparedLinear, PreparedWeight};

use crate::tensor::Matrix;

/// GELU (tanh approximation, matching `jax.nn.gelu(approximate=True)`).
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// d gelu / dx.
pub fn gelu_grad(x: f32) -> f32 {
    const C: f32 = 0.7978845608;
    let x3 = 0.044715 * x * x * x;
    let t = (C * (x + x3)).tanh();
    let sech2 = 1.0 - t * t;
    0.5 * (1.0 + t) + 0.5 * x * sech2 * C * (1.0 + 3.0 * 0.044715 * x * x)
}

/// Row-wise softmax in place.
pub fn softmax_rows(m: &mut Matrix) {
    for r in 0..m.rows {
        let row = m.row_mut(r);
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Mean-pool each item's `seq` consecutive rows: `[b·seq, dim]` →
/// `[b, dim]`.  The single implementation behind the train model's
/// forward/infer paths and the serving encoder — the bit-identical
/// train/serve encoding contract depends on these sharing one body.
pub fn mean_pool_rows(x: &Matrix, seq: usize, dim: usize) -> Matrix {
    let b = x.rows / seq;
    let mut pooled = Matrix::zeros(b, dim);
    let inv = 1.0 / seq as f32;
    for i in 0..b {
        let prow = pooled.row_mut(i);
        for t in 0..seq {
            let xrow = x.row(i * seq + t);
            for (p, &v) in prow.iter_mut().zip(xrow) {
                *p += v * inv;
            }
        }
    }
    pooled
}

/// L2-normalize rows in place (f64 norm accumulation, CLIP's unit-sphere
/// embeddings); returns each row's pre-normalization norm.  All-zero rows
/// are left untouched (their recorded norm is 0).
pub fn l2_normalize_rows(m: &mut Matrix) -> Vec<f32> {
    let mut norms = vec![0.0f32; m.rows];
    for r in 0..m.rows {
        let row = m.row_mut(r);
        let norm = row.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt() as f32;
        norms[r] = norm;
        if norm > 0.0 {
            let inv = 1.0 / norm;
            for v in row.iter_mut() {
                *v *= inv;
            }
        }
    }
    norms
}

/// Softmax backward: given `s = softmax(z)` and upstream `ds`, returns
/// `dz = s ⊙ (ds − ⟨ds, s⟩)` row-wise, in place over `ds`.
pub fn softmax_backward_rows(s: &Matrix, ds: &mut Matrix) {
    for r in 0..s.rows {
        let srow = s.row(r);
        let drow = ds.row_mut(r);
        let dot: f32 = srow.iter().zip(drow.iter()).map(|(a, b)| a * b).sum();
        for (d, &sv) in drow.iter_mut().zip(srow) {
            *d = sv * (*d - dot);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gelu_matches_known_values() {
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(1.0) - 0.8412).abs() < 1e-3);
        assert!((gelu(-1.0) + 0.1588).abs() < 1e-3);
    }

    #[test]
    fn gelu_grad_matches_finite_difference() {
        for &x in &[-2.0f32, -0.5, 0.0, 0.3, 1.7] {
            let h = 1e-3;
            let fd = (gelu(x + h) - gelu(x - h)) / (2.0 * h);
            assert!((gelu_grad(x) - fd).abs() < 1e-3, "x={x}");
        }
    }

    #[test]
    fn mean_pool_and_l2_normalize() {
        // 2 items × seq 2, dim 3
        let x = Matrix::from_vec(
            4,
            3,
            vec![1.0, 2.0, 3.0, 3.0, 2.0, 1.0, 0.0, 0.0, 0.0, 4.0, 0.0, 0.0],
        );
        let pooled = mean_pool_rows(&x, 2, 3);
        assert_eq!(pooled.data, vec![2.0, 2.0, 2.0, 2.0, 0.0, 0.0]);
        let mut m = Matrix::from_vec(2, 2, vec![3.0, 4.0, 0.0, 0.0]);
        let norms = l2_normalize_rows(&mut m);
        assert_eq!(norms, vec![5.0, 0.0]);
        assert_eq!(m.row(0), &[0.6, 0.8]);
        assert_eq!(m.row(1), &[0.0, 0.0], "zero row untouched");
    }

    #[test]
    fn softmax_rows_normalizes() {
        let mut m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]);
        softmax_rows(&mut m);
        for r in 0..2 {
            let s: f32 = m.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!(m.at(0, 2) > m.at(0, 1));
    }

    #[test]
    fn softmax_backward_matches_finite_difference() {
        let z = Matrix::from_vec(1, 4, vec![0.1, -0.2, 0.5, 0.0]);
        let upstream = vec![0.3f32, -0.1, 0.2, 0.4];
        let mut s = z.clone();
        softmax_rows(&mut s);
        let mut ds = Matrix::from_vec(1, 4, upstream.clone());
        softmax_backward_rows(&s, &mut ds);
        for i in 0..4 {
            let h = 1e-3;
            let mut zp = z.clone();
            zp.data[i] += h;
            softmax_rows(&mut zp);
            let mut zm = z.clone();
            zm.data[i] -= h;
            softmax_rows(&mut zm);
            let mut fd = 0.0;
            for j in 0..4 {
                fd += upstream[j] * (zp.data[j] - zm.data[j]) / (2.0 * h);
            }
            assert!((ds.data[i] - fd).abs() < 1e-3, "i={i}: {} vs {fd}", ds.data[i]);
        }
    }
}
