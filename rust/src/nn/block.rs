//! A full pre-norm transformer block with hand-written backward — the
//! end-to-end speed workload for Fig 4 (right) and Fig 13.
//!
//! All six projections (q/k/v/out + mlp up/down) route through the
//! precision-pluggable [`Linear`]; layernorm / softmax / gelu / residuals
//! stay f32 (the paper replaces only the nn.Linear layers).  The backward
//! is exact for the Standard variant (finite-difference tested) and uses
//! each variant's quantized dgrad/wgrad rules otherwise.

use super::linear::{Linear, LinearCache, LinearKind, PreparedLinear};
use super::{gelu, gelu_grad, softmax_backward_rows, softmax_rows};
use crate::gemm::{gemm_f32_nn, gemm_f32_nt};
use crate::quant::{rowwise_quant, QuantizedRow};
use crate::tensor::{Matrix, Rng};

/// LayerNorm over the last dim with affine params.
#[derive(Clone)]
struct LayerNorm {
    g: Vec<f32>,
    b: Vec<f32>,
}

struct LnCache {
    xhat: Matrix,
    inv_std: Vec<f32>,
}

impl LayerNorm {
    fn new(d: usize) -> Self {
        Self { g: vec![1.0; d], b: vec![0.0; d] }
    }

    fn forward(&self, x: &Matrix) -> (Matrix, LnCache) {
        let d = x.cols;
        let mut out = Matrix::zeros(x.rows, d);
        let mut xhat = Matrix::zeros(x.rows, d);
        let mut inv_std = vec![0.0f32; x.rows];
        for r in 0..x.rows {
            let row = x.row(r);
            let mean: f32 = row.iter().sum::<f32>() / d as f32;
            let var: f32 =
                row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
            let istd = 1.0 / (var + 1e-5).sqrt();
            inv_std[r] = istd;
            for c in 0..d {
                let xh = (row[c] - mean) * istd;
                xhat.data[r * d + c] = xh;
                out.data[r * d + c] = xh * self.g[c] + self.b[c];
            }
        }
        (out, LnCache { xhat, inv_std })
    }

    /// Inference-mode layernorm: no `xhat`/`inv_std` cache is built.
    fn apply(&self, x: &Matrix) -> Matrix {
        let d = x.cols;
        let mut out = Matrix::zeros(x.rows, d);
        for r in 0..x.rows {
            let row = x.row(r);
            let mean: f32 = row.iter().sum::<f32>() / d as f32;
            let var: f32 =
                row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
            let istd = 1.0 / (var + 1e-5).sqrt();
            let orow = out.row_mut(r);
            for c in 0..d {
                orow[c] = (row[c] - mean) * istd * self.g[c] + self.b[c];
            }
        }
        out
    }

    /// Returns dx (param grads are not tracked in the speed benches — the
    //  projections dominate; accuracy runs use the XLA path).
    fn backward(&self, cache: &LnCache, dy: &Matrix) -> Matrix {
        let d = dy.cols;
        let mut dx = Matrix::zeros(dy.rows, d);
        for r in 0..dy.rows {
            let istd = cache.inv_std[r];
            let xh = cache.xhat.row(r);
            let dyr = dy.row(r);
            let mut sum_dxhat = 0.0f32;
            let mut sum_dxhat_xhat = 0.0f32;
            for c in 0..d {
                let dxh = dyr[c] * self.g[c];
                sum_dxhat += dxh;
                sum_dxhat_xhat += dxh * xh[c];
            }
            let n = d as f32;
            for c in 0..d {
                let dxh = dyr[c] * self.g[c];
                dx.data[r * d + c] =
                    istd * (dxh - sum_dxhat / n - xh[c] * sum_dxhat_xhat / n);
            }
        }
        dx
    }
}

/// Multi-head self-attention cache.
struct AttnCache {
    q: Matrix,
    k: Matrix,
    v: Matrix,
    /// softmax(scores) per (batch, head): [B*h] matrices of [T, T]
    probs: Vec<Matrix>,
    cq: LinearCache,
    ck: LinearCache,
    cv: LinearCache,
    co: LinearCache,
}

/// One transformer block (attention + MLP) with residuals.
pub struct TransformerBlock {
    pub dim: usize,
    pub heads: usize,
    pub seq: usize,
    ln1: LayerNorm,
    ln2: LayerNorm,
    pub wq: Linear,
    pub wk: Linear,
    pub wv: Linear,
    pub wo: Linear,
    pub w1: Linear,
    pub w2: Linear,
}

/// Weight gradients of one block.
pub struct BlockGrads {
    pub dwq: Matrix,
    pub dwk: Matrix,
    pub dwv: Matrix,
    pub dwo: Matrix,
    pub dw1: Matrix,
    pub dw2: Matrix,
}

impl BlockGrads {
    /// The six weight gradients in the same canonical order as
    /// [`TransformerBlock::projections`].
    pub fn into_array(self) -> [Matrix; 6] {
        [self.dwq, self.dwk, self.dwv, self.dwo, self.dw1, self.dw2]
    }
}

pub struct BlockCache {
    x: Matrix,
    ln1c: LnCache,
    attn: AttnCache,
    ln2c: LnCache,
    h_pre: Matrix,
    c1: LinearCache,
    c2: LinearCache,
}

impl TransformerBlock {
    pub fn new(dim: usize, heads: usize, seq: usize, kind: LinearKind, rng: &mut Rng) -> Self {
        assert_eq!(dim % heads, 0);
        Self {
            dim,
            heads,
            seq,
            ln1: LayerNorm::new(dim),
            ln2: LayerNorm::new(dim),
            wq: Linear::new(dim, dim, kind, rng),
            wk: Linear::new(dim, dim, kind, rng),
            wv: Linear::new(dim, dim, kind, rng),
            wo: Linear::new(dim, dim, kind, rng),
            w1: Linear::new(4 * dim, dim, kind, rng),
            w2: Linear::new(dim, 4 * dim, kind, rng),
        }
    }

    /// `x [B*T, d]` (T = self.seq); returns `(y, cache)`.
    pub fn forward(&self, x: &Matrix) -> (Matrix, BlockCache) {
        let (t, d, h) = (self.seq, self.dim, self.heads);
        let hd = d / h;
        let batch = x.rows / t;
        let (xn, ln1c) = self.ln1.forward(x);
        let (q, cq) = self.wq.forward(&xn);
        let (k, ck) = self.wk.forward(&xn);
        let (v, cv) = self.wv.forward(&xn);
        // attention core per (batch, head), f32
        let scale = 1.0 / (hd as f32).sqrt();
        let mut probs = Vec::with_capacity(batch * h);
        let mut concat = Matrix::zeros(x.rows, d);
        for b in 0..batch {
            for hh in 0..h {
                // gather head slices [T, hd]
                let mut qh = Matrix::zeros(t, hd);
                let mut kh = Matrix::zeros(t, hd);
                let mut vh = Matrix::zeros(t, hd);
                for i in 0..t {
                    let row = (b * t + i) * d + hh * hd;
                    qh.row_mut(i).copy_from_slice(&q.data[row..row + hd]);
                    kh.row_mut(i).copy_from_slice(&k.data[row..row + hd]);
                    vh.row_mut(i).copy_from_slice(&v.data[row..row + hd]);
                }
                let mut scores = gemm_f32_nt(&qh, &kh);
                for s in scores.data.iter_mut() {
                    *s *= scale;
                }
                softmax_rows(&mut scores);
                let out = gemm_f32_nn(&scores, &vh);
                for i in 0..t {
                    let row = (b * t + i) * d + hh * hd;
                    concat.data[row..row + hd].copy_from_slice(out.row(i));
                }
                probs.push(scores);
            }
        }
        let (attn_out, co) = self.wo.forward(&concat);
        let mut x_mid = x.clone();
        for (m, a) in x_mid.data.iter_mut().zip(&attn_out.data) {
            *m += a;
        }
        let (xn2, ln2c) = self.ln2.forward(&x_mid);
        let (h_pre, c1) = self.w1.forward(&xn2);
        let mut h_act = h_pre.clone();
        for v in h_act.data.iter_mut() {
            *v = gelu(*v);
        }
        let (mlp_out, c2) = self.w2.forward(&h_act);
        let mut y = x_mid.clone();
        for (o, m) in y.data.iter_mut().zip(&mlp_out.data) {
            *o += m;
        }
        let _ = concat;
        let attn = AttnCache { q, k, v, probs, cq, ck, cv, co };
        (y, BlockCache { x: x.clone(), ln1c, attn, ln2c, h_pre, c1, c2 })
    }

    /// Backward through the whole block: upstream `dy [B*T, d]` →
    /// `(dx, weight grads)`.
    pub fn backward(&self, cache: &BlockCache, dy: &Matrix) -> (Matrix, BlockGrads) {
        let (t, d, h) = (self.seq, self.dim, self.heads);
        let hd = d / h;
        let batch = cache.x.rows / t;
        // MLP branch
        let (dh_act, dw2) = self.w2.backward(&cache.c2, dy);
        let mut dh_pre = dh_act;
        for (g, &xp) in dh_pre.data.iter_mut().zip(&cache.h_pre.data) {
            *g *= gelu_grad(xp);
        }
        let (dxn2, dw1) = self.w1.backward(&cache.c1, &dh_pre);
        let dx_mid_mlp = self.ln2.backward(&cache.ln2c, &dxn2);
        // residual: d x_mid = dy + mlp-branch grad
        let mut dx_mid = dy.clone();
        for (g, a) in dx_mid.data.iter_mut().zip(&dx_mid_mlp.data) {
            *g += a;
        }
        // attention branch
        let (dconcat, dwo) = self.wo.backward(&cache.attn.co, &dx_mid);
        let scale = 1.0 / (hd as f32).sqrt();
        let mut dq = Matrix::zeros(cache.x.rows, d);
        let mut dk = Matrix::zeros(cache.x.rows, d);
        let mut dv = Matrix::zeros(cache.x.rows, d);
        for b in 0..batch {
            for hh in 0..h {
                let probs = &cache.attn.probs[b * h + hh];
                // rebuild head slices
                let mut dout = Matrix::zeros(t, hd);
                let mut qh = Matrix::zeros(t, hd);
                let mut kh = Matrix::zeros(t, hd);
                let mut vh = Matrix::zeros(t, hd);
                for i in 0..t {
                    let row = (b * t + i) * d + hh * hd;
                    dout.row_mut(i).copy_from_slice(&dconcat.data[row..row + hd]);
                    qh.row_mut(i).copy_from_slice(&cache.attn.q.data[row..row + hd]);
                    kh.row_mut(i).copy_from_slice(&cache.attn.k.data[row..row + hd]);
                    vh.row_mut(i).copy_from_slice(&cache.attn.v.data[row..row + hd]);
                }
                // out = probs @ vh  ⇒  dprobs = dout @ vhᵀ, dvh = probsᵀ @ dout
                let mut dprobs = gemm_f32_nt(&dout, &vh);
                let dvh = gemm_f32_nn(&probs.transpose(), &dout);
                softmax_backward_rows(probs, &mut dprobs);
                for s in dprobs.data.iter_mut() {
                    *s *= scale;
                }
                // scores = qh @ khᵀ (scaled)
                let dqh = gemm_f32_nn(&dprobs, &kh);
                let dkh = gemm_f32_nn(&dprobs.transpose(), &qh);
                for i in 0..t {
                    let row = (b * t + i) * d + hh * hd;
                    dq.data[row..row + hd].copy_from_slice(dqh.row(i));
                    dk.data[row..row + hd].copy_from_slice(dkh.row(i));
                    dv.data[row..row + hd].copy_from_slice(dvh.row(i));
                }
            }
        }
        let (dxn_q, dwq) = self.wq.backward(&cache.attn.cq, &dq);
        let (dxn_k, dwk) = self.wk.backward(&cache.attn.ck, &dk);
        let (dxn_v, dwv) = self.wv.backward(&cache.attn.cv, &dv);
        let mut dxn = dxn_q;
        for i in 0..dxn.data.len() {
            dxn.data[i] += dxn_k.data[i] + dxn_v.data[i];
        }
        let dx_ln1 = self.ln1.backward(&cache.ln1c, &dxn);
        let mut dx = dx_mid;
        for (g, a) in dx.data.iter_mut().zip(&dx_ln1.data) {
            *g += a;
        }
        (dx, BlockGrads { dwq, dwk, dwv, dwo, dw1, dw2 })
    }

    /// One full training-step worth of block compute (fwd + bwd) — the unit
    /// the Fig 4/13 speed benches measure.
    pub fn train_step_compute(&self, x: &Matrix) -> (Matrix, BlockGrads) {
        let (y, cache) = self.forward(x);
        // pretend upstream gradient = y (keeps magnitudes realistic)
        self.backward(&cache, &y)
    }

    /// Inference-mode forward: numerically identical to [`Self::forward`]'s
    /// output, but no [`BlockCache`] / [`LinearCache`] / softmax probs are
    /// retained — the serving path's memory stays O(batch·dim).
    pub fn forward_infer(&self, x: &Matrix) -> Matrix {
        infer_body(self.dim, self.heads, self.seq, &self.ln1, &self.ln2, x, &LiveProj(self))
    }

    /// The six projection layers in canonical (q, k, v, o, up, down)
    /// order — the order [`BlockGrads::into_array`] mirrors, so the
    /// native trainer's parameter registry stays index-aligned.
    pub fn projections(&self) -> [&Linear; 6] {
        [&self.wq, &self.wk, &self.wv, &self.wo, &self.w1, &self.w2]
    }

    pub fn projections_mut(&mut self) -> [&mut Linear; 6] {
        [
            &mut self.wq,
            &mut self.wk,
            &mut self.wv,
            &mut self.wo,
            &mut self.w1,
            &mut self.w2,
        ]
    }

    /// Quantize all six projection weights once for forward-only serving.
    pub fn prepare(&self) -> PreparedBlock {
        PreparedBlock {
            dim: self.dim,
            heads: self.heads,
            seq: self.seq,
            ln1: self.ln1.clone(),
            ln2: self.ln2.clone(),
            wq: self.wq.prepare(),
            wk: self.wk.prepare(),
            wv: self.wv.prepare(),
            wo: self.wo.prepare(),
            w1: self.w1.prepare(),
            w2: self.w2.prepare(),
        }
    }
}

/// Which of the block's six projections to run (see [`infer_body`]).
enum Proj {
    Q,
    K,
    V,
    O,
    Up,
    Down,
}

/// The projection surface [`infer_body`] drives — implemented by both the
/// live ([`TransformerBlock`]) and pre-packed ([`PreparedBlock`]) forms.
///
/// For int8 kinds (`quantized()`), `infer_body` row-quantizes each block
/// input **once** and feeds the shared codes to Q/K/V via `proj_quant`,
/// and runs the MLP through `up_fused_gelu`: the up-projection's GEMM
/// epilogue applies gelu and re-quantizes in one pass, so the hidden
/// activation flows to the down-projection as int8 codes without an f32
/// round-trip through memory.
trait InferProj {
    /// Whether the projections consume row-quantized activations.
    fn quantized(&self) -> bool;
    /// f32-in, f32-out projection (any kind).
    fn proj(&self, p: Proj, x: &Matrix) -> Matrix;
    /// Projection from shared, already-quantized activations (int8 kinds).
    fn proj_quant(&self, p: Proj, xq: &QuantizedRow) -> Matrix;
    /// Up-projection with the fused gelu+quantize epilogue (int8 kinds).
    fn up_fused_gelu(&self, xq: &QuantizedRow) -> QuantizedRow;
}

/// [`InferProj`] over live (unprepared) weights: quantize-per-call.
struct LiveProj<'a>(&'a TransformerBlock);

impl LiveProj<'_> {
    fn layer(&self, p: &Proj) -> &Linear {
        match p {
            Proj::Q => &self.0.wq,
            Proj::K => &self.0.wk,
            Proj::V => &self.0.wv,
            Proj::O => &self.0.wo,
            Proj::Up => &self.0.w1,
            Proj::Down => &self.0.w2,
        }
    }
}

impl InferProj for LiveProj<'_> {
    fn quantized(&self) -> bool {
        self.0.wq.kind.plan().quantizes_activations()
    }

    fn proj(&self, p: Proj, x: &Matrix) -> Matrix {
        self.layer(&p).forward_infer(x)
    }

    fn proj_quant(&self, p: Proj, xq: &QuantizedRow) -> Matrix {
        let l = self.layer(&p);
        l.kind.plan().forward_quantized(xq, &l.w)
    }

    fn up_fused_gelu(&self, xq: &QuantizedRow) -> QuantizedRow {
        let l = &self.0.w1;
        l.kind.plan().forward_fused_quant(xq, &l.w, Some(gelu))
    }
}

/// [`InferProj`] over pre-packed weights: per call only activations move.
struct PreparedProj<'a>(&'a PreparedBlock);

impl PreparedProj<'_> {
    fn layer(&self, p: &Proj) -> &PreparedLinear {
        match p {
            Proj::Q => &self.0.wq,
            Proj::K => &self.0.wk,
            Proj::V => &self.0.wv,
            Proj::O => &self.0.wo,
            Proj::Up => &self.0.w1,
            Proj::Down => &self.0.w2,
        }
    }
}

impl InferProj for PreparedProj<'_> {
    fn quantized(&self) -> bool {
        self.0.wq.quantizes_input()
    }

    fn proj(&self, p: Proj, x: &Matrix) -> Matrix {
        self.layer(&p).forward(x)
    }

    fn proj_quant(&self, p: Proj, xq: &QuantizedRow) -> Matrix {
        self.layer(&p).forward_quant(xq)
    }

    fn up_fused_gelu(&self, xq: &QuantizedRow) -> QuantizedRow {
        self.0.w1.forward_fused_quant(xq, Some(gelu))
    }
}

/// The forward-only block body shared by [`TransformerBlock::forward_infer`]
/// and [`PreparedBlock::forward`]: pre-norm attention + MLP with residuals,
/// allocating nothing beyond the live activations.
///
/// Bit-identical to the training forward for every kind: sharing one
/// row-quantize across Q/K/V reuses codes the training path computes
/// identically per projection, and the fused gelu+quant epilogue produces
/// exactly the codes `rowwise_quant(gelu(up_out))` would.
fn infer_body(
    dim: usize,
    heads: usize,
    seq: usize,
    ln1: &LayerNorm,
    ln2: &LayerNorm,
    x: &Matrix,
    proj: &impl InferProj,
) -> Matrix {
    let (t, d, h) = (seq, dim, heads);
    let hd = d / h;
    let batch = x.rows / t;
    let quantized = proj.quantized();
    let xn = ln1.apply(x);
    let (q, k, v) = if quantized {
        // one row-quantize of the normed input, shared by Q, K and V
        let xnq = rowwise_quant(&xn);
        (
            proj.proj_quant(Proj::Q, &xnq),
            proj.proj_quant(Proj::K, &xnq),
            proj.proj_quant(Proj::V, &xnq),
        )
    } else {
        (
            proj.proj(Proj::Q, &xn),
            proj.proj(Proj::K, &xn),
            proj.proj(Proj::V, &xn),
        )
    };
    let scale = 1.0 / (hd as f32).sqrt();
    let mut concat = Matrix::zeros(x.rows, d);
    for b in 0..batch {
        for hh in 0..h {
            let mut qh = Matrix::zeros(t, hd);
            let mut kh = Matrix::zeros(t, hd);
            let mut vh = Matrix::zeros(t, hd);
            for i in 0..t {
                let row = (b * t + i) * d + hh * hd;
                qh.row_mut(i).copy_from_slice(&q.data[row..row + hd]);
                kh.row_mut(i).copy_from_slice(&k.data[row..row + hd]);
                vh.row_mut(i).copy_from_slice(&v.data[row..row + hd]);
            }
            let mut scores = gemm_f32_nt(&qh, &kh);
            for s in scores.data.iter_mut() {
                *s *= scale;
            }
            softmax_rows(&mut scores);
            let out = gemm_f32_nn(&scores, &vh);
            for i in 0..t {
                let row = (b * t + i) * d + hh * hd;
                concat.data[row..row + hd].copy_from_slice(out.row(i));
            }
        }
    }
    let attn_out = proj.proj(Proj::O, &concat);
    let mut x_mid = x.clone();
    for (m, a) in x_mid.data.iter_mut().zip(&attn_out.data) {
        *m += a;
    }
    let xn2 = ln2.apply(&x_mid);
    let mlp_out = if quantized {
        // fused MLP: up-GEMM → gelu → re-quantize inside the epilogue;
        // the hidden activation reaches the down-GEMM as int8 codes
        let xn2q = rowwise_quant(&xn2);
        let h_q = proj.up_fused_gelu(&xn2q);
        proj.proj_quant(Proj::Down, &h_q)
    } else {
        let mut h_act = proj.proj(Proj::Up, &xn2);
        for v in h_act.data.iter_mut() {
            *v = gelu(*v);
        }
        proj.proj(Proj::Down, &h_act)
    };
    let mut y = x_mid;
    for (o, m) in y.data.iter_mut().zip(&mlp_out.data) {
        *o += m;
    }
    y
}

/// A transformer block with every projection weight pre-quantized at load
/// time — the serving engine's per-block unit (forward-only, no caches,
/// per-call quantization limited to activations).
pub struct PreparedBlock {
    pub dim: usize,
    pub heads: usize,
    pub seq: usize,
    ln1: LayerNorm,
    ln2: LayerNorm,
    wq: PreparedLinear,
    wk: PreparedLinear,
    wv: PreparedLinear,
    wo: PreparedLinear,
    w1: PreparedLinear,
    w2: PreparedLinear,
}

impl PreparedBlock {
    /// `x [B*T, d]` → `[B*T, d]` (T = `self.seq`), forward only.
    pub fn forward(&self, x: &Matrix) -> Matrix {
        infer_body(self.dim, self.heads, self.seq, &self.ln1, &self.ln2, x, &PreparedProj(self))
    }

    /// Resident weight bytes across all six projections.
    pub fn weight_bytes(&self) -> usize {
        self.wq.weight_bytes()
            + self.wk.weight_bytes()
            + self.wv.weight_bytes()
            + self.wo.weight_bytes()
            + self.w1.weight_bytes()
            + self.w2.weight_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Exact-gradient check of the whole block (Standard variant) against
    /// finite differences on a random scalar projection of the output.
    #[test]
    fn block_backward_matches_finite_difference() {
        let mut rng = Rng::seed(90);
        let blk = TransformerBlock::new(8, 2, 3, LinearKind::Standard, &mut rng);
        let x = Matrix::randn(6, 8, 0.5, &mut rng); // batch 2 × seq 3
        let r = Matrix::randn(6, 8, 1.0, &mut rng);
        let loss = |xx: &Matrix| -> f32 {
            let (y, _) = blk.forward(xx);
            y.data.iter().zip(&r.data).map(|(a, b)| a * b).sum()
        };
        let (_, cache) = blk.forward(&x);
        let (dx, _) = blk.backward(&cache, &r);
        let h = 1e-3;
        let mut worst = 0.0f32;
        for i in 0..x.data.len() {
            let mut xp = x.clone();
            xp.data[i] += h;
            let mut xm = x.clone();
            xm.data[i] -= h;
            let fd = (loss(&xp) - loss(&xm)) / (2.0 * h);
            worst = worst.max((dx.data[i] - fd).abs());
        }
        assert!(worst < 2e-2, "worst dx error {worst}");
    }

    #[test]
    fn weight_grads_match_finite_difference_spotcheck() {
        let mut rng = Rng::seed(91);
        let blk = TransformerBlock::new(8, 2, 3, LinearKind::Standard, &mut rng);
        let x = Matrix::randn(6, 8, 0.5, &mut rng);
        let r = Matrix::randn(6, 8, 1.0, &mut rng);
        let (_, cache) = blk.forward(&x);
        let (_, grads) = blk.backward(&cache, &r);
        let h = 1e-3;
        // spot-check a handful of w1 entries
        for &i in &[0usize, 7, 63, 100] {
            let mut bp = TransformerBlock::new(8, 2, 3, LinearKind::Standard, &mut Rng::seed(91));
            // rebuild identical block, then perturb
            bp.ln1.g.copy_from_slice(&blk.ln1.g);
            bp.wq.w = blk.wq.w.clone();
            bp.wk.w = blk.wk.w.clone();
            bp.wv.w = blk.wv.w.clone();
            bp.wo.w = blk.wo.w.clone();
            bp.w1.w = blk.w1.w.clone();
            bp.w2.w = blk.w2.w.clone();
            let loss_at = |delta: f32, bp: &mut TransformerBlock| -> f32 {
                bp.w1.w.data[i] += delta;
                let (y, _) = bp.forward(&x);
                let l = y.data.iter().zip(&r.data).map(|(a, b)| a * b).sum();
                bp.w1.w.data[i] -= delta;
                l
            };
            let fd = (loss_at(h, &mut bp) - loss_at(-h, &mut bp)) / (2.0 * h);
            assert!(
                (grads.dw1.data[i] - fd).abs() < 2e-2,
                "dw1[{i}]: {} vs {fd}",
                grads.dw1.data[i]
            );
        }
    }

    /// The cache-free inference path and the pre-quantized path must agree
    /// bit-for-bit with the training forward for every precision kind.
    #[test]
    fn infer_paths_match_training_forward_all_kinds() {
        for (i, kind) in [
            LinearKind::Standard,
            LinearKind::SwitchBack,
            LinearKind::SwitchBackM,
            LinearKind::LlmInt8,
        ]
        .into_iter()
        .enumerate()
        {
            let mut rng = Rng::seed(93 + i as u64);
            let blk = TransformerBlock::new(16, 4, 4, kind, &mut rng);
            let x = Matrix::randn(12, 16, 0.5, &mut rng); // batch 3 × seq 4
            let (y_train, _) = blk.forward(&x);
            let y_infer = blk.forward_infer(&x);
            let y_prep = blk.prepare().forward(&x);
            assert_eq!(y_train.max_abs_diff(&y_infer), 0.0, "{kind:?} infer");
            assert_eq!(y_train.max_abs_diff(&y_prep), 0.0, "{kind:?} prepared");
        }
    }

    /// Row independence across batch items: an item's embedding must not
    /// depend on what else was micro-batched with it (the serving batcher
    /// relies on this).
    #[test]
    fn forward_infer_is_batch_composition_invariant() {
        let mut rng = Rng::seed(97);
        let blk = TransformerBlock::new(8, 2, 3, LinearKind::Standard, &mut rng);
        let a = Matrix::randn(3, 8, 0.5, &mut rng); // one item (seq 3)
        let b = Matrix::randn(3, 8, 0.5, &mut rng);
        let mut both = Matrix::zeros(6, 8);
        both.data[..24].copy_from_slice(&a.data);
        both.data[24..].copy_from_slice(&b.data);
        let ya = blk.forward_infer(&a);
        let y_both = blk.forward_infer(&both);
        for i in 0..ya.data.len() {
            assert_eq!(ya.data[i], y_both.data[i], "elem {i}");
        }
    }

    #[test]
    fn quantized_block_close_to_standard() {
        let mut rng = Rng::seed(92);
        let std_blk = TransformerBlock::new(16, 4, 4, LinearKind::Standard, &mut rng);
        let mut sb_blk =
            TransformerBlock::new(16, 4, 4, LinearKind::SwitchBack, &mut Rng::seed(92));
        // share weights
        sb_blk.wq.w = std_blk.wq.w.clone();
        sb_blk.wk.w = std_blk.wk.w.clone();
        sb_blk.wv.w = std_blk.wv.w.clone();
        sb_blk.wo.w = std_blk.wo.w.clone();
        sb_blk.w1.w = std_blk.w1.w.clone();
        sb_blk.w2.w = std_blk.w2.w.clone();
        let x = Matrix::randn(8, 16, 0.5, &mut rng);
        let (ys, _) = std_blk.forward(&x);
        let (yq, _) = sb_blk.forward(&x);
        let rel = {
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for (a, b) in yq.data.iter().zip(&ys.data) {
                num += ((a - b) as f64).powi(2);
                den += (*b as f64).powi(2);
            }
            (num / den).sqrt()
        };
        assert!(rel < 0.05, "block output rel err {rel}");
    }
}
