//! Sharded LRU embedding cache keyed by input content hash.
//!
//! * **Sharded**: the key's low bits pick one of N independently locked
//!   shards, so cache traffic from the client threads never serializes on
//!   a single mutex (hits are the common case at production traffic).
//! * **Lazy LRU**: each shard keeps a `HashMap` plus a recency log of
//!   `(key, stamp)` pairs.  Touches append; eviction pops stale log
//!   entries until it finds one whose stamp is current.  O(1) amortized
//!   with no intrusive linked list, and the log is compacted when it
//!   outgrows the live set.
//!
//! Values are `Arc<Vec<f32>>` so a hit shares the embedding with every
//! waiting client instead of copying it.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// FNV-1a 64-bit — stable across platforms/runs (unlike `DefaultHasher`),
/// so cache keys are reproducible in tests and logs.
pub struct Fnv1a(u64);

impl Default for Fnv1a {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv1a {
    /// The standard FNV-1a offset basis.
    pub fn new() -> Self {
        Self(0xcbf29ce484222325)
    }

    /// Fold `bytes` into the running hash.
    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }

    /// The current 64-bit digest.
    pub fn finish(&self) -> u64 {
        self.0
    }
}

struct Entry {
    val: Arc<Vec<f32>>,
    stamp: u64,
}

struct Shard {
    map: HashMap<u64, Entry>,
    /// recency log: (key, stamp at touch time); stale pairs are skipped
    log: VecDeque<(u64, u64)>,
    tick: u64,
    cap: usize,
}

impl Shard {
    fn touch(&mut self, key: u64) -> u64 {
        self.tick += 1;
        self.log.push_back((key, self.tick));
        self.tick
    }

    fn maybe_compact(&mut self) {
        if self.log.len() > self.map.len() * 4 + 64 {
            let map = &self.map;
            self.log.retain(|&(k, s)| map.get(&k).is_some_and(|e| e.stamp == s));
        }
    }

    fn evict_one(&mut self) {
        while let Some((k, s)) = self.log.pop_front() {
            let stale = match self.map.get(&k) {
                Some(e) => e.stamp != s,
                None => true,
            };
            if !stale {
                self.map.remove(&k);
                return;
            }
        }
    }
}

/// A sharded, thread-safe LRU mapping `u64` content hashes to embeddings.
pub struct ShardedLru {
    shards: Vec<Mutex<Shard>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ShardedLru {
    /// `capacity` total entries spread over `n_shards` locks.
    ///
    /// Capacity is enforced *per shard* (`ceil(capacity / n_shards)`), so
    /// with hash-imbalanced keys some shards fill before others; callers
    /// that need "hold this working set" semantics should size capacity
    /// with headroom (2× is plenty for FNV-distributed keys).
    pub fn new(capacity: usize, n_shards: usize) -> Self {
        let n = n_shards.max(1);
        let per = capacity.div_ceil(n).max(1);
        Self {
            shards: (0..n)
                .map(|_| {
                    Mutex::new(Shard {
                        map: HashMap::new(),
                        log: VecDeque::new(),
                        tick: 0,
                        cap: per,
                    })
                })
                .collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Poison-recovering lock: a holder that panicked mid-op leaves the
    /// map/log coherent (worst case a stale recency stamp) — a poisoned
    /// shard must never panic the connection thread that hits it next.
    fn lock_shard(m: &Mutex<Shard>) -> std::sync::MutexGuard<'_, Shard> {
        m.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn shard(&self, key: u64) -> &Mutex<Shard> {
        let idx = (key as usize) % self.shards.len().max(1);
        // the modulo above keeps idx in range even for a 1-shard cache
        self.shards.get(idx).unwrap_or_else(|| &self.shards[0])
    }

    /// Look up an embedding, refreshing its recency on hit.
    pub fn get(&self, key: u64) -> Option<Arc<Vec<f32>>> {
        let mut sh = Self::lock_shard(self.shard(key));
        sh.tick += 1;
        let tick = sh.tick;
        match sh.map.get_mut(&key) {
            Some(e) => {
                e.stamp = tick;
                let val = Arc::clone(&e.val);
                sh.log.push_back((key, tick));
                sh.maybe_compact();
                drop(sh);
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(val)
            }
            None => {
                drop(sh);
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (or refresh) an embedding, evicting the least recently used
    /// entry if the shard is at capacity.
    pub fn insert(&self, key: u64, val: Arc<Vec<f32>>) {
        let mut sh = Self::lock_shard(self.shard(key));
        let stamp = sh.touch(key);
        let existed = sh.map.insert(key, Entry { val, stamp }).is_some();
        if !existed && sh.map.len() > sh.cap {
            sh.evict_one();
        }
        sh.maybe_compact();
    }

    /// Total live entries across shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| Self::lock_shard(s).map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (hits, misses) counters since construction.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lru(cap: usize) -> ShardedLru {
        // single shard so eviction order is fully deterministic
        ShardedLru::new(cap, 1)
    }

    fn val(v: f32) -> Arc<Vec<f32>> {
        Arc::new(vec![v])
    }

    #[test]
    fn evicts_least_recently_used() {
        let c = lru(3);
        c.insert(1, val(1.0));
        c.insert(2, val(2.0));
        c.insert(3, val(3.0));
        // touch 1 so 2 becomes the LRU
        assert!(c.get(1).is_some());
        c.insert(4, val(4.0));
        assert_eq!(c.len(), 3);
        assert!(c.get(2).is_none(), "2 was LRU and must be evicted");
        assert!(c.get(1).is_some());
        assert!(c.get(3).is_some());
        assert!(c.get(4).is_some());
    }

    #[test]
    fn reinsert_refreshes_instead_of_growing() {
        let c = lru(2);
        c.insert(1, val(1.0));
        c.insert(2, val(2.0));
        c.insert(1, val(1.5)); // refresh, not growth
        assert_eq!(c.len(), 2);
        c.insert(3, val(3.0)); // evicts 2 (1 was refreshed later)
        assert!(c.get(2).is_none());
        assert_eq!(c.get(1).unwrap()[0], 1.5);
    }

    #[test]
    fn hit_returns_shared_value_and_counts() {
        let c = lru(4);
        c.insert(9, val(9.0));
        let a = c.get(9).unwrap();
        let b = c.get(9).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "hits share one allocation");
        assert!(c.get(8).is_none());
        assert_eq!(c.stats(), (2, 1));
    }

    #[test]
    fn log_compaction_keeps_lru_correct_under_heavy_touching() {
        let c = lru(4);
        for k in 0..4u64 {
            c.insert(k, val(k as f32));
        }
        // hammer one key so the log grows and compacts repeatedly
        for _ in 0..10_000 {
            assert!(c.get(2).is_some());
        }
        c.insert(99, val(99.0));
        assert!(c.get(2).is_some(), "hot key must survive eviction");
        assert_eq!(c.len(), 4);
    }

    #[test]
    fn shards_partition_keys() {
        let c = ShardedLru::new(64, 8);
        for k in 0..64u64 {
            c.insert(k, val(k as f32));
        }
        assert_eq!(c.len(), 64);
        for k in 0..64u64 {
            assert_eq!(c.get(k).unwrap()[0], k as f32);
        }
    }

    #[test]
    fn fnv_is_stable_and_sensitive() {
        let mut h = Fnv1a::new();
        h.update(b"abc");
        // reference FNV-1a 64 of "abc"
        assert_eq!(h.finish(), 0xe71fa2190541574b);
        let mut h2 = Fnv1a::new();
        h2.update(b"abd");
        assert_ne!(h.finish(), h2.finish());
    }
}
