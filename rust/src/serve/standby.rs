//! Warm-standby checkpoint reload: watch a directory of training
//! snapshots, prepare and validate the newest one **off the serving
//! path**, and promote it into the live [`Engine`] via the existing
//! generation-bump hot-swap — or reject it without ever touching the
//! live generation (DESIGN.md §Warm-standby).
//!
//! State machine (one watcher thread, spawned by [`spawn`]):
//!
//! ```text
//!          ┌────────────────────────────────────────────────────┐
//!          ▼                                                    │
//!  WATCH: poll the directory, ckpt::peek the fresh snapshots    │
//!  (manifest-only read — no tensor I/O; v1 files and v2 shard   │
//!  directories alike), newest-manifest-wins; unreadable or      │
//!  incomplete files retry with bounded backoff, then QUARANTINE │
//!          │ newer + shape-compatible snapshot                  │
//!          ▼                                                    │
//!  PREPARE (off-thread): full CRC-checked ckpt::load,           │
//!  re-quantize for the serving LinearKind, encode the canary    │
//!  batch on live + candidate in parallel (util::threads)        │
//!          │                                                    │
//!          ├── drift > bound / non-finite / bad file ──▶ REJECT ┤
//!          ▼                                            (live   │
//!  PROMOTE: Engine::install_encoder (pointer-swap pause,  gen   │
//!  generation bump, zero dropped requests)              intact) │
//!          │                                                    │
//!          ▼                                                    │
//!  PROBE: canary requests through the live engine must match    │
//!  the promoted candidate bit-for-bit ──ok──────────────────────┘
//!          │ mismatch
//!          ▼
//!  ROLLBACK: rebuild the previous generation's weights and
//!  install them (another generation bump)
//! ```
//!
//! The **canary drift bound** is the promotion gate: the candidate and
//! the live encoder embed the same deterministic canary inputs, and the
//! worst per-input cosine distance must stay under `drift_max`.  Trained
//! successors of the live weights drift a little; a corrupt, mis-seeded
//! or wrongly-converted checkpoint lands near-orthogonal and is
//! rejected.  This mirrors how low-precision recipes stage numeric
//! changes behind validation instead of trusting the bytes (PAPERS.md:
//! *InfiR2*'s staged FP8 validation, *Scalify*'s scale-propagation
//! checks).
//!
//! Everything the watcher does is observable through
//! [`super::metrics::ServeMetrics`]: promote/reject/rollback counters
//! plus prepare-time and swap-pause histograms, all surfaced in
//! `BENCH_serve.json` / `BENCH_ckpt.json`.

use super::encoder::{ClipEncoder, EncoderConfig};
use super::engine::Engine;
use super::EncodeInput;
use crate::ckpt;
use crate::tensor::Rng;
use crate::util::threads::par_map;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Watcher knobs.  `StandbyConfig::new` picks production-shaped defaults;
/// every field is also reachable from the CLI (`serve --watch-dir
/// --canary-every --drift-max --standby`).
#[derive(Debug, Clone)]
pub struct StandbyConfig {
    /// directory to watch for `ckpt-*.sbck` snapshots
    pub watch_dir: PathBuf,
    /// poll interval of the watcher thread
    pub poll: Duration,
    /// canary inputs *per modality* (images + captions)
    pub canary: usize,
    /// seed for the deterministic canary population
    pub canary_seed: u64,
    /// max allowed per-input cosine distance between live and candidate
    /// canary embeddings; `None` disables the bound (non-finite
    /// embeddings are always rejected)
    pub drift_max: Option<f32>,
    /// run a post-promotion canary probe every N polls (0 = never)
    pub probe_every: u32,
    /// snapshots at or below this step are ignored (the booted weights)
    pub initial_step: u64,
    /// give up on a snapshot that stays unreadable or incomplete after
    /// this many failed peeks and **quarantine** it (counted in
    /// `ServeMetrics::standby_quarantines`, never revisited).  Retries
    /// run every poll for the first 3 attempts — the original
    /// non-atomic-copy grace window — then back off exponentially
    /// (2, 4, 8, 16, then every 32 polls), so a permanently truncated
    /// file costs a bounded number of peeks instead of one per poll
    /// forever.  0 = retry forever (the pre-quarantine behavior).
    pub stall_retries: u32,
    /// flat parameter vector of the booted weights (train layout) — the
    /// rollback anchor for the *first* promotion; without it a failed
    /// first-generation probe has nothing to restore
    pub baseline: Option<Vec<Vec<f32>>>,
    /// print promote/reject/rollback lines from the watcher thread
    pub verbose: bool,
}

impl StandbyConfig {
    /// Defaults: 25 ms poll, 8+8 canaries, drift bound 0.5, probe every
    /// 4th poll, quarantine after 20 failed peeks (≈ 11 s of backoff at
    /// the 25 ms poll).
    pub fn new(watch_dir: impl Into<PathBuf>) -> Self {
        Self {
            watch_dir: watch_dir.into(),
            poll: Duration::from_millis(25),
            canary: 8,
            canary_seed: 0xCA9A_817D,
            drift_max: Some(0.5),
            probe_every: 4,
            initial_step: 0,
            stall_retries: 20,
            baseline: None,
            verbose: false,
        }
    }
}

/// The deterministic canary population for one serving shape.  Built once
/// per watcher (and per `loadgen --swap-every` run) so every validation
/// compares the same inputs.
pub struct CanarySet {
    images: Vec<Vec<f32>>,
    texts: Vec<Vec<i32>>,
}

impl CanarySet {
    /// `per_modality` images + captions drawn from `seed` for `cfg`'s
    /// payload shape.
    pub fn build(cfg: &EncoderConfig, per_modality: usize, seed: u64) -> Self {
        let base = Rng::seed(seed);
        let images = (0..per_modality)
            .map(|i| {
                let mut r = base.fork(i as u64);
                (0..cfg.image_len()).map(|_| r.normal()).collect()
            })
            .collect();
        let texts = (0..per_modality)
            .map(|i| {
                let mut r = base.fork(0x7E77 + i as u64);
                (0..cfg.text_seq).map(|_| r.below(cfg.vocab) as i32).collect()
            })
            .collect();
        Self { images, texts }
    }

    /// Encode the whole set directly on `enc` (images first, then
    /// captions) — the off-engine half of the drift comparison.
    pub fn encode_with(&self, enc: &ClipEncoder) -> Vec<Vec<f32>> {
        let imgs: Vec<&[f32]> = self.images.iter().map(Vec::as_slice).collect();
        let txts: Vec<&[i32]> = self.texts.iter().map(Vec::as_slice).collect();
        let mut out = enc.encode_images(&imgs);
        out.extend(enc.encode_texts(&txts));
        out
    }

    /// The same set as engine requests, index-aligned with
    /// [`Self::encode_with`]'s output.
    pub fn inputs(&self) -> Vec<EncodeInput> {
        self.images
            .iter()
            .map(|px| EncodeInput::Image(px.clone()))
            .chain(self.texts.iter().map(|t| EncodeInput::Text(t.clone())))
            .collect()
    }
}

/// Worst per-input cosine distance between two index-aligned embedding
/// sets (both L2-normalized, so the dot product is the cosine).
/// Non-finite embeddings yield `f32::INFINITY` — always past any bound.
pub fn max_drift(a: &[Vec<f32>], b: &[Vec<f32>]) -> f32 {
    assert_eq!(a.len(), b.len(), "canary sets must be index-aligned");
    let mut worst = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        let dot: f32 = x.iter().zip(y).map(|(p, q)| p * q).sum();
        if !dot.is_finite() {
            return f32::INFINITY;
        }
        worst = worst.max(1.0 - dot);
    }
    worst
}

/// A successful [`validate_and_promote`] /
/// [`validate_and_promote_all`] outcome.
pub struct Promotion {
    /// worst per-input canary cosine distance observed live-vs-candidate
    pub drift: f32,
    /// the engine's exclusive swap pause (worst engine for a fan-out)
    pub pause: Duration,
    /// the candidate's canary embeddings — what the live engine must now
    /// reproduce bit-for-bit (the post-promotion probe expectation);
    /// returned so callers never pay the canary forward pass twice
    pub canary_embs: Vec<Vec<f32>>,
}

/// Canary-validate `candidate` against the live encoder and promote it
/// through the generation-bump swap.  On success records a promotion
/// (with `prepare_t0 → now` as the preparation time); on failure records
/// a rejection and leaves the live generation untouched.
///
/// `drift_max: None` skips the drift bound (used by `loadgen
/// --swap-every`, whose fresh-seeded generations are *intentionally*
/// unrelated) but still rejects non-finite candidate embeddings.
pub fn validate_and_promote(
    engine: &Engine,
    candidate: ClipEncoder,
    canary: &CanarySet,
    drift_max: Option<f32>,
    prepare_t0: Instant,
) -> Result<Promotion, String> {
    validate_and_promote_all(&[engine], vec![candidate], canary, drift_max, prepare_t0)
}

/// The fan-out form of [`validate_and_promote`]: one candidate *per
/// engine* (all built from the same snapshot weights), validated **once**
/// against engine 0's live encoder, then installed across every engine.
///
/// The no-torn-fan-out contract: nothing is installed anywhere until the
/// canary gate has passed and every candidate's shape has been checked
/// against its engine, so a rejection leaves **all** generations
/// untouched (each engine records the rejection).  After the installs,
/// per-engine generation agreement is asserted — disagreement means the
/// engines were not aligned going in, and is reported as an error rather
/// than papered over.
pub fn validate_and_promote_all(
    engines: &[&Engine],
    candidates: Vec<ClipEncoder>,
    canary: &CanarySet,
    drift_max: Option<f32>,
    prepare_t0: Instant,
) -> Result<Promotion, String> {
    assert_eq!(
        engines.len(),
        candidates.len(),
        "one candidate per engine"
    );
    assert!(!engines.is_empty(), "at least one engine");
    let reject = |why: String| -> String {
        for e in engines {
            e.metrics().record_reject();
        }
        why
    };
    // Shape pre-check on every engine *before* validating or installing
    // anything: install_encoder would refuse too, but only after siblings
    // were already promoted — exactly the torn fan-out this guards against.
    for (i, (e, c)) in engines.iter().zip(&candidates).enumerate() {
        if !c.config().same_shape(e.encoder_config()) {
            return Err(reject(format!(
                "candidate shape does not match engine {i}'s serving contract"
            )));
        }
    }
    let live = engines[0].current_encoder();
    // live + candidate canary encodes run concurrently on the
    // util::threads pool — the preparation cost never rides a request
    let mut embs = par_map(2, |i| {
        if i == 0 {
            canary.encode_with(&live)
        } else {
            canary.encode_with(&candidates[0])
        }
    });
    let (Some(cand_embs), Some(live_embs)) = (embs.pop(), embs.pop()) else {
        return Err(reject("canary encode returned no embeddings".into()));
    };
    let drift = max_drift(&live_embs, &cand_embs);
    if !drift.is_finite() {
        return Err(reject("candidate canary embeddings are non-finite".into()));
    }
    if let Some(bound) = drift_max {
        if drift > bound {
            return Err(reject(format!(
                "canary drift {drift:.3} exceeds bound {bound:.3}"
            )));
        }
    }
    let _sp = crate::trace::span("standby.promote", "standby");
    let mut worst_pause = Duration::ZERO;
    for (i, (engine, candidate)) in engines.iter().zip(candidates).enumerate() {
        // swap + promotion counters are one atomic group per engine: a
        // concurrent metrics snapshot must never observe the promotion
        // without its hot-swap (promotions > swaps)
        let _g = engine.metrics().grouped();
        match engine.install_encoder(candidate) {
            Ok(pause) => {
                engine
                    .metrics()
                    .record_promote(prepare_t0.elapsed().as_nanos() as u64);
                worst_pause = worst_pause.max(pause);
            }
            // Unreachable after the shape pre-check; surfaced loudly
            // because engines before `i` are already promoted.
            Err(e) => {
                return Err(format!(
                    "install on engine {i} rejected after {i} sibling(s) promoted: {e}"
                ))
            }
        }
    }
    let gen0 = engines[0].generation();
    for (i, e) in engines.iter().enumerate() {
        if e.generation() != gen0 {
            return Err(format!(
                "generation disagreement after fan-out: engine 0 at {gen0}, \
                 engine {i} at {}",
                e.generation()
            ));
        }
    }
    Ok(Promotion { drift, pause: worst_pause, canary_embs: cand_embs })
}

/// What one watcher step observed (returned by [`Standby::poll_once`] /
/// [`Standby::probe_once`] so the CLI and tests can react).
#[derive(Debug)]
pub enum StandbyEvent {
    /// nothing new in the watch directory / probes passed
    Idle,
    /// a snapshot passed the canary gate and is now live
    Promoted {
        step: u64,
        generation: u64,
        drift: f32,
        pause: Duration,
    },
    /// a snapshot was refused; the live generation is untouched
    Rejected { step: u64, reason: String },
    /// a snapshot stayed unreadable/incomplete past the bounded
    /// retry/backoff budget (`stall_retries`) — e.g. a permanently
    /// truncated copy — and is now quarantined: counted in
    /// `ServeMetrics::standby_quarantines`, never peeked again
    Quarantined { step: u64, reason: String },
    /// a post-promotion probe failed and the previous generation's
    /// weights were reinstalled
    RolledBack { generation: u64, reason: String },
    /// a probe failed but no previous generation is retained to restore
    ProbeFailed { reason: String },
}

/// Retry bookkeeping for one unreadable/incomplete snapshot file.
#[derive(Debug, Default)]
struct Stall {
    /// failed peeks so far
    attempts: u32,
    /// polls to skip before the next peek (the backoff window)
    skip: u32,
}

/// Polls to skip after `attempts` failed peeks: the first 3 retry every
/// poll (the original "non-atomic copy in flight" grace window — cheap
/// 16-byte reads), then 2, 4, 8, 16, capped at 32 polls between peeks.
fn backoff_polls(attempts: u32) -> u32 {
    if attempts <= 3 {
        0
    } else {
        1u32 << (attempts - 3).min(5)
    }
}

/// The standby slot: owns the watch cursor, the canary population, the
/// rollback anchor and the probe expectation.  [`spawn`] runs it on a
/// dedicated thread; tests drive [`Self::poll_once`] /
/// [`Self::probe_once`] directly.
pub struct Standby {
    engine: Arc<Engine>,
    /// sibling engines behind the same router: every promotion (and
    /// rollback) fans out to these too, validated once against the
    /// primary — empty for the classic single-engine watcher
    fanout: Vec<Arc<Engine>>,
    cfg: StandbyConfig,
    canary: CanarySet,
    /// highest *promoted manifest* step (starts at `initial_step`) —
    /// snapshots whose manifest is at or below this are stale content
    last_step: u64,
    /// filename steps already handled (promoted, stale, rejected after a
    /// successful peek, or quarantined) — never revisited.  Files whose
    /// *peek* fails or reads incomplete are NOT added immediately: an
    /// unreadable header usually means a non-atomic copy still in
    /// flight, so they are retried (with backoff, see [`Stall`]) until
    /// they parse — or until the `stall_retries` budget runs out and
    /// they are quarantined
    handled_steps: std::collections::HashSet<u64>,
    /// per-file retry bookkeeping for unreadable/incomplete snapshots
    stalls: std::collections::HashMap<u64, Stall>,
    /// params of the generation *before* the current one (rollback target)
    anchor: Option<Vec<Vec<f32>>>,
    /// params of the current generation (becomes the anchor on the next
    /// promotion)
    current: Option<Vec<Vec<f32>>>,
    /// the current generation's canary embeddings (probe expectation)
    expected: Option<Vec<Vec<f32>>>,
}

impl Standby {
    /// A fresh watcher state over `engine`: builds the canary
    /// population and seats the baseline as the first rollback anchor.
    pub fn new(engine: Arc<Engine>, cfg: StandbyConfig) -> Self {
        Self::new_fanout(vec![engine], cfg)
    }

    /// A watcher over a router's whole engine fleet: `engines[0]` is the
    /// primary (canary validation, probes, the rollback anchor); every
    /// promotion and rollback is installed across all of them, with
    /// generation agreement asserted after each fan-out.
    pub fn new_fanout(mut engines: Vec<Arc<Engine>>, cfg: StandbyConfig) -> Self {
        assert!(!engines.is_empty(), "standby needs at least one engine");
        let engine = engines.remove(0);
        let canary =
            CanarySet::build(engine.encoder_config(), cfg.canary.max(1), cfg.canary_seed);
        let last_step = cfg.initial_step;
        let current = cfg.baseline.clone();
        Self {
            engine,
            fanout: engines,
            cfg,
            canary,
            last_step,
            handled_steps: std::collections::HashSet::new(),
            stalls: std::collections::HashMap::new(),
            anchor: None,
            current,
            expected: None,
        }
    }

    /// One watch-directory scan: peek every not-yet-handled snapshot
    /// ([`ckpt::peek`] — header + manifest, no tensor I/O; for a v2
    /// shard directory the shards are only `stat`ed) and prepare the one
    /// with the newest *manifest* step above the cursor (filename
    /// numbers are advisory: a copied/renamed snapshot may carry any
    /// name), then promote or reject.  A rejected file is marked handled
    /// (never retried); an *unreadable or incomplete* file — usually a
    /// non-atomic copy still in flight — is retried with bounded backoff
    /// and eventually **quarantined** (see [`StandbyConfig::stall_retries`]),
    /// and can never block a valid sibling, because the cursor only
    /// advances on promotions.
    pub fn poll_once(&mut self) -> StandbyEvent {
        let fresh: Vec<(u64, PathBuf)> = ckpt::list_snapshots(&self.cfg.watch_dir)
            .into_iter()
            .filter(|(s, _)| !self.handled_steps.contains(s))
            .collect();
        if fresh.is_empty() {
            return StandbyEvent::Idle;
        }
        // (manifest step, filename step, path) of the best candidate
        let mut best: Option<(u64, u64, PathBuf)> = None;
        let mut quarantined: Option<StandbyEvent> = None;
        for (fstep, path) in &fresh {
            // a stalled file inside its backoff window is not even peeked
            if let Some(st) = self.stalls.get_mut(fstep) {
                if st.skip > 0 {
                    st.skip -= 1;
                    continue;
                }
            }
            match ckpt::peek(path) {
                // a readable manifest whose blobs/shards are shorter than
                // it promises is a copy still in flight: preparing it now
                // would CRC-fail and permanently blacklist a snapshot
                // that is about to become valid — retry (bounded)
                Ok(p) if !p.is_complete() => {
                    let ev = self.note_stall(*fstep, "incomplete past the retry budget");
                    if quarantined.is_none() {
                        quarantined = ev;
                    }
                }
                Ok(p) if p.step > self.last_step => {
                    self.stalls.remove(fstep);
                    let newer = match &best {
                        Some((bs, _, _)) => p.step > *bs,
                        None => true,
                    };
                    if newer {
                        best = Some((p.step, *fstep, path.clone()));
                    }
                }
                Ok(_) => {
                    // readable, complete, but the manifest is not newer
                    // than what we serve: stale content — never revisit
                    self.stalls.remove(fstep);
                    self.handled_steps.insert(*fstep);
                }
                Err(e) => {
                    // unreadable header/manifest: likely a copy still in
                    // flight — retry (bounded) on later polls
                    let ev = self.note_stall(
                        *fstep,
                        &format!("unreadable past the retry budget: {e}"),
                    );
                    if quarantined.is_none() {
                        quarantined = ev;
                    }
                }
            }
        }
        let Some((mstep, fstep, path)) = best else {
            // no candidate this poll: surface a quarantine if one fired
            // (metrics count every one either way)
            return quarantined.unwrap_or(StandbyEvent::Idle);
        };
        let event = self.prepare_and_promote(mstep, &path);
        match &event {
            StandbyEvent::Promoted { .. } => {
                // the cursor is the promoted *manifest* step; the file
                // itself is done either way
                self.last_step = self.last_step.max(mstep);
                self.handled_steps.insert(fstep);
            }
            StandbyEvent::Rejected { .. } => {
                self.handled_steps.insert(fstep);
            }
            _ => {}
        }
        event
    }

    /// Count one failed peek of `fstep`.  Within the budget: schedule the
    /// next retry (exponential poll backoff) and return `None`.  Budget
    /// exhausted: quarantine the file — handled forever, counted in
    /// `ServeMetrics` — and return the event.
    fn note_stall(&mut self, fstep: u64, reason: &str) -> Option<StandbyEvent> {
        let max = self.cfg.stall_retries;
        let st = self.stalls.entry(fstep).or_default();
        st.attempts += 1;
        if max > 0 && st.attempts >= max {
            self.stalls.remove(&fstep);
            self.handled_steps.insert(fstep);
            self.engine.metrics().record_quarantine();
            return Some(StandbyEvent::Quarantined {
                step: fstep,
                reason: reason.to_string(),
            });
        }
        st.skip = backoff_polls(st.attempts);
        None
    }

    /// Prepare (CRC-checked load + re-quantize + canary encode) and
    /// promote one snapshot.  Rejection leaves the live generation — and
    /// the rollback anchor — untouched.
    fn prepare_and_promote(&mut self, step: u64, path: &std::path::Path) -> StandbyEvent {
        let _sp = crate::trace::span("standby.prepare", "standby");
        // `/readyz` reports not-ready for the whole prepare→promote
        // window — on every engine in the fan-out; the guards clear the
        // flag on every exit path
        let _promoting: Vec<_> = std::iter::once(&self.engine)
            .chain(self.fanout.iter())
            .map(|e| e.metrics().mark_promoting())
            .collect();
        let t0 = crate::trace::clock();
        let reject = |me: &Self, reason: String| -> StandbyEvent {
            me.engine.metrics().record_reject();
            for e in &me.fanout {
                e.metrics().record_reject();
            }
            StandbyEvent::Rejected { step, reason }
        };
        let ck = match ckpt::load(path) {
            Ok((ck, _io)) => ck,
            Err(e) => return reject(self, format!("load failed: {e}")),
        };
        let serve_cfg = self.engine.encoder_config();
        if !ck.encoder.same_shape(serve_cfg) {
            return reject(
                self,
                format!(
                    "snapshot shape {:?} does not match the serving contract {:?}",
                    ck.encoder, serve_cfg
                ),
            );
        }
        // serving precision is the engine's choice, not the checkpoint's
        let cand_cfg = EncoderConfig { kind: serve_cfg.kind, ..ck.encoder.clone() };
        // One candidate per engine, all from the same snapshot params —
        // built *before* anything is installed (no torn fan-out).
        let engines: Vec<&Engine> = std::iter::once(self.engine.as_ref())
            .chain(self.fanout.iter().map(Arc::as_ref))
            .collect();
        let mut candidates = Vec::with_capacity(engines.len());
        for _ in &engines {
            let weights = match ckpt::encoder_weights(&cand_cfg, &ck.params) {
                Ok(w) => w,
                Err(e) => return reject(self, format!("weight layout: {e}")),
            };
            candidates.push(ClipEncoder::from_weights(cand_cfg.clone(), weights));
        }
        match validate_and_promote_all(
            &engines,
            candidates,
            &self.canary,
            self.cfg.drift_max,
            t0,
        ) {
            Ok(promo) => {
                self.anchor = self.current.take();
                self.current = Some(ck.params);
                self.expected = Some(promo.canary_embs);
                StandbyEvent::Promoted {
                    step,
                    generation: self.engine.generation(),
                    drift: promo.drift,
                    pause: promo.pause,
                }
            }
            Err(reason) => StandbyEvent::Rejected { step, reason },
        }
    }

    /// Post-promotion canary probe: every canary request served by the
    /// live engine must match the promoted candidate's embeddings
    /// bit-for-bit (the substrate is deterministic and batch-composition
    /// independent, so any difference means the live weights are not the
    /// ones that passed validation).  On mismatch, roll back to the
    /// previous generation.
    pub fn probe_once(&mut self) -> StandbyEvent {
        let Some(expected) = self.expected.clone() else {
            return StandbyEvent::Idle; // nothing promoted yet
        };
        for (input, want) in self.canary.inputs().into_iter().zip(&expected) {
            match self.engine.encode(input) {
                Ok(resp) => {
                    if *resp.embedding != *want {
                        return self.rollback("canary probe diverged from the \
                                              promoted weights");
                    }
                }
                // an encode error here is engine shutdown, not bad weights
                Err(_) => return StandbyEvent::Idle,
            }
        }
        StandbyEvent::Idle
    }

    /// Reinstall the previous generation's weights (another generation
    /// bump, so stale cache entries from the bad generation die too) —
    /// across the whole fan-out, so the fleet stays generation-aligned.
    fn rollback(&mut self, reason: &str) -> StandbyEvent {
        let Some(params) = self.anchor.take() else {
            self.expected = None; // stop re-probing an expectation we can't fix
            return StandbyEvent::ProbeFailed {
                reason: format!("{reason}; no previous generation retained"),
            };
        };
        let serve_cfg = self.engine.encoder_config().clone();
        // One restored encoder per engine, all built before any install.
        let mut restored = Vec::with_capacity(1 + self.fanout.len());
        for _ in 0..(1 + self.fanout.len()) {
            match ckpt::encoder_weights(&serve_cfg, &params) {
                Ok(w) => restored.push(ClipEncoder::from_weights(serve_cfg.clone(), w)),
                Err(e) => {
                    return StandbyEvent::ProbeFailed {
                        reason: format!("{reason}; rollback rebuild failed: {e}"),
                    }
                }
            }
        }
        let expected = self.canary.encode_with(&restored[0]);
        let engines: Vec<&Engine> = std::iter::once(self.engine.as_ref())
            .chain(self.fanout.iter().map(Arc::as_ref))
            .collect();
        for (engine, enc) in engines.iter().zip(restored) {
            if let Err(e) = engine.install_encoder(enc) {
                return StandbyEvent::ProbeFailed {
                    reason: format!("{reason}; rollback install failed: {e}"),
                };
            }
            engine.metrics().record_rollback();
        }
        self.current = Some(params);
        self.expected = Some(expected);
        StandbyEvent::RolledBack {
            generation: self.engine.generation(),
            reason: reason.to_string(),
        }
    }
}

/// Handle to a running watcher thread; stops (and joins) on
/// [`StandbyHandle::stop`] or drop.
pub struct StandbyHandle {
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl StandbyHandle {
    /// Signal the watcher to exit and join it.
    pub fn stop(self) {
        // Drop does the work; consuming the handle makes intent explicit.
    }
}

impl Drop for StandbyHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Start the watcher thread: poll → prepare → canary → promote/reject,
/// with a probe (and possible rollback) every `probe_every` polls.
pub fn spawn(engine: Arc<Engine>, cfg: StandbyConfig) -> StandbyHandle {
    spawn_fanout(vec![engine], cfg)
}

/// [`spawn`] over a router's whole fleet: **one** watcher thread
/// validates each snapshot once (against `engines[0]`) and promotes it
/// across every engine, keeping the generations in lock-step.
pub fn spawn_fanout(engines: Vec<Arc<Engine>>, cfg: StandbyConfig) -> StandbyHandle {
    let stop = Arc::new(AtomicBool::new(false));
    let flag = Arc::clone(&stop);
    let join = std::thread::spawn(move || {
        let poll = cfg.poll;
        let probe_every = cfg.probe_every;
        let verbose = cfg.verbose;
        let mut sb = Standby::new_fanout(engines, cfg);
        let mut ticks: u32 = 0;
        while !flag.load(Ordering::Relaxed) {
            log_event(verbose, &sb.poll_once());
            ticks = ticks.wrapping_add(1);
            if probe_every > 0 && ticks % probe_every == 0 {
                log_event(verbose, &sb.probe_once());
            }
            std::thread::sleep(poll);
        }
    });
    StandbyHandle { stop, join: Some(join) }
}

fn log_event(verbose: bool, ev: &StandbyEvent) {
    if !verbose {
        return;
    }
    match ev {
        StandbyEvent::Idle => {}
        StandbyEvent::Promoted { step, generation, drift, pause } => println!(
            "[standby] promoted snapshot step {step} → generation {generation} \
             (drift {drift:.4}, swap pause {:.1} µs)",
            pause.as_secs_f64() * 1e6
        ),
        StandbyEvent::Rejected { step, reason } => {
            println!("[standby] rejected snapshot step {step}: {reason}")
        }
        StandbyEvent::Quarantined { step, reason } => {
            println!("[standby] QUARANTINED snapshot file step {step}: {reason}")
        }
        StandbyEvent::RolledBack { generation, reason } => println!(
            "[standby] ROLLED BACK to generation {generation}: {reason}"
        ),
        StandbyEvent::ProbeFailed { reason } => {
            println!("[standby] probe failed, no rollback possible: {reason}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckpt::TrainCheckpoint;
    use crate::config::TrainHyper;
    use crate::data::DataCursor;
    use crate::nn::LinearKind;
    use crate::optim::OptimizerState;
    use crate::serve::batcher::BatchPolicy;
    use crate::serve::engine::ServeConfig;
    use crate::train::ClipTrainModel;

    fn tiny_cfg(seed: u64) -> EncoderConfig {
        EncoderConfig {
            kind: LinearKind::SwitchBack,
            dim: 16,
            heads: 2,
            blocks: 1,
            embed_dim: 8,
            patches: 4,
            patch_dim: 12,
            text_seq: 5,
            vocab: 64,
            seed,
        }
    }

    fn engine_from(params: &[Vec<f32>], enc_cfg: &EncoderConfig) -> Arc<Engine> {
        let serve_cfg = ServeConfig {
            encoder: enc_cfg.clone(),
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
            },
            workers: 2,
            cache_capacity: 256,
            cache_shards: 2,
        };
        let weights = ckpt::encoder_weights(enc_cfg, params).unwrap();
        let enc = ClipEncoder::from_weights(enc_cfg.clone(), weights);
        Arc::new(Engine::start_with_encoder(serve_cfg, enc))
    }

    fn ckpt_with(params: Vec<Vec<f32>>, step: u64, enc: &EncoderConfig) -> TrainCheckpoint {
        TrainCheckpoint {
            step,
            encoder: enc.clone(),
            hyper: TrainHyper::preset(1000),
            shifts: vec![],
            batch: 4,
            grad_shards: 1,
            param_names: (0..params.len()).map(|i| format!("t{i}")).collect(),
            params,
            opt: OptimizerState { name: "lion".into(), t: step, slots: vec![] },
            data: DataCursor {
                step,
                gain: 1.0,
                mapping: vec![0],
                rng: [1, 2, 3, 4],
                rng_spare: None,
            },
        }
    }

    fn perturbed(params: &[Vec<f32>], scale: f32) -> Vec<Vec<f32>> {
        params
            .iter()
            .map(|t| t.iter().map(|v| v * scale).collect())
            .collect()
    }

    fn standby_in(dir: &std::path::Path, engine: &Arc<Engine>, base: Vec<Vec<f32>>) -> Standby {
        let mut cfg = StandbyConfig::new(dir);
        cfg.baseline = Some(base);
        Standby::new(Arc::clone(engine), cfg)
    }

    /// A newer snapshot of (nearly) the same weights is prepared,
    /// canary-validated and promoted; the engine then serves exactly the
    /// candidate's embeddings and the probe passes.
    #[test]
    fn watcher_promotes_newer_compatible_snapshot() {
        let dir = std::env::temp_dir().join("sbck_standby_promote");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let enc_cfg = tiny_cfg(7);
        let params = ClipTrainModel::new(enc_cfg.clone()).collect_params();
        let engine = engine_from(&params, &enc_cfg);
        let mut sb = standby_in(&dir, &engine, params.clone());

        assert!(matches!(sb.poll_once(), StandbyEvent::Idle), "empty dir");

        let newer = perturbed(&params, 1.001);
        ckpt::save(&ckpt::snapshot_path(&dir, 10), &ckpt_with(newer, 10, &enc_cfg))
            .unwrap();
        match sb.poll_once() {
            StandbyEvent::Promoted { step, generation, drift, .. } => {
                assert_eq!(step, 10);
                assert_eq!(generation, 1);
                assert!(drift < 0.1, "near-identical weights, drift {drift}");
            }
            other => panic!("expected promotion, got {other:?}"),
        }
        assert_eq!(engine.generation(), 1);
        let snap = engine.metrics().snapshot();
        assert_eq!(snap.standby_promotions, 1);
        assert_eq!(snap.standby_rejects, 0);
        assert!(snap.prepare_p99_ms >= 0.0);
        assert!(matches!(sb.poll_once(), StandbyEvent::Idle), "handled once");
        assert!(matches!(sb.probe_once(), StandbyEvent::Idle), "probe passes");
        assert_eq!(engine.metrics().snapshot().standby_rollbacks, 0);
    }

    /// A drifted snapshot (different-seed weights) is rejected by the
    /// canary bound: the live generation, and serving, are untouched —
    /// and the file is not re-prepared on later polls.
    #[test]
    fn drifted_snapshot_is_rejected_without_touching_the_generation() {
        let dir = std::env::temp_dir().join("sbck_standby_reject");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let enc_cfg = tiny_cfg(7);
        let params = ClipTrainModel::new(enc_cfg.clone()).collect_params();
        let engine = engine_from(&params, &enc_cfg);
        let mut sb = standby_in(&dir, &engine, params.clone());

        let alien = ClipTrainModel::new(tiny_cfg(999)).collect_params();
        ckpt::save(&ckpt::snapshot_path(&dir, 20), &ckpt_with(alien, 20, &enc_cfg))
            .unwrap();
        match sb.poll_once() {
            StandbyEvent::Rejected { step, reason } => {
                assert_eq!(step, 20);
                assert!(reason.contains("drift"), "{reason}");
            }
            other => panic!("expected rejection, got {other:?}"),
        }
        assert_eq!(engine.generation(), 0, "reject must not bump the generation");
        assert_eq!(engine.metrics().snapshot().standby_rejects, 1);
        assert!(matches!(sb.poll_once(), StandbyEvent::Idle), "not re-prepared");
        // serving still works on the original weights
        let mut rng = Rng::seed(5);
        let img: Vec<f32> = (0..enc_cfg.image_len()).map(|_| rng.normal()).collect();
        assert!(engine.encode(EncodeInput::Image(img)).is_ok());
    }

    /// CRC-corrupt and shape-mismatched snapshot files are rejected
    /// (counted once each, never retried), never promoted.
    #[test]
    fn corrupt_and_mismatched_snapshots_are_rejected() {
        let dir = std::env::temp_dir().join("sbck_standby_corrupt");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let enc_cfg = tiny_cfg(7);
        let params = ClipTrainModel::new(enc_cfg.clone()).collect_params();
        let engine = engine_from(&params, &enc_cfg);
        let mut sb = standby_in(&dir, &engine, params.clone());

        // readable manifest, corrupt tensor blob: CRC rejection at load
        let crc_path = ckpt::snapshot_path(&dir, 30);
        ckpt::save(&crc_path, &ckpt_with(perturbed(&params, 1.001), 30, &enc_cfg))
            .unwrap();
        let mut raw = std::fs::read(&crc_path).unwrap();
        let n = raw.len();
        raw[n - 2] ^= 0x40;
        std::fs::write(&crc_path, &raw).unwrap();
        match sb.poll_once() {
            StandbyEvent::Rejected { step: 30, reason } => {
                assert!(reason.contains("load failed"), "{reason}");
            }
            other => panic!("expected CRC rejection, got {other:?}"),
        }
        assert!(matches!(sb.poll_once(), StandbyEvent::Idle), "rejected once");

        let mut bad_shape = tiny_cfg(7);
        bad_shape.dim = 32;
        bad_shape.heads = 4;
        let alien = ClipTrainModel::new(bad_shape.clone()).collect_params();
        ckpt::save(
            &ckpt::snapshot_path(&dir, 40),
            &ckpt_with(alien, 40, &bad_shape),
        )
        .unwrap();
        match sb.poll_once() {
            StandbyEvent::Rejected { step: 40, reason } => {
                assert!(reason.contains("shape"), "{reason}");
            }
            other => panic!("expected shape rejection, got {other:?}"),
        }
        assert_eq!(engine.generation(), 0);
        assert_eq!(engine.metrics().snapshot().standby_rejects, 2);
    }

    /// An unreadable file (a non-atomic copy still in flight) neither
    /// wedges the watcher nor gets blacklisted: valid siblings promote
    /// around it, and once the "copy" completes it promotes too.
    #[test]
    fn unreadable_file_is_retried_and_does_not_block_siblings() {
        let dir = std::env::temp_dir().join("sbck_standby_noblock");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let enc_cfg = tiny_cfg(7);
        let params = ClipTrainModel::new(enc_cfg.clone()).collect_params();
        let engine = engine_from(&params, &enc_cfg);
        let mut sb = standby_in(&dir, &engine, params.clone());

        // half-written file with an absurdly high step number: skipped,
        // not rejected (it may still be mid-copy)
        std::fs::write(ckpt::snapshot_path(&dir, 99_999_999), b"torn").unwrap();
        assert!(matches!(sb.poll_once(), StandbyEvent::Idle));
        assert_eq!(engine.metrics().snapshot().standby_rejects, 0);

        // a legitimate snapshot with a *lower* step promotes regardless
        ckpt::save(
            &ckpt::snapshot_path(&dir, 10),
            &ckpt_with(perturbed(&params, 1.001), 10, &enc_cfg),
        )
        .unwrap();
        match sb.poll_once() {
            StandbyEvent::Promoted { step: 10, generation: 1, .. } => {}
            other => panic!("valid snapshot was blocked: {other:?}"),
        }

        // the "copy" completes: the same filename becomes readable and
        // newer → promoted on a later poll (retry, not blacklist)
        ckpt::save(
            &ckpt::snapshot_path(&dir, 99_999_999),
            &ckpt_with(perturbed(&params, 1.002), 99_999_999, &enc_cfg),
        )
        .unwrap();
        match sb.poll_once() {
            StandbyEvent::Promoted { step: 99_999_999, generation: 2, .. } => {}
            other => panic!("completed copy was not retried: {other:?}"),
        }
        let snap = engine.metrics().snapshot();
        assert_eq!(snap.standby_promotions, 2);
        assert_eq!(snap.standby_rejects, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A copied/renamed snapshot (filename step far above its manifest
    /// step) must not blind the cursor: a later file with a lower
    /// filename step but a genuinely newer manifest still promotes.
    #[test]
    fn renamed_snapshot_does_not_blind_the_cursor() {
        let dir = std::env::temp_dir().join("sbck_standby_renamed");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let enc_cfg = tiny_cfg(7);
        let params = ClipTrainModel::new(enc_cfg.clone()).collect_params();
        let engine = engine_from(&params, &enc_cfg);
        let mut sb = standby_in(&dir, &engine, params.clone());

        // manifest step 100 hiding behind filename step 1000
        ckpt::save(
            &ckpt::snapshot_path(&dir, 1000),
            &ckpt_with(perturbed(&params, 1.001), 100, &enc_cfg),
        )
        .unwrap();
        assert!(matches!(
            sb.poll_once(),
            StandbyEvent::Promoted { step: 100, .. }
        ));
        assert!(matches!(sb.poll_once(), StandbyEvent::Idle), "handled once");

        // lower filename step, newer manifest: must still win
        ckpt::save(
            &ckpt::snapshot_path(&dir, 200),
            &ckpt_with(perturbed(&params, 1.002), 200, &enc_cfg),
        )
        .unwrap();
        match sb.poll_once() {
            StandbyEvent::Promoted { step: 200, generation: 2, .. } => {}
            other => panic!("newer manifest was blinded by the filename: {other:?}"),
        }

        // even a filename *below* every previous one is considered:
        // freshness is decided by the manifest alone
        ckpt::save(
            &ckpt::snapshot_path(&dir, 5),
            &ckpt_with(perturbed(&params, 1.003), 300, &enc_cfg),
        )
        .unwrap();
        match sb.poll_once() {
            StandbyEvent::Promoted { step: 300, generation: 3, .. } => {}
            other => panic!("low filename hid a newer manifest: {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A snapshot whose *manifest* is readable but whose tensor blobs
    /// are still being written (peek OK, incomplete) is retried — not
    /// CRC-rejected and blacklisted — and promotes once complete.
    #[test]
    fn incomplete_blobs_are_retried_until_the_copy_finishes() {
        let dir = std::env::temp_dir().join("sbck_standby_midcopy");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let enc_cfg = tiny_cfg(7);
        let params = ClipTrainModel::new(enc_cfg.clone()).collect_params();
        let engine = engine_from(&params, &enc_cfg);
        let mut sb = standby_in(&dir, &engine, params.clone());

        // simulate a mid-copy file: full save, then chop the blob tail
        let path = ckpt::snapshot_path(&dir, 60);
        ckpt::save(&path, &ckpt_with(perturbed(&params, 1.001), 60, &enc_cfg))
            .unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 64]).unwrap();
        assert!(matches!(sb.poll_once(), StandbyEvent::Idle), "mid-copy skip");
        assert_eq!(engine.metrics().snapshot().standby_rejects, 0);

        // the copy completes → promoted on a later poll
        std::fs::write(&path, &full).unwrap();
        match sb.poll_once() {
            StandbyEvent::Promoted { step: 60, generation: 1, .. } => {}
            other => panic!("completed blobs were not retried: {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// When the live weights stop matching the promoted candidate (an
    /// out-of-band install behind the watcher's back), the canary probe
    /// catches it and rolls back to the previous generation's weights.
    #[test]
    fn probe_failure_rolls_back_to_previous_generation() {
        let dir = std::env::temp_dir().join("sbck_standby_rollback");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let enc_cfg = tiny_cfg(7);
        let params = ClipTrainModel::new(enc_cfg.clone()).collect_params();
        let engine = engine_from(&params, &enc_cfg);
        let mut sb = standby_in(&dir, &engine, params.clone());

        let newer = perturbed(&params, 1.001);
        ckpt::save(
            &ckpt::snapshot_path(&dir, 10),
            &ckpt_with(newer, 10, &enc_cfg),
        )
        .unwrap();
        assert!(matches!(sb.poll_once(), StandbyEvent::Promoted { .. }));
        assert!(matches!(sb.probe_once(), StandbyEvent::Idle));

        // out-of-band swap: different weights slip in behind the watcher
        engine
            .install_encoder(ClipEncoder::new(tiny_cfg(4242)))
            .unwrap();
        match sb.probe_once() {
            StandbyEvent::RolledBack { generation, .. } => {
                assert_eq!(generation, 3, "promote + oob + rollback = 3 bumps");
            }
            other => panic!("expected rollback, got {other:?}"),
        }
        assert_eq!(engine.metrics().snapshot().standby_rollbacks, 1);
        // the engine now serves the *baseline* weights again (the
        // generation before the tampered one)
        let weights = ckpt::encoder_weights(&enc_cfg, &params).unwrap();
        let baseline_enc = ClipEncoder::from_weights(enc_cfg.clone(), weights);
        let want = sb.canary.encode_with(&baseline_enc);
        let got = engine
            .encode(sb.canary.inputs().remove(0))
            .unwrap()
            .embedding;
        assert_eq!(*got, want[0], "rollback must restore the previous weights");
        // and the probe expectation now tracks the restored generation
        assert!(matches!(sb.probe_once(), StandbyEvent::Idle));
    }

    /// `validate_and_promote` is the shared gate: unrelated weights fail
    /// a finite bound (counted as a reject, generation untouched) but
    /// pass with the bound disabled (the loadgen --swap-every mode).
    #[test]
    fn validate_and_promote_gates_on_the_bound() {
        let enc_cfg = tiny_cfg(7);
        let params = ClipTrainModel::new(enc_cfg.clone()).collect_params();
        let engine = engine_from(&params, &enc_cfg);
        let canary = CanarySet::build(engine.encoder_config(), 8, 0xCA9A);

        let unrelated = || ClipEncoder::new(tiny_cfg(31337));
        let err = validate_and_promote(
            &engine,
            unrelated(),
            &canary,
            Some(0.5),
            Instant::now(),
        )
        .unwrap_err();
        assert!(err.contains("drift"), "{err}");
        assert_eq!(engine.generation(), 0);
        assert_eq!(engine.metrics().snapshot().standby_rejects, 1);

        let promo = validate_and_promote(
            &engine,
            unrelated(),
            &canary,
            None,
            Instant::now(),
        )
        .unwrap();
        assert!(
            promo.drift > 0.5,
            "unrelated weights must drift, got {}",
            promo.drift
        );
        assert_eq!(promo.canary_embs.len(), 16, "8 images + 8 captions");
        assert_eq!(engine.generation(), 1);
        assert_eq!(engine.metrics().snapshot().standby_promotions, 1);
    }

    /// The quarantine satellite (ISSUE 5): a permanently truncated file
    /// must not be re-peeked every poll forever — after `stall_retries`
    /// failed peeks (with exponential backoff between them) it is
    /// quarantined, counted, and never revisited, even if the filename
    /// later becomes valid.  Fails on the pre-fix watcher, which retried
    /// unconditionally on every poll.
    #[test]
    fn permanently_truncated_snapshot_is_quarantined_after_bounded_retries() {
        let dir = std::env::temp_dir().join("sbck_standby_quarantine");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let enc_cfg = tiny_cfg(7);
        let params = ClipTrainModel::new(enc_cfg.clone()).collect_params();
        let engine = engine_from(&params, &enc_cfg);
        let mut cfg = StandbyConfig::new(&dir);
        cfg.baseline = Some(params.clone());
        cfg.stall_retries = 5;
        let mut sb = Standby::new(Arc::clone(&engine), cfg);

        std::fs::write(ckpt::snapshot_path(&dir, 77), b"torn forever").unwrap();
        let mut polls = 0u32;
        let ev = loop {
            polls += 1;
            assert!(polls < 50, "stalled file was never quarantined");
            match sb.poll_once() {
                StandbyEvent::Idle => {}
                ev => break ev,
            }
        };
        match ev {
            StandbyEvent::Quarantined { step: 77, reason } => {
                assert!(reason.contains("unreadable"), "{reason}");
            }
            other => panic!("expected quarantine, got {other:?}"),
        }
        // attempts 1–3 run back to back, then backoff 2 + 4 polls:
        // quarantine lands on poll 7 — pinning this proves the backoff
        // actually spaces the peeks instead of hammering every poll
        assert_eq!(polls, 7, "exponential backoff schedule changed");
        let snap = engine.metrics().snapshot();
        assert_eq!(snap.standby_quarantines, 1);
        assert_eq!(snap.standby_rejects, 0, "quarantine is not a reject");

        // the quarantined *filename* is dead even once its content heals
        ckpt::save(
            &ckpt::snapshot_path(&dir, 77),
            &ckpt_with(perturbed(&params, 1.001), 77, &enc_cfg),
        )
        .unwrap();
        assert!(matches!(sb.poll_once(), StandbyEvent::Idle), "resurrected");

        // …but the watcher itself is healthy: a sibling under a fresh
        // name (same newer manifest) still promotes
        ckpt::save(
            &ckpt::snapshot_path(&dir, 78),
            &ckpt_with(perturbed(&params, 1.001), 78, &enc_cfg),
        )
        .unwrap();
        assert!(matches!(
            sb.poll_once(),
            StandbyEvent::Promoted { step: 78, .. }
        ));
        std::fs::remove_dir_all(&dir).ok();
    }

    /// An incomplete v2 shard directory (a copy missing a shard forever)
    /// follows the same bounded-retry → quarantine path, with the
    /// incomplete-specific reason.
    #[test]
    fn incomplete_shard_directory_quarantines_with_incomplete_reason() {
        let dir = std::env::temp_dir().join("sbck_standby_quarantine_v2");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let enc_cfg = tiny_cfg(7);
        let params = ClipTrainModel::new(enc_cfg.clone()).collect_params();
        let engine = engine_from(&params, &enc_cfg);
        let mut cfg = StandbyConfig::new(&dir);
        cfg.baseline = Some(params.clone());
        cfg.stall_retries = 4;
        let mut sb = Standby::new(Arc::clone(&engine), cfg);

        let snap = ckpt::snapshot_path(&dir, 90);
        ckpt::save_sharded(&snap, &ckpt_with(perturbed(&params, 1.001), 90, &enc_cfg), 3)
            .unwrap();
        std::fs::remove_file(snap.join(ckpt::format::shard_filename(1))).unwrap();
        let mut polls = 0u32;
        let ev = loop {
            polls += 1;
            assert!(polls < 50, "incomplete shard dir was never quarantined");
            match sb.poll_once() {
                StandbyEvent::Idle => {}
                ev => break ev,
            }
        };
        match ev {
            StandbyEvent::Quarantined { step: 90, reason } => {
                assert!(reason.contains("incomplete"), "{reason}");
            }
            other => panic!("expected quarantine, got {other:?}"),
        }
        assert_eq!(engine.metrics().snapshot().standby_quarantines, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// `stall_retries = 0` keeps the old retry-forever behavior.
    #[test]
    fn stall_retries_zero_never_quarantines() {
        let dir = std::env::temp_dir().join("sbck_standby_noquarantine");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let enc_cfg = tiny_cfg(7);
        let params = ClipTrainModel::new(enc_cfg.clone()).collect_params();
        let engine = engine_from(&params, &enc_cfg);
        let mut cfg = StandbyConfig::new(&dir);
        cfg.baseline = Some(params.clone());
        cfg.stall_retries = 0;
        let mut sb = Standby::new(Arc::clone(&engine), cfg);
        std::fs::write(ckpt::snapshot_path(&dir, 55), b"torn").unwrap();
        for _ in 0..100 {
            assert!(matches!(sb.poll_once(), StandbyEvent::Idle));
        }
        assert_eq!(engine.metrics().snapshot().standby_quarantines, 0);
        // and it still heals if the copy eventually completes
        ckpt::save(
            &ckpt::snapshot_path(&dir, 55),
            &ckpt_with(perturbed(&params, 1.001), 55, &enc_cfg),
        )
        .unwrap();
        let mut promoted = false;
        for _ in 0..40 {
            if matches!(sb.poll_once(), StandbyEvent::Promoted { step: 55, .. }) {
                promoted = true;
                break;
            }
        }
        assert!(promoted, "healed file never promoted (backoff too sticky?)");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The watcher promotes v2 shard-directory snapshots exactly like v1
    /// files — and an incomplete shard dir is retried, then promotes
    /// once the missing shard lands (the generalized blob-size retry).
    #[test]
    fn sharded_snapshots_promote_and_incomplete_shards_are_retried() {
        let dir = std::env::temp_dir().join("sbck_standby_v2_promote");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let enc_cfg = tiny_cfg(7);
        let params = ClipTrainModel::new(enc_cfg.clone()).collect_params();
        let engine = engine_from(&params, &enc_cfg);
        let mut sb = standby_in(&dir, &engine, params.clone());

        // a complete sharded snapshot promotes directly
        ckpt::save_sharded(
            &ckpt::snapshot_path(&dir, 10),
            &ckpt_with(perturbed(&params, 1.001), 10, &enc_cfg),
            4,
        )
        .unwrap();
        match sb.poll_once() {
            StandbyEvent::Promoted { step: 10, generation: 1, .. } => {}
            other => panic!("sharded snapshot did not promote: {other:?}"),
        }

        // mid-copy: shard missing → skipped, not rejected; restore → promoted
        let snap = ckpt::snapshot_path(&dir, 20);
        ckpt::save_sharded(
            &snap,
            &ckpt_with(perturbed(&params, 1.002), 20, &enc_cfg),
            4,
        )
        .unwrap();
        let shard1 = snap.join(ckpt::format::shard_filename(1));
        let bytes = std::fs::read(&shard1).unwrap();
        std::fs::remove_file(&shard1).unwrap();
        assert!(matches!(sb.poll_once(), StandbyEvent::Idle), "mid-copy skip");
        assert_eq!(engine.metrics().snapshot().standby_rejects, 0);
        std::fs::write(&shard1, &bytes).unwrap();
        match sb.poll_once() {
            StandbyEvent::Promoted { step: 20, generation: 2, .. } => {}
            other => panic!("completed shard dir was not retried: {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// End to end through the spawned thread: drop a snapshot into the
    /// watched directory, the watcher promotes it under a running engine.
    #[test]
    fn spawned_watcher_promotes_in_the_background() {
        let dir = std::env::temp_dir().join("sbck_standby_spawn");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let enc_cfg = tiny_cfg(7);
        let params = ClipTrainModel::new(enc_cfg.clone()).collect_params();
        let engine = engine_from(&params, &enc_cfg);
        let mut cfg = StandbyConfig::new(&dir);
        cfg.poll = Duration::from_millis(2);
        cfg.baseline = Some(params.clone());
        let handle = spawn(Arc::clone(&engine), cfg);

        let newer = perturbed(&params, 1.001);
        ckpt::save(
            &ckpt::snapshot_path(&dir, 50),
            &ckpt_with(newer, 50, &enc_cfg),
        )
        .unwrap();
        let t0 = Instant::now();
        while engine.metrics().snapshot().standby_promotions < 1 {
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "watcher never promoted the dropped snapshot"
            );
            std::thread::sleep(Duration::from_millis(2));
        }
        handle.stop();
        assert_eq!(engine.generation(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
