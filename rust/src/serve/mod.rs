//! `serve` — a batched int8 CLIP-embedding serving engine on the native
//! SwitchBack substrate (the first runtime subsystem off the training
//! path; DESIGN.md §Serve).
//!
//! The paper's result that int8 matmuls track bf16 within 0.1 pp is
//! exactly the property that makes a high-throughput embedding service
//! cheap: serving is forward-only, so the one numerically delicate matmul
//! (the wgrad with its batch×seq inner dimension, Appendix C) never runs.
//! Row-wise activation quant + tensor-wise weight quant — the same scheme
//! [`crate::gemm`] benchmarks for Fig 3 — is all the precision machinery
//! the encoder needs.
//!
//! Architecture (request flow left to right):
//!
//! ```text
//!  clients ──▶ Engine::encode ──▶ sharded LRU cache ──(hit)──▶ reply
//!                   │ miss
//!                   ▼
//!            BatchQueue (dynamic micro-batcher: max-batch / max-wait)
//!                   │ batches
//!                   ▼
//!            worker pool ──▶ ClipEncoder (forward-only, pre-quantized
//!                   │         weights, no LinearCache allocation)
//!                   ▼
//!            fill cache + reply + record telemetry (p50/p95/p99,
//!            batch occupancy, hit rate → telemetry::Histogram)
//! ```
//!
//! * [`batcher`] — the generic max-batch/max-wait coalescing queue.
//! * [`cache`] — sharded LRU keyed by an FNV-1a hash of the raw input;
//!   hits are served without touching the GEMM substrate at all.
//! * [`encoder`] — dual-tower forward-only CLIP encoder built from
//!   [`crate::nn::PreparedBlock`]s (weights quantized once at load).
//! * [`engine`] — worker pool wiring the above together, plus the live
//!   weight hot-swap path (`Engine::install_encoder`): trained
//!   checkpoints ([`crate::ckpt`]) are installed atomically between
//!   micro-batches, with a cache-generation bump invalidating stale
//!   embeddings and zero dropped in-flight requests.
//! * [`standby`] — the warm-standby slot: watches a checkpoint
//!   directory, prepares + CRC-validates the newest snapshot off-thread,
//!   gates promotion on a canary embedding-drift bound, and rolls back
//!   to the previous generation if post-promotion probes fail.
//! * [`metrics`] — atomic serving telemetry + JSON snapshot (including
//!   standby promote/reject/rollback counters and prepare/swap-pause
//!   histograms).
//! * [`loadgen`] — closed-loop load generator (the `loadgen` subcommand,
//!   with `--swap-every` for sustained throughput across repeated
//!   generations, and `--socket` for real-TCP clients against a bound
//!   front door), emits `BENCH_serve.json` so the perf trajectory is
//!   tracked per PR.
//! * [`router`] — multi-engine fan-out: doc-hash affinity routing across
//!   N engines (per-engine caches stay hot and disjoint), deterministic
//!   shedding when an engine dies, and fleet-wide generation agreement.
//! * [`frontend`] — the network front door: [`crate::net::http1`] bound
//!   as the data plane (`POST /encode`, JSON wire format, bounded
//!   admission window → explicit `429`/`503`, never unbounded queueing).

pub mod batcher;
pub mod cache;
pub mod encoder;
pub mod engine;
pub mod frontend;
pub mod loadgen;
pub mod metrics;
pub mod router;
pub mod standby;

pub use batcher::{BatchPolicy, BatchQueue};
pub use cache::ShardedLru;
pub use encoder::{ClipEncoder, EncoderConfig, EncoderWeights};
pub use engine::{EncodeResponse, Engine, ServeConfig};
pub use frontend::{EncodeClient, Frontend, FrontendConfig, SocketOutcome};
pub use loadgen::{
    planned_swaps, run_loadgen, run_loadgen_socket, write_bench_json, LoadgenConfig, LoadgenReport,
};
pub use metrics::{PromotionMark, ServeMetrics, ServeSnapshot};
pub use router::{engine_index, Router};
pub use standby::{CanarySet, Promotion, Standby, StandbyConfig, StandbyEvent, StandbyHandle};

/// One encode request's payload: a patchified image or a token sequence.
#[derive(Debug, Clone, PartialEq)]
pub enum EncodeInput {
    /// `patches × patch_dim` floats, row-major (the training data layout).
    Image(Vec<f32>),
    /// `seq` token ids in `[0, vocab)`.
    Text(Vec<i32>),
}

impl EncodeInput {
    /// Image payload? (workers partition micro-batches by modality)
    pub fn is_image(&self) -> bool {
        matches!(self, Self::Image(_))
    }

    /// Stable 64-bit content hash (FNV-1a over a modality tag + raw bytes)
    /// — the embedding-cache key.
    pub fn content_hash(&self) -> u64 {
        let mut h = cache::Fnv1a::new();
        match self {
            Self::Image(px) => {
                h.update(b"img");
                for v in px {
                    h.update(&v.to_le_bytes());
                }
            }
            Self::Text(toks) => {
                h.update(b"txt");
                for t in toks {
                    h.update(&t.to_le_bytes());
                }
            }
        }
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn content_hash_distinguishes_inputs_and_modalities() {
        let a = EncodeInput::Image(vec![1.0, 2.0]);
        let b = EncodeInput::Image(vec![1.0, 2.5]);
        assert_ne!(a.content_hash(), b.content_hash());
        assert_eq!(a.content_hash(), a.clone().content_hash());
        // same bytes, different modality must not collide
        let img = EncodeInput::Image(vec![0.0]);
        let txt = EncodeInput::Text(vec![0]);
        assert_ne!(img.content_hash(), txt.content_hash());
    }
}
