//! The serving engine: cache front, micro-batcher, worker pool.
//!
//! `Engine::encode` is the (blocking) request path:
//!
//! 1. validate the payload shape against the encoder config,
//! 2. probe the sharded LRU — a hit replies immediately *without touching
//!    the GEMM substrate* (no quantize, no matmul, no queue),
//! 3. on miss, enqueue into the [`BatchQueue`] and wait for a worker.
//!
//! Workers loop on `pop_batch`, partition each micro-batch by modality,
//! run the forward-only encoder once per modality, fill the cache, and
//! reply through each request's single-slot channel.  Worker count
//! defaults to a fraction of [`crate::util::threads::num_threads`]: the
//! GEMMs inside the encoder already fan out over the same pool helper, so
//! a few batch-level workers keep the cores busy without oversubscribing.
//!
//! Identical concurrent misses may both be encoded (no in-flight dedup);
//! both land on the same cache key, so the window is one batch wide.

use super::batcher::{BatchPolicy, BatchQueue};
use super::cache::ShardedLru;
use super::encoder::{ClipEncoder, EncoderConfig};
use super::metrics::ServeMetrics;
use super::EncodeInput;
use crate::trace;
use crate::util::threads::num_threads;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Engine construction knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub encoder: EncoderConfig,
    pub policy: BatchPolicy,
    /// batch-level worker threads (0 = auto: cores/4, at least 1)
    pub workers: usize,
    /// total embedding-cache entries (0 disables the cache)
    pub cache_capacity: usize,
    /// lock shards for the cache (0 = auto)
    pub cache_shards: usize,
}

impl ServeConfig {
    /// The default serving stack around [`EncoderConfig::demo`].
    pub fn demo(kind: crate::nn::LinearKind) -> Self {
        Self {
            encoder: EncoderConfig::demo(kind),
            policy: BatchPolicy::default(),
            workers: 0,
            cache_capacity: 8192,
            cache_shards: 0,
        }
    }
}

/// A served embedding.
#[derive(Debug, Clone)]
pub struct EncodeResponse {
    /// L2-normalized `embed_dim` vector (shared with the cache)
    pub embedding: Arc<Vec<f32>>,
    pub cache_hit: bool,
}

/// Errors are plain strings (the CLI boundary stringifies anyway).
pub type EncodeResult = Result<EncodeResponse, String>;

/// One queued unit of work.
struct Job {
    input: EncodeInput,
    key: u64,
    enqueued: Instant,
    reply: SyncSender<EncodeResult>,
}

struct Shared {
    /// shape contract every request is validated against — fixed at boot;
    /// hot-swapped encoders must match it (kind may differ)
    cfg: EncoderConfig,
    /// the live encoder.  Workers take the read lock only long enough to
    /// clone the `Arc` (one pointer bump), so a hot-swap's exclusive pause
    /// is the write-lock acquisition, not a batch's forward pass.
    encoder: RwLock<Arc<ClipEncoder>>,
    /// cache-key generation: bumped on every hot-swap, mixed into every
    /// cache key, so embeddings from old weights become unreachable (and
    /// LRU-evict) without walking or locking the whole cache
    generation: AtomicU64,
    queue: BatchQueue<Job>,
    cache: Option<ShardedLru>,
    metrics: ServeMetrics,
}

/// Mix the cache generation into a content hash.  Generation 0 leaves the
/// key untouched, so pre-swap behavior (and tests) are unchanged.
fn cache_key(content: u64, generation: u64) -> u64 {
    content ^ generation.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// The running engine (workers live until [`Engine::shutdown`] / drop).
pub struct Engine {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl Engine {
    /// Build the encoder (pre-quantizing all weights once) and start the
    /// worker pool.
    pub fn start(cfg: ServeConfig) -> Engine {
        let encoder = ClipEncoder::new(cfg.encoder.clone());
        Self::start_with_encoder(cfg, encoder)
    }

    /// Start with an already-built encoder (e.g. weights loaded from a
    /// checkpoint via [`ClipEncoder::from_weights`] instead of fresh
    /// seeds).  The encoder's shape must match `cfg.encoder`.
    pub fn start_with_encoder(cfg: ServeConfig, encoder: ClipEncoder) -> Engine {
        assert!(
            same_shape(encoder.config(), &cfg.encoder),
            "encoder shape does not match the serve config"
        );
        let workers = if cfg.workers > 0 {
            cfg.workers
        } else {
            (num_threads() / 4).max(1)
        };
        let cache = if cfg.cache_capacity > 0 {
            let shards = if cfg.cache_shards > 0 {
                cfg.cache_shards
            } else {
                16.min(cfg.cache_capacity.max(1))
            };
            Some(ShardedLru::new(cfg.cache_capacity, shards))
        } else {
            None
        };
        let shared = Arc::new(Shared {
            cfg: cfg.encoder,
            encoder: RwLock::new(Arc::new(encoder)),
            generation: AtomicU64::new(0),
            queue: BatchQueue::new(cfg.policy),
            cache,
            metrics: ServeMetrics::new(),
        });
        let handles = (0..workers)
            .map(|_| {
                let sh = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&sh))
            })
            .collect();
        Engine { shared, workers: handles }
    }

    /// Atomically install a new encoder between micro-batches (live weight
    /// hot-swap).  In-flight requests are never dropped: batches already
    /// executing finish on the old encoder (their workers hold an `Arc`),
    /// queued requests encode on the new one, and the cache generation
    /// bump invalidates every stale embedding.  Returns the exclusive
    /// pause (write-lock hold, a pointer swap — microseconds).
    pub fn install_encoder(&self, encoder: ClipEncoder) -> Result<Duration, String> {
        let sh = &self.shared;
        if !same_shape(encoder.config(), &sh.cfg) {
            return Err(format!(
                "hot-swap rejected: encoder shape {:?} does not match the \
                 serving shape contract {:?}",
                encoder.config(),
                sh.cfg
            ));
        }
        let fresh = Arc::new(encoder);
        let t0 = crate::trace::clock();
        {
            let mut slot = sh.encoder.write().map_err(|_| "encoder lock poisoned")?;
            *slot = fresh;
            // bump inside the write hold so no request can pair the new
            // weights with an old-generation cache key
            sh.generation.fetch_add(1, Ordering::SeqCst);
        }
        let pause = t0.elapsed();
        let pause_ns = pause.as_nanos() as u64;
        let gen = sh.generation.load(Ordering::SeqCst) as u32;
        trace::event_at(
            "serve.swap_pause",
            "serve",
            trace::now_ns().saturating_sub(pause_ns),
            pause_ns,
            gen,
        );
        sh.metrics.record_swap(pause_ns);
        Ok(pause)
    }

    /// Cache generation (bumped once per hot-swap).
    pub fn generation(&self) -> u64 {
        self.shared.generation.load(Ordering::SeqCst)
    }

    /// Pin the *current* live encoder (one `Arc` clone, the same pointer
    /// bump the workers do per micro-batch).  The standby watcher encodes
    /// its canary batch through this to measure embedding drift against a
    /// candidate without consuming engine capacity.
    pub fn current_encoder(&self) -> Arc<ClipEncoder> {
        Arc::clone(&read_encoder(&self.shared.encoder))
    }

    /// Blocking encode of one input.  Thread-safe; call from any number of
    /// client threads.
    pub fn encode(&self, input: EncodeInput) -> EncodeResult {
        let sh = &self.shared;
        if let Err(e) = self.validate(&input) {
            sh.metrics.rejected.inc();
            return Err(e);
        }
        // counted after validation so hit_rate's denominator is accepted
        // requests only
        sh.metrics.requests.inc();
        let key = cache_key(input.content_hash(), sh.generation.load(Ordering::SeqCst));
        let t0 = crate::trace::clock();
        if let Some(cache) = &sh.cache {
            let probed = {
                let _sp = trace::span("serve.cache_probe", "serve");
                cache.get(key)
            };
            if let Some(emb) = probed {
                sh.metrics.cache_hits.inc();
                sh.metrics.hit_ns.record(t0.elapsed().as_nanos() as u64);
                return Ok(EncodeResponse { embedding: emb, cache_hit: true });
            }
        }
        let (tx, rx) = sync_channel(1);
        let job = Job { input, key, enqueued: t0, reply: tx };
        if !sh.queue.push(job) {
            sh.metrics.rejected.inc();
            return Err("engine is shut down".into());
        }
        // counted only once actually enqueued, so misses == batched work
        sh.metrics.cache_misses.inc();
        match rx.recv() {
            Ok(res) => res,
            Err(_) => Err("worker dropped the request (engine shutting down)".into()),
        }
    }

    fn validate(&self, input: &EncodeInput) -> Result<(), String> {
        let cfg = &self.shared.cfg;
        match input {
            EncodeInput::Image(px) => {
                if px.len() != cfg.image_len() {
                    return Err(format!(
                        "image payload must be patches×patch_dim = {} floats, got {}",
                        cfg.image_len(),
                        px.len()
                    ));
                }
                if px.iter().any(|v| !v.is_finite()) {
                    return Err("image payload contains non-finite values".into());
                }
            }
            EncodeInput::Text(toks) => {
                if toks.len() != cfg.text_seq {
                    return Err(format!(
                        "caption must be text_seq = {} tokens, got {}",
                        cfg.text_seq,
                        toks.len()
                    ));
                }
            }
        }
        Ok(())
    }

    /// Live metrics handle (snapshot whenever needed).
    pub fn metrics(&self) -> &ServeMetrics {
        &self.shared.metrics
    }

    /// The engine's model-shape contract (loadgen builds matching inputs
    /// from it; hot-swaps never change it).
    pub fn encoder_config(&self) -> &EncoderConfig {
        &self.shared.cfg
    }

    /// Precision label of the *current* serving encoder ("standard",
    /// "switchback", …) — may change across hot-swaps.
    pub fn kind_label(&self) -> &'static str {
        read_encoder(&self.shared.encoder).config().kind.label()
    }

    /// (hits, misses) seen by the embedding cache, if enabled.
    pub fn cache_stats(&self) -> Option<(u64, u64)> {
        self.shared.cache.as_ref().map(|c| c.stats())
    }

    /// Resident encoder weight bytes (pre-quantized form).
    pub fn weight_bytes(&self) -> usize {
        read_encoder(&self.shared.encoder).weight_bytes()
    }

    /// Stop accepting work, drain the queue, and join the workers.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    /// Chaos hook: abruptly close the request queue *without* consuming
    /// the engine or joining its workers (contrast [`Engine::shutdown`]).
    /// Already-queued work still drains and gets replies; every later
    /// [`Engine::encode`] is shed deterministically (`"engine is shut
    /// down"`, counted in the `rejected` counter).  The router chaos test
    /// kills one engine of a fleet mid-load with this and asserts the
    /// siblings keep serving.
    pub fn kill(&self) {
        self.shared.queue.close();
    }

    fn shutdown_inner(&mut self) {
        self.shared.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Shape equality of two encoder configs (kind and seed are free — a
/// hot-swap may retrain or requantize, but never resize the model).
fn same_shape(a: &EncoderConfig, b: &EncoderConfig) -> bool {
    a.same_shape(b)
}

/// Poison-recovering encoder read.  The only writer
/// ([`Engine::install_encoder`]) holds the write lock for a pointer swap
/// that cannot leave the slot torn, so even a poisoned lock guards a
/// coherent `Arc` — readers keep serving instead of panicking.
fn read_encoder(
    slot: &RwLock<Arc<ClipEncoder>>,
) -> std::sync::RwLockReadGuard<'_, Arc<ClipEncoder>> {
    slot.read().unwrap_or_else(|e| e.into_inner())
}

/// Worker: pull micro-batches until the queue closes and drains.
fn worker_loop(sh: &Shared) {
    let mut assemble_t0 = trace::now_ns();
    while let Some(batch) = sh.queue.pop_batch() {
        // assembly = wait-for-first-job + the batching window
        trace::event_at(
            "serve.batch_assemble",
            "serve",
            assemble_t0,
            trace::now_ns().saturating_sub(assemble_t0),
            batch.len() as u32,
        );
        // per-request queue wait, recorded retroactively from the enqueue
        // stamp (the interval does not nest on this call stack)
        let popped_ns = trace::now_ns();
        for job in &batch {
            let waited = job.enqueued.elapsed().as_nanos() as u64;
            trace::event_at(
                "serve.queue_wait",
                "serve",
                popped_ns.saturating_sub(waited),
                waited,
                0,
            );
        }
        let _sp = trace::span_n("serve.batch", "serve", batch.len() as u32);
        let t0 = crate::trace::clock();
        // pin the live encoder for this whole micro-batch: a concurrent
        // hot-swap takes effect at the next batch boundary, and the read
        // guard is dropped immediately so a swap never waits on a forward
        let encoder = Arc::clone(&read_encoder(&sh.encoder));
        let n = batch.len();
        // partition by modality in one pass, remembering original slots
        let mut img_idx = vec![];
        let mut imgs: Vec<&[f32]> = vec![];
        let mut txt_idx = vec![];
        let mut txts: Vec<&[i32]> = vec![];
        for (i, job) in batch.iter().enumerate() {
            match &job.input {
                EncodeInput::Image(px) => {
                    img_idx.push(i);
                    imgs.push(px.as_slice());
                }
                EncodeInput::Text(t) => {
                    txt_idx.push(i);
                    txts.push(t.as_slice());
                }
            }
        }
        let img_embs = encoder.encode_images(&imgs);
        let txt_embs = encoder.encode_texts(&txts);
        let mut out: Vec<Option<Arc<Vec<f32>>>> = vec![None; n];
        for (slot, emb) in img_idx.iter().zip(img_embs) {
            if let Some(o) = out.get_mut(*slot) {
                *o = Some(Arc::new(emb));
            }
        }
        for (slot, emb) in txt_idx.iter().zip(txt_embs) {
            if let Some(o) = out.get_mut(*slot) {
                *o = Some(Arc::new(emb));
            }
        }
        for (job, emb) in batch.iter().zip(out) {
            // a slot can only be empty if the encoder returned fewer
            // embeddings than inputs — fail that request, never the
            // worker thread that every other connection depends on
            let Some(emb) = emb else {
                sh.metrics.rejected.inc();
                let _ = job
                    .reply
                    .send(Err("internal error: batch slot not encoded".into()));
                continue;
            };
            if let Some(cache) = &sh.cache {
                cache.insert(job.key, Arc::clone(&emb));
            }
            sh.metrics
                .request_ns
                .record(job.enqueued.elapsed().as_nanos() as u64);
            // the client may have vanished; ignore send failures
            let _ = job
                .reply
                .send(Ok(EncodeResponse { embedding: emb, cache_hit: false }));
        }
        {
            // one atomic group: a snapshot either sees this whole batch
            // (count + occupancy + latency sample) or none of it
            let _g = sh.metrics.grouped();
            sh.metrics.batches.inc();
            sh.metrics.batched_requests.add(n as u64);
            sh.metrics.batch_ns.record(t0.elapsed().as_nanos() as u64);
        }
        assemble_t0 = trace::now_ns();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::LinearKind;
    use crate::tensor::Rng;
    use std::time::Duration;

    fn tiny_cfg(kind: LinearKind, cache: usize) -> ServeConfig {
        ServeConfig {
            encoder: EncoderConfig {
                kind,
                dim: 16,
                heads: 2,
                blocks: 1,
                embed_dim: 8,
                patches: 4,
                patch_dim: 12,
                text_seq: 5,
                vocab: 64,
                seed: 11,
            },
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
            },
            workers: 2,
            cache_capacity: cache,
            cache_shards: 2,
        }
    }

    fn random_image(rng: &mut Rng) -> EncodeInput {
        EncodeInput::Image((0..48).map(|_| rng.normal()).collect())
    }

    #[test]
    fn miss_then_hit_shares_the_embedding() {
        let eng = Engine::start(tiny_cfg(LinearKind::SwitchBack, 64));
        let mut rng = Rng::seed(1);
        let img = random_image(&mut rng);
        let first = eng.encode(img.clone()).unwrap();
        assert!(!first.cache_hit);
        let second = eng.encode(img).unwrap();
        assert!(second.cache_hit, "second request must hit the cache");
        assert!(Arc::ptr_eq(&first.embedding, &second.embedding));
        let snap = eng.metrics().snapshot();
        assert_eq!(snap.cache_hits, 1);
        assert_eq!(snap.cache_misses, 1);
        eng.shutdown();
    }

    #[test]
    fn concurrent_clients_all_get_correct_embeddings() {
        let eng = Arc::new(Engine::start(tiny_cfg(LinearKind::SwitchBack, 0)));
        let solo = {
            let mut rng = Rng::seed(5);
            let img = random_image(&mut rng);
            (img.clone(), eng.encode(img).unwrap().embedding)
        };
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let eng = Arc::clone(&eng);
                let reference = solo.clone();
                std::thread::spawn(move || {
                    let mut rng = Rng::seed(100 + t);
                    for _ in 0..10 {
                        // mix of the shared image and fresh ones + texts
                        let r = eng.encode(reference.0.clone()).unwrap();
                        assert_eq!(*r.embedding, *reference.1, "batching changed numerics");
                        let fresh = eng.encode(random_image(&mut rng)).unwrap();
                        assert_eq!(fresh.embedding.len(), 8);
                        let toks: Vec<i32> =
                            (0..5).map(|_| rng.below(64) as i32).collect();
                        let te = eng.encode(EncodeInput::Text(toks)).unwrap();
                        assert_eq!(te.embedding.len(), 8);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let snap = eng.metrics().snapshot();
        assert_eq!(snap.requests, 241);
        assert_eq!(snap.cache_hits, 0, "cache disabled");
        assert!(snap.batches >= 1);
        assert!(snap.mean_batch_occupancy >= 1.0);
    }

    #[test]
    fn invalid_payloads_are_rejected_not_encoded() {
        let eng = Engine::start(tiny_cfg(LinearKind::Standard, 16));
        let err = eng.encode(EncodeInput::Image(vec![1.0; 7])).unwrap_err();
        assert!(err.contains("patches×patch_dim"), "{err}");
        let err = eng.encode(EncodeInput::Text(vec![1, 2])).unwrap_err();
        assert!(err.contains("text_seq"), "{err}");
        let err = eng
            .encode(EncodeInput::Image(vec![f32::NAN; 48]))
            .unwrap_err();
        assert!(err.contains("non-finite"), "{err}");
        let snap = eng.metrics().snapshot();
        assert_eq!(snap.rejected, 3);
        assert_eq!(snap.cache_misses, 0);
        eng.shutdown();
    }

    #[test]
    fn shutdown_rejects_new_work() {
        let cfg = tiny_cfg(LinearKind::Standard, 16);
        let eng = Engine::start(cfg);
        let shared = Arc::clone(&eng.shared);
        eng.shutdown();
        // the queue is closed now; a late push is rejected
        assert_eq!(shared.queue.depth(), 0);
    }

    /// Hot-swap: embeddings change to the new weights, stale cache entries
    /// are invalidated via the generation bump, and no request errors.
    #[test]
    fn hot_swap_installs_new_weights_and_invalidates_cache() {
        let cfg = tiny_cfg(LinearKind::SwitchBack, 64);
        let eng = Engine::start(cfg.clone());
        let mut rng = Rng::seed(21);
        let img = random_image(&mut rng);
        let before = eng.encode(img.clone()).unwrap();
        assert!(eng.encode(img.clone()).unwrap().cache_hit, "warm before swap");
        assert_eq!(eng.generation(), 0);

        // different seed → genuinely different weights, same shape
        let mut swapped_cfg = cfg.encoder.clone();
        swapped_cfg.seed = 999;
        let pause = eng.install_encoder(ClipEncoder::new(swapped_cfg)).unwrap();
        assert_eq!(eng.generation(), 1);
        assert!(pause.as_millis() < 1000, "swap pause is a pointer write");

        let after = eng.encode(img.clone()).unwrap();
        assert!(!after.cache_hit, "generation bump must invalidate the cache");
        assert_ne!(*before.embedding, *after.embedding, "weights must have changed");
        assert!(eng.encode(img).unwrap().cache_hit, "new generation re-caches");
        let snap = eng.metrics().snapshot();
        assert_eq!(snap.hot_swaps, 1);
        assert_eq!(snap.rejected, 0);
        eng.shutdown();
    }

    /// A shape-mismatched encoder is rejected without disturbing serving.
    #[test]
    fn hot_swap_rejects_shape_mismatch() {
        let cfg = tiny_cfg(LinearKind::Standard, 16);
        let eng = Engine::start(cfg.clone());
        let mut bad = cfg.encoder.clone();
        bad.dim = 32;
        let err = eng.install_encoder(ClipEncoder::new(bad)).unwrap_err();
        assert!(err.contains("shape"), "{err}");
        assert_eq!(eng.generation(), 0);
        let mut rng = Rng::seed(3);
        assert!(eng.encode(random_image(&mut rng)).is_ok());
        eng.shutdown();
    }

    /// Swaps under concurrent load: every request succeeds (zero drops)
    /// while generations advance mid-traffic.
    #[test]
    fn hot_swap_under_load_drops_nothing() {
        let cfg = tiny_cfg(LinearKind::SwitchBack, 128);
        let eng = Arc::new(Engine::start(cfg.clone()));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let clients: Vec<_> = (0..4)
            .map(|t| {
                let eng = Arc::clone(&eng);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    let mut rng = Rng::seed(300 + t);
                    let mut n = 0u64;
                    while !stop.load(Ordering::Relaxed) || n < 20 {
                        eng.encode(random_image(&mut rng)).expect("dropped request");
                        let toks: Vec<i32> = (0..5).map(|_| rng.below(64) as i32).collect();
                        eng.encode(EncodeInput::Text(toks)).expect("dropped request");
                        n += 2;
                    }
                    n
                })
            })
            .collect();
        for gen in 0..3u64 {
            let mut c = cfg.encoder.clone();
            c.seed = 1000 + gen;
            eng.install_encoder(ClipEncoder::new(c)).unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        let total: u64 = clients.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(eng.generation(), 3);
        let snap = eng.metrics().snapshot();
        assert_eq!(snap.requests, total, "every request accounted for");
        assert_eq!(snap.rejected, 0);
        assert_eq!(snap.hot_swaps, 3);
    }

    #[test]
    fn hit_path_never_touches_the_queue() {
        let eng = Engine::start(tiny_cfg(LinearKind::SwitchBack, 64));
        let mut rng = Rng::seed(9);
        let img = random_image(&mut rng);
        eng.encode(img.clone()).unwrap();
        let batches_before = eng.metrics().snapshot().batches;
        for _ in 0..20 {
            assert!(eng.encode(img.clone()).unwrap().cache_hit);
        }
        let snap = eng.metrics().snapshot();
        assert_eq!(
            snap.batches, batches_before,
            "hits must not reach the worker pool"
        );
        eng.shutdown();
    }
}
