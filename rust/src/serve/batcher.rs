//! Dynamic micro-batcher: a coalescing MPMC queue with a max-batch /
//! max-wait policy.
//!
//! Workers call [`BatchQueue::pop_batch`], which returns as soon as either
//! * `max_batch` items are queued (full batch, zero added latency), or
//! * the *oldest* queued item has waited `max_wait` (partial batch — the
//!   knob that bounds tail latency at low offered load).
//!
//! The queue is intentionally payload-generic so the policy logic is
//! testable without spinning up the whole engine.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// The two-knob coalescing policy (max-batch / max-wait).
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Hard cap on items per batch (the encoder's micro-batch size).
    pub max_batch: usize,
    /// Longest the oldest item may wait before a partial batch is flushed.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 32, max_wait: Duration::from_millis(2) }
    }
}

struct State<T> {
    queue: VecDeque<(T, Instant)>,
    closed: bool,
}

/// A blocking coalescing queue (multi-producer, multi-consumer).
pub struct BatchQueue<T> {
    state: Mutex<State<T>>,
    cv: Condvar,
    policy: BatchPolicy,
}

/// Poison-recovering lock: a holder that panicked only did single queue
/// ops under the lock, so the `VecDeque` is still coherent — recovering
/// keeps one bad request from wedging every connection thread.
fn lock_state<T>(m: &Mutex<State<T>>) -> std::sync::MutexGuard<'_, State<T>> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl<T> BatchQueue<T> {
    /// An open queue under `policy` (panics on a zero `max_batch`).
    pub fn new(policy: BatchPolicy) -> Self {
        assert!(policy.max_batch >= 1, "max_batch must be >= 1");
        Self {
            state: Mutex::new(State { queue: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            policy,
        }
    }

    /// The policy this queue batches under.
    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Enqueue an item; returns `false` (with the item dropped) if the
    /// queue is closed.
    pub fn push(&self, item: T) -> bool {
        let mut st = lock_state(&self.state);
        if st.closed {
            return false;
        }
        st.queue.push_back((item, crate::trace::clock()));
        drop(st);
        self.cv.notify_one();
        true
    }

    /// Number of items currently waiting (diagnostics only).
    pub fn depth(&self) -> usize {
        lock_state(&self.state).queue.len()
    }

    /// Close the queue: pending items still drain; subsequent `push`es are
    /// rejected; `pop_batch` returns `None` once empty.
    pub fn close(&self) {
        lock_state(&self.state).closed = true;
        self.cv.notify_all();
    }

    /// Block until a batch is ready per the policy.  Returns `None` only
    /// after [`Self::close`] once the queue has fully drained.
    pub fn pop_batch(&self) -> Option<Vec<T>> {
        let mut st = lock_state(&self.state);
        loop {
            if st.queue.len() >= self.policy.max_batch {
                return Some(self.drain(&mut st));
            }
            if st.closed {
                if st.queue.is_empty() {
                    return None;
                }
                return Some(self.drain(&mut st));
            }
            if let Some(&(_, enqueued)) = st.queue.front() {
                let deadline = enqueued + self.policy.max_wait;
                let now = crate::trace::clock();
                if now >= deadline {
                    return Some(self.drain(&mut st));
                }
                let (next, _timeout) =
                    self.cv.wait_timeout(st, deadline - now).unwrap_or_else(|e| e.into_inner());
                st = next;
                // loop around: the deadline is recomputed from the current
                // front, so an item another worker drained mid-wait cannot
                // cause a freshly-enqueued item to flush early
            } else {
                st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        }
    }

    fn drain(&self, st: &mut State<T>) -> Vec<T> {
        let n = st.queue.len().min(self.policy.max_batch);
        let batch: Vec<T> = st.queue.drain(..n).map(|(item, _)| item).collect();
        if !st.queue.is_empty() {
            // leftovers may already satisfy the policy for another worker
            self.cv.notify_one();
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Instant;

    fn policy(max_batch: usize, wait_ms: u64) -> BatchPolicy {
        BatchPolicy { max_batch, max_wait: Duration::from_millis(wait_ms) }
    }

    #[test]
    fn full_batch_returns_without_waiting() {
        let q = BatchQueue::new(policy(4, 10_000));
        for i in 0..4 {
            assert!(q.push(i));
        }
        let t0 = Instant::now();
        let b = q.pop_batch().unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        assert!(t0.elapsed() < Duration::from_millis(1000), "must not wait");
    }

    #[test]
    fn partial_batch_flushes_after_max_wait() {
        let q = Arc::new(BatchQueue::new(policy(8, 30)));
        q.push(1u32);
        q.push(2);
        let t0 = Instant::now();
        let b = q.pop_batch().unwrap();
        assert_eq!(b, vec![1, 2]);
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(5), "flushed too early: {waited:?}");
    }

    #[test]
    fn oversize_backlog_splits_into_policy_batches() {
        let q = BatchQueue::new(policy(3, 1));
        for i in 0..7 {
            q.push(i);
        }
        assert_eq!(q.pop_batch().unwrap(), vec![0, 1, 2]);
        assert_eq!(q.pop_batch().unwrap(), vec![3, 4, 5]);
        assert_eq!(q.pop_batch().unwrap(), vec![6]);
    }

    #[test]
    fn close_drains_then_returns_none() {
        let q = BatchQueue::new(policy(10, 10_000));
        q.push(7u8);
        q.close();
        assert!(!q.push(8), "push after close must be rejected");
        assert_eq!(q.pop_batch().unwrap(), vec![7]);
        assert!(q.pop_batch().is_none());
        assert!(q.pop_batch().is_none(), "stays closed");
    }

    #[test]
    fn producers_and_consumers_in_parallel_lose_nothing() {
        let q = Arc::new(BatchQueue::new(policy(5, 2)));
        let n_items = 500;
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..n_items / 4 {
                        q.push(p * 1000 + i);
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    let mut got = vec![];
                    while let Some(b) = q.pop_batch() {
                        assert!(b.len() <= 5);
                        got.extend(b);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<i32> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all.len(), n_items as usize);
        all.dedup();
        assert_eq!(all.len(), n_items as usize, "no duplicates");
    }
}
