//! Serving telemetry: atomic counters + latency histograms, snapshotted
//! into a JSON-serializable report.
//!
//! Everything here is recorded from hot paths (client threads on hits,
//! workers per batch), so it is all relaxed atomics — no locks, no
//! allocation.  `loadgen` and the `serve` smoke subcommand read one
//! [`ServeSnapshot`] at the end; BENCH_serve.json is built from these.

use crate::telemetry::Histogram;
use crate::util::json::ObjWriter;
use std::sync::atomic::{AtomicU64, Ordering};

/// Live serving metrics (shared by the engine, its workers and clients).
#[derive(Default)]
pub struct ServeMetrics {
    /// requests accepted by `Engine::encode` (rejects are counted only in
    /// `rejected`, so `hit_rate = hits / requests` is over accepted work)
    pub requests: AtomicU64,
    /// served straight from the embedding cache (no GEMM work at all)
    pub cache_hits: AtomicU64,
    /// enqueued for encoding
    pub cache_misses: AtomicU64,
    /// rejected before enqueue (bad shape / shutdown)
    pub rejected: AtomicU64,
    /// batches executed by the worker pool
    pub batches: AtomicU64,
    /// requests carried by those batches (occupancy = this / batches)
    pub batched_requests: AtomicU64,
    /// end-to-end latency of encode-path requests (enqueue → reply), ns
    pub request_ns: Histogram,
    /// latency of cache hits (lookup only), ns
    pub hit_ns: Histogram,
    /// worker time per batch (forward pass + bookkeeping), ns
    pub batch_ns: Histogram,
    /// live weight hot-swaps installed ([`super::Engine::install_encoder`])
    pub hot_swaps: AtomicU64,
    /// worst-case swap pause (exclusive write-lock hold), ns
    pub swap_pause_max_ns: AtomicU64,
    /// distribution of swap pauses across generations, ns
    pub swap_pause_ns: Histogram,
    /// standby promotions: candidates that passed the canary drift bound
    /// and were installed ([`super::standby`])
    pub standby_promotions: AtomicU64,
    /// standby rejections: unreadable/mismatched/drifted candidates that
    /// never touched the live generation
    pub standby_rejects: AtomicU64,
    /// automatic rollbacks to the previous generation after a failed
    /// post-promotion canary probe
    pub standby_rollbacks: AtomicU64,
    /// snapshots the watcher gave up on: unreadable or incomplete past
    /// the bounded retry/backoff budget (a permanently truncated copy) —
    /// quarantined and never revisited ([`super::standby`])
    pub standby_quarantines: AtomicU64,
    /// off-thread candidate preparation time (CRC-checked load +
    /// re-quantize + canary encode), ns
    pub prepare_ns: Histogram,
}

impl ServeMetrics {
    /// All-zero counters and empty histograms.
    pub fn new() -> Self {
        Self::default()
    }

    /// Point-in-time copy of everything a report needs.
    pub fn snapshot(&self) -> ServeSnapshot {
        let requests = self.requests.load(Ordering::Relaxed);
        let hits = self.cache_hits.load(Ordering::Relaxed);
        let misses = self.cache_misses.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        let batched = self.batched_requests.load(Ordering::Relaxed);
        let (p50, p95, p99) = self.request_ns.percentiles();
        let (h50, h95, h99) = self.hit_ns.percentiles();
        let (b50, b95, b99) = self.batch_ns.percentiles();
        let (s50, _, s99) = self.swap_pause_ns.percentiles();
        let (pr50, _, pr99) = self.prepare_ns.percentiles();
        ServeSnapshot {
            requests,
            cache_hits: hits,
            cache_misses: misses,
            rejected: self.rejected.load(Ordering::Relaxed),
            batches,
            hit_rate: if requests > 0 { hits as f64 / requests as f64 } else { 0.0 },
            mean_batch_occupancy: if batches > 0 {
                batched as f64 / batches as f64
            } else {
                0.0
            },
            request_p50_ms: ns_to_ms(p50),
            request_p95_ms: ns_to_ms(p95),
            request_p99_ms: ns_to_ms(p99),
            hit_p50_ms: ns_to_ms(h50),
            hit_p95_ms: ns_to_ms(h95),
            hit_p99_ms: ns_to_ms(h99),
            batch_p50_ms: ns_to_ms(b50),
            batch_p95_ms: ns_to_ms(b95),
            batch_p99_ms: ns_to_ms(b99),
            hot_swaps: self.hot_swaps.load(Ordering::Relaxed),
            swap_pause_max_us: self.swap_pause_max_ns.load(Ordering::Relaxed) as f64 / 1e3,
            swap_pause_p50_us: s50 as f64 / 1e3,
            swap_pause_p99_us: s99 as f64 / 1e3,
            standby_promotions: self.standby_promotions.load(Ordering::Relaxed),
            standby_rejects: self.standby_rejects.load(Ordering::Relaxed),
            standby_rollbacks: self.standby_rollbacks.load(Ordering::Relaxed),
            standby_quarantines: self.standby_quarantines.load(Ordering::Relaxed),
            prepare_p50_ms: ns_to_ms(pr50),
            prepare_p99_ms: ns_to_ms(pr99),
        }
    }

    /// Record one hot-swap's exclusive pause: the max (the worst case is
    /// what matters for tail latency) plus the full distribution across
    /// generations.
    pub fn record_swap(&self, pause_ns: u64) {
        self.hot_swaps.fetch_add(1, Ordering::Relaxed);
        self.swap_pause_max_ns.fetch_max(pause_ns, Ordering::Relaxed);
        self.swap_pause_ns.record(pause_ns);
    }

    /// Record a standby promotion and its off-thread preparation time.
    pub fn record_promote(&self, prepare_ns: u64) {
        self.standby_promotions.fetch_add(1, Ordering::Relaxed);
        self.prepare_ns.record(prepare_ns);
    }

    /// Record a standby rejection (the live generation was not touched).
    pub fn record_reject(&self) {
        self.standby_rejects.fetch_add(1, Ordering::Relaxed);
    }

    /// Record an automatic rollback to the previous generation.
    pub fn record_rollback(&self) {
        self.standby_rollbacks.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a quarantined snapshot (retry budget exhausted).
    pub fn record_quarantine(&self) {
        self.standby_quarantines.fetch_add(1, Ordering::Relaxed);
    }
}

fn ns_to_ms(ns: u64) -> f64 {
    ns as f64 / 1e6
}

/// A point-in-time serving report (all latencies in milliseconds).
#[derive(Debug, Clone)]
pub struct ServeSnapshot {
    pub requests: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub rejected: u64,
    pub batches: u64,
    pub hit_rate: f64,
    pub mean_batch_occupancy: f64,
    pub request_p50_ms: f64,
    pub request_p95_ms: f64,
    pub request_p99_ms: f64,
    pub hit_p50_ms: f64,
    pub hit_p95_ms: f64,
    pub hit_p99_ms: f64,
    pub batch_p50_ms: f64,
    pub batch_p95_ms: f64,
    pub batch_p99_ms: f64,
    pub hot_swaps: u64,
    pub swap_pause_max_us: f64,
    pub swap_pause_p50_us: f64,
    pub swap_pause_p99_us: f64,
    pub standby_promotions: u64,
    pub standby_rejects: u64,
    pub standby_rollbacks: u64,
    pub standby_quarantines: u64,
    pub prepare_p50_ms: f64,
    pub prepare_p99_ms: f64,
}

impl ServeSnapshot {
    /// JSON object (nested inside BENCH_serve.json result entries).
    pub fn to_json(&self) -> String {
        let mut w = ObjWriter::new();
        w.field_u64("requests", self.requests)
            .field_u64("cache_hits", self.cache_hits)
            .field_u64("cache_misses", self.cache_misses)
            .field_u64("rejected", self.rejected)
            .field_u64("batches", self.batches)
            .field_f32("hit_rate", self.hit_rate as f32)
            .field_f32("mean_batch_occupancy", self.mean_batch_occupancy as f32)
            .field_f32("request_p50_ms", self.request_p50_ms as f32)
            .field_f32("request_p95_ms", self.request_p95_ms as f32)
            .field_f32("request_p99_ms", self.request_p99_ms as f32)
            .field_f32("hit_p50_ms", self.hit_p50_ms as f32)
            .field_f32("hit_p95_ms", self.hit_p95_ms as f32)
            .field_f32("hit_p99_ms", self.hit_p99_ms as f32)
            .field_f32("batch_p50_ms", self.batch_p50_ms as f32)
            .field_f32("batch_p95_ms", self.batch_p95_ms as f32)
            .field_f32("batch_p99_ms", self.batch_p99_ms as f32);
        if self.hot_swaps > 0 {
            w.field_u64("hot_swaps", self.hot_swaps)
                .field_f32("swap_pause_max_us", self.swap_pause_max_us as f32)
                .field_f32("swap_pause_p50_us", self.swap_pause_p50_us as f32)
                .field_f32("swap_pause_p99_us", self.swap_pause_p99_us as f32);
        }
        let standby_active = self.standby_promotions
            + self.standby_rejects
            + self.standby_rollbacks
            + self.standby_quarantines;
        if standby_active > 0 {
            w.field_u64("standby_promotions", self.standby_promotions)
                .field_u64("standby_rejects", self.standby_rejects)
                .field_u64("standby_rollbacks", self.standby_rollbacks)
                .field_u64("standby_quarantines", self.standby_quarantines)
                .field_f32("prepare_p50_ms", self.prepare_p50_ms as f32)
                .field_f32("prepare_p99_ms", self.prepare_p99_ms as f32);
        }
        w.finish()
    }

    /// Human-readable one-screen summary.
    pub fn print(&self, label: &str) {
        println!(
            "  [{label}] {} reqs  hit-rate {:.1}%  occupancy {:.1}  \
             p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms  (hit p50 {:.3} ms)",
            self.requests,
            100.0 * self.hit_rate,
            self.mean_batch_occupancy,
            self.request_p50_ms,
            self.request_p95_ms,
            self.request_p99_ms,
            self.hit_p50_ms,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn snapshot_math_and_json() {
        let m = ServeMetrics::new();
        m.requests.store(10, Ordering::Relaxed);
        m.cache_hits.store(4, Ordering::Relaxed);
        m.cache_misses.store(6, Ordering::Relaxed);
        m.batches.store(3, Ordering::Relaxed);
        m.batched_requests.store(6, Ordering::Relaxed);
        m.request_ns.record(1_000_000);
        m.request_ns.record(3_000_000);
        let s = m.snapshot();
        assert!((s.hit_rate - 0.4).abs() < 1e-9);
        assert!((s.mean_batch_occupancy - 2.0).abs() < 1e-9);
        assert!(s.request_p50_ms > 0.5 && s.request_p50_ms < 3.5);
        let v = parse(&s.to_json()).unwrap();
        assert_eq!(v.get("requests").unwrap().as_usize(), Some(10));
        assert!(v.get("hit_rate").unwrap().as_f64().unwrap() > 0.39);
    }

    /// Standby counters and histograms surface in the snapshot + JSON,
    /// and stay absent from the JSON of a run that never used standby
    /// (so pre-standby baselines remain comparable).
    #[test]
    fn standby_counters_round_trip_to_json() {
        let m = ServeMetrics::new();
        let plain = parse(&m.snapshot().to_json()).unwrap();
        assert!(plain.get("standby_promotions").is_none());
        assert!(plain.get("hot_swaps").is_none());

        m.record_promote(2_000_000); // 2 ms prepare
        m.record_promote(4_000_000);
        m.record_reject();
        m.record_rollback();
        m.record_quarantine();
        m.record_swap(30_000); // 30 µs pause
        let s = m.snapshot();
        assert_eq!(s.standby_promotions, 2);
        assert_eq!(s.standby_rejects, 1);
        assert_eq!(s.standby_rollbacks, 1);
        assert_eq!(s.standby_quarantines, 1);
        assert!(s.prepare_p99_ms > 1.0 && s.prepare_p99_ms < 10.0);
        assert!(s.swap_pause_p99_us > 10.0 && s.swap_pause_p99_us < 100.0);
        let v = parse(&s.to_json()).unwrap();
        assert_eq!(v.get("standby_promotions").unwrap().as_usize(), Some(2));
        assert_eq!(v.get("standby_rejects").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("standby_rollbacks").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("standby_quarantines").unwrap().as_usize(), Some(1));
        assert!(v.get("prepare_p99_ms").unwrap().as_f64().unwrap() > 0.0);
        assert!(v.get("swap_pause_p99_us").unwrap().as_f64().unwrap() > 0.0);

        // a quarantine alone must surface the standby block too (it is
        // the only signal a stuck snapshot leaves behind)
        let q = ServeMetrics::new();
        q.record_quarantine();
        let v = parse(&q.snapshot().to_json()).unwrap();
        assert_eq!(v.get("standby_quarantines").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("standby_promotions").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn empty_snapshot_is_all_zero() {
        let s = ServeMetrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.hit_rate, 0.0);
        assert_eq!(s.mean_batch_occupancy, 0.0);
        assert_eq!(s.request_p50_ms, 0.0);
    }
}
