//! Serving telemetry on the unified [`crate::trace`] metrics registry.
//!
//! Every metric here is a [`Registry`] handle — recording stays relaxed
//! atomics with no locks or allocation on the hot paths (client threads
//! on hits, workers per batch).  What the registry adds is **snapshot
//! consistency**: [`ServeMetrics::snapshot`] reads every counter and
//! histogram in one pass behind the registry's update gate, and
//! multi-metric updates that maintain an invariant (a standby promotion
//! records its hot-swap *and* its promotion; a worker records its batch
//! triple) hold [`ServeMetrics::grouped`] across the writes.  `loadgen`
//! snapshotting mid-run therefore can never observe
//! `standby_promotions > hot_swaps` or a batch counted without its
//! occupancy — the race the old field-by-field snapshot allowed.
//!
//! `ServeMetrics` owns a private registry instance (not the process
//! [`crate::trace::global`] one) so concurrent engines/tests never share
//! counters; [`ServeMetrics::registry`] exposes it for the JSON /
//! Prometheus-style expositions.

use crate::trace::registry::{
    Counter, Gauge, Hist, HistSummary, MetricValue, Registry, UpdateGuard,
};
use crate::util::json::ObjWriter;

/// Live serving metrics (shared by the engine, its workers and clients).
pub struct ServeMetrics {
    registry: Registry,
    /// requests accepted by `Engine::encode` (rejects are counted only in
    /// `rejected`, so `hit_rate = hits / requests` is over accepted work)
    pub requests: Counter,
    /// served straight from the embedding cache (no GEMM work at all)
    pub cache_hits: Counter,
    /// enqueued for encoding
    pub cache_misses: Counter,
    /// rejected before enqueue (bad shape / shutdown)
    pub rejected: Counter,
    /// batches executed by the worker pool
    pub batches: Counter,
    /// requests carried by those batches (occupancy = this / batches)
    pub batched_requests: Counter,
    /// end-to-end latency of encode-path requests (enqueue → reply), ns
    pub request_ns: Hist,
    /// latency of cache hits (lookup only), ns
    pub hit_ns: Hist,
    /// worker time per batch (forward pass + bookkeeping), ns
    pub batch_ns: Hist,
    /// live weight hot-swaps installed ([`super::Engine::install_encoder`])
    pub hot_swaps: Counter,
    /// worst-case swap pause (exclusive write-lock hold), ns
    pub swap_pause_max_ns: Counter,
    /// distribution of swap pauses across generations, ns
    pub swap_pause_ns: Hist,
    /// standby promotions: candidates that passed the canary drift bound
    /// and were installed ([`super::standby`])
    pub standby_promotions: Counter,
    /// standby rejections: unreadable/mismatched/drifted candidates that
    /// never touched the live generation
    pub standby_rejects: Counter,
    /// automatic rollbacks to the previous generation after a failed
    /// post-promotion canary probe
    pub standby_rollbacks: Counter,
    /// snapshots the watcher gave up on: unreadable or incomplete past
    /// the bounded retry/backoff budget (a permanently truncated copy) —
    /// quarantined and never revisited ([`super::standby`])
    pub standby_quarantines: Counter,
    /// off-thread candidate preparation time (CRC-checked load +
    /// re-quantize + canary encode), ns
    pub prepare_ns: Hist,
    /// 1.0 while a standby candidate is mid prepare→promote, else 0.0 —
    /// the `/readyz` "not mid-promotion" signal, also visible on
    /// `/metrics` as `serve_standby_promoting`
    standby_promoting: Gauge,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeMetrics {
    /// All-zero counters and empty histograms on a fresh registry.
    pub fn new() -> Self {
        let registry = Registry::new();
        let c = |name: &str| registry.counter(name);
        let h = |name: &str| registry.histogram(name);
        Self {
            requests: c("serve.requests"),
            cache_hits: c("serve.cache_hits"),
            cache_misses: c("serve.cache_misses"),
            rejected: c("serve.rejected"),
            batches: c("serve.batches"),
            batched_requests: c("serve.batched_requests"),
            request_ns: h("serve.request_ns"),
            hit_ns: h("serve.hit_ns"),
            batch_ns: h("serve.batch_ns"),
            hot_swaps: c("serve.hot_swaps"),
            swap_pause_max_ns: c("serve.swap_pause_max_ns"),
            swap_pause_ns: h("serve.swap_pause_ns"),
            standby_promotions: c("serve.standby_promotions"),
            standby_rejects: c("serve.standby_rejects"),
            standby_rollbacks: c("serve.standby_rollbacks"),
            standby_quarantines: c("serve.standby_quarantines"),
            prepare_ns: h("serve.prepare_ns"),
            standby_promoting: registry.gauge("serve.standby_promoting"),
            registry,
        }
    }

    /// The backing registry (JSON / Prometheus exposition, extra metrics).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Mark a multi-metric update as atomic with respect to
    /// [`snapshot`](Self::snapshot).  Hold this across writes that
    /// maintain a cross-metric invariant (swap + promotion, the worker's
    /// batch triple).  Do not nest on one thread.
    pub fn grouped(&self) -> UpdateGuard<'_> {
        self.registry.grouped()
    }

    /// Point-in-time copy of everything a report needs — **one pass**
    /// behind the registry's update gate, so no [`grouped`](Self::grouped)
    /// update is half-visible.
    pub fn snapshot(&self) -> ServeSnapshot {
        let snap = self.registry.snapshot();
        let c = |name: &str| match snap.get(name) {
            Some(MetricValue::Counter(v)) => *v,
            _ => 0,
        };
        let h = |name: &str| match snap.get(name) {
            Some(MetricValue::Hist(s)) => *s,
            _ => HistSummary::default(),
        };
        let requests = c("serve.requests");
        let hits = c("serve.cache_hits");
        let batches = c("serve.batches");
        let batched = c("serve.batched_requests");
        let req = h("serve.request_ns");
        let hit = h("serve.hit_ns");
        let bat = h("serve.batch_ns");
        let swap = h("serve.swap_pause_ns");
        let prep = h("serve.prepare_ns");
        ServeSnapshot {
            requests,
            cache_hits: hits,
            cache_misses: c("serve.cache_misses"),
            rejected: c("serve.rejected"),
            batches,
            hit_rate: if requests > 0 { hits as f64 / requests as f64 } else { 0.0 },
            mean_batch_occupancy: if batches > 0 {
                batched as f64 / batches as f64
            } else {
                0.0
            },
            request_p50_ms: ns_to_ms(req.p50),
            request_p95_ms: ns_to_ms(req.p95),
            request_p99_ms: ns_to_ms(req.p99),
            hit_p50_ms: ns_to_ms(hit.p50),
            hit_p95_ms: ns_to_ms(hit.p95),
            hit_p99_ms: ns_to_ms(hit.p99),
            batch_p50_ms: ns_to_ms(bat.p50),
            batch_p95_ms: ns_to_ms(bat.p95),
            batch_p99_ms: ns_to_ms(bat.p99),
            hot_swaps: c("serve.hot_swaps"),
            swap_pause_max_us: c("serve.swap_pause_max_ns") as f64 / 1e3,
            swap_pause_p50_us: swap.p50 as f64 / 1e3,
            swap_pause_p99_us: swap.p99 as f64 / 1e3,
            standby_promotions: c("serve.standby_promotions"),
            standby_rejects: c("serve.standby_rejects"),
            standby_rollbacks: c("serve.standby_rollbacks"),
            standby_quarantines: c("serve.standby_quarantines"),
            prepare_p50_ms: ns_to_ms(prep.p50),
            prepare_p99_ms: ns_to_ms(prep.p99),
        }
    }

    /// Record one hot-swap's exclusive pause: the max (the worst case is
    /// what matters for tail latency) plus the full distribution across
    /// generations.  Takes no gate itself — the standby promotion flow
    /// wraps this together with [`record_promote`](Self::record_promote)
    /// under one [`grouped`](Self::grouped) guard.
    pub fn record_swap(&self, pause_ns: u64) {
        self.hot_swaps.inc();
        self.swap_pause_max_ns.fetch_max(pause_ns);
        self.swap_pause_ns.record(pause_ns);
    }

    /// Record a standby promotion and its off-thread preparation time.
    pub fn record_promote(&self, prepare_ns: u64) {
        self.standby_promotions.inc();
        self.prepare_ns.record(prepare_ns);
    }

    /// Record a standby rejection (the live generation was not touched).
    pub fn record_reject(&self) {
        self.standby_rejects.inc();
    }

    /// Record an automatic rollback to the previous generation.
    pub fn record_rollback(&self) {
        self.standby_rollbacks.inc();
    }

    /// Record a quarantined snapshot (retry budget exhausted).
    pub fn record_quarantine(&self) {
        self.standby_quarantines.inc();
    }

    /// Mark the standby watcher as mid prepare→promote for the lifetime
    /// of the returned guard (panic-safe: the flag clears on drop either
    /// way).  `/readyz` reports not-ready while the mark is held.
    pub fn mark_promoting(&self) -> PromotionMark<'_> {
        self.standby_promoting.set(1.0);
        PromotionMark(self)
    }

    /// Is a standby candidate mid prepare→promote right now?
    pub fn is_promoting(&self) -> bool {
        self.standby_promoting.get() != 0.0
    }
}

/// RAII guard from [`ServeMetrics::mark_promoting`].
#[must_use = "the promoting mark lasts until the guard is dropped"]
pub struct PromotionMark<'a>(&'a ServeMetrics);

impl Drop for PromotionMark<'_> {
    fn drop(&mut self) {
        self.0.standby_promoting.set(0.0);
    }
}

fn ns_to_ms(ns: u64) -> f64 {
    ns as f64 / 1e6
}

/// A point-in-time serving report (all latencies in milliseconds).
#[derive(Debug, Clone)]
pub struct ServeSnapshot {
    pub requests: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub rejected: u64,
    pub batches: u64,
    pub hit_rate: f64,
    pub mean_batch_occupancy: f64,
    pub request_p50_ms: f64,
    pub request_p95_ms: f64,
    pub request_p99_ms: f64,
    pub hit_p50_ms: f64,
    pub hit_p95_ms: f64,
    pub hit_p99_ms: f64,
    pub batch_p50_ms: f64,
    pub batch_p95_ms: f64,
    pub batch_p99_ms: f64,
    pub hot_swaps: u64,
    pub swap_pause_max_us: f64,
    pub swap_pause_p50_us: f64,
    pub swap_pause_p99_us: f64,
    pub standby_promotions: u64,
    pub standby_rejects: u64,
    pub standby_rollbacks: u64,
    pub standby_quarantines: u64,
    pub prepare_p50_ms: f64,
    pub prepare_p99_ms: f64,
}

impl ServeSnapshot {
    /// JSON object (nested inside BENCH_serve.json result entries).
    pub fn to_json(&self) -> String {
        let mut w = ObjWriter::new();
        w.field_u64("requests", self.requests)
            .field_u64("cache_hits", self.cache_hits)
            .field_u64("cache_misses", self.cache_misses)
            .field_u64("rejected", self.rejected)
            .field_u64("batches", self.batches)
            .field_f32("hit_rate", self.hit_rate as f32)
            .field_f32("mean_batch_occupancy", self.mean_batch_occupancy as f32)
            .field_f32("request_p50_ms", self.request_p50_ms as f32)
            .field_f32("request_p95_ms", self.request_p95_ms as f32)
            .field_f32("request_p99_ms", self.request_p99_ms as f32)
            .field_f32("hit_p50_ms", self.hit_p50_ms as f32)
            .field_f32("hit_p95_ms", self.hit_p95_ms as f32)
            .field_f32("hit_p99_ms", self.hit_p99_ms as f32)
            .field_f32("batch_p50_ms", self.batch_p50_ms as f32)
            .field_f32("batch_p95_ms", self.batch_p95_ms as f32)
            .field_f32("batch_p99_ms", self.batch_p99_ms as f32);
        if self.hot_swaps > 0 {
            w.field_u64("hot_swaps", self.hot_swaps)
                .field_f32("swap_pause_max_us", self.swap_pause_max_us as f32)
                .field_f32("swap_pause_p50_us", self.swap_pause_p50_us as f32)
                .field_f32("swap_pause_p99_us", self.swap_pause_p99_us as f32);
        }
        let standby_active = self.standby_promotions
            + self.standby_rejects
            + self.standby_rollbacks
            + self.standby_quarantines;
        if standby_active > 0 {
            w.field_u64("standby_promotions", self.standby_promotions)
                .field_u64("standby_rejects", self.standby_rejects)
                .field_u64("standby_rollbacks", self.standby_rollbacks)
                .field_u64("standby_quarantines", self.standby_quarantines)
                .field_f32("prepare_p50_ms", self.prepare_p50_ms as f32)
                .field_f32("prepare_p99_ms", self.prepare_p99_ms as f32);
        }
        w.finish()
    }

    /// Human-readable one-screen summary.
    pub fn print(&self, label: &str) {
        println!(
            "  [{label}] {} reqs  hit-rate {:.1}%  occupancy {:.1}  \
             p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms  (hit p50 {:.3} ms)",
            self.requests,
            100.0 * self.hit_rate,
            self.mean_batch_occupancy,
            self.request_p50_ms,
            self.request_p95_ms,
            self.request_p99_ms,
            self.hit_p50_ms,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;
    use std::sync::Arc;

    #[test]
    fn snapshot_math_and_json() {
        let m = ServeMetrics::new();
        m.requests.set(10);
        m.cache_hits.set(4);
        m.cache_misses.set(6);
        m.batches.set(3);
        m.batched_requests.set(6);
        m.request_ns.record(1_000_000);
        m.request_ns.record(3_000_000);
        let s = m.snapshot();
        assert!((s.hit_rate - 0.4).abs() < 1e-9);
        assert!((s.mean_batch_occupancy - 2.0).abs() < 1e-9);
        assert!(s.request_p50_ms > 0.5 && s.request_p50_ms < 3.5);
        let v = parse(&s.to_json()).unwrap();
        assert_eq!(v.get("requests").unwrap().as_usize(), Some(10));
        assert!(v.get("hit_rate").unwrap().as_f64().unwrap() > 0.39);
    }

    /// Standby counters and histograms surface in the snapshot + JSON,
    /// and stay absent from the JSON of a run that never used standby
    /// (so pre-standby baselines remain comparable).
    #[test]
    fn standby_counters_round_trip_to_json() {
        let m = ServeMetrics::new();
        let plain = parse(&m.snapshot().to_json()).unwrap();
        assert!(plain.get("standby_promotions").is_none());
        assert!(plain.get("hot_swaps").is_none());

        m.record_promote(2_000_000); // 2 ms prepare
        m.record_promote(4_000_000);
        m.record_reject();
        m.record_rollback();
        m.record_quarantine();
        m.record_swap(30_000); // 30 µs pause
        let s = m.snapshot();
        assert_eq!(s.standby_promotions, 2);
        assert_eq!(s.standby_rejects, 1);
        assert_eq!(s.standby_rollbacks, 1);
        assert_eq!(s.standby_quarantines, 1);
        assert!(s.prepare_p99_ms > 1.0 && s.prepare_p99_ms < 10.0);
        assert!(s.swap_pause_p99_us > 10.0 && s.swap_pause_p99_us < 100.0);
        let v = parse(&s.to_json()).unwrap();
        assert_eq!(v.get("standby_promotions").unwrap().as_usize(), Some(2));
        assert_eq!(v.get("standby_rejects").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("standby_rollbacks").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("standby_quarantines").unwrap().as_usize(), Some(1));
        assert!(v.get("prepare_p99_ms").unwrap().as_f64().unwrap() > 0.0);
        assert!(v.get("swap_pause_p99_us").unwrap().as_f64().unwrap() > 0.0);

        // a quarantine alone must surface the standby block too (it is
        // the only signal a stuck snapshot leaves behind)
        let q = ServeMetrics::new();
        q.record_quarantine();
        let v = parse(&q.snapshot().to_json()).unwrap();
        assert_eq!(v.get("standby_quarantines").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("standby_promotions").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn promoting_mark_sets_and_clears_the_gauge() {
        let m = ServeMetrics::new();
        assert!(!m.is_promoting());
        {
            let _mark = m.mark_promoting();
            assert!(m.is_promoting());
            // visible on the wire exposition too
            let text = m.registry().snapshot().to_prometheus();
            assert!(text.contains("serve_standby_promoting 1"), "{text}");
        }
        assert!(!m.is_promoting());
    }

    #[test]
    fn empty_snapshot_is_all_zero() {
        let s = ServeMetrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.hit_rate, 0.0);
        assert_eq!(s.mean_batch_occupancy, 0.0);
        assert_eq!(s.request_p50_ms, 0.0);
    }

    /// The regression this migration fixes: a snapshot racing promotion
    /// flows (hot-swap then promote, recorded under one `grouped` guard —
    /// the production order in `standby::validate_and_promote`) must never
    /// observe `standby_promotions > hot_swaps`.  The old field-by-field
    /// snapshot read `hot_swaps` first, so a swap+promote pair landing
    /// between the loads showed up promotion-first.
    #[test]
    fn concurrent_snapshot_never_sees_promotions_exceed_swaps() {
        let m = Arc::new(ServeMetrics::new());
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        std::thread::scope(|scope| {
            let writer = {
                let (m, stop) = (Arc::clone(&m), Arc::clone(&stop));
                scope.spawn(move || {
                    use std::sync::atomic::Ordering;
                    while !stop.load(Ordering::Relaxed) {
                        let _g = m.grouped();
                        m.record_swap(100);
                        m.record_promote(1_000);
                    }
                })
            };
            for _ in 0..2_000 {
                let s = m.snapshot();
                assert!(
                    s.standby_promotions <= s.hot_swaps,
                    "snapshot split a promotion: {} promotions > {} swaps",
                    s.standby_promotions,
                    s.hot_swaps
                );
            }
            stop.store(true, std::sync::atomic::Ordering::Relaxed);
            writer.join().expect("writer");
        });
        let s = m.snapshot();
        assert_eq!(s.standby_promotions, s.hot_swaps);
    }

    /// The batch triple (batches, batched_requests, batch_ns) recorded
    /// under one guard keeps occupancy exact in every snapshot.
    #[test]
    fn concurrent_snapshot_sees_whole_batch_triples() {
        let m = Arc::new(ServeMetrics::new());
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        std::thread::scope(|scope| {
            let writer = {
                let (m, stop) = (Arc::clone(&m), Arc::clone(&stop));
                scope.spawn(move || {
                    use std::sync::atomic::Ordering;
                    while !stop.load(Ordering::Relaxed) {
                        let _g = m.grouped();
                        m.batches.inc();
                        m.batched_requests.add(4);
                        m.batch_ns.record(5_000);
                    }
                })
            };
            for _ in 0..2_000 {
                let s = m.snapshot();
                if s.batches > 0 {
                    assert!(
                        (s.mean_batch_occupancy - 4.0).abs() < 1e-9,
                        "occupancy {} from a torn batch triple",
                        s.mean_batch_occupancy
                    );
                }
            }
            stop.store(true, std::sync::atomic::Ordering::Relaxed);
            writer.join().expect("writer");
        });
    }
}
