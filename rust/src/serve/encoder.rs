//! Forward-only dual-tower CLIP encoder for serving.
//!
//! Built once at load time from [`crate::nn::TransformerBlock`]s whose
//! projection weights are immediately pre-quantized *and packed* into
//! the blocked tile-major layout ([`TransformerBlock::prepare`] →
//! [`crate::gemm::PreparedWeight::Packed`], DESIGN.md §GEMM) — serving
//! never pays the per-call weight quantize+pack that the training
//! forward does, never allocates a backward cache, and every int8
//! projection runs on the packed cache-blocked kernel with the next
//! quantize fused into the epilogue where the block wiring allows
//! (Q/K/V share one activation quantize; up-proj emits quantized GELU
//! output straight into down-proj).  Precision is pluggable exactly like
//! training
//! ([`LinearKind`]), so the `loadgen` sweep compares Standard (f32),
//! SwitchBack and LLM.int8() serving on identical weights: seeding is
//! kind-independent, so every kind encodes the *same* underlying f32
//! model.
//!
//! Tower shape (both towers): input projection / token embedding → N
//! pre-norm transformer blocks → mean-pool over the sequence → output
//! projection → L2 normalize.  This mirrors `python/compile/model.py`'s
//! dual tower at serving-friendly scale.

use crate::nn::Linear;
use crate::nn::{
    l2_normalize_rows, mean_pool_rows, LinearKind, PreparedBlock, PreparedLinear,
    TransformerBlock,
};
use crate::tensor::{Matrix, Rng};

/// Model shape + precision for the serving encoder.
#[derive(Debug, Clone)]
pub struct EncoderConfig {
    pub kind: LinearKind,
    /// transformer width (divisible by `heads`)
    pub dim: usize,
    pub heads: usize,
    /// blocks per tower
    pub blocks: usize,
    /// output embedding dimension
    pub embed_dim: usize,
    /// image tower: patches per image and raw patch width
    pub patches: usize,
    pub patch_dim: usize,
    /// text tower: tokens per caption and vocabulary size
    pub text_seq: usize,
    pub vocab: usize,
    pub seed: u64,
}

impl EncoderConfig {
    /// The default serving model: big enough that int8 vs f32 GEMM time
    /// dominates per-request overheads, small enough for CPU loadgen.
    pub fn demo(kind: LinearKind) -> Self {
        Self {
            kind,
            dim: 128,
            heads: 4,
            blocks: 2,
            embed_dim: 64,
            patches: 16,
            patch_dim: 64,
            text_seq: 16,
            vocab: 512,
            seed: 42,
        }
    }

    /// Expected `EncodeInput::Image` payload length.
    pub fn image_len(&self) -> usize {
        self.patches * self.patch_dim
    }

    /// Shape equality (kind and seed are free): a hot-swap or standby
    /// promotion may retrain or requantize the model, but never resize it
    /// — the serving shape is a boot-time contract.
    pub fn same_shape(&self, other: &EncoderConfig) -> bool {
        self.dim == other.dim
            && self.heads == other.heads
            && self.blocks == other.blocks
            && self.embed_dim == other.embed_dim
            && self.patches == other.patches
            && self.patch_dim == other.patch_dim
            && self.text_seq == other.text_seq
            && self.vocab == other.vocab
    }
}

/// One tower: input embedding → blocks → pooled output projection.
struct Tower {
    /// tokens per item this tower was built for
    seq: usize,
    blocks: Vec<PreparedBlock>,
    out_proj: PreparedLinear,
}

impl Tower {
    /// `x [B*seq, dim]` → L2-normalized `[B, embed_dim]` (pool + normalize
    /// via the shared `nn` helpers — the train model uses the same ones,
    /// which is what keeps train/serve encodings bit-identical).
    fn encode(&self, mut x: Matrix, dim: usize) -> Matrix {
        for (i, blk) in self.blocks.iter().enumerate() {
            // one span per transformer block: the 6 projection GEMMs +
            // attention/MLP glue, tagged with the layer index
            let _sp = crate::trace::span_n("serve.gemm_block", "serve", i as u32);
            x = blk.forward(&x);
        }
        let pooled = mean_pool_rows(&x, self.seq, dim);
        let mut emb = self.out_proj.forward(&pooled);
        l2_normalize_rows(&mut emb);
        emb
    }
}

/// Raw f32 model weights in the serving encoder's layout — the bridge
/// between a training checkpoint ([`crate::ckpt`]) and a live encoder.
/// Block matrices are in the canonical projection order
/// (`wq, wk, wv, wo, w1, w2`), matching the train model's param layout.
pub struct EncoderWeights {
    /// `[dim, patch_dim]`
    pub patch_embed: Matrix,
    /// `[vocab, dim]`
    pub tok_embed: Matrix,
    pub image_blocks: Vec<[Matrix; 6]>,
    /// `[embed_dim, dim]`
    pub image_out: Matrix,
    pub text_blocks: Vec<[Matrix; 6]>,
    /// `[embed_dim, dim]`
    pub text_out: Matrix,
}

/// The serving encoder: image + text towers with pre-quantized weights.
pub struct ClipEncoder {
    cfg: EncoderConfig,
    patch_embed: PreparedLinear,
    /// `[vocab, dim]` f32 token-embedding table (a lookup, not a matmul —
    /// quantizing it would buy nothing)
    tok_embed: Matrix,
    image_tower: Tower,
    text_tower: Tower,
}

impl ClipEncoder {
    /// Deterministic init from `cfg.seed`; weights are identical across
    /// precision kinds (the RNG stream does not depend on `kind`).
    pub fn new(cfg: EncoderConfig) -> Self {
        assert_eq!(cfg.dim % cfg.heads, 0, "dim must divide by heads");
        let mut rng = Rng::seed(cfg.seed);
        let patch_embed =
            Linear::new(cfg.dim, cfg.patch_dim, cfg.kind, &mut rng).prepare();
        let tok_embed = Matrix::randn(cfg.vocab, cfg.dim, 0.02, &mut rng);
        let build_tower = |seq: usize, rng: &mut Rng| -> Tower {
            let blocks = (0..cfg.blocks)
                .map(|_| {
                    TransformerBlock::new(cfg.dim, cfg.heads, seq, cfg.kind, rng)
                        .prepare()
                })
                .collect();
            let out_proj =
                Linear::new(cfg.embed_dim, cfg.dim, cfg.kind, rng).prepare();
            Tower { seq, blocks, out_proj }
        };
        let image_tower = build_tower(cfg.patches, &mut rng);
        let text_tower = build_tower(cfg.text_seq, &mut rng);
        Self { cfg, patch_embed, tok_embed, image_tower, text_tower }
    }

    /// Build an encoder from explicit f32 weights (a loaded checkpoint)
    /// instead of fresh seeds.  `cfg.kind` picks the serving quantization
    /// scheme applied to those weights — the same trained f32 master can
    /// serve as Standard, SwitchBack or LLM.int8().  Panics on shape
    /// mismatch (callers validate via [`crate::ckpt`] first).
    pub fn from_weights(cfg: EncoderConfig, w: EncoderWeights) -> Self {
        assert_eq!(cfg.dim % cfg.heads, 0, "dim must divide by heads");
        assert_eq!(w.image_blocks.len(), cfg.blocks, "image tower block count");
        assert_eq!(w.text_blocks.len(), cfg.blocks, "text tower block count");
        assert_eq!(
            (w.patch_embed.rows, w.patch_embed.cols),
            (cfg.dim, cfg.patch_dim),
            "patch_embed shape"
        );
        assert_eq!((w.tok_embed.rows, w.tok_embed.cols), (cfg.vocab, cfg.dim));
        let lin = |m: &Matrix| Linear { w: m.clone(), kind: cfg.kind }.prepare();
        let build_tower = |seq: usize, blocks: &[[Matrix; 6]], out: &Matrix| -> Tower {
            assert_eq!((out.rows, out.cols), (cfg.embed_dim, cfg.dim), "out_proj shape");
            // a dummy RNG seeds the scaffold block; every projection is
            // overwritten before prepare() quantizes anything
            let mut scaffold_rng = Rng::seed(0);
            let prepared = blocks
                .iter()
                .map(|mats| {
                    let mut blk = TransformerBlock::new(
                        cfg.dim,
                        cfg.heads,
                        seq,
                        cfg.kind,
                        &mut scaffold_rng,
                    );
                    for (dst, src) in blk.projections_mut().into_iter().zip(mats) {
                        assert_eq!(
                            (dst.w.rows, dst.w.cols),
                            (src.rows, src.cols),
                            "block projection shape"
                        );
                        dst.w = src.clone();
                    }
                    blk.prepare()
                })
                .collect();
            Tower { seq, blocks: prepared, out_proj: lin(out) }
        };
        let image_tower = build_tower(cfg.patches, &w.image_blocks, &w.image_out);
        let text_tower = build_tower(cfg.text_seq, &w.text_blocks, &w.text_out);
        Self {
            patch_embed: lin(&w.patch_embed),
            tok_embed: w.tok_embed,
            image_tower,
            text_tower,
            cfg,
        }
    }

    /// The shape/precision this encoder was built with.
    pub fn config(&self) -> &EncoderConfig {
        &self.cfg
    }

    /// Total resident weight bytes (int8 kinds ≈ 4× smaller than f32).
    pub fn weight_bytes(&self) -> usize {
        let towers: usize = self
            .image_tower
            .blocks
            .iter()
            .chain(&self.text_tower.blocks)
            .map(|b| b.weight_bytes())
            .sum();
        towers
            + self.patch_embed.weight_bytes()
            + self.image_tower.out_proj.weight_bytes()
            + self.text_tower.out_proj.weight_bytes()
            + self.tok_embed.data.len() * 4
    }

    /// Encode a micro-batch of images; each slice is `patches×patch_dim`
    /// floats.  Returns one L2-normalized `embed_dim` vector per image.
    pub fn encode_images(&self, batch: &[&[f32]]) -> Vec<Vec<f32>> {
        if batch.is_empty() {
            return vec![];
        }
        let (p, pd) = (self.cfg.patches, self.cfg.patch_dim);
        let mut x = Matrix::zeros(batch.len() * p, pd);
        for (i, img) in batch.iter().enumerate() {
            assert_eq!(img.len(), p * pd, "image payload length");
            x.data[i * p * pd..(i + 1) * p * pd].copy_from_slice(img);
        }
        let h = self.patch_embed.forward(&x);
        let emb = self.image_tower.encode(h, self.cfg.dim);
        split_rows(emb)
    }

    /// Encode a micro-batch of captions; each slice is `text_seq` token
    /// ids.  Returns one L2-normalized `embed_dim` vector per caption.
    pub fn encode_texts(&self, batch: &[&[i32]]) -> Vec<Vec<f32>> {
        if batch.is_empty() {
            return vec![];
        }
        let (t, d) = (self.cfg.text_seq, self.cfg.dim);
        let mut x = Matrix::zeros(batch.len() * t, d);
        for (i, toks) in batch.iter().enumerate() {
            assert_eq!(toks.len(), t, "caption token length");
            for (j, &tok) in toks.iter().enumerate() {
                let tok = tok.rem_euclid(self.cfg.vocab as i32) as usize;
                x.row_mut(i * t + j).copy_from_slice(self.tok_embed.row(tok));
            }
        }
        let emb = self.text_tower.encode(x, d);
        split_rows(emb)
    }
}

fn split_rows(m: Matrix) -> Vec<Vec<f32>> {
    (0..m.rows).map(|r| m.row(r).to_vec()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(kind: LinearKind) -> EncoderConfig {
        EncoderConfig {
            kind,
            dim: 16,
            heads: 2,
            blocks: 2,
            embed_dim: 8,
            patches: 4,
            patch_dim: 12,
            text_seq: 5,
            vocab: 64,
            seed: 7,
        }
    }

    fn cosine(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    #[test]
    fn embeddings_are_unit_norm_and_deterministic() {
        let enc = ClipEncoder::new(tiny(LinearKind::SwitchBack));
        let mut rng = Rng::seed(1);
        let img: Vec<f32> = (0..48).map(|_| rng.normal()).collect();
        let toks: Vec<i32> = (0..5).map(|i| i * 3).collect();
        let e1 = enc.encode_images(&[&img]);
        let e2 = enc.encode_images(&[&img]);
        assert_eq!(e1, e2, "deterministic");
        let n: f32 = e1[0].iter().map(|v| v * v).sum::<f32>();
        assert!((n - 1.0).abs() < 1e-4, "unit norm, got {n}");
        let t = enc.encode_texts(&[&toks]);
        assert_eq!(t[0].len(), 8);
        let nt: f32 = t[0].iter().map(|v| v * v).sum::<f32>();
        assert!((nt - 1.0).abs() < 1e-4);
    }

    #[test]
    fn batch_composition_does_not_change_embeddings() {
        let enc = ClipEncoder::new(tiny(LinearKind::SwitchBack));
        let mut rng = Rng::seed(2);
        let a: Vec<f32> = (0..48).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..48).map(|_| rng.normal()).collect();
        let solo = enc.encode_images(&[&a]);
        let both = enc.encode_images(&[&a, &b]);
        assert_eq!(solo[0], both[0], "item embedding independent of batch");
    }

    #[test]
    fn int8_kinds_track_the_f32_model() {
        // identical seed → identical underlying weights, so the embedding
        // difference is pure quantization noise (the paper's 0.1pp story).
        let std_enc = ClipEncoder::new(tiny(LinearKind::Standard));
        let sb_enc = ClipEncoder::new(tiny(LinearKind::SwitchBack));
        let llm_enc = ClipEncoder::new(tiny(LinearKind::LlmInt8));
        let mut rng = Rng::seed(3);
        for _ in 0..8 {
            let img: Vec<f32> = (0..48).map(|_| rng.normal()).collect();
            let e_std = &std_enc.encode_images(&[&img])[0];
            let e_sb = &sb_enc.encode_images(&[&img])[0];
            let e_llm = &llm_enc.encode_images(&[&img])[0];
            assert!(cosine(e_std, e_sb) > 0.98, "switchback drifted");
            assert!(cosine(e_std, e_llm) > 0.95, "llmint8 drifted");
        }
    }

    #[test]
    fn int8_weights_are_quartered() {
        let std_b = ClipEncoder::new(tiny(LinearKind::Standard)).weight_bytes();
        let sb_b = ClipEncoder::new(tiny(LinearKind::SwitchBack)).weight_bytes();
        assert!(sb_b < std_b, "int8 must be smaller ({sb_b} vs {std_b})");
        // block weights dominate; the f32 token table is shared overhead
        let table = 64 * 16 * 4;
        assert!((std_b - table) > 3 * (sb_b - table), "≈4× on the matmul weights");
    }

    #[test]
    fn text_tokens_wrap_into_vocab() {
        let enc = ClipEncoder::new(tiny(LinearKind::Standard));
        let toks_a: Vec<i32> = vec![0, 1, 2, 3, 4];
        let toks_b: Vec<i32> = vec![64, 65, 66, 67, 68]; // same mod vocab
        let ea = enc.encode_texts(&[&toks_a]);
        let eb = enc.encode_texts(&[&toks_b]);
        assert_eq!(ea, eb);
    }
}
