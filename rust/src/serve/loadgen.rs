//! Closed-loop load generator for the serving engine.
//!
//! `concurrency` client threads share a global request counter; each
//! claims the next request id, maps it onto a fixed synthetic population
//! of inputs, and issues a blocking `Engine::encode` (closed loop: a
//! client never has more than one request in flight, so offered load
//! scales with concurrency — the standard serving-benchmark shape).
//!
//! Request `i` targets `population[i % population]`, so with
//! `population < requests` the first cycle is all cache misses and every
//! later cycle is all hits: the hit rate is deterministic
//! (`1 − population/requests`) and the throughput ratio between precision
//! kinds stays dominated by encode work, which is what the
//! Standard-vs-SwitchBack acceptance ratio measures.
//!
//! Results are written to `BENCH_serve.json` (machine-readable, one entry
//! per kind×concurrency) so the perf trajectory is tracked across PRs.
//!
//! [`run_loadgen_socket`] is the same closed loop over real TCP: each
//! client thread owns a persistent [`EncodeClient`] against a bound
//! `serve --listen` front door, explicit admission sheds (`429`/`503`)
//! land in a client-side `rejected` counter, and the report's entry is
//! tagged `"socket":true` (plus `"overload":true` for the
//! deliberately-over-window run) so benchdiff gates the wire path
//! separately from the in-process path.

use super::encoder::{ClipEncoder, EncoderConfig};
use super::engine::Engine;
use super::frontend::{EncodeClient, SocketOutcome};
use super::metrics::{ServeMetrics, ServeSnapshot};
use super::standby::{validate_and_promote, CanarySet};
use super::EncodeInput;
use crate::net::http_get;
use crate::tensor::Rng;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// One loadgen run's knobs.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    pub requests: usize,
    pub concurrency: usize,
    /// distinct inputs in the synthetic population
    pub population: usize,
    /// fraction of the population that is images (rest are captions)
    pub image_fraction: f32,
    pub seed: u64,
    /// install a freshly prepared encoder generation every N issued
    /// requests (0 = no swaps).  Swaps go through the standby
    /// promote path ([`validate_and_promote`], drift bound disabled —
    /// the generations are intentionally unrelated), so the reported
    /// tail latency is measured *across* repeated generations and the
    /// promotions land in the snapshot's standby counters.
    pub swap_every: usize,
    /// scrape `scrape_url` every N ms from a rider thread while the
    /// closed loop runs (0 = no scraper).  The report gains scrape
    /// counts and the p99 scrape latency, so BENCH_serve.json can gate
    /// "a concurrent scraper neither fails nor moves the serve tail".
    pub scrape_every_ms: u64,
    /// `/metrics` URL the scraper hits (required when `scrape_every_ms`
    /// is nonzero)
    pub scrape_url: Option<String>,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        Self {
            requests: 2000,
            concurrency: 32,
            population: 1000,
            image_fraction: 0.7,
            seed: 1234,
            swap_every: 0,
            scrape_every_ms: 0,
            scrape_url: None,
        }
    }
}

/// Outcome of one run against one engine.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    /// precision label of the engine under test
    pub kind: String,
    pub concurrency: usize,
    pub requests: usize,
    /// swap cadence of the run (0 = single-generation run)
    pub swap_every: usize,
    pub wall_secs: f64,
    pub requests_per_sec: f64,
    pub errors: u64,
    /// scrape cadence of the run in ms (0 = no scraper attached)
    pub scrape_every_ms: u64,
    /// well-formed `/metrics` scrapes completed by the rider thread
    pub scrapes: u64,
    /// scrapes that failed or returned a malformed exposition
    pub scrape_errors: u64,
    /// p99 scrape latency in µs (0.0 when no scraper)
    pub scrape_p99_us: f64,
    /// true when the run went over real TCP through the front door (the
    /// snapshot is then the *client-side* ledger, not an engine's)
    pub socket: bool,
    /// true for the deliberate-overload socket run: concurrency beyond
    /// the server's admission window, expecting explicit `429` sheds
    pub overload: bool,
    pub snapshot: ServeSnapshot,
}

impl LoadgenReport {
    /// Human-readable per-run summary (plus swap metrics when enabled).
    pub fn print(&self) {
        println!(
            "[{:<12}] c={:<3} {:>7} reqs in {:>7.2}s  →  {:>8.1} req/s",
            self.kind, self.concurrency, self.requests, self.wall_secs,
            self.requests_per_sec
        );
        self.snapshot.print(&self.kind);
        if self.swap_every > 0 {
            println!(
                "  [{}] swap-every {}: {} promotions across generations \
                 (swap-pause p99 {:.1} µs, prepare p99 {:.2} ms)",
                self.kind,
                self.swap_every,
                self.snapshot.standby_promotions,
                self.snapshot.swap_pause_p99_us,
                self.snapshot.prepare_p99_ms,
            );
        }
        if self.scrape_every_ms > 0 {
            println!(
                "  [{}] scrape-every {} ms: {} scrapes, {} errors, \
                 scrape p99 {:.1} µs",
                self.kind,
                self.scrape_every_ms,
                self.scrapes,
                self.scrape_errors,
                self.scrape_p99_us,
            );
        }
        if self.socket {
            println!(
                "  [{}] socket{}: {} explicit 429/503 sheds, {} errors",
                self.kind,
                if self.overload { " overload" } else { "" },
                self.snapshot.rejected,
                self.errors,
            );
        }
    }
}

/// Build the deterministic input population for an engine's model shape.
pub fn build_population(engine: &Engine, cfg: &LoadgenConfig) -> Vec<EncodeInput> {
    build_population_for(engine.encoder_config(), cfg)
}

/// [`build_population`] from a bare shape — the socket path has no local
/// [`Engine`], only the server's advertised [`EncoderConfig`].
pub fn build_population_for(enc: &EncoderConfig, cfg: &LoadgenConfig) -> Vec<EncodeInput> {
    let rng = Rng::seed(cfg.seed);
    let n_images =
        ((cfg.population as f32 * cfg.image_fraction) as usize).min(cfg.population);
    (0..cfg.population)
        .map(|i| {
            let mut r = rng.fork(i as u64);
            if i < n_images {
                let px =
                    (0..enc.image_len()).map(|_| r.normal()).collect::<Vec<f32>>();
                EncodeInput::Image(px)
            } else {
                let toks = (0..enc.text_seq)
                    .map(|_| r.below(enc.vocab) as i32)
                    .collect::<Vec<i32>>();
                EncodeInput::Text(toks)
            }
        })
        .collect()
}

/// How many generations a `swap_every` run promotes by the time `issued`
/// requests have been claimed.  Promotions fire at the *midpoint* of
/// each window (issued = s/2, 3s/2, 5s/2, …) so every one of them lands
/// while traffic is still flowing — scheduling them at window *ends*
/// would push the final promotion past the last request.  For a whole
/// run this is `planned_swaps(requests, s)` — exactly `requests/s` when
/// `s` divides `requests` (the verify.sh configuration).
pub fn planned_swaps(issued: usize, swap_every: usize) -> usize {
    if swap_every == 0 {
        return 0;
    }
    (issued + swap_every / 2) / swap_every
}

/// Run one closed-loop sweep against a started engine.  With
/// `swap_every > 0` a swapper thread rides along: every N issued
/// requests it prepares a fresh same-shape encoder generation and
/// promotes it through the standby path, so the report's latency
/// percentiles span repeated hot-swaps instead of one static generation.
pub fn run_loadgen(engine: &Engine, cfg: &LoadgenConfig) -> LoadgenReport {
    assert!(cfg.population > 0, "population must be positive");
    assert!(
        cfg.scrape_every_ms == 0 || cfg.scrape_url.is_some(),
        "scrape_every_ms needs scrape_url"
    );
    let population = Arc::new(build_population(engine, cfg));
    let next = Arc::new(AtomicUsize::new(0));
    let errors = Arc::new(AtomicU64::new(0));
    let scrape_lat = Mutex::new(Vec::<u64>::new());
    let scrape_errors = AtomicU64::new(0);
    let t0 = crate::trace::clock();
    std::thread::scope(|s| {
        for _ in 0..cfg.concurrency.max(1) {
            let population = Arc::clone(&population);
            let next = Arc::clone(&next);
            let errors = Arc::clone(&errors);
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cfg.requests {
                    return;
                }
                let Some(input) = population.get(i % population.len().max(1)) else {
                    errors.fetch_add(1, Ordering::Relaxed);
                    continue;
                };
                if engine.encode(input.clone()).is_err() {
                    errors.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
        if cfg.swap_every > 0 {
            let next = Arc::clone(&next);
            s.spawn(move || {
                let canary = CanarySet::build(engine.encoder_config(), 4, cfg.seed ^ 0xCA9A);
                let mut generation = 0usize;
                loop {
                    // the shared counter overshoots by up to `concurrency`
                    // (claim first, bounds-check after) — clamp it
                    let issued = next.load(Ordering::Relaxed).min(cfg.requests);
                    // every generation that is *due* at the current issue
                    // count gets promoted (mid-window cadence, see
                    // `planned_swaps`), even if the clients outran the
                    // swapper — a run always ends with
                    // planned_swaps(requests, swap_every) promotions,
                    // deterministically
                    if generation < planned_swaps(issued, cfg.swap_every) {
                        // prepare off the request path: fresh weights,
                        // same shape contract, canary-checked for
                        // finiteness (no drift bound — generations are
                        // unrelated by design)
                        let prep_t0 = crate::trace::clock();
                        let mut ec = engine.encoder_config().clone();
                        ec.seed = cfg.seed ^ (0x5AB0 + generation as u64);
                        let candidate = ClipEncoder::new(ec);
                        match validate_and_promote(
                            engine, candidate, &canary, None, prep_t0,
                        ) {
                            Ok(_) => generation += 1,
                            Err(e) => {
                                // a failed install is persistent (lock
                                // poisoned / non-finite weights): stop
                                // swapping and let the shortfall in
                                // standby_promotions (+ the recorded
                                // reject) fail the run's gates
                                eprintln!(
                                    "loadgen swapper: promotion of \
                                     generation {generation} failed: {e}"
                                );
                                return;
                            }
                        }
                        continue;
                    }
                    if issued >= cfg.requests {
                        return;
                    }
                    std::thread::sleep(std::time::Duration::from_micros(200));
                }
            });
        }
        if let Some(url) =
            cfg.scrape_url.clone().filter(|_| cfg.scrape_every_ms > 0)
        {
            let next = Arc::clone(&next);
            let (lat, errs) = (&scrape_lat, &scrape_errors);
            s.spawn(move || {
                // one scrape happens before the exit check, so even a
                // run the clients finish instantly records `scrapes ≥ 1`
                loop {
                    let st0 = crate::trace::clock();
                    match http_get(&url, Duration::from_secs(5)) {
                        Ok(resp) if resp.is_ok() && exposition_well_formed(&resp.body) => {
                            lat.lock()
                                .unwrap_or_else(|e| e.into_inner())
                                .push(st0.elapsed().as_micros() as u64);
                        }
                        _ => {
                            errs.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    if next.load(Ordering::Relaxed) >= cfg.requests {
                        return;
                    }
                    std::thread::sleep(Duration::from_millis(cfg.scrape_every_ms));
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let mut lat = scrape_lat.into_inner().unwrap_or_else(|e| e.into_inner());
    LoadgenReport {
        kind: engine.kind_label().to_string(),
        concurrency: cfg.concurrency,
        requests: cfg.requests,
        swap_every: cfg.swap_every,
        wall_secs: wall,
        requests_per_sec: cfg.requests as f64 / wall.max(1e-9),
        errors: errors.load(Ordering::Relaxed),
        scrape_every_ms: cfg.scrape_every_ms,
        scrapes: lat.len() as u64,
        scrape_errors: scrape_errors.load(Ordering::Relaxed),
        scrape_p99_us: p99_us(&mut lat),
        socket: false,
        overload: false,
        snapshot: engine.metrics().snapshot(),
    }
}

/// Run one closed-loop sweep over real TCP against a bound front door.
///
/// Each of `concurrency` threads owns a persistent [`EncodeClient`]
/// (keep-alive, transparent reconnect when the server's per-connection
/// request cap closes the socket) and drives the same deterministic
/// population as the in-process path — same seed, same draws, so the
/// doc→engine affinity is identical across both.  The report's snapshot
/// is a *client-side* ledger: explicit admission sheds (`429`/`503`)
/// count in `rejected` (bounded queues working as designed), while
/// transport failures and unexpected statuses count as request `errors`.
/// `overload` labels the run for the benchdiff gate; the caller picks a
/// concurrency beyond the server's admission window to earn it.
pub fn run_loadgen_socket(
    addr: &str,
    kind: &str,
    enc: &EncoderConfig,
    cfg: &LoadgenConfig,
    overload: bool,
) -> Result<LoadgenReport, String> {
    assert!(cfg.population > 0, "population must be positive");
    // Fail fast on an unresolvable address before spawning the fleet.
    EncodeClient::connect(addr, Duration::from_secs(5))?;
    let population = build_population_for(enc, cfg);
    let next = AtomicUsize::new(0);
    let errors = AtomicU64::new(0);
    let metrics = ServeMetrics::new();
    let t0 = crate::trace::clock();
    std::thread::scope(|s| {
        for _ in 0..cfg.concurrency.max(1) {
            let (population, next, errors, metrics) =
                (&population, &next, &errors, &metrics);
            s.spawn(move || {
                let Ok(mut client) = EncodeClient::connect(addr, Duration::from_secs(5))
                else {
                    errors.fetch_add(1, Ordering::Relaxed);
                    return;
                };
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= cfg.requests {
                        return;
                    }
                    let Some(input) = population.get(i % population.len().max(1))
                    else {
                        errors.fetch_add(1, Ordering::Relaxed);
                        continue;
                    };
                    metrics.requests.inc();
                    let rt0 = crate::trace::clock();
                    match client.encode(input) {
                        Ok(SocketOutcome::Ok { cache_hit, .. }) => {
                            metrics.request_ns.record(rt0.elapsed().as_nanos() as u64);
                            if cache_hit {
                                metrics.cache_hits.inc();
                            } else {
                                metrics.cache_misses.inc();
                            }
                        }
                        Ok(SocketOutcome::Rejected(_)) => metrics.rejected.inc(),
                        Err(_) => {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    Ok(LoadgenReport {
        kind: kind.to_string(),
        concurrency: cfg.concurrency,
        requests: cfg.requests,
        swap_every: 0,
        wall_secs: wall,
        requests_per_sec: cfg.requests as f64 / wall.max(1e-9),
        errors: errors.load(Ordering::Relaxed),
        scrape_every_ms: 0,
        scrapes: 0,
        scrape_errors: 0,
        scrape_p99_us: 0.0,
        socket: true,
        overload,
        snapshot: metrics.snapshot(),
    })
}

/// A minimal wire-validity check on one `/metrics` body: every
/// non-comment line is exactly `name value`.  The scraper counts a
/// malformed exposition as an error, so the benchdiff gate
/// (`scrape_errors == 0`) asserts *parseable* scrapes, not just 200s.
fn exposition_well_formed(body: &str) -> bool {
    !body.is_empty()
        && body
            .lines()
            .filter(|l| !l.starts_with('#') && !l.is_empty())
            .all(|l| l.split(' ').count() == 2)
}

/// p99 over raw µs samples (sorts in place; 0.0 when empty).
fn p99_us(lat: &mut [u64]) -> f64 {
    if lat.is_empty() {
        return 0.0;
    }
    lat.sort_unstable();
    let idx = ((lat.len() as f64) * 0.99).ceil() as usize;
    lat.get(idx.clamp(1, lat.len()) - 1).copied().unwrap_or(0) as f64
}

/// Write `BENCH_serve.json`: machine-readable perf trajectory artifact.
pub fn write_bench_json(
    path: &str,
    max_batch: usize,
    max_wait_us: u64,
    reports: &[LoadgenReport],
) -> std::io::Result<()> {
    use crate::util::json::{quote, ObjWriter};
    let mut entries = Vec::with_capacity(reports.len());
    for r in reports {
        let mut w = ObjWriter::new();
        w.field_str("kind", &r.kind)
            .field_u64("concurrency", r.concurrency as u64)
            .field_u64("requests", r.requests as u64);
        if r.swap_every > 0 {
            w.field_u64("swap_every", r.swap_every as u64);
        }
        if r.scrape_every_ms > 0 {
            w.field_u64("scrape_every_ms", r.scrape_every_ms)
                .field_u64("scrapes", r.scrapes)
                .field_u64("scrape_errors", r.scrape_errors)
                .field_f32("scrape_p99_us", r.scrape_p99_us as f32);
        }
        if r.socket {
            w.field_bool("socket", true);
            if r.overload {
                w.field_bool("overload", true);
            }
        }
        w.field_f32("wall_secs", r.wall_secs as f32)
            .field_f32("requests_per_sec", r.requests_per_sec as f32)
            .field_u64("errors", r.errors)
            .field_raw("metrics", &r.snapshot.to_json());
        entries.push(w.finish());
    }
    let mut top = ObjWriter::new();
    top.field_str("bench", "serve_throughput")
        .field_raw(
            "policy",
            &format!(
                "{{\"max_batch\":{max_batch},\"max_wait_us\":{max_wait_us}}}"
            ),
        )
        .field_raw("results", &format!("[{}]", entries.join(",")));
    let doc = top.finish();
    // sanity: the artifact must stay parseable by the in-tree parser
    debug_assert!(crate::util::json::parse(&doc).is_ok(), "invalid {}", quote(path));
    std::fs::write(path, doc + "\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::LinearKind;
    use crate::serve::engine::ServeConfig;
    use crate::serve::EncoderConfig;
    use crate::serve::batcher::BatchPolicy;
    use crate::util::json::parse;
    use std::time::Duration;

    fn tiny_engine(cache: usize) -> Engine {
        Engine::start(ServeConfig {
            encoder: EncoderConfig {
                kind: LinearKind::SwitchBack,
                dim: 16,
                heads: 2,
                blocks: 1,
                embed_dim: 8,
                patches: 4,
                patch_dim: 12,
                text_seq: 5,
                vocab: 64,
                seed: 3,
            },
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
            workers: 2,
            cache_capacity: cache,
            cache_shards: 2,
        })
    }

    #[test]
    fn deterministic_hit_rate_from_population_cycling() {
        let eng = tiny_engine(4096);
        let cfg = LoadgenConfig {
            requests: 120,
            concurrency: 6,
            population: 40,
            image_fraction: 0.5,
            seed: 9,
            swap_every: 0,
            ..LoadgenConfig::default()
        };
        let rep = run_loadgen(&eng, &cfg);
        assert_eq!(rep.errors, 0);
        assert_eq!(rep.snapshot.requests, 120);
        // ≥ 2/3 of requests revisit the population; allow slack for the
        // race where a repeat arrives before its first copy finished
        assert!(
            rep.snapshot.hit_rate > 0.5,
            "expected mostly hits, got {}",
            rep.snapshot.hit_rate
        );
        assert!(rep.requests_per_sec > 0.0);
        eng.shutdown();
    }

    #[test]
    fn bench_json_is_parseable_and_complete() {
        let eng = tiny_engine(64);
        let cfg = LoadgenConfig {
            requests: 30,
            concurrency: 3,
            population: 10,
            image_fraction: 1.0,
            seed: 2,
            swap_every: 0,
            ..LoadgenConfig::default()
        };
        let rep = run_loadgen(&eng, &cfg);
        let path = std::env::temp_dir().join("bench_serve_test.json");
        let path = path.to_str().unwrap().to_string();
        write_bench_json(&path, 8, 1000, &[rep]).unwrap();
        let doc = std::fs::read_to_string(&path).unwrap();
        let v = parse(&doc).unwrap();
        assert_eq!(v.get("bench").unwrap().as_str(), Some("serve_throughput"));
        let results = v.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        let r0 = &results[0];
        assert_eq!(r0.get("kind").unwrap().as_str(), Some("switchback"));
        assert!(r0.get("requests_per_sec").unwrap().as_f64().unwrap() > 0.0);
        let m = r0.get("metrics").unwrap();
        assert!(m.get("hit_rate").is_some());
        assert!(m.get("request_p99_ms").is_some());
        let _ = std::fs::remove_file(&path);
        eng.shutdown();
    }

    /// `swap_every`: generations advance mid-run through the standby
    /// promote path, every request still succeeds, and the swap metrics
    /// land in the report + JSON entry.
    #[test]
    fn swap_every_promotes_generations_without_dropping_requests() {
        let eng = tiny_engine(4096);
        let cfg = LoadgenConfig {
            requests: 300,
            concurrency: 4,
            population: 50,
            image_fraction: 0.5,
            seed: 11,
            swap_every: 100,
            ..LoadgenConfig::default()
        };
        let rep = run_loadgen(&eng, &cfg);
        assert_eq!(rep.errors, 0, "swaps must not fail requests");
        // every due generation is promoted even if the clients outrun the
        // swapper: planned_swaps(300, 100) = 3, at issue counts 50/150/250
        assert_eq!(planned_swaps(300, 100), 3);
        assert_eq!(planned_swaps(1000, 250), 4, "the verify.sh shape");
        assert_eq!(planned_swaps(0, 100), 0);
        assert_eq!(planned_swaps(100, 0), 0);
        assert_eq!(rep.snapshot.standby_promotions, 3);
        assert_eq!(rep.snapshot.standby_promotions, rep.snapshot.hot_swaps);
        assert_eq!(rep.snapshot.standby_rejects, 0);
        assert_eq!(eng.generation(), 3);
        let path = std::env::temp_dir().join("bench_serve_swap_test.json");
        let path = path.to_str().unwrap().to_string();
        write_bench_json(&path, 8, 1000, &[rep]).unwrap();
        let doc = std::fs::read_to_string(&path).unwrap();
        let v = parse(&doc).unwrap();
        let r0 = &v.get("results").unwrap().as_arr().unwrap()[0];
        assert_eq!(r0.get("swap_every").unwrap().as_usize(), Some(100));
        let m = r0.get("metrics").unwrap();
        assert!(m.get("standby_promotions").unwrap().as_usize().unwrap() >= 1);
        assert!(m.get("swap_pause_p99_us").is_some());
        let _ = std::fs::remove_file(&path);
        eng.shutdown();
    }

    /// The scraper-present run: a rider thread scrapes a real localhost
    /// `/metrics` plane over the engine under test while the closed loop
    /// runs, every scrape is well-formed, and the scrape latency stats
    /// land in the report + JSON entry (the benchdiff gate's inputs).
    #[test]
    fn scraper_rides_along_and_records_latency() {
        use crate::trace::{Readiness, TelemetryConfig, TelemetryServer};
        use std::sync::Arc;
        let eng = Arc::new(tiny_engine(4096));
        let snap_eng = Arc::clone(&eng);
        let mut srv = TelemetryServer::bind(
            "127.0.0.1:0",
            TelemetryConfig {
                mode: "serve",
                snapshot: Arc::new(move || snap_eng.metrics().registry().snapshot()),
                ready: Arc::new(|| Readiness::new(true)),
                flight: None,
                http: Default::default(),
            },
        )
        .expect("bind telemetry");
        let cfg = LoadgenConfig {
            requests: 200,
            concurrency: 4,
            population: 50,
            image_fraction: 0.5,
            seed: 21,
            scrape_every_ms: 1,
            scrape_url: Some(format!("{}/metrics", srv.url())),
            ..LoadgenConfig::default()
        };
        let rep = run_loadgen(&eng, &cfg);
        assert_eq!(rep.errors, 0);
        assert!(rep.scrapes >= 1, "rider must complete at least one scrape");
        assert_eq!(rep.scrape_errors, 0, "every scrape must be well-formed");
        assert!(rep.scrape_p99_us > 0.0);
        let path = std::env::temp_dir().join("bench_serve_scrape_test.json");
        let path = path.to_str().unwrap().to_string();
        write_bench_json(&path, 8, 1000, &[rep]).unwrap();
        let doc = std::fs::read_to_string(&path).unwrap();
        let r0 = &parse(&doc).unwrap().get("results").unwrap().as_arr().unwrap()[0];
        assert_eq!(r0.get("scrape_every_ms").unwrap().as_usize(), Some(1));
        assert!(r0.get("scrapes").unwrap().as_usize().unwrap() >= 1);
        assert_eq!(r0.get("scrape_errors").unwrap().as_usize(), Some(0));
        assert!(r0.get("scrape_p99_us").unwrap().as_f64().unwrap() > 0.0);
        let _ = std::fs::remove_file(&path);
        srv.shutdown();
        drop(eng); // Engine::drop joins the worker pool
    }

    #[test]
    fn p99_and_exposition_checks() {
        assert_eq!(p99_us(&mut []), 0.0);
        assert_eq!(p99_us(&mut [7]), 7.0);
        let mut lat: Vec<u64> = (1..=100).collect();
        assert_eq!(p99_us(&mut lat), 99.0);
        assert!(exposition_well_formed("# HELP x\na_total 1\nb 2.5"));
        assert!(!exposition_well_formed(""));
        assert!(!exposition_well_formed("torn line with spaces"));
    }

    /// The socket path: a real front door over a 2-engine router, driven
    /// by `run_loadgen_socket` — zero request errors, the client-side
    /// ledger accounts for every request, and the JSON entry is tagged
    /// `"socket":true` for the benchdiff comparator.
    #[test]
    fn socket_loadgen_round_trips_through_a_real_front_door() {
        use crate::serve::frontend::{Frontend, FrontendConfig};
        use crate::serve::router::Router;
        use std::sync::Arc;
        let router = Arc::new(Router::start(
            ServeConfig {
                encoder: EncoderConfig {
                    kind: LinearKind::SwitchBack,
                    dim: 16,
                    heads: 2,
                    blocks: 1,
                    embed_dim: 8,
                    patches: 4,
                    patch_dim: 12,
                    text_seq: 5,
                    vocab: 64,
                    seed: 3,
                },
                policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
                workers: 2,
                cache_capacity: 4096,
                cache_shards: 2,
            },
            2,
        ));
        let fe = Frontend::bind(
            "127.0.0.1:0",
            Arc::clone(&router),
            FrontendConfig::default(),
        )
        .unwrap();
        let cfg = LoadgenConfig {
            requests: 120,
            concurrency: 4,
            population: 40,
            image_fraction: 0.5,
            seed: 9,
            ..LoadgenConfig::default()
        };
        let rep = run_loadgen_socket(
            &fe.local_addr().to_string(),
            router.kind_label(),
            router.encoder_config(),
            &cfg,
            false,
        )
        .unwrap();
        assert_eq!(rep.errors, 0, "clean run must see zero request errors");
        assert!(rep.socket && !rep.overload);
        // Client-side ledger balances: every claimed request was either
        // answered or explicitly shed (none expected here: closed-loop
        // in-flight of 4 is far under the default admission window).
        assert_eq!(rep.snapshot.requests, 120);
        assert_eq!(rep.snapshot.rejected, 0);
        assert_eq!(
            rep.snapshot.cache_hits + rep.snapshot.cache_misses,
            rep.snapshot.requests
        );
        assert!(rep.snapshot.hit_rate > 0.5, "population cycles must hit");
        // Server-side view agrees across the fleet: requests fan out to
        // both engines and nothing was shed.
        let server_reqs: u64 = router
            .engines()
            .iter()
            .map(|e| e.metrics().snapshot().requests)
            .sum();
        assert_eq!(server_reqs, 120);
        for e in router.engines() {
            assert!(e.metrics().snapshot().requests > 0, "both engines served");
            assert_eq!(e.metrics().snapshot().rejected, 0);
        }
        let path = std::env::temp_dir().join("bench_serve_socket_test.json");
        let path = path.to_str().unwrap().to_string();
        write_bench_json(&path, 8, 1000, &[rep]).unwrap();
        let doc = std::fs::read_to_string(&path).unwrap();
        let r0 = &parse(&doc).unwrap().get("results").unwrap().as_arr().unwrap()[0];
        assert_eq!(r0.get("socket").unwrap().as_bool(), Some(true));
        assert!(r0.get("overload").is_none(), "clean run is not tagged overload");
        assert_eq!(r0.get("errors").unwrap().as_usize(), Some(0));
        assert!(r0.get("metrics").unwrap().get("rejected").is_some());
        let _ = std::fs::remove_file(&path);
    }

    /// The overload tag rides into the JSON entry (the benchdiff gate
    /// keys socket entries on it and requires `rejected ≥ 1` there).
    #[test]
    fn overload_tag_is_emitted_for_overload_socket_reports() {
        let eng = tiny_engine(64);
        let cfg = LoadgenConfig {
            requests: 20,
            concurrency: 2,
            population: 10,
            ..LoadgenConfig::default()
        };
        let mut rep = run_loadgen(&eng, &cfg);
        rep.socket = true;
        rep.overload = true;
        let path = std::env::temp_dir().join("bench_serve_overload_tag_test.json");
        let path = path.to_str().unwrap().to_string();
        write_bench_json(&path, 8, 1000, &[rep]).unwrap();
        let doc = std::fs::read_to_string(&path).unwrap();
        let r0 = &parse(&doc).unwrap().get("results").unwrap().as_arr().unwrap()[0];
        assert_eq!(r0.get("socket").unwrap().as_bool(), Some(true));
        assert_eq!(r0.get("overload").unwrap().as_bool(), Some(true));
        let _ = std::fs::remove_file(&path);
        eng.shutdown();
    }

    #[test]
    fn population_mixes_modalities() {
        let eng = tiny_engine(0);
        let cfg = LoadgenConfig {
            requests: 1,
            concurrency: 1,
            population: 10,
            image_fraction: 0.5,
            seed: 4,
            swap_every: 0,
            ..LoadgenConfig::default()
        };
        let pop = build_population(&eng, &cfg);
        let imgs = pop.iter().filter(|p| p.is_image()).count();
        assert_eq!(imgs, 5);
        assert_eq!(pop.len(), 10);
        eng.shutdown();
    }
}
