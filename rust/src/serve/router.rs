//! Multi-engine fan-out with hash-affinity routing — the layer between
//! the network front door ([`super::frontend`]) and N [`Engine`]
//! instances (DESIGN.md §Network-front-door).
//!
//! **Affinity.** Every request carries a doc identity — the FNV-1a
//! [`EncodeInput::content_hash`] the cache already keys on — and
//! [`engine_index`] maps it to `hash % N`.  The mapping is pure and
//! stable for a fixed fleet size, so repeated requests for one doc
//! always land on the same engine and its sharded LRU stays hot; with
//! per-engine caches there is no cross-engine invalidation protocol to
//! get wrong, because no doc ever has cache entries on two engines.
//!
//! **Shedding, not silent loss.** The router never re-routes around a
//! dead engine: an engine whose queue is closed sheds the request
//! deterministically (`"engine is shut down"`, counted in that engine's
//! `rejected` counter) and the caller sees the error.  Re-routing would
//! silently move docs to cold caches and make the failure mode
//! load-dependent; explicit shed keeps `ok + rejected` exactly equal to
//! requests routed, which the chaos test pins.
//!
//! **Promotion.** One standby watcher validates each snapshot once and
//! installs it across the whole fleet
//! ([`super::standby::validate_and_promote_all`] /
//! [`super::standby::spawn_fanout`]); [`Router::generation_agreement`]
//! is the post-promotion invariant — every engine serves the same
//! generation, or the router reports itself unready.

use super::encoder::EncoderConfig;
use super::engine::{EncodeResult, Engine, ServeConfig};
use super::EncodeInput;
use std::sync::Arc;

/// Stable doc→engine affinity: `doc_hash % n`.  Pure so tests can pin
/// the mapping; `n` is clamped to at least 1.
pub fn engine_index(doc_hash: u64, n: usize) -> usize {
    (doc_hash % n.max(1) as u64) as usize
}

/// N engines behind one routing function.  Dropping the router drops
/// the engines (each shuts down on its last `Arc`).
pub struct Router {
    engines: Vec<Arc<Engine>>,
}

impl Router {
    /// Boot `n` engines from one config.  Each engine seeds its encoder
    /// from the same `cfg.encoder`, so the fleet starts weight-identical
    /// at generation 0.
    pub fn start(cfg: ServeConfig, n: usize) -> Router {
        let engines = (0..n.max(1))
            .map(|_| Arc::new(Engine::start(cfg.clone())))
            .collect();
        Router { engines }
    }

    /// Wrap already-running engines (checkpoint boots build each engine
    /// with `Engine::start_with_encoder` first).
    pub fn from_engines(engines: Vec<Arc<Engine>>) -> Router {
        assert!(!engines.is_empty(), "router needs at least one engine");
        Router { engines }
    }

    /// The fleet, primary (index 0) first.
    pub fn engines(&self) -> &[Arc<Engine>] {
        &self.engines
    }

    /// Fleet size.
    pub fn len(&self) -> usize {
        self.engines.len()
    }

    /// Always false — construction requires at least one engine.
    pub fn is_empty(&self) -> bool {
        self.engines.is_empty()
    }

    /// Which engine `input` is affined to.
    pub fn route(&self, input: &EncodeInput) -> usize {
        engine_index(input.content_hash(), self.engines.len())
    }

    /// Encode on the affined engine.  A dead engine sheds (error +
    /// its `rejected` counter); the router never re-routes.
    pub fn encode(&self, input: EncodeInput) -> EncodeResult {
        let idx = self.route(&input);
        // fail closed: `route` is modulo the fleet size, so a miss here
        // would be an internal bug — shed the request, don't panic
        match self.engines.get(idx) {
            Some(engine) => engine.encode(input),
            None => Err("router selected an unavailable engine".into()),
        }
    }

    /// Per-engine generations, index-aligned with [`Self::engines`].
    pub fn generations(&self) -> Vec<u64> {
        self.engines.iter().map(|e| e.generation()).collect()
    }

    /// The fleet's single generation, or an error naming the disagreeing
    /// engines — the post-fan-out-promotion invariant `/readyz` reflects.
    pub fn generation_agreement(&self) -> Result<u64, String> {
        let gens = self.generations();
        let g0 = gens[0];
        if gens.iter().all(|g| *g == g0) {
            Ok(g0)
        } else {
            Err(format!("generation disagreement across the fleet: {gens:?}"))
        }
    }

    /// Is any engine mid prepare→promote?
    pub fn is_promoting(&self) -> bool {
        self.engines.iter().any(|e| e.metrics().is_promoting())
    }

    /// The shared model-shape contract (identical across the fleet).
    pub fn encoder_config(&self) -> &EncoderConfig {
        self.engines[0].encoder_config()
    }

    /// Precision label of the primary engine's current encoder.
    pub fn kind_label(&self) -> &'static str {
        self.engines[0].kind_label()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::LinearKind;
    use crate::serve::batcher::BatchPolicy;
    use crate::serve::encoder::ClipEncoder;
    use crate::serve::standby::{validate_and_promote_all, CanarySet};
    use crate::tensor::Rng;
    use std::time::{Duration, Instant};

    fn tiny_cfg(seed: u64) -> EncoderConfig {
        EncoderConfig {
            kind: LinearKind::SwitchBack,
            dim: 16,
            heads: 2,
            blocks: 1,
            embed_dim: 8,
            patches: 4,
            patch_dim: 12,
            text_seq: 5,
            vocab: 64,
            seed,
        }
    }

    fn tiny_router(n: usize, cache: usize) -> Router {
        Router::start(
            ServeConfig {
                encoder: tiny_cfg(7),
                policy: BatchPolicy {
                    max_batch: 8,
                    max_wait: Duration::from_millis(1),
                },
                workers: 2,
                cache_capacity: cache,
                cache_shards: 2,
            },
            n,
        )
    }

    fn docs(cfg: &EncoderConfig, n: usize, seed: u64) -> Vec<EncodeInput> {
        let base = Rng::seed(seed);
        (0..n)
            .map(|i| {
                let mut r = base.fork(i as u64);
                if i % 2 == 0 {
                    EncodeInput::Image((0..cfg.image_len()).map(|_| r.normal()).collect())
                } else {
                    EncodeInput::Text(
                        (0..cfg.text_seq).map(|_| r.below(cfg.vocab) as i32).collect(),
                    )
                }
            })
            .collect()
    }

    #[test]
    fn affinity_is_pure_and_spreads_across_the_fleet() {
        let router = tiny_router(3, 256);
        let population = docs(router.encoder_config(), 64, 11);
        let mapping: Vec<usize> = population.iter().map(|d| router.route(d)).collect();
        // Pure: recomputing yields the identical mapping.
        let again: Vec<usize> = population.iter().map(|d| router.route(d)).collect();
        assert_eq!(mapping, again);
        // And it matches the free function the caches key on.
        for (d, idx) in population.iter().zip(&mapping) {
            assert_eq!(engine_index(d.content_hash(), 3), *idx);
        }
        // 64 docs over 3 engines: every engine owns some.
        for e in 0..3 {
            assert!(mapping.contains(&e), "engine {e} owns no docs");
        }
    }

    #[test]
    fn affinity_keeps_per_engine_caches_hot_and_disjoint() {
        let router = tiny_router(3, 256);
        let population = docs(router.encoder_config(), 12, 23);
        for d in &population {
            assert!(!router.encode(d.clone()).unwrap().cache_hit);
        }
        // Second pass: every doc lands back on its engine's warm cache.
        for d in &population {
            assert!(router.encode(d.clone()).unwrap().cache_hit);
        }
        // Requests spread exactly by the pinned mapping — no engine saw a
        // doc it does not own.
        let mut want = [0u64; 3];
        for d in &population {
            want[router.route(d)] += 2;
        }
        for (e, w) in router.engines().iter().zip(want) {
            assert_eq!(e.metrics().snapshot().requests, w);
        }
    }

    /// Satellite: kill one engine's worker pool mid-load and assert the
    /// shed accounting balances exactly — no silently lost requests —
    /// while the surviving engines' affinity is unchanged.
    #[test]
    fn chaos_killing_one_engine_sheds_exactly_and_siblings_survive() {
        const ENGINES: usize = 3;
        const THREADS: usize = 4;
        let router = Arc::new(tiny_router(ENGINES, 256));
        let cfg = router.encoder_config().clone();

        // Phase 1 docs (served before the kill) and phase 2 docs (fresh,
        // so none can be answered from a dead engine's cache).
        let phase1 = docs(&cfg, 24, 101);
        let phase2 = docs(&cfg, 24, 202);
        let mapping1: Vec<usize> = phase1.iter().map(|d| router.route(d)).collect();

        let barrier = Arc::new(std::sync::Barrier::new(THREADS + 1));
        let (ok, errs) = std::thread::scope(|s| {
            let mut handles = Vec::new();
            for t in 0..THREADS {
                let router = Arc::clone(&router);
                let barrier = Arc::clone(&barrier);
                let phase1 = &phase1;
                let phase2 = &phase2;
                handles.push(s.spawn(move || {
                    let mut ok = 0u64;
                    let mut errs = 0u64;
                    for d in phase1.iter().skip(t).step_by(THREADS) {
                        match router.encode(d.clone()) {
                            Ok(_) => ok += 1,
                            Err(_) => errs += 1,
                        }
                    }
                    barrier.wait(); // all phase-1 requests done
                    barrier.wait(); // the kill has happened
                    for d in phase2.iter().skip(t).step_by(THREADS) {
                        match router.encode(d.clone()) {
                            Ok(_) => ok += 1,
                            Err(_) => errs += 1,
                        }
                    }
                    (ok, errs)
                }));
            }
            barrier.wait();
            router.engines()[1].kill();
            barrier.wait();
            handles
                .into_iter()
                .map(|h| h.join().unwrap())
                .fold((0u64, 0u64), |(a, b), (o, e)| (a + o, b + e))
        });

        let total = (phase1.len() + phase2.len()) as u64;
        // Exact balance: every request is either served or explicitly shed.
        assert_eq!(ok + errs, total, "requests lost or double-counted");
        // Exactly the phase-2 docs affined to the dead engine were shed.
        let expected_shed = phase2.iter().filter(|d| router.route(d) == 1).count() as u64;
        assert!(expected_shed > 0, "chaos test needs docs on the dead engine");
        assert_eq!(errs, expected_shed);
        // The server-side ledger agrees with the client view.
        let snaps: Vec<_> = router.engines().iter().map(|e| e.metrics().snapshot()).collect();
        assert_eq!(snaps[1].rejected, expected_shed);
        assert_eq!(snaps[0].rejected, 0);
        assert_eq!(snaps[2].rejected, 0);
        // Survivors saw exactly their affined share — the doc→engine
        // mapping did not move after the kill.
        let mapping1_after: Vec<usize> = phase1.iter().map(|d| router.route(d)).collect();
        assert_eq!(mapping1, mapping1_after, "affinity must not re-hash on failure");
        for e in [0usize, 2] {
            let want = phase1.iter().chain(&phase2).filter(|d| router.route(d) == e).count();
            assert_eq!(snaps[e].requests, want as u64, "engine {e} request count");
        }
    }

    /// Satellite: one snapshot promotes across N=3 engines atomically —
    /// same generation everywhere — and a canary reject touches nothing.
    #[test]
    fn fanout_promotion_lands_one_generation_everywhere_and_reject_is_torn_free() {
        let router = tiny_router(3, 256);
        let refs: Vec<&Engine> = router.engines().iter().map(Arc::as_ref).collect();
        let canary = CanarySet::build(router.encoder_config(), 4, 99);

        // A doc cached at generation 0 (on its affined engine).
        let doc = docs(router.encoder_config(), 1, 7).pop().unwrap();
        let before = router.encode(doc.clone()).unwrap();

        // Same-seed candidates = drift 0 → must pass any bound.
        let candidates: Vec<ClipEncoder> =
            (0..3).map(|_| ClipEncoder::new(tiny_cfg(7))).collect();
        let promo =
            validate_and_promote_all(&refs, candidates, &canary, Some(0.5), Instant::now())
                .expect("identical weights must promote");
        assert_eq!(promo.drift, 0.0);
        assert_eq!(router.generations(), vec![1, 1, 1]);
        assert_eq!(router.generation_agreement().unwrap(), 1);

        // Cache coherence across the generation bump: the old entry is
        // dead (key mixes the generation), the re-encode repopulates.
        let after = router.encode(doc.clone()).unwrap();
        assert!(!after.cache_hit, "generation bump must invalidate the cache");
        assert_eq!(
            *after.embedding, *before.embedding,
            "identical weights must reproduce the embedding"
        );
        assert!(router.encode(doc).unwrap().cache_hit);

        // A wildly different candidate set is rejected with **no** torn
        // fan-out: every generation stays, every engine records the reject.
        let unrelated: Vec<ClipEncoder> =
            (0..3).map(|_| ClipEncoder::new(tiny_cfg(31337))).collect();
        let err = validate_and_promote_all(
            &refs,
            unrelated,
            &canary,
            Some(0.05),
            Instant::now(),
        )
        .unwrap_err();
        assert!(err.contains("drift"), "{err}");
        assert_eq!(router.generations(), vec![1, 1, 1]);
        for e in router.engines() {
            let snap = e.metrics().snapshot();
            assert_eq!(snap.standby_promotions, 1);
            assert_eq!(snap.standby_rejects, 1);
        }
    }
}
