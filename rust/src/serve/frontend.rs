//! The network front door: [`Http1Server`] bound as the serving data
//! plane (DESIGN.md §Network-front-door).
//!
//! **Wire format.** `POST /encode` with a JSON body,
//! `{"kind":"image","data":[…]}` or `{"kind":"text","tokens":[…]}`
//! (`util::json` both ways — no serde).  Success is
//! `{"embedding":[…],"cache_hit":…,"engine":…,"generation":…}`; every
//! error is `{"error":"…"}` with a status that tells the client what to
//! do: `400` fix the request, `429` back off (admission window full),
//! `503` a component is down or the accept queue overflowed.  Bodies are
//! length-prefixed by `Content-Length` and bounded by
//! [`Http1Config::max_body`]; an oversized declaration is `413` before a
//! byte of payload is read.
//!
//! **Backpressure, never unbounded queueing.** Three bounded windows
//! stack up:
//! 1. *per connection* — HTTP/1.1 requests on one connection are served
//!    serially, so a connection has at most one request in flight;
//! 2. *per server* — the admission window
//!    ([`FrontendConfig::max_inflight`]) caps requests inside the
//!    parse→route→encode section across all connections; overflow is an
//!    immediate `429` that also increments the primary engine's
//!    `rejected` counter (the same ledger in-process sheds use);
//! 3. *accept* — beyond `queue_depth` waiting connections the accept
//!    thread answers `503` inline (`net::http1`).
//!
//! Behind the door, requests route by doc-hash affinity to a fleet of
//! engines ([`super::router`]); the engine's own bounded batch queue is
//! the final stage, and its sheds surface as `503`.

use super::engine::EncodeResponse;
use super::router::Router;
use super::EncodeInput;
use crate::net::http1::{Handler, Http1Client, Http1Config, Http1Server, Request, Response};
use crate::trace;
use crate::util::json::{self, ObjWriter, Value};
use std::net::SocketAddr;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Front-door knobs.
#[derive(Debug, Clone)]
pub struct FrontendConfig {
    /// Requests admitted into parse→route→encode at once, across all
    /// connections; beyond this the door answers `429` immediately.
    /// 0 disables the window (the accept queue still bounds load).
    pub max_inflight: usize,
    /// Wire-layer limits.  The worker pool is per-*connection* (a
    /// persistent client pins a worker while connected), so `workers`
    /// must comfortably exceed the expected concurrent client count —
    /// the default here is sized for loadgen's overload runs, not the
    /// telemetry plane's two-worker default.
    pub http: Http1Config,
}

impl Default for FrontendConfig {
    fn default() -> Self {
        FrontendConfig {
            max_inflight: 32,
            http: Http1Config {
                workers: 96,
                queue_depth: 256,
                ..Http1Config::default()
            },
        }
    }
}

/// The global in-flight window: a permit per admitted request, released
/// on drop (panic-safe).  `cap == 0` means unlimited.
struct Admission {
    cap: usize,
    inflight: AtomicUsize,
}

struct Permit<'a>(&'a Admission);

impl Admission {
    fn new(cap: usize) -> Self {
        Admission { cap, inflight: AtomicUsize::new(0) }
    }

    fn try_acquire(&self) -> Option<Permit<'_>> {
        if self.cap == 0 {
            return Some(Permit(self));
        }
        // Optimistic claim: overshoot briefly, then give the slot back.
        if self.inflight.fetch_add(1, Ordering::AcqRel) < self.cap {
            Some(Permit(self))
        } else {
            self.inflight.fetch_sub(1, Ordering::AcqRel);
            None
        }
    }
}

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        if self.0.cap != 0 {
            self.0.inflight.fetch_sub(1, Ordering::AcqRel);
        }
    }
}

/// A running front door over a [`Router`] fleet.  Shut down explicitly
/// or on drop (the inner server joins its threads either way).
pub struct Frontend {
    server: Http1Server,
    admission: Arc<Admission>,
}

impl Frontend {
    /// Bind `addr` (e.g. `127.0.0.1:0`) and serve `POST /encode` over
    /// `router`.
    pub fn bind(addr: &str, router: Arc<Router>, cfg: FrontendConfig) -> Result<Frontend, String> {
        let admission = Arc::new(Admission::new(cfg.max_inflight));
        let gate = Arc::clone(&admission);
        let handler: Handler = Arc::new(move |req: &Request| handle(req, &router, &gate));
        let server =
            Http1Server::bind(addr, cfg.http, handler).map_err(|e| format!("frontend: {e:#}"))?;
        Ok(Frontend { server, admission })
    }

    /// The address actually bound (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.server.local_addr()
    }

    /// Stop accepting, drain and join. Idempotent.
    pub fn shutdown(&mut self) {
        self.server.shutdown();
    }

    /// Test hook: occupy the whole admission window so the next request
    /// deterministically sees `429`.
    #[cfg(test)]
    fn hold_window(&self) -> Vec<Permit<'_>> {
        std::iter::from_fn(|| self.admission.try_acquire()).collect()
    }
}

fn err_json(status: u16, msg: &str) -> Response {
    Response::json(status, format!("{{\"error\":{}}}", json::quote(msg)))
}

fn handle(req: &Request, router: &Arc<Router>, gate: &Admission) -> Response {
    if req.path != "/encode" {
        return err_json(404, "unknown path; the data plane serves POST /encode");
    }
    if req.method != "POST" {
        return err_json(405, "use POST /encode");
    }
    // Admission first — under overload the door sheds before paying for
    // JSON parsing.  The primary engine's `rejected` counter is the
    // ledger (the per-engine affinity is unknown before parsing).
    let Some(_permit) = gate.try_acquire() else {
        if let Some(primary) = router.engines().first() {
            primary.metrics().rejected.inc();
        }
        return err_json(429, "admission window full; back off and retry");
    };
    let input = match parse_encode_body(&req.body) {
        Ok(input) => input,
        Err(e) => return err_json(400, &e),
    };
    let idx = router.route(&input);
    // fail closed: a routing index outside the fleet is an internal bug,
    // and it must cost this request a 500, never the connection thread
    let Some(engine) = router.engines().get(idx) else {
        trace::global().counter("serve.frontend.misroute").inc();
        return err_json(500, "router selected an unavailable engine");
    };
    match engine.encode(input) {
        Ok(resp) => ok_json(&resp, idx, engine.generation()),
        // The engine's own shed (closed queue) — a component is down.
        Err(e) if e.contains("shut down") => err_json(503, &e),
        // Validation errors — the client sent a bad payload.
        Err(e) => err_json(400, &e),
    }
}

fn ok_json(resp: &EncodeResponse, engine: usize, generation: u64) -> Response {
    let mut w = ObjWriter::new();
    w.field_f32_arr("embedding", &resp.embedding)
        .field_bool("cache_hit", resp.cache_hit)
        .field_u64("engine", engine as u64)
        .field_u64("generation", generation);
    Response::json(200, w.finish())
}

/// Parse one `/encode` request body into an [`EncodeInput`].
fn parse_encode_body(body: &[u8]) -> Result<EncodeInput, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not utf-8".to_string())?;
    let v = json::parse(text).map_err(|e| format!("body is not valid JSON: {e}"))?;
    let kind = v
        .get("kind")
        .and_then(Value::as_str)
        .ok_or_else(|| "missing string field \"kind\"".to_string())?;
    match kind {
        "image" => {
            let arr = v
                .get("data")
                .and_then(Value::as_arr)
                .ok_or_else(|| "image requests need a \"data\" array".to_string())?;
            let mut px = Vec::with_capacity(arr.len());
            for x in arr {
                px.push(
                    x.as_f64()
                        .ok_or_else(|| "\"data\" must be all numbers".to_string())?
                        as f32,
                );
            }
            Ok(EncodeInput::Image(px))
        }
        "text" => {
            let arr = v
                .get("tokens")
                .and_then(Value::as_arr)
                .ok_or_else(|| "text requests need a \"tokens\" array".to_string())?;
            let mut toks = Vec::with_capacity(arr.len());
            for x in arr {
                toks.push(
                    x.as_f64()
                        .ok_or_else(|| "\"tokens\" must be all numbers".to_string())?
                        as i32,
                );
            }
            Ok(EncodeInput::Text(toks))
        }
        other => Err(format!("unknown kind {other:?}; expected \"image\" or \"text\"")),
    }
}

/// Serialize one [`EncodeInput`] as an `/encode` request body — the
/// client half of the wire format, shared by loadgen and the tests.
pub fn encode_request_json(input: &EncodeInput) -> String {
    let mut w = ObjWriter::new();
    match input {
        EncodeInput::Image(px) => {
            w.field_str("kind", "image").field_f32_arr("data", px);
        }
        EncodeInput::Text(toks) => {
            let toks_f: Vec<f32> = toks.iter().map(|t| *t as f32).collect();
            w.field_str("kind", "text").field_f32_arr("tokens", &toks_f);
        }
    }
    w.finish()
}

/// What one socket `/encode` call produced, from the client's seat.
#[derive(Debug)]
pub enum SocketOutcome {
    /// 200 with a well-formed embedding.
    Ok {
        cache_hit: bool,
        embedding: Vec<f32>,
    },
    /// Explicit admission shed (`429`) or component-down (`503`) — the
    /// bounded-queue design working as intended, not a request error.
    Rejected(u16),
}

/// A persistent-connection `/encode` client: one [`Http1Client`] (TCP
/// keep-alive, transparent reconnect when the server closes) plus the
/// wire format.  Loadgen's `--socket` worker threads each own one.
pub struct EncodeClient {
    inner: Http1Client,
}

impl EncodeClient {
    /// `addr` is `host:port` (as printed by `serve --listen`).
    pub fn connect(addr: &str, timeout: Duration) -> Result<EncodeClient, String> {
        let inner = Http1Client::connect(addr, timeout).map_err(|e| format!("{e:#}"))?;
        Ok(EncodeClient { inner })
    }

    /// One round trip.  `Err` is a *request error* (transport failure or
    /// a 4xx/5xx outside the explicit-shed statuses) — loadgen counts
    /// those as errors, while [`SocketOutcome::Rejected`] counts as
    /// admission-control sheds.
    pub fn encode(&mut self, input: &EncodeInput) -> Result<SocketOutcome, String> {
        let body = encode_request_json(input);
        let resp = self
            .inner
            .post("/encode", "application/json", body.as_bytes())
            .map_err(|e| format!("{e:#}"))?;
        match resp.status {
            200 => {
                let v = json::parse(&resp.body)
                    .map_err(|e| format!("malformed 200 body: {e}"))?;
                let cache_hit = v
                    .get("cache_hit")
                    .and_then(Value::as_bool)
                    .ok_or("200 body missing cache_hit")?;
                let emb = v
                    .get("embedding")
                    .and_then(Value::as_arr)
                    .ok_or("200 body missing embedding")?;
                let mut embedding = Vec::with_capacity(emb.len());
                for x in emb {
                    embedding.push(x.as_f64().ok_or("embedding must be numbers")? as f32);
                }
                Ok(SocketOutcome::Ok { cache_hit, embedding })
            }
            429 | 503 => Ok(SocketOutcome::Rejected(resp.status)),
            s => Err(format!("status {s}: {}", resp.body.trim())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckpt;
    use crate::ckpt::TrainCheckpoint;
    use crate::config::TrainHyper;
    use crate::data::DataCursor;
    use crate::net::http1::http_post;
    use crate::nn::LinearKind;
    use crate::optim::OptimizerState;
    use crate::serve::batcher::BatchPolicy;
    use crate::serve::encoder::{ClipEncoder, EncoderConfig};
    use crate::serve::engine::{Engine, ServeConfig};
    use crate::serve::standby::{Standby, StandbyConfig, StandbyEvent};
    use crate::tensor::Rng;
    use crate::train::ClipTrainModel;

    fn tiny_cfg(seed: u64) -> EncoderConfig {
        EncoderConfig {
            kind: LinearKind::SwitchBack,
            dim: 16,
            heads: 2,
            blocks: 1,
            embed_dim: 8,
            patches: 4,
            patch_dim: 12,
            text_seq: 5,
            vocab: 64,
            seed,
        }
    }

    fn serve_cfg(enc: EncoderConfig) -> ServeConfig {
        ServeConfig {
            encoder: enc,
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
            },
            workers: 2,
            cache_capacity: 256,
            cache_shards: 2,
        }
    }

    /// A bound frontend over `n` fresh engines, with a small worker pool
    /// (tests use few connections).
    fn frontend(n: usize) -> (Frontend, Arc<Router>) {
        let router = Arc::new(Router::start(serve_cfg(tiny_cfg(7)), n));
        let cfg = FrontendConfig {
            max_inflight: 16,
            http: Http1Config {
                workers: 8,
                ..Http1Config::default()
            },
        };
        let fe = Frontend::bind("127.0.0.1:0", Arc::clone(&router), cfg).unwrap();
        (fe, router)
    }

    fn image_for(cfg: &EncoderConfig, seed: u64) -> EncodeInput {
        let mut r = Rng::seed(seed);
        EncodeInput::Image((0..cfg.image_len()).map(|_| r.normal()).collect())
    }

    fn text_for(cfg: &EncoderConfig, seed: u64) -> EncodeInput {
        let mut r = Rng::seed(seed);
        EncodeInput::Text((0..cfg.text_seq).map(|_| r.below(cfg.vocab) as i32).collect())
    }

    #[test]
    fn socket_roundtrip_matches_in_process_encode() {
        let (fe, router) = frontend(2);
        let addr = fe.local_addr().to_string();
        let mut client = EncodeClient::connect(&addr, Duration::from_secs(5)).unwrap();
        for input in [
            image_for(router.encoder_config(), 3),
            text_for(router.encoder_config(), 4),
        ] {
            let want = router.encode(input.clone()).unwrap();
            match client.encode(&input).unwrap() {
                SocketOutcome::Ok { cache_hit, embedding } => {
                    // The doc was just encoded in-process on the same
                    // affined engine, so the socket path must hit its
                    // cache and return the identical embedding.
                    assert!(cache_hit, "affined cache must be hot");
                    assert_eq!(embedding, *want.embedding);
                }
                other => panic!("expected Ok, got {other:?}"),
            }
        }
    }

    #[test]
    fn malformed_bodies_are_400_and_the_door_keeps_serving() {
        let (fe, router) = frontend(1);
        let base = format!("http://{}", fe.local_addr());
        let t = Duration::from_secs(5);
        for bad in [
            "not json at all".to_string(),
            "{\"kind\":\"soup\"}".to_string(),
            "{\"no\":\"kind\"}".to_string(),
            "{\"kind\":\"image\",\"data\":[1,\"x\"]}".to_string(),
            // right shape field, wrong length → engine-side validation
            "{\"kind\":\"text\",\"tokens\":[1,2]}".to_string(),
        ] {
            let resp =
                http_post(&format!("{base}/encode"), "application/json", bad.as_bytes(), t)
                    .unwrap();
            assert_eq!(resp.status, 400, "{bad} → {}", resp.body);
            assert!(resp.body.contains("error"), "{}", resp.body);
        }
        // Unknown path and wrong method have their own statuses.
        assert_eq!(http_post(&format!("{base}/nope"), "application/json", b"{}", t)
                .unwrap()
                .status, 404);
        assert_eq!(
            crate::net::http1::http_get(&format!("{base}/encode"), t).unwrap().status,
            405
        );
        // A healthy request still round-trips after all that.
        let mut client =
            EncodeClient::connect(&fe.local_addr().to_string(), t).unwrap();
        let ok = client.encode(&image_for(router.encoder_config(), 9)).unwrap();
        assert!(matches!(ok, SocketOutcome::Ok { .. }));
    }

    #[test]
    fn admission_window_full_is_429_and_counted_as_rejected() {
        let (fe, router) = frontend(1);
        let rejected_before = router.engines()[0].metrics().snapshot().rejected;
        let permits = fe.hold_window();
        assert_eq!(permits.len(), 16, "test must seize the whole window");
        let mut client =
            EncodeClient::connect(&fe.local_addr().to_string(), Duration::from_secs(5)).unwrap();
        match client.encode(&image_for(router.encoder_config(), 5)).unwrap() {
            SocketOutcome::Rejected(status) => assert_eq!(status, 429),
            other => panic!("expected 429 shed, got {other:?}"),
        }
        assert_eq!(
            router.engines()[0].metrics().snapshot().rejected,
            rejected_before + 1,
            "admission sheds must land in the rejected ledger"
        );
        // Release the window: the same client and connection recover.
        drop(permits);
        let ok = client.encode(&image_for(router.encoder_config(), 5)).unwrap();
        assert!(matches!(ok, SocketOutcome::Ok { .. }));
    }

    #[test]
    fn dead_engine_sheds_as_503_while_siblings_serve() {
        let (fe, router) = frontend(3);
        let cfg = router.encoder_config().clone();
        // Find one doc per engine.
        let mut per_engine: Vec<Option<EncodeInput>> = vec![None, None, None];
        for seed in 0..64 {
            let d = image_for(&cfg, seed);
            let idx = router.route(&d);
            per_engine[idx].get_or_insert(d);
        }
        let docs: Vec<EncodeInput> =
            per_engine.into_iter().map(|d| d.expect("doc per engine")).collect();

        router.engines()[1].kill();
        let mut client =
            EncodeClient::connect(&fe.local_addr().to_string(), Duration::from_secs(5)).unwrap();
        match client.encode(&docs[1]).unwrap() {
            SocketOutcome::Rejected(status) => assert_eq!(status, 503),
            other => panic!("expected 503 from the dead engine, got {other:?}"),
        }
        for alive in [0usize, 2] {
            assert!(
                matches!(client.encode(&docs[alive]).unwrap(), SocketOutcome::Ok { .. }),
                "sibling engine {alive} must keep serving"
            );
        }
    }

    #[test]
    fn oversized_body_is_413_and_the_connection_pool_survives() {
        let router = Arc::new(Router::start(serve_cfg(tiny_cfg(7)), 1));
        let cfg = FrontendConfig {
            max_inflight: 4,
            http: Http1Config {
                workers: 4,
                max_body: 128,
                ..Http1Config::default()
            },
        };
        let fe = Frontend::bind("127.0.0.1:0", Arc::clone(&router), cfg).unwrap();
        let big = encode_request_json(&image_for(router.encoder_config(), 1));
        assert!(big.len() > 128);
        let resp = http_post(
            &format!("http://{}/encode", fe.local_addr()),
            "application/json",
            big.as_bytes(),
            Duration::from_secs(5),
        )
        .unwrap();
        assert_eq!(resp.status, 413);
        // Sibling connections unaffected; a small text request fits.
        let mut client =
            EncodeClient::connect(&fe.local_addr().to_string(), Duration::from_secs(5)).unwrap();
        let small = text_for(router.encoder_config(), 2);
        assert!(encode_request_json(&small).len() <= 128);
        assert!(matches!(client.encode(&small).unwrap(), SocketOutcome::Ok { .. }));
    }

    fn ckpt_with(params: Vec<Vec<f32>>, step: u64, enc: &EncoderConfig) -> TrainCheckpoint {
        TrainCheckpoint {
            step,
            encoder: enc.clone(),
            hyper: TrainHyper::preset(1000),
            shifts: vec![],
            batch: 4,
            grad_shards: 1,
            param_names: (0..params.len()).map(|i| format!("t{i}")).collect(),
            params,
            opt: OptimizerState { name: "lion".into(), t: step, slots: vec![] },
            data: DataCursor {
                step,
                gain: 1.0,
                mapping: vec![0],
                rng: [1, 2, 3, 4],
                rng_spare: None,
            },
        }
    }

    /// Satellite: one standby watcher promotes a snapshot across N=3
    /// engines while real TCP clients hammer the door — same generation
    /// everywhere, canary-reject touches nothing, zero request errors.
    #[test]
    fn fanout_promotion_under_concurrent_socket_load() {
        let dir = std::env::temp_dir().join("sbck_frontend_fanout");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();

        let enc_cfg = tiny_cfg(7);
        let params = ClipTrainModel::new(enc_cfg.clone()).collect_params();
        let engines: Vec<Arc<Engine>> = (0..3)
            .map(|_| {
                let weights = ckpt::encoder_weights(&enc_cfg, &params).unwrap();
                let enc = ClipEncoder::from_weights(enc_cfg.clone(), weights);
                Arc::new(Engine::start_with_encoder(serve_cfg(enc_cfg.clone()), enc))
            })
            .collect();
        let router = Arc::new(Router::from_engines(engines));
        let fe = Frontend::bind(
            "127.0.0.1:0",
            Arc::clone(&router),
            FrontendConfig {
                max_inflight: 32,
                http: Http1Config { workers: 8, ..Http1Config::default() },
            },
        )
        .unwrap();
        let addr = fe.local_addr().to_string();

        let mut cfg = StandbyConfig::new(&dir);
        cfg.baseline = Some(params.clone());
        let mut sb = Standby::new_fanout(router.engines().to_vec(), cfg);

        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let (events, errors) = std::thread::scope(|s| {
            // Two real TCP clients loop over a small doc population for
            // the whole promote + reject sequence.
            let mut handles = Vec::new();
            for t in 0..2u64 {
                let addr = addr.clone();
                let stop = Arc::clone(&stop);
                let enc_cfg = enc_cfg.clone();
                handles.push(s.spawn(move || {
                    let mut client =
                        EncodeClient::connect(&addr, Duration::from_secs(5)).unwrap();
                    let mut errors = 0u64;
                    let mut i = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        let input = if i % 2 == 0 {
                            image_for(&enc_cfg, 1000 + t * 100 + (i % 8))
                        } else {
                            text_for(&enc_cfg, 2000 + t * 100 + (i % 8))
                        };
                        if client.encode(&input).is_err() {
                            errors += 1;
                        }
                        i += 1;
                    }
                    errors
                }));
            }

            // Promote a near-identical snapshot across the fleet.
            let newer: Vec<Vec<f32>> =
                params.iter().map(|p| p.iter().map(|v| v * 1.001).collect()).collect();
            ckpt::save(&ckpt::snapshot_path(&dir, 10), &ckpt_with(newer, 10, &enc_cfg))
                .unwrap();
            let ev1 = sb.poll_once();
            // Then a drifted one: rejected, nothing moves anywhere.
            let alien = ClipTrainModel::new(tiny_cfg(999)).collect_params();
            ckpt::save(&ckpt::snapshot_path(&dir, 20), &ckpt_with(alien, 20, &enc_cfg))
                .unwrap();
            let ev2 = sb.poll_once();

            stop.store(true, Ordering::Relaxed);
            let errors: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
            ((ev1, ev2), errors)
        });

        assert!(
            matches!(events.0, StandbyEvent::Promoted { generation: 1, .. }),
            "expected fan-out promotion, got {:?}",
            events.0
        );
        assert!(
            matches!(events.1, StandbyEvent::Rejected { .. }),
            "expected canary rejection, got {:?}",
            events.1
        );
        assert_eq!(errors, 0, "socket clients must see zero request errors");
        // Same generation everywhere; the reject left all of them alone.
        assert_eq!(router.generations(), vec![1, 1, 1]);
        assert_eq!(router.generation_agreement().unwrap(), 1);
        for e in router.engines() {
            let snap = e.metrics().snapshot();
            assert_eq!(snap.standby_promotions, 1, "every engine promoted once");
            assert_eq!(snap.standby_rejects, 1, "every engine recorded the reject");
        }
        // Per-engine caches stayed generation-coherent: the same doc now
        // encodes identically on every engine (fresh weights everywhere).
        let probe = image_for(&enc_cfg, 31);
        let embs: Vec<Vec<f32>> = router
            .engines()
            .iter()
            .map(|e| e.encode(probe.clone()).unwrap().embedding.to_vec())
            .collect();
        assert_eq!(embs[0], embs[1]);
        assert_eq!(embs[1], embs[2]);
    }
}
