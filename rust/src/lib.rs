//! # switchback — Stable and low-precision training for large-scale
//! # vision-language models (NeurIPS 2023), reproduced in Rust + JAX + Pallas
//!
//! Three-layer architecture (see `DESIGN.md`):
//!
//! * **L1 (Pallas, build time)** — int8/fp8 quantization + fused
//!   matmul-dequantize kernels (`python/compile/kernels/`).
//! * **L2 (JAX, build time)** — CLIP dual-tower with precision-pluggable
//!   linear layers, AOT-lowered to HLO text artifacts.
//! * **L3 (this crate, runtime)** — everything on the training path:
//!   - `runtime` (feature `pjrt`) loads + executes the AOT artifacts via
//!     PJRT,
//!   - [`optim`] implements **StableAdamW** (the paper's Algorithm 2),
//!     AdamW, gradient clipping, loss scalers,
//!   - [`telemetry`] implements the RMS-spike / loss-spike analysis
//!     apparatus (paper §3.4, Fig 9 & 16–21),
//!   - [`data`] generates the synthetic image–text corpus (the LAION-2B
//!     stand-in) with a scheduled distribution shift,
//!   - [`quant`]/[`gemm`]/[`nn`] are the *measured-speed substrate*: native
//!     int8/f32 GEMMs and hand-written fwd/bwd linear-layer variants that
//!     regenerate the paper's Fig 3/4/13 speed results on this hardware,
//!   - [`coordinator`] orchestrates training runs and experiment sweeps
//!     and holds the training policy shared by both training paths,
//!   - [`train`] is the **native end-to-end training subsystem**: a
//!     dual-tower CLIP model on the measured-speed substrate with a
//!     hand-written InfoNCE gradient, data-parallel gradient
//!     accumulation, and the full optimizer/telemetry stack — no PJRT,
//!   - [`serve`] is the first runtime subsystem *off* the training path: a
//!     batched int8 embedding-serving engine (dynamic micro-batcher +
//!     forward-only encoder + worker pool + sharded LRU cache) built on
//!     the same measured-speed substrate, fronted by a real TCP data
//!     plane — a doc-hash fan-out router across N engines and an
//!     admission-gated HTTP/1.1 `POST /encode` front door,
//!   - [`ckpt`] is the subsystem that joins the two: versioned, CRC-checked
//!     binary checkpoints of model + optimizer + RNG/schedule state, giving
//!     the trainer bit-identical `--resume` and spike-rollback, and the
//!     serving engine `--weights` load-at-boot plus live weight hot-swap,
//!   - [`trace`] is the cross-cutting observability substrate: an
//!     always-on span profiler, one metrics registry shared by
//!     train/serve/ckpt, and the spike flight recorder that dumps the
//!     paper's `g²/v` under-estimation probes when a spike fires,
//!   - [`analysis`] is the in-tree static analyzer behind `switchback
//!     lint`: a lexical Rust scanner, the repo-invariant rule engine
//!     (panic-free serve/net/ckpt paths, SAFETY comments, checked
//!     narrowing, the trace epoch clock, metric naming, joined spawns)
//!     and the lock-order analyzer that builds the inter-procedural
//!     acquisition graph and rejects cycles and locks held across
//!     blocking calls,
//!   - [`net`] is the hand-rolled `std::net` HTTP/1.1 layer underneath
//!     both the live telemetry plane (`--telemetry-addr`) and the
//!     serving data plane (`--listen`): strict parsing limits, bounded
//!     POST bodies, keep-alive with per-connection caps, a persistent
//!     client, a bounded worker pool and a clean shutdown handle —
//!     hardened by a network fault-injection test suite.
//!
//! Python never runs on the training path: `make artifacts` lowers the
//! model once; the `switchback` binary is then self-contained.
//!
//! The `runtime` module and the artifact-driven parts of
//! [`coordinator`] need the PJRT toolchain and are gated behind the
//! `pjrt` cargo feature; everything else (including the native trainer,
//! the serving engine and all benches) builds and tests without it.

pub mod analysis;
pub mod ckpt;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod gemm;
pub mod net;
pub mod nn;
pub mod optim;
pub mod quant;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod serve;
pub mod telemetry;
pub mod tensor;
pub mod trace;
pub mod train;
pub mod util;

pub use config::{OptimizerKind, TrainConfig};
