//! Minimal hand-rolled HTTP/1.1 server and client over `std::net`.
//!
//! Scope: what the telemetry plane and the serving front door
//! ([`crate::serve::frontend`]) need, and nothing more.  `GET`/`HEAD`
//! plus `POST` with a strictly bounded `Content-Length` body — no TLS,
//! no chunked transfer.  What it does do, it does carefully:
//!
//! * **Parsing with hard limits** — request-line length, per-header-line
//!   length, header count, method token length, body size.  Every limit
//!   violation maps to a definite 4xx and the connection is closed;
//!   malformed bytes never panic the worker.  Oversized bodies are
//!   answered `413` *before* a byte of body is read.
//! * **Keep-alive** — HTTP/1.1 connections persist by default (HTTP/1.0
//!   and `Connection: close` do not), bounded by a per-connection request
//!   cap and a per-read socket timeout so an idle or trickling peer —
//!   including a slow-loris body writer — cannot pin a worker forever.
//! * **Bounded concurrency** — one accept thread feeds a fixed worker
//!   pool through a bounded queue; when the queue is full the accept
//!   thread answers `503` inline and closes, so load cannot queue
//!   unboundedly behind the engine it is serving or observing.
//! * **Clean shutdown** — [`Http1Server::shutdown`] stops the accept
//!   loop (self-connecting to unblock `accept(2)`), drains the workers
//!   and joins every thread.  Dropping the server shuts it down too.
//!
//! The client half is two shapes: [`http_get`] / [`http_post`] for
//! one-shot calls (`switchback probe`, the loadgen scraper), and
//! [`Http1Client`] — a persistent keep-alive connection that
//! transparently reconnects when the server closes it (request cap,
//! restart) — for the loadgen socket clients, so verify.sh and CI need
//! no `curl`.

use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Context, Result};

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

/// Hard limits and sizing for an [`Http1Server`].
#[derive(Debug, Clone)]
pub struct Http1Config {
    /// Maximum bytes in the request line (`GET /path HTTP/1.1`).
    pub max_request_line: usize,
    /// Maximum bytes in a single header line.
    pub max_header_line: usize,
    /// Maximum number of headers per request.
    pub max_headers: usize,
    /// Requests served on one connection before it is closed.
    pub max_requests_per_conn: usize,
    /// Per-read socket timeout; an idle keep-alive peer is dropped after
    /// this long without bytes.
    pub read_timeout: Duration,
    /// Worker threads handling connections.
    pub workers: usize,
    /// Accepted connections queued ahead of the workers; beyond this the
    /// accept thread answers `503` inline.
    pub queue_depth: usize,
    /// Maximum accepted request-body bytes (`POST` payloads); a declared
    /// `Content-Length` beyond this is answered `413` without reading a
    /// byte of body.
    pub max_body: usize,
}

impl Default for Http1Config {
    fn default() -> Self {
        Http1Config {
            max_request_line: 4096,
            max_header_line: 4096,
            max_headers: 64,
            max_requests_per_conn: 128,
            read_timeout: Duration::from_secs(5),
            workers: 2,
            queue_depth: 32,
            max_body: 1 << 20,
        }
    }
}

// ---------------------------------------------------------------------------
// Request / response types
// ---------------------------------------------------------------------------

/// A parsed request.
#[derive(Debug, Clone)]
pub struct Request {
    /// `GET`, `HEAD` or `POST` (anything else is answered `405` before
    /// dispatch).
    pub method: String,
    /// Path component of the target, without the query string.
    pub path: String,
    /// Raw query string after `?`, if any.
    pub query: Option<String>,
    /// Headers with lower-cased names, in arrival order.
    pub headers: Vec<(String, String)>,
    /// Request body — empty unless the method is `POST`, bounded by
    /// [`Http1Config::max_body`] and fully read before dispatch.
    pub body: Vec<u8>,
}

impl Request {
    /// First header value with the given (lower-case) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
    }
}

/// A response the handler hands back; the server adds `Content-Length`
/// and `Connection` framing headers itself.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: String,
    pub body: Vec<u8>,
}

impl Response {
    pub fn text(status: u16, body: impl Into<String>) -> Self {
        Response {
            status,
            content_type: "text/plain; charset=utf-8".to_string(),
            body: body.into().into_bytes(),
        }
    }

    pub fn json(status: u16, body: impl Into<String>) -> Self {
        Response {
            status,
            content_type: "application/json".to_string(),
            body: body.into().into_bytes(),
        }
    }

    pub fn not_found() -> Self {
        Response::text(404, "not found\n")
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        414 => "URI Too Long",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Status",
    }
}

/// Connection handler: pure function from request to response.
pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync>;

// ---------------------------------------------------------------------------
// Parsing
// ---------------------------------------------------------------------------

/// Outcome of trying to parse one request off a connection.
enum Parsed {
    /// A well-formed request (second field: peer asked to keep the
    /// connection alive).
    Ok(Request, bool),
    /// Clean EOF before the first byte of a request — peer is done.
    Closed,
    /// Read timed out or errored — close without a response.
    IoGone,
    /// Protocol violation: answer with this status (+ message) and close.
    Bad(u16, &'static str),
}

enum Line {
    Some(Vec<u8>),
    Eof,
    TooLong,
    IoErr,
}

/// Read one CRLF- (or LF-) terminated line, enforcing a byte cap.  The
/// cap is checked as bytes accumulate, so an attacker streaming an
/// endless line is cut off at `max`, not buffered.
fn read_line_limited<R: BufRead>(r: &mut R, max: usize) -> Line {
    let mut line: Vec<u8> = Vec::new();
    loop {
        let (done, used) = {
            let buf = match r.fill_buf() {
                Ok(b) => b,
                Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                    return Line::IoErr
                }
                Err(_) => return Line::IoErr,
            };
            if buf.is_empty() {
                return Line::Eof;
            }
            match buf.iter().position(|&b| b == b'\n') {
                Some(i) => {
                    line.extend_from_slice(&buf[..i]);
                    (true, i + 1)
                }
                None => {
                    line.extend_from_slice(buf);
                    (false, buf.len())
                }
            }
        };
        r.consume(used);
        if line.len() > max {
            return Line::TooLong;
        }
        if done {
            if line.last() == Some(&b'\r') {
                line.pop();
            }
            return Line::Some(line);
        }
    }
}

fn parse_request<R: BufRead>(r: &mut R, cfg: &Http1Config) -> Parsed {
    // Request line.
    let line = match read_line_limited(r, cfg.max_request_line) {
        Line::Some(l) => l,
        Line::Eof => return Parsed::Closed,
        Line::TooLong => return Parsed::Bad(414, "request line too long"),
        Line::IoErr => return Parsed::IoGone,
    };
    if line.is_empty() {
        return Parsed::Bad(400, "empty request line");
    }
    let line = match String::from_utf8(line) {
        Ok(s) => s,
        Err(_) => return Parsed::Bad(400, "request line is not utf-8"),
    };
    let mut parts = line.split(' ');
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v), None) if !m.is_empty() && !t.is_empty() => (m, t, v),
        _ => return Parsed::Bad(400, "malformed request line"),
    };
    if method.len() > 16 || !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Parsed::Bad(400, "malformed method");
    }
    let http11 = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        _ => return Parsed::Bad(400, "unsupported HTTP version"),
    };
    if !target.starts_with('/') {
        return Parsed::Bad(400, "target must be origin-form");
    }

    // Headers.
    let mut headers: Vec<(String, String)> = Vec::new();
    let mut conn_close = !http11; // HTTP/1.0 defaults to close
    let mut content_length: u64 = 0;
    let mut chunked = false;
    loop {
        let line = match read_line_limited(r, cfg.max_header_line) {
            Line::Some(l) => l,
            Line::Eof => return Parsed::Bad(400, "truncated headers"),
            Line::TooLong => return Parsed::Bad(431, "header line too long"),
            Line::IoErr => return Parsed::IoGone,
        };
        if line.is_empty() {
            break;
        }
        if headers.len() >= cfg.max_headers {
            return Parsed::Bad(431, "too many headers");
        }
        let line = match String::from_utf8(line) {
            Ok(s) => s,
            Err(_) => return Parsed::Bad(400, "header is not utf-8"),
        };
        let Some((name, value)) = line.split_once(':') else {
            return Parsed::Bad(400, "malformed header");
        };
        if name.is_empty() || name.contains(' ') {
            return Parsed::Bad(400, "malformed header name");
        }
        let name = name.to_ascii_lowercase();
        let value = value.trim().to_string();
        match name.as_str() {
            "connection" => {
                let v = value.to_ascii_lowercase();
                if v.contains("close") {
                    conn_close = true;
                } else if v.contains("keep-alive") {
                    conn_close = false;
                }
            }
            "content-length" => match value.parse::<u64>() {
                Ok(n) => content_length = n,
                Err(_) => return Parsed::Bad(400, "malformed content-length"),
            },
            "transfer-encoding" => chunked = true,
            _ => {}
        }
        headers.push((name, value));
    }
    if chunked {
        return Parsed::Bad(400, "chunked transfer not supported");
    }
    match method {
        "GET" | "HEAD" => {
            if content_length > 0 {
                return Parsed::Bad(400, "request bodies not supported");
            }
        }
        "POST" => {
            // Refuse before reading: an oversized declaration never makes
            // the worker buffer (or even skip) the payload.
            if content_length > cfg.max_body as u64 {
                return Parsed::Bad(413, "request body too large");
            }
        }
        _ => return Parsed::Bad(405, "only GET, HEAD and POST are supported"),
    }
    let mut body = vec![0u8; content_length as usize];
    if !body.is_empty() {
        // The per-read socket timeout covers the body too, so a slow-loris
        // writer trickling body bytes is dropped, not waited on forever.
        if r.read_exact(&mut body).is_err() {
            return Parsed::IoGone;
        }
    }

    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target.to_string(), None),
    };
    Parsed::Ok(
        Request {
            method: method.to_string(),
            path,
            query,
            headers,
            body,
        },
        !conn_close,
    )
}

// ---------------------------------------------------------------------------
// Response writing
// ---------------------------------------------------------------------------

fn write_response(
    stream: &mut TcpStream,
    resp: &Response,
    keep_alive: bool,
    head_only: bool,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        resp.status,
        reason(resp.status),
        resp.content_type,
        resp.body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    stream.write_all(head.as_bytes())?;
    if !head_only {
        stream.write_all(&resp.body)?;
    }
    stream.flush()
}

/// Best-effort error reply on a raw stream (accept-queue overflow, parse
/// failure). Errors writing it are ignored — the connection is being
/// dropped either way.
fn write_error(stream: &mut TcpStream, status: u16, msg: &str) {
    let resp = Response::text(status, format!("{msg}\n"));
    let _ = write_response(stream, &resp, false, false);
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// A running HTTP/1.1 server. Shut down explicitly with
/// [`Http1Server::shutdown`] or implicitly on drop.
pub struct Http1Server {
    local: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Http1Server {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and start
    /// serving `handler` on a bounded worker pool.
    pub fn bind(addr: &str, cfg: Http1Config, handler: Handler) -> Result<Http1Server> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("http1: bind {addr} failed"))?;
        let local = listener.local_addr().context("http1: local_addr failed")?;
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx): (SyncSender<TcpStream>, Receiver<TcpStream>) =
            mpsc::sync_channel(cfg.queue_depth.max(1));
        let rx = Arc::new(Mutex::new(rx));

        let mut workers = Vec::new();
        for i in 0..cfg.workers.max(1) {
            let rx = Arc::clone(&rx);
            let handler = Arc::clone(&handler);
            let cfg = cfg.clone();
            let stop = Arc::clone(&stop);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("http1-worker-{i}"))
                    .spawn(move || loop {
                        let stream = {
                            let guard = rx.lock().unwrap_or_else(|e| e.into_inner());
                            guard.recv()
                        };
                        match stream {
                            Ok(s) => handle_connection(s, &cfg, &handler, &stop),
                            Err(_) => break, // accept thread gone
                        }
                    })
                    .context("http1: spawn worker failed")?,
            );
        }

        let accept_stop = Arc::clone(&stop);
        let accept = std::thread::Builder::new()
            .name("http1-accept".to_string())
            .spawn(move || {
                for conn in listener.incoming() {
                    if accept_stop.load(Ordering::Acquire) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    match tx.try_send(stream) {
                        Ok(()) => {}
                        Err(TrySendError::Full(mut stream)) => {
                            write_error(&mut stream, 503, "connection queue full");
                        }
                        Err(TrySendError::Disconnected(_)) => break,
                    }
                }
                // tx drops here; workers drain the queue and exit.
            })
            .context("http1: spawn accept thread failed")?;

        Ok(Http1Server {
            local,
            stop,
            accept: Some(accept),
            workers,
        })
    }

    /// The address actually bound (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Stop accepting, drain workers, join all threads. Idempotent.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Release);
        // Unblock accept(2): the flag is checked after each accept.
        let _ = TcpStream::connect(self.local);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Http1Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_connection(stream: TcpStream, cfg: &Http1Config, handler: &Handler, stop: &AtomicBool) {
    if stream.set_read_timeout(Some(cfg.read_timeout)).is_err() {
        return;
    }
    let _ = stream.set_write_timeout(Some(cfg.read_timeout));
    let _ = stream.set_nodelay(true);
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut write_half = stream;
    let mut reader = BufReader::new(read_half);

    for served in 0..cfg.max_requests_per_conn {
        if stop.load(Ordering::Acquire) {
            break;
        }
        match parse_request(&mut reader, cfg) {
            Parsed::Ok(req, peer_keep_alive) => {
                let keep_alive = peer_keep_alive && served + 1 < cfg.max_requests_per_conn;
                // A panicking handler must not take the worker thread (and
                // its share of the pool) with it: answer 500 and carry on.
                let resp = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| handler(&req)))
                    .unwrap_or_else(|_| Response::text(500, "handler panicked\n"));
                let head_only = req.method == "HEAD";
                if write_response(&mut write_half, &resp, keep_alive, head_only).is_err() {
                    return;
                }
                if !keep_alive {
                    return;
                }
            }
            Parsed::Closed | Parsed::IoGone => return,
            Parsed::Bad(status, msg) => {
                write_error(&mut write_half, status, msg);
                return;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// A scraped response.
#[derive(Debug, Clone)]
pub struct HttpResponse {
    pub status: u16,
    pub body: String,
}

impl HttpResponse {
    pub fn is_ok(&self) -> bool {
        (200..300).contains(&self.status)
    }
}

/// Split `http://host:port/path` into (authority, path-with-query).
fn split_url(url: &str) -> Result<(String, String)> {
    let rest = url
        .strip_prefix("http://")
        .with_context(|| format!("only http:// URLs are supported, got {url}"))?;
    let (authority, path) = match rest.find('/') {
        Some(i) => (&rest[..i], &rest[i..]),
        None => (rest, "/"),
    };
    if authority.is_empty() {
        bail!("URL has no host: {url}");
    }
    Ok((authority.to_string(), path.to_string()))
}

/// Read one HTTP/1.1 response (status line, headers, body) off `reader`.
/// Returns the response plus whether the server left the connection open
/// (`Connection: keep-alive` semantics).
fn read_response<R: BufRead>(reader: &mut R, origin: &str) -> Result<(HttpResponse, bool)> {
    let status_line = match read_line_limited(reader, 4096) {
        Line::Some(l) => String::from_utf8(l).context("status line is not utf-8")?,
        _ => bail!("no response from {origin}"),
    };
    let mut parts = status_line.split(' ');
    let (proto, code) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    if !proto.starts_with("HTTP/1.") {
        bail!("malformed status line from {origin}: {status_line:?}");
    }
    let status: u16 = code
        .parse()
        .with_context(|| format!("malformed status code from {origin}: {status_line:?}"))?;

    let mut content_length: Option<usize> = None;
    let mut keep = true; // HTTP/1.1 default
    loop {
        let line = match read_line_limited(reader, 16 * 1024) {
            Line::Some(l) => l,
            Line::Eof => bail!("truncated response headers from {origin}"),
            Line::TooLong => bail!("oversized response header from {origin}"),
            Line::IoErr => bail!("read timed out on response headers from {origin}"),
        };
        if line.is_empty() {
            break;
        }
        let line = String::from_utf8_lossy(&line).to_string();
        if let Some((name, value)) = line.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse::<usize>().ok();
            } else if name.eq_ignore_ascii_case("connection")
                && value.trim().eq_ignore_ascii_case("close")
            {
                keep = false;
            }
        }
    }

    let mut body = Vec::new();
    match content_length {
        Some(n) => {
            body.resize(n, 0);
            reader
                .read_exact(&mut body)
                .with_context(|| format!("truncated response body from {origin}"))?;
        }
        None => {
            // No framing: the body runs to EOF, so the connection is spent.
            keep = false;
            reader
                .read_to_end(&mut body)
                .with_context(|| format!("reading response body from {origin} failed"))?;
        }
    }
    Ok((
        HttpResponse {
            status,
            body: String::from_utf8_lossy(&body).to_string(),
        },
        keep,
    ))
}

/// Blocking `GET url` with a deadline on connect, read and write.
/// `Connection: close` is always sent, so one call is one TCP connection.
pub fn http_get(url: &str, timeout: Duration) -> Result<HttpResponse> {
    let (authority, path) = split_url(url)?;
    let addr = authority
        .to_socket_addrs()
        .with_context(|| format!("cannot resolve {authority}"))?
        .next()
        .with_context(|| format!("no address for {authority}"))?;
    let stream = TcpStream::connect_timeout(&addr, timeout)
        .with_context(|| format!("connect {authority} failed"))?;
    stream.set_read_timeout(Some(timeout)).ok();
    stream.set_write_timeout(Some(timeout)).ok();
    let mut write_half = stream.try_clone().context("clone stream failed")?;
    write_half
        .write_all(
            format!("GET {path} HTTP/1.1\r\nHost: {authority}\r\nConnection: close\r\n\r\n")
                .as_bytes(),
        )
        .context("write request failed")?;
    write_half.flush().ok();

    let mut reader = BufReader::new(stream);
    let (resp, _keep) = read_response(&mut reader, url)?;
    Ok(resp)
}

/// Blocking one-shot `POST url` on a fresh connection.  For request
/// streams, use [`Http1Client`] — the keep-alive variant.
pub fn http_post(
    url: &str,
    content_type: &str,
    body: &[u8],
    timeout: Duration,
) -> Result<HttpResponse> {
    let (authority, path) = split_url(url)?;
    let mut client = Http1Client::connect(&authority, timeout)?;
    client.post(&path, content_type, body)
}

/// A persistent keep-alive HTTP/1.1 client pinned to one authority
/// (`host:port`).  Requests are issued serially on a single connection;
/// when the server closes it (per-connection request cap, error close,
/// restart) the next call transparently redials and retries once.  The
/// retry can re-send a request the server may already have executed, so
/// callers should only POST idempotent operations — `/encode` is.
pub struct Http1Client {
    authority: String,
    addr: SocketAddr,
    timeout: Duration,
    conn: Option<BufReader<TcpStream>>,
}

impl Http1Client {
    /// Resolve `authority` (`host:port`) once; the connection itself is
    /// dialed lazily on the first request.
    pub fn connect(authority: &str, timeout: Duration) -> Result<Http1Client> {
        let addr = authority
            .to_socket_addrs()
            .with_context(|| format!("cannot resolve {authority}"))?
            .next()
            .with_context(|| format!("no address for {authority}"))?;
        Ok(Http1Client {
            authority: authority.to_string(),
            addr,
            timeout,
            conn: None,
        })
    }

    fn dial(&self) -> Result<BufReader<TcpStream>> {
        let stream = TcpStream::connect_timeout(&self.addr, self.timeout)
            .with_context(|| format!("connect {} failed", self.authority))?;
        stream.set_read_timeout(Some(self.timeout)).ok();
        stream.set_write_timeout(Some(self.timeout)).ok();
        stream.set_nodelay(true).ok();
        Ok(BufReader::new(stream))
    }

    /// POST `body` to `path`, reusing the live connection when possible.
    /// A request that fails on a *reused* connection redials and retries
    /// once — the server may have closed between requests.
    pub fn post(&mut self, path: &str, content_type: &str, body: &[u8]) -> Result<HttpResponse> {
        let reused = self.conn.is_some();
        match self.try_post(path, content_type, body) {
            Ok(resp) => Ok(resp),
            Err(e) => {
                self.conn = None;
                if reused {
                    self.try_post(path, content_type, body)
                } else {
                    Err(e)
                }
            }
        }
    }

    fn try_post(&mut self, path: &str, content_type: &str, body: &[u8]) -> Result<HttpResponse> {
        if self.conn.is_none() {
            self.conn = Some(self.dial()?);
        }
        let Some(reader) = self.conn.as_mut() else {
            bail!("connection lost immediately after dial");
        };
        let head = format!(
            "POST {path} HTTP/1.1\r\nHost: {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n\r\n",
            self.authority,
            body.len(),
        );
        {
            let stream = reader.get_mut();
            stream
                .write_all(head.as_bytes())
                .context("write request head failed")?;
            stream.write_all(body).context("write request body failed")?;
            stream.flush().context("flush request failed")?;
        }
        let (resp, keep) = read_response(reader, &self.authority)?;
        if !keep {
            self.conn = None;
        }
        Ok(resp)
    }
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    /// Echo-ish handler: 200 with the path as body, 404 on `/missing`,
    /// body echo on `/echo`, a 2 MiB payload on `/big`.
    fn test_handler() -> Handler {
        Arc::new(|req: &Request| {
            if req.path == "/missing" {
                Response::not_found()
            } else if req.path == "/panic" {
                panic!("handler bug under test");
            } else if req.path == "/echo" {
                Response::text(
                    200,
                    format!(
                        "len={} body={}",
                        req.body.len(),
                        String::from_utf8_lossy(&req.body)
                    ),
                )
            } else if req.path == "/big" {
                Response {
                    status: 200,
                    content_type: "application/octet-stream".to_string(),
                    body: vec![b'x'; 2 << 20],
                }
            } else {
                Response::text(
                    200,
                    format!("path={} query={}", req.path, req.query.as_deref().unwrap_or("-")),
                )
            }
        })
    }

    fn spawn(cfg: Http1Config) -> Http1Server {
        Http1Server::bind("127.0.0.1:0", cfg, test_handler()).expect("bind")
    }

    fn url(srv: &Http1Server, path: &str) -> String {
        format!("http://{}{}", srv.local_addr(), path)
    }

    /// Open a raw connection with client-side timeouts so no test can hang.
    fn raw_conn(srv: &Http1Server) -> TcpStream {
        let s = TcpStream::connect(srv.local_addr()).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.set_write_timeout(Some(Duration::from_secs(5))).unwrap();
        s
    }

    /// Write `req` raw, read everything until the server closes.
    fn raw_roundtrip(srv: &Http1Server, req: &[u8]) -> String {
        let mut s = raw_conn(srv);
        s.write_all(req).expect("write");
        let mut out = String::new();
        let _ = s.read_to_string(&mut out);
        out
    }

    #[test]
    fn get_roundtrip_via_client() {
        let srv = spawn(Http1Config::default());
        let resp = http_get(&url(&srv, "/hello?x=1"), Duration::from_secs(5)).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, "path=/hello query=x=1");
        let resp = http_get(&url(&srv, "/missing"), Duration::from_secs(5)).unwrap();
        assert_eq!(resp.status, 404);
    }

    #[test]
    fn keep_alive_serves_multiple_requests_on_one_connection() {
        let srv = spawn(Http1Config::default());
        let mut s = raw_conn(&srv);
        for i in 0..3 {
            s.write_all(format!("GET /r{i} HTTP/1.1\r\nHost: t\r\n\r\n").as_bytes())
                .unwrap();
            let mut buf = [0u8; 2048];
            let n = s.read(&mut buf).expect("read");
            let text = String::from_utf8_lossy(&buf[..n]).to_string();
            assert!(text.starts_with("HTTP/1.1 200"), "resp {i}: {text}");
            assert!(text.contains(&format!("path=/r{i}")), "resp {i}: {text}");
            assert!(text.contains("Connection: keep-alive"), "resp {i}: {text}");
        }
    }

    #[test]
    fn per_connection_request_cap_closes_connection() {
        let cfg = Http1Config {
            max_requests_per_conn: 2,
            ..Http1Config::default()
        };
        let srv = spawn(cfg);
        let mut s = raw_conn(&srv);
        // First response keeps the connection; the second (cap) closes it.
        s.write_all(b"GET /a HTTP/1.1\r\nHost: t\r\n\r\nGET /b HTTP/1.1\r\nHost: t\r\n\r\n")
            .unwrap();
        let mut out = String::new();
        let _ = s.read_to_string(&mut out);
        assert!(out.contains("path=/a"), "{out}");
        assert!(out.contains("path=/b"), "{out}");
        assert!(out.contains("Connection: close"), "{out}");
    }

    #[test]
    fn head_gets_headers_but_no_body() {
        let srv = spawn(Http1Config::default());
        let out = raw_roundtrip(&srv, b"HEAD /h HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
        assert!(out.starts_with("HTTP/1.1 200"), "{out}");
        assert!(out.contains("Content-Length:"), "{out}");
        assert!(!out.contains("path=/h"), "HEAD must not carry a body: {out}");
    }

    #[test]
    fn http10_defaults_to_close() {
        let srv = spawn(Http1Config::default());
        let out = raw_roundtrip(&srv, b"GET /ten HTTP/1.0\r\n\r\n");
        assert!(out.starts_with("HTTP/1.1 200"), "{out}");
        assert!(out.contains("Connection: close"), "{out}");
    }

    // -- malformed-input fuzzing (the parser must 4xx-or-close, never panic,
    //    never hang; client-side timeouts in raw_conn bound every read) -----

    #[test]
    fn garbage_request_line_is_400() {
        let srv = spawn(Http1Config::default());
        let out = raw_roundtrip(&srv, b"\x01\x02\xff garbage\r\n\r\n");
        assert!(out.starts_with("HTTP/1.1 400"), "{out}");
    }

    #[test]
    fn bad_method_is_rejected() {
        let srv = spawn(Http1Config::default());
        // Unknown-but-well-formed method: parse succeeds, dispatch refuses.
        let out = raw_roundtrip(&srv, b"BREW /pot HTTP/1.1\r\nHost: t\r\n\r\n");
        assert!(out.starts_with("HTTP/1.1 405"), "{out}");
        // Lower-case (token rule violated) is a parse error.
        let out = raw_roundtrip(&srv, b"get / HTTP/1.1\r\n\r\n");
        assert!(out.starts_with("HTTP/1.1 400"), "{out}");
        // Absurdly long method token.
        let long = format!("{} / HTTP/1.1\r\n\r\n", "M".repeat(64));
        let out = raw_roundtrip(&srv, long.as_bytes());
        assert!(out.starts_with("HTTP/1.1 400"), "{out}");
    }

    #[test]
    fn oversized_request_line_is_414() {
        let cfg = Http1Config {
            max_request_line: 256,
            ..Http1Config::default()
        };
        let srv = spawn(cfg);
        let req = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(1024));
        let out = raw_roundtrip(&srv, req.as_bytes());
        assert!(out.starts_with("HTTP/1.1 414"), "{out}");
    }

    #[test]
    fn oversized_header_line_is_431() {
        let cfg = Http1Config {
            max_header_line: 256,
            ..Http1Config::default()
        };
        let srv = spawn(cfg);
        let req = format!("GET / HTTP/1.1\r\nX-Big: {}\r\n\r\n", "b".repeat(1024));
        let out = raw_roundtrip(&srv, req.as_bytes());
        assert!(out.starts_with("HTTP/1.1 431"), "{out}");
    }

    #[test]
    fn too_many_headers_is_431() {
        let cfg = Http1Config {
            max_headers: 8,
            ..Http1Config::default()
        };
        let srv = spawn(cfg);
        let mut req = String::from("GET / HTTP/1.1\r\n");
        for i in 0..32 {
            req.push_str(&format!("X-H{i}: v\r\n"));
        }
        req.push_str("\r\n");
        let out = raw_roundtrip(&srv, req.as_bytes());
        assert!(out.starts_with("HTTP/1.1 431"), "{out}");
    }

    #[test]
    fn request_body_is_400() {
        let srv = spawn(Http1Config::default());
        let out = raw_roundtrip(
            &srv,
            b"GET / HTTP/1.1\r\nHost: t\r\nContent-Length: 5\r\n\r\nhello",
        );
        assert!(out.starts_with("HTTP/1.1 400"), "{out}");
        let out = raw_roundtrip(
            &srv,
            b"GET / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n0\r\n\r\n",
        );
        assert!(out.starts_with("HTTP/1.1 400"), "{out}");
    }

    #[test]
    fn truncated_headers_then_close_gets_400_and_server_survives() {
        let srv = spawn(Http1Config::default());
        {
            let mut s = raw_conn(&srv);
            s.write_all(b"GET / HTTP/1.1\r\nX-Half: tru").unwrap();
            drop(s); // close mid-request
        }
        {
            let mut s = raw_conn(&srv);
            // Clean close after headers started → 400 "truncated headers".
            s.write_all(b"GET / HTTP/1.1\r\nX-Half: whole\r\n").unwrap();
            let _ = s.shutdown(std::net::Shutdown::Write);
            let mut out = String::new();
            let _ = s.read_to_string(&mut out);
            assert!(out.starts_with("HTTP/1.1 400"), "{out}");
        }
        // Server still answers a well-formed request afterwards.
        let resp = http_get(&url(&srv, "/alive"), Duration::from_secs(5)).unwrap();
        assert_eq!(resp.status, 200);
    }

    #[test]
    fn early_close_before_any_bytes_is_silent() {
        let srv = spawn(Http1Config::default());
        for _ in 0..4 {
            let s = raw_conn(&srv);
            drop(s);
        }
        let resp = http_get(&url(&srv, "/still-here"), Duration::from_secs(5)).unwrap();
        assert_eq!(resp.status, 200);
    }

    #[test]
    fn pipelined_garbage_after_valid_request_closes_with_4xx() {
        let srv = spawn(Http1Config::default());
        let mut s = raw_conn(&srv);
        s.write_all(b"GET /ok HTTP/1.1\r\nHost: t\r\n\r\n?!?! not http\r\n\r\n")
            .unwrap();
        let mut out = String::new();
        let _ = s.read_to_string(&mut out);
        assert!(out.contains("path=/ok"), "{out}");
        assert!(out.contains("HTTP/1.1 400"), "pipelined garbage must 400: {out}");
    }

    #[test]
    fn idle_connection_is_dropped_after_read_timeout() {
        let cfg = Http1Config {
            read_timeout: Duration::from_millis(100),
            ..Http1Config::default()
        };
        let srv = spawn(cfg);
        let mut s = raw_conn(&srv);
        // Send nothing; the server should drop us within ~read_timeout.
        let mut buf = [0u8; 64];
        let n = s.read(&mut buf).unwrap_or(0);
        assert_eq!(n, 0, "expected EOF from idle-timeout close");
    }

    #[test]
    fn handler_panic_is_500_and_pool_survives() {
        let srv = spawn(Http1Config::default());
        let resp = http_get(&url(&srv, "/panic"), Duration::from_secs(5)).unwrap();
        assert_eq!(resp.status, 500);
        // Same worker pool still serves afterwards (repeat past pool size).
        for _ in 0..4 {
            let resp = http_get(&url(&srv, "/after"), Duration::from_secs(5)).unwrap();
            assert_eq!(resp.status, 200);
        }
    }

    #[test]
    fn shutdown_joins_and_port_stops_answering() {
        let mut srv = spawn(Http1Config::default());
        let addr = srv.local_addr();
        assert_eq!(
            http_get(&format!("http://{addr}/x"), Duration::from_secs(5))
                .unwrap()
                .status,
            200
        );
        srv.shutdown();
        srv.shutdown(); // idempotent
        let after = http_get(&format!("http://{addr}/x"), Duration::from_millis(500));
        assert!(after.is_err(), "server must stop serving after shutdown");
    }

    // -- POST bodies + persistent client ------------------------------------

    #[test]
    fn post_roundtrip_and_keep_alive_via_persistent_client() {
        let srv = spawn(Http1Config::default());
        let authority = srv.local_addr().to_string();
        let mut client = Http1Client::connect(&authority, Duration::from_secs(5)).unwrap();
        for i in 0..3 {
            let body = format!("payload-{i}");
            let resp = client.post("/echo", "text/plain", body.as_bytes()).unwrap();
            assert_eq!(resp.status, 200);
            assert_eq!(resp.body, format!("len={} body={body}", body.len()));
        }
        // One-shot helper takes the same path on a fresh connection.
        let resp = http_post(
            &url(&srv, "/echo"),
            "text/plain",
            b"oneshot",
            Duration::from_secs(5),
        )
        .unwrap();
        assert_eq!(resp.body, "len=7 body=oneshot");
    }

    #[test]
    fn post_with_empty_body_is_ok() {
        let srv = spawn(Http1Config::default());
        let out = raw_roundtrip(
            &srv,
            b"POST /echo HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n",
        );
        assert!(out.starts_with("HTTP/1.1 200"), "{out}");
        assert!(out.contains("len=0"), "{out}");
    }

    #[test]
    fn persistent_client_reconnects_past_request_cap() {
        let cfg = Http1Config {
            max_requests_per_conn: 2,
            ..Http1Config::default()
        };
        let srv = spawn(cfg);
        let authority = srv.local_addr().to_string();
        let mut client = Http1Client::connect(&authority, Duration::from_secs(5)).unwrap();
        // 5 requests over a 2-request cap forces at least two reconnects;
        // every call must still succeed.
        for i in 0..5 {
            let resp = client
                .post("/echo", "text/plain", format!("r{i}").as_bytes())
                .unwrap();
            assert_eq!(resp.status, 200, "request {i}");
        }
    }

    #[test]
    fn oversized_body_is_413() {
        let cfg = Http1Config {
            max_body: 64,
            ..Http1Config::default()
        };
        let srv = spawn(cfg);
        // The 413 must come back on the declaration alone — no body sent.
        let out = raw_roundtrip(
            &srv,
            b"POST /echo HTTP/1.1\r\nHost: t\r\nContent-Length: 1048576\r\n\r\n",
        );
        assert!(out.starts_with("HTTP/1.1 413"), "{out}");
        // Server keeps serving other connections.
        let resp = http_get(&url(&srv, "/alive"), Duration::from_secs(5)).unwrap();
        assert_eq!(resp.status, 200);
    }

    #[test]
    fn malformed_content_length_is_400() {
        let srv = spawn(Http1Config::default());
        let out = raw_roundtrip(
            &srv,
            b"POST /echo HTTP/1.1\r\nHost: t\r\nContent-Length: banana\r\n\r\n",
        );
        assert!(out.starts_with("HTTP/1.1 400"), "{out}");
        let out = raw_roundtrip(
            &srv,
            b"POST /echo HTTP/1.1\r\nHost: t\r\nContent-Length: -5\r\n\r\n",
        );
        assert!(out.starts_with("HTTP/1.1 400"), "{out}");
    }

    // -- network fault injection (the front door must 4xx-or-close, never
    //    panic, and keep serving sibling connections) -----------------------

    #[test]
    fn slow_loris_body_is_dropped_and_sibling_survives() {
        let cfg = Http1Config {
            read_timeout: Duration::from_millis(150),
            ..Http1Config::default()
        };
        let srv = spawn(cfg);
        let mut s = raw_conn(&srv);
        s.write_all(b"POST /echo HTTP/1.1\r\nHost: t\r\nContent-Length: 10\r\n\r\n")
            .unwrap();
        // Trickle one byte, then stall past the read timeout.
        s.write_all(b"x").unwrap();
        // A healthy sibling is served *while* the loris stalls.
        let resp = http_get(&url(&srv, "/sibling"), Duration::from_secs(5)).unwrap();
        assert_eq!(resp.status, 200);
        // The stalled connection is dropped without a response.
        let mut buf = [0u8; 64];
        let n = s.read(&mut buf).unwrap_or(0);
        assert_eq!(n, 0, "expected EOF after body read timeout");
        // And the worker that held it is back in rotation.
        let resp = http_get(&url(&srv, "/after-loris"), Duration::from_secs(5)).unwrap();
        assert_eq!(resp.status, 200);
    }

    #[test]
    fn disconnect_mid_body_is_survived() {
        let srv = spawn(Http1Config::default());
        {
            let mut s = raw_conn(&srv);
            s.write_all(b"POST /echo HTTP/1.1\r\nHost: t\r\nContent-Length: 10\r\n\r\nabc")
                .unwrap();
            drop(s); // vanish with 7 body bytes owed
        }
        let resp = http_get(&url(&srv, "/alive"), Duration::from_secs(5)).unwrap();
        assert_eq!(resp.status, 200);
    }

    #[test]
    fn premature_eof_during_response_write_is_survived() {
        let srv = spawn(Http1Config::default());
        // Ask for 2 MiB, then walk away before reading any of it: the
        // server's write eventually fails (reset/EPIPE) or lands in limbo —
        // either way no panic, and the pool keeps serving.
        for _ in 0..3 {
            let mut s = raw_conn(&srv);
            s.write_all(b"GET /big HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
            drop(s);
        }
        for _ in 0..4 {
            let resp = http_get(&url(&srv, "/alive"), Duration::from_secs(5)).unwrap();
            assert_eq!(resp.status, 200);
        }
    }

    #[test]
    fn split_url_accepts_bare_authority_and_rejects_https() {
        assert_eq!(
            split_url("http://127.0.0.1:9100").unwrap(),
            ("127.0.0.1:9100".to_string(), "/".to_string())
        );
        assert_eq!(
            split_url("http://h:1/metrics?x=1").unwrap(),
            ("h:1".to_string(), "/metrics?x=1".to_string())
        );
        assert!(split_url("https://h/").is_err());
        assert!(split_url("http:///nohost").is_err());
    }
}
