//! Networking layer — hand-rolled, `std::net` only (the crate vendors no
//! HTTP stack and CI is offline).
//!
//! [`http1`] is a deliberately minimal HTTP/1.1 server + client pair built
//! for the read-only telemetry plane (`trace::telemetry_http`): strict
//! request parsing with hard limits, keep-alive with a per-connection
//! request cap, a bounded accept-thread + worker-pool model, and a clean
//! shutdown handle.  It is also the first proving ground for the
//! connection machinery the planned network serving front-end
//! (ROADMAP #1) will reuse.

pub mod http1;

pub use http1::{
    http_get, Handler, Http1Config, Http1Server, HttpResponse, Request, Response,
};
