//! Networking layer — hand-rolled, `std::net` only (the crate vendors no
//! HTTP stack and CI is offline).
//!
//! [`http1`] is a deliberately minimal HTTP/1.1 server + client pair:
//! strict request parsing with hard limits (request line, headers, body),
//! keep-alive with a per-connection request cap, a bounded accept-thread +
//! worker-pool model, and a clean shutdown handle.  It started life as the
//! wire layer of the read-only telemetry plane (`trace::telemetry_http`)
//! and now also carries the serving data plane: `serve::frontend` binds it
//! as the `POST /encode` front door, and [`http1::Http1Client`] is the
//! persistent reconnect-on-close client the loadgen socket mode drives it
//! with.

pub mod http1;

pub use http1::{
    http_get, http_post, Handler, Http1Client, Http1Config, Http1Server, HttpResponse, Request,
    Response,
};
