//! Configuration system: everything a training run or experiment sweep
//! needs.  Serializable to JSON (via the in-tree [`crate::util::json`]
//! writer) so experiment presets can be recorded alongside their logs.

use crate::data::Shift;
use crate::util::json::ObjWriter;

/// Which optimizer drives the run (paper §3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimizerKind {
    /// plain AdamW [37]
    Adamw,
    /// AdamW + AdaFactor update clipping — the paper's StableAdamW (Alg. 2)
    StableAdamw,
    /// Lion (Appendix E baseline)
    Lion,
}

impl OptimizerKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "adamw" => Some(Self::Adamw),
            "stable_adamw" => Some(Self::StableAdamw),
            "lion" => Some(Self::Lion),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::Adamw => "adamw",
            Self::StableAdamw => "stable_adamw",
            Self::Lion => "lion",
        }
    }
}

impl std::str::FromStr for OptimizerKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Self::parse(s).ok_or_else(|| format!("unknown optimizer {s:?}"))
    }
}

/// Loss-scaler policy (§3.6).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ScalerKind {
    /// no fp16 simulation (pure f32/bf16-style training)
    #[default]
    None,
    /// PyTorch-style dynamic global scaler (skip whole step, halve/double)
    DynamicGlobal,
    /// the paper's fixed tensor-level scaler (skip offending tensors only)
    FixedTensor,
}

impl ScalerKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "none" => Some(Self::None),
            "dynamic_global" => Some(Self::DynamicGlobal),
            "fixed_tensor" => Some(Self::FixedTensor),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::None => "none",
            Self::DynamicGlobal => "dynamic_global",
            Self::FixedTensor => "fixed_tensor",
        }
    }
}

/// The optimizer/schedule hyperparameters every training path shares.
///
/// Both trainers — the PJRT artifact path ([`TrainConfig`] →
/// `coordinator::Trainer`) and the native path (`train::NativeTrainer`) —
/// consume exactly this struct, so the optimizer construction and LR
/// schedule logic live in one place (`coordinator::common`) instead of
/// being duplicated per path.
#[derive(Debug, Clone)]
pub struct TrainHyper {
    pub steps: u64,
    /// linear-warmup steps (paper: 25% of the run)
    pub warmup: u64,
    pub lr: f32,
    pub weight_decay: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub optimizer: OptimizerKind,
    /// β₂ schedule 1 − t^{−λ} (Fig 15); overrides beta2 when set
    pub beta2_lambda: Option<f32>,
    /// global-norm gradient clipping (Fig 10 baseline); None = off
    pub grad_clip: Option<f32>,
    pub seed: u64,
}

impl TrainHyper {
    /// Paper-shaped defaults (lr 2e-3, wd 0.2, 25% warmup, StableAdamW)
    /// scaled to a short run.
    pub fn preset(steps: u64) -> Self {
        Self {
            steps,
            warmup: steps / 4,
            lr: 2e-3,
            weight_decay: 0.2,
            beta1: 0.9,
            beta2: 0.999,
            optimizer: OptimizerKind::StableAdamw,
            beta2_lambda: None,
            grad_clip: None,
            seed: 0,
        }
    }

    /// JSON summary fragment (shared by both paths' run logs).
    pub fn write_json(&self, w: &mut ObjWriter) {
        w.field_u64("steps", self.steps)
            .field_u64("warmup", self.warmup)
            .field_f32("lr", self.lr)
            .field_f32("weight_decay", self.weight_decay)
            .field_f32("beta1", self.beta1)
            .field_f32("beta2", self.beta2)
            .field_str("optimizer", self.optimizer.label())
            .field_u64("seed", self.seed);
        if let Some(l) = self.beta2_lambda {
            w.field_f32("beta2_lambda", l);
        }
        if let Some(c) = self.grad_clip {
            w.field_f32("grad_clip", c);
        }
    }
}

/// One training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// artifact name (e.g. "switchback_int8_small_b32") under `artifact_dir`
    pub artifact: String,
    pub artifact_dir: String,
    pub steps: u64,
    /// linear-warmup steps (paper: 25% of the run)
    pub warmup: u64,
    pub lr: f32,
    pub weight_decay: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub optimizer: OptimizerKind,
    /// β₂ schedule 1 − t^{−λ} (Fig 15); overrides beta2 when set
    pub beta2_lambda: Option<f32>,
    /// global-norm gradient clipping (Fig 10 baseline); None = off
    pub grad_clip: Option<f32>,
    pub scaler: ScalerKind,
    pub seed: u64,
    /// re-initialize params from the manifest init specs with this seed
    /// instead of loading params.bin (seed 0 keeps the jax init exactly)
    pub reinit: bool,
    /// scheduled distribution shifts (the spike trigger; DESIGN.md)
    pub shifts: Vec<Shift>,
    /// log feature magnitudes / grad probes every N steps (0 = never)
    pub probe_every: u64,
    /// JSONL metrics path (None = in-memory only)
    pub metrics_path: Option<String>,
    /// evaluate zero-shot accuracy every N steps (0 = only at the end)
    pub eval_every: u64,
    /// examples per concept in the eval set
    pub eval_per_concept: usize,
}

impl TrainConfig {
    /// Baseline config used by the experiment presets: paper-shaped
    /// (lr 2e-3, wd 0.2, 25% warmup) scaled to a short run.
    pub fn preset(artifact: &str, steps: u64) -> Self {
        Self {
            artifact: artifact.to_string(),
            artifact_dir: "artifacts".into(),
            steps,
            warmup: steps / 4,
            lr: 2e-3,
            weight_decay: 0.2,
            beta1: 0.9,
            beta2: 0.999,
            optimizer: OptimizerKind::StableAdamw,
            beta2_lambda: None,
            grad_clip: None,
            scaler: ScalerKind::None,
            seed: 0,
            reinit: false,
            shifts: vec![],
            probe_every: 1,
            metrics_path: None,
            eval_every: 0,
            eval_per_concept: 4,
        }
    }

    pub fn with_optimizer(mut self, opt: OptimizerKind, beta2: f32) -> Self {
        self.optimizer = opt;
        self.beta2 = beta2;
        self
    }

    /// The shared optimizer/schedule hyperparameters of this run — the
    /// slice of the config that `coordinator::common::build_optimizer`
    /// and the LR schedule consume (identical for the native path).
    pub fn hyper(&self) -> TrainHyper {
        TrainHyper {
            steps: self.steps,
            warmup: self.warmup,
            lr: self.lr,
            weight_decay: self.weight_decay,
            beta1: self.beta1,
            beta2: self.beta2,
            optimizer: self.optimizer,
            beta2_lambda: self.beta2_lambda,
            grad_clip: self.grad_clip,
            seed: self.seed,
        }
    }

    /// JSON summary for run logs (records the exact knob settings).
    pub fn to_json(&self) -> String {
        let mut w = ObjWriter::new();
        w.field_str("artifact", &self.artifact);
        self.hyper().write_json(&mut w);
        w.field_str("scaler", self.scaler.label())
            .field_bool("reinit", self.reinit);
        if !self.shifts.is_empty() {
            w.field_u64("n_shifts", self.shifts.len() as u64);
        }
        w.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn preset_is_paper_shaped() {
        let cfg = TrainConfig::preset("highprec_micro_b32", 100);
        assert_eq!(cfg.warmup, 25);
        assert_eq!(cfg.lr, 2e-3);
        assert_eq!(cfg.weight_decay, 0.2);
        assert_eq!(cfg.optimizer, OptimizerKind::StableAdamw);
    }

    #[test]
    fn kind_labels_roundtrip() {
        for k in [OptimizerKind::Adamw, OptimizerKind::StableAdamw, OptimizerKind::Lion] {
            assert_eq!(OptimizerKind::parse(k.label()), Some(k));
        }
        for s in [ScalerKind::None, ScalerKind::DynamicGlobal, ScalerKind::FixedTensor] {
            assert_eq!(ScalerKind::parse(s.label()), Some(s));
        }
        assert_eq!(OptimizerKind::parse("bogus"), None);
    }

    #[test]
    fn hyper_slice_matches_config() {
        let mut cfg = TrainConfig::preset("a", 120);
        cfg.grad_clip = Some(1.0);
        let h = cfg.hyper();
        assert_eq!(h.steps, 120);
        assert_eq!(h.warmup, 30);
        assert_eq!(h.optimizer, OptimizerKind::StableAdamw);
        assert_eq!(h.grad_clip, Some(1.0));
        let preset = TrainHyper::preset(120);
        assert_eq!(preset.lr, cfg.lr);
        assert_eq!(preset.weight_decay, cfg.weight_decay);
    }

    #[test]
    fn to_json_is_valid() {
        let mut cfg = TrainConfig::preset("a", 10).with_optimizer(OptimizerKind::Adamw, 0.99);
        cfg.grad_clip = Some(1.0);
        let v = parse(&cfg.to_json()).unwrap();
        assert_eq!(v.get("optimizer").unwrap().as_str(), Some("adamw"));
        assert_eq!(v.get("grad_clip").unwrap().as_f64(), Some(1.0));
    }
}
