//! Spike-detection heuristics, transcribed from paper Appendix D:
//!
//! * **RMS spike events**: `{t : RMS_t ≥ 2.3}`.
//! * **Loss spike events**: loss at `t` exceeds the running mean by 3.2×
//!   the running standard deviation; a spike only *counts* if there are
//!   multiple deviations within an interval of 10 ("which indicates that
//!   loss has meaningfully spiked").
//! * Both kinds are deduplicated: multiple events within 10 iterations
//!   count as one spike starting at the earliest time.
//! * The first `burn_in` iterations are ignored (paper: 1000, when the LR
//!   is still low; configurable because our runs are shorter).

/// Paper's loss-spike threshold: 3.2 running standard deviations.
pub const DEFAULT_LOSS_SIGMA: f32 = 3.2;
/// Paper's RMS-spike threshold: RMS_t ≥ 2.3.
pub const DEFAULT_RMS_THRESHOLD: f32 = 2.3;
/// Paper's dedup / confirmation interval: 10 iterations.
pub const DEDUP_WINDOW: u64 = 10;

#[derive(Debug, Clone)]
pub struct SpikeConfig {
    pub loss_sigma: f32,
    pub rms_threshold: f32,
    /// trailing window for the running mean/std of the loss
    pub stat_window: usize,
    /// iterations to ignore at the start
    pub burn_in: u64,
}

impl Default for SpikeConfig {
    fn default() -> Self {
        Self {
            loss_sigma: DEFAULT_LOSS_SIGMA,
            rms_threshold: DEFAULT_RMS_THRESHOLD,
            stat_window: 100,
            burn_in: 50,
        }
    }
}

/// Deduplicate raw event iterations: events within `DEDUP_WINDOW` of the
/// previous *kept* event are merged into it (earliest time wins).
fn dedup(events: &[u64]) -> Vec<u64> {
    let mut out: Vec<u64> = vec![];
    for &t in events {
        match out.last() {
            Some(&last) if t <= last + DEDUP_WINDOW => {}
            _ => out.push(t),
        }
    }
    out
}

/// Detect loss spikes in a loss trace (index = iteration, 0-based).
///
/// Running statistics use a trailing window of `cfg.stat_window` values
/// *before* the current iteration, so a spike does not inflate its own
/// baseline.
pub fn detect_loss_spikes(loss: &[f32], cfg: &SpikeConfig) -> Vec<u64> {
    let w = cfg.stat_window;
    let mut deviations: Vec<u64> = vec![];
    for t in 0..loss.len() {
        if (t as u64) < cfg.burn_in || t < 5 {
            continue;
        }
        let lo = t.saturating_sub(w);
        let hist = &loss[lo..t];
        let n = hist.len() as f64;
        let mean: f64 = hist.iter().map(|&v| v as f64).sum::<f64>() / n;
        let var: f64 =
            hist.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
        let std = var.sqrt().max(1e-12);
        if (loss[t] as f64) > mean + cfg.loss_sigma as f64 * std {
            deviations.push(t as u64);
        }
    }
    // Confirmation: a deviation only seeds a spike if another deviation
    // occurs within 10 iterations (Appendix D).
    let confirmed: Vec<u64> = deviations
        .iter()
        .copied()
        .filter(|&t| {
            deviations
                .iter()
                .any(|&s| s != t && s.abs_diff(t) <= DEDUP_WINDOW)
        })
        .collect();
    dedup(&confirmed)
}

/// Detect RMS spikes: `{t : RMS_t ≥ threshold}`, deduplicated.
pub fn detect_rms_spikes(rms: &[f32], cfg: &SpikeConfig) -> Vec<u64> {
    let raw: Vec<u64> = rms
        .iter()
        .enumerate()
        .filter(|&(t, &v)| (t as u64) >= cfg.burn_in && v >= cfg.rms_threshold)
        .map(|(t, _)| t as u64)
        .collect();
    dedup(&raw)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SpikeConfig {
        SpikeConfig { burn_in: 10, stat_window: 50, ..Default::default() }
    }

    fn flat_with_spike(at: usize, width: usize) -> Vec<f32> {
        let mut loss = vec![1.0f32; 300];
        // small jitter so std > 0
        for (i, v) in loss.iter_mut().enumerate() {
            *v += ((i % 7) as f32 - 3.0) * 0.01;
        }
        for i in at..at + width {
            loss[i] = 5.0;
        }
        loss
    }

    #[test]
    fn detects_a_sustained_spike() {
        let loss = flat_with_spike(100, 4);
        let spikes = detect_loss_spikes(&loss, &cfg());
        assert_eq!(spikes, vec![100]);
    }

    #[test]
    fn single_outlier_is_not_confirmed() {
        let loss = flat_with_spike(100, 1);
        let spikes = detect_loss_spikes(&loss, &cfg());
        assert!(spikes.is_empty(), "lone deviation must not count: {spikes:?}");
    }

    #[test]
    fn nearby_spikes_are_deduplicated() {
        let mut loss = flat_with_spike(100, 3);
        for i in 105..108 {
            loss[i] = 5.0;
        }
        let spikes = detect_loss_spikes(&loss, &cfg());
        assert_eq!(spikes, vec![100], "within-10 events merge to earliest");
    }

    #[test]
    fn separated_spikes_both_count() {
        let mut loss = flat_with_spike(100, 3);
        for i in 200..203 {
            loss[i] = 5.0;
        }
        let spikes = detect_loss_spikes(&loss, &cfg());
        assert_eq!(spikes, vec![100, 200]);
    }

    #[test]
    fn burn_in_ignored() {
        let mut loss = flat_with_spike(200, 3);
        loss[5] = 50.0;
        loss[6] = 50.0;
        let spikes = detect_loss_spikes(&loss, &cfg());
        assert_eq!(spikes, vec![200]);
    }

    #[test]
    fn rms_threshold_and_dedup() {
        let mut rms = vec![1.0f32; 100];
        rms[40] = 3.0;
        rms[45] = 2.5; // merged into 40
        rms[80] = 2.3; // exactly at threshold counts
        let spikes = detect_rms_spikes(&rms, &cfg());
        assert_eq!(spikes, vec![40, 80]);
    }

    /// Dedup window boundary: an event exactly `DEDUP_WINDOW` after the
    /// last *kept* event still merges; one iteration later starts a new
    /// spike (Appendix D's "interval of 10" is inclusive).
    #[test]
    fn dedup_window_boundary_is_inclusive() {
        let mut rms = vec![1.0f32; 120];
        rms[40] = 3.0;
        rms[50] = 3.0; // 40 + 10: inclusive → merged
        let spikes = detect_rms_spikes(&rms, &cfg());
        assert_eq!(spikes, vec![40]);

        let mut rms = vec![1.0f32; 120];
        rms[40] = 3.0;
        rms[51] = 3.0; // 40 + 11: outside → separate spike
        let spikes = detect_rms_spikes(&rms, &cfg());
        assert_eq!(spikes, vec![40, 51]);
    }

    /// Dedup anchors on the earliest *kept* event, not on the previous raw
    /// event: a chain 40,50,60 does NOT merge transitively into one spike —
    /// 50 merges into 40, but 60 is 20 past the kept event and stands alone.
    #[test]
    fn dedup_chain_does_not_merge_transitively() {
        let mut rms = vec![1.0f32; 120];
        rms[40] = 3.0;
        rms[50] = 3.0;
        rms[60] = 3.0;
        let spikes = detect_rms_spikes(&rms, &cfg());
        assert_eq!(spikes, vec![40, 60]);
    }

    /// An event exactly at `burn_in` counts; one before it does not.
    #[test]
    fn burn_in_boundary() {
        let c = cfg(); // burn_in = 10
        let mut rms = vec![1.0f32; 60];
        rms[9] = 5.0;
        assert!(detect_rms_spikes(&rms, &c).is_empty());
        rms[10] = 5.0;
        assert_eq!(detect_rms_spikes(&rms, &c), vec![10]);
    }

    /// Loss-spike confirmation straddling the dedup window: two deviations
    /// exactly 10 apart confirm each other and merge into one spike.
    #[test]
    fn loss_confirmation_at_window_edge() {
        let mut loss = vec![1.0f32; 300];
        for (i, v) in loss.iter_mut().enumerate() {
            *v += ((i % 7) as f32 - 3.0) * 0.01;
        }
        loss[100] = 5.0;
        loss[110] = 5.0; // distance exactly DEDUP_WINDOW
        let spikes = detect_loss_spikes(&loss, &cfg());
        assert_eq!(spikes, vec![100]);
        // distance 11: neither deviation is confirmed → no spikes at all
        let mut loss = vec![1.0f32; 300];
        for (i, v) in loss.iter_mut().enumerate() {
            *v += ((i % 7) as f32 - 3.0) * 0.01;
        }
        loss[100] = 5.0;
        loss[111] = 5.0;
        let spikes = detect_loss_spikes(&loss, &cfg());
        assert!(spikes.is_empty(), "unconfirmed deviations: {spikes:?}");
    }
}
