//! Lead–lag analysis: do RMS spikes *predict* loss spikes? (Fig 9, 16–21.)
//!
//! The paper's claim: 28/30 detected loss spikes follow an RMS spike in the
//! patch embedding layer by **1–8 iterations**, while the chance of that
//! happening randomly is ≈1%.  This module reproduces the computation:
//!
//! * a loss spike at `t` is *predicted* if some RMS spike occurred at
//!   `t − 8 ≤ s ≤ t − 1`;
//! * the **chance** baseline is the fraction of iterations covered by the
//!   union of `[s+1, s+8]` windows over all RMS spikes — i.e. the
//!   probability that a uniformly-random iteration is "predicted";
//! * a binomial tail p-value for observing ≥ k predicted out of n loss
//!   spikes under that chance probability.

use super::spikes::{detect_loss_spikes, detect_rms_spikes, SpikeConfig};

/// The paper's prediction window: RMS spike 1–8 iterations before the loss
/// spike.
pub const LEAD_MIN: u64 = 1;
pub const LEAD_MAX: u64 = 8;

#[derive(Debug, Clone)]
pub struct LeadLagReport {
    pub loss_spikes: Vec<u64>,
    pub rms_spikes: Vec<u64>,
    /// loss spikes with an RMS spike 1–8 iterations earlier
    pub predicted: usize,
    pub total_loss_spikes: usize,
    /// P(uniformly random iteration is inside some prediction window)
    pub chance_fraction: f64,
    /// P(≥ predicted out of total by chance)  (binomial upper tail)
    pub binom_pvalue: f64,
}

impl LeadLagReport {
    pub fn summary(&self) -> String {
        format!(
            "{}/{} loss spikes follow an RMS spike by {}-{} iters \
             (chance/spike {:.2}%, p = {:.2e}; {} RMS spikes)",
            self.predicted,
            self.total_loss_spikes,
            LEAD_MIN,
            LEAD_MAX,
            100.0 * self.chance_fraction,
            self.binom_pvalue,
            self.rms_spikes.len(),
        )
    }
}

fn binom_upper_tail(n: usize, k: usize, p: f64) -> f64 {
    // sum_{i=k..n} C(n,i) p^i (1-p)^(n-i), computed in log space for
    // robustness on tiny p.
    if k == 0 {
        return 1.0;
    }
    let ln_fact = |m: usize| -> f64 { (1..=m).map(|v| (v as f64).ln()).sum() };
    let lnp = p.max(1e-300).ln();
    let lnq = (1.0 - p).max(1e-300).ln();
    let mut total = 0.0f64;
    for i in k..=n {
        let lnc = ln_fact(n) - ln_fact(i) - ln_fact(n - i);
        total += (lnc + i as f64 * lnp + (n - i) as f64 * lnq).exp();
    }
    total.min(1.0)
}

/// Is iteration `t` predicted by any RMS spike? (some s with t-8 ≤ s ≤ t-1)
fn is_predicted(t: u64, rms_spikes: &[u64]) -> bool {
    rms_spikes
        .iter()
        .any(|&s| s + LEAD_MIN <= t && t <= s + LEAD_MAX)
}

/// Full analysis from raw traces.
pub fn lead_lag_analysis(
    loss: &[f32],
    rms: &[f32],
    cfg: &SpikeConfig,
) -> LeadLagReport {
    let loss_spikes = detect_loss_spikes(loss, cfg);
    let rms_spikes = detect_rms_spikes(rms, cfg);
    lead_lag_from_events(&loss_spikes, &rms_spikes, loss.len() as u64)
}

/// Analysis from pre-detected spike events (used by sweep aggregation,
/// where spikes from many runs pool into one report as in Fig 16/17).
pub fn lead_lag_from_events(
    loss_spikes: &[u64],
    rms_spikes: &[u64],
    trace_len: u64,
) -> LeadLagReport {
    let predicted = loss_spikes
        .iter()
        .filter(|&&t| is_predicted(t, rms_spikes))
        .count();
    // Union of prediction windows (events are sorted; windows are length 8).
    let mut covered = 0u64;
    let mut last_end = 0u64;
    for &s in rms_spikes {
        let start = (s + LEAD_MIN).max(last_end);
        let end = (s + LEAD_MAX + 1).min(trace_len);
        if end > start {
            covered += end - start;
        }
        last_end = last_end.max(end);
    }
    let chance = if trace_len > 0 {
        covered as f64 / trace_len as f64
    } else {
        0.0
    };
    let pval = binom_upper_tail(loss_spikes.len(), predicted, chance);
    LeadLagReport {
        loss_spikes: loss_spikes.to_vec(),
        rms_spikes: rms_spikes.to_vec(),
        predicted,
        total_loss_spikes: loss_spikes.len(),
        chance_fraction: chance,
        binom_pvalue: pval,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_prediction() {
        // RMS spikes at 100, 200; loss spikes 3 iterations later.
        let r = lead_lag_from_events(&[103, 203], &[100, 200], 1000);
        assert_eq!(r.predicted, 2);
        assert_eq!(r.total_loss_spikes, 2);
        assert!((r.chance_fraction - 16.0 / 1000.0).abs() < 1e-9);
        assert!(r.binom_pvalue < 1e-3, "p = {}", r.binom_pvalue);
    }

    #[test]
    fn window_boundaries_are_1_to_8() {
        assert_eq!(lead_lag_from_events(&[101], &[100], 1000).predicted, 1);
        assert_eq!(lead_lag_from_events(&[108], &[100], 1000).predicted, 1);
        assert_eq!(lead_lag_from_events(&[100], &[100], 1000).predicted, 0);
        assert_eq!(lead_lag_from_events(&[109], &[100], 1000).predicted, 0);
    }

    #[test]
    fn no_rms_spikes_means_nothing_predicted() {
        let r = lead_lag_from_events(&[50, 60], &[], 100);
        assert_eq!(r.predicted, 0);
        assert_eq!(r.chance_fraction, 0.0);
        assert_eq!(r.binom_pvalue, 1.0);
    }

    #[test]
    fn overlapping_windows_counted_once() {
        // spikes at 100 and 104: windows [101,108] and [105,112] overlap.
        let r = lead_lag_from_events(&[], &[100, 104], 1000);
        assert!((r.chance_fraction - 12.0 / 1000.0).abs() < 1e-9);
    }

    #[test]
    fn binomial_tail_sane() {
        assert!((binom_upper_tail(10, 0, 0.5) - 1.0).abs() < 1e-12);
        assert!((binom_upper_tail(1, 1, 0.5) - 0.5).abs() < 1e-12);
        // 14/15 at 1% chance each: astronomically small
        assert!(binom_upper_tail(15, 14, 0.01) < 1e-20);
    }
}
