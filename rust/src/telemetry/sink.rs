//! Metrics output: one JSONL record per training step.
//!
//! Every experiment harness regenerates its figure from these logs (the
//! `exp` subcommands print figure-shaped summaries from them), so the
//! record carries everything the paper plots: loss, LR, grad norms, the
//! per-probe RMS_t values, feature magnitudes, and loss-scaler activity.

use crate::util::json::{self, ObjWriter, Value};
use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// One training step's telemetry.
#[derive(Debug, Clone, Default)]
pub struct StepRecord {
    pub step: u64,
    pub loss: f32,
    pub lr: f32,
    /// global gradient norm (pre-clip)
    pub grad_norm: f32,
    /// RMS_t for probed tensors, keyed by tensor name (patch embed + a
    /// mid-transformer control tensor, per Fig 9 vs Fig 21)
    pub rms: BTreeMap<String, f32>,
    /// the paper's spike predictor (§3.3–3.4): per-probe mean
    /// `g²/max(u, ε²)` against AdamW's second moment — values ≫ 1 mean the
    /// estimator lags the gradient distribution and a loss spike is likely
    /// 1–8 iterations out ([`crate::optim::under_estimation_ratio`])
    pub under_est: BTreeMap<String, f32>,
    /// per-block mean |features| (vision ++ text), logged every probe_every
    pub feature_mags: Vec<f32>,
    /// probes of selected gradient tensors (mean/max abs, Fig 11/14)
    pub grad_probes: BTreeMap<String, super::TensorProbe>,
    /// loss-scaler state
    pub loss_scale: Option<f32>,
    pub skipped_tensors: usize,
    pub skipped_step: bool,
    /// wall time of this step, ms (native trainer's per-step breakdown
    /// lives in BENCH_train.json; this is the per-step total)
    pub step_ms: Option<f32>,
}

impl StepRecord {
    /// Serialize to one JSON line.
    pub fn to_json(&self) -> String {
        let mut w = ObjWriter::new();
        w.field_u64("step", self.step)
            .field_f32("loss", self.loss)
            .field_f32("lr", self.lr)
            .field_f32("grad_norm", self.grad_norm);
        if !self.rms.is_empty() {
            let mut inner = ObjWriter::new();
            for (k, v) in &self.rms {
                inner.field_f32(k, *v);
            }
            w.field_raw("rms", &inner.finish());
        }
        if !self.under_est.is_empty() {
            let mut inner = ObjWriter::new();
            for (k, v) in &self.under_est {
                inner.field_f32(k, *v);
            }
            w.field_raw("under_estimation_ratio", &inner.finish());
        }
        if !self.feature_mags.is_empty() {
            w.field_f32_arr("feature_mags", &self.feature_mags);
        }
        if !self.grad_probes.is_empty() {
            let mut inner = ObjWriter::new();
            for (k, p) in &self.grad_probes {
                let mut pw = ObjWriter::new();
                pw.field_f32("mean_abs", p.mean_abs)
                    .field_f32("max_abs", p.max_abs)
                    .field_bool("nonfinite", p.nonfinite);
                inner.field_raw(k, &pw.finish());
            }
            w.field_raw("grad_probes", &inner.finish());
        }
        if let Some(s) = self.loss_scale {
            w.field_f32("loss_scale", s);
        }
        if self.skipped_tensors > 0 {
            w.field_u64("skipped_tensors", self.skipped_tensors as u64);
        }
        if self.skipped_step {
            w.field_bool("skipped_step", true);
        }
        if let Some(ms) = self.step_ms {
            w.field_f32("step_ms", ms);
        }
        w.finish()
    }

    /// Parse back from one JSON line (offline analysis path).
    pub fn from_json(line: &str) -> Option<Self> {
        let v = json::parse(line).ok()?;
        let f = |k: &str| v.get(k).and_then(Value::as_f64).unwrap_or(0.0) as f32;
        let mut rec = StepRecord {
            step: v.get("step").and_then(Value::as_f64).unwrap_or(0.0) as u64,
            loss: f("loss"),
            lr: f("lr"),
            grad_norm: f("grad_norm"),
            loss_scale: v.get("loss_scale").and_then(Value::as_f64).map(|x| x as f32),
            skipped_tensors: v
                .get("skipped_tensors")
                .and_then(Value::as_usize)
                .unwrap_or(0),
            skipped_step: v
                .get("skipped_step")
                .and_then(Value::as_bool)
                .unwrap_or(false),
            step_ms: v.get("step_ms").and_then(Value::as_f64).map(|x| x as f32),
            ..Default::default()
        };
        if let Some(Value::Obj(m)) = v.get("rms") {
            for (k, x) in m {
                if let Some(x) = x.as_f64() {
                    rec.rms.insert(k.clone(), x as f32);
                }
            }
        }
        if let Some(Value::Obj(m)) = v.get("under_estimation_ratio") {
            for (k, x) in m {
                if let Some(x) = x.as_f64() {
                    rec.under_est.insert(k.clone(), x as f32);
                }
            }
        }
        if let Some(arr) = v.get("feature_mags").and_then(Value::as_arr) {
            rec.feature_mags =
                arr.iter().map(|x| x.as_f64().unwrap_or(0.0) as f32).collect();
        }
        if let Some(Value::Obj(m)) = v.get("grad_probes") {
            for (k, p) in m {
                rec.grad_probes.insert(
                    k.clone(),
                    super::TensorProbe {
                        mean_abs: p.get("mean_abs").and_then(Value::as_f64).unwrap_or(0.0)
                            as f32,
                        max_abs: p.get("max_abs").and_then(Value::as_f64).unwrap_or(0.0)
                            as f32,
                        nonfinite: p
                            .get("nonfinite")
                            .and_then(Value::as_bool)
                            .unwrap_or(false),
                    },
                );
            }
        }
        Some(rec)
    }
}

/// Buffered JSONL writer + in-memory trace (the analyzers read the trace
/// directly; the file is for offline plotting).
pub struct MetricsSink {
    writer: Option<BufWriter<File>>,
    pub records: Vec<StepRecord>,
}

impl MetricsSink {
    /// In-memory only.
    pub fn memory() -> Self {
        Self { writer: None, records: vec![] }
    }

    /// Also append JSONL to `path`.
    pub fn to_file(path: &Path) -> std::io::Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        Ok(Self {
            writer: Some(BufWriter::new(File::create(path)?)),
            records: vec![],
        })
    }

    pub fn log(&mut self, rec: StepRecord) {
        if let Some(w) = &mut self.writer {
            // best-effort: metrics must never kill a training run
            let _ = writeln!(w, "{}", rec.to_json());
        }
        self.records.push(rec);
    }

    pub fn flush(&mut self) {
        if let Some(w) = &mut self.writer {
            let _ = w.flush();
        }
    }

    /// Loss trace (for the spike detectors).
    pub fn loss_trace(&self) -> Vec<f32> {
        self.records.iter().map(|r| r.loss).collect()
    }

    /// RMS trace for one probed tensor name (missing entries become 1.0).
    pub fn rms_trace(&self, tensor: &str) -> Vec<f32> {
        self.records
            .iter()
            .map(|r| r.rms.get(tensor).copied().unwrap_or(1.0))
            .collect()
    }

    /// Number of loss-scale drops observed across the run.
    pub fn scale_drops(&self) -> usize {
        let mut drops = 0;
        let mut prev: Option<f32> = None;
        for r in &self.records {
            if let (Some(p), Some(s)) = (prev, r.loss_scale) {
                if s < p {
                    drops += 1;
                }
            }
            prev = r.loss_scale.or(prev);
        }
        drops
    }
}

impl Drop for MetricsSink {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsonl_roundtrip() {
        let dir = std::env::temp_dir().join("switchback_sink_test");
        let path = dir.join("run.jsonl");
        {
            let mut sink = MetricsSink::to_file(&path).unwrap();
            for step in 0..3 {
                let mut rec = StepRecord {
                    step,
                    loss: step as f32,
                    ..Default::default()
                };
                rec.rms.insert("pe".into(), 2.5);
                rec.feature_mags = vec![1.0, 2.0];
                sink.log(rec);
            }
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let recs: Vec<StepRecord> = text
            .lines()
            .map(|l| StepRecord::from_json(l).unwrap())
            .collect();
        assert_eq!(recs.len(), 3);
        assert_eq!(recs[2].loss, 2.0);
        assert_eq!(recs[1].rms.get("pe"), Some(&2.5));
        assert_eq!(recs[0].feature_mags, vec![1.0, 2.0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn probe_roundtrip() {
        let mut rec = StepRecord { step: 9, ..Default::default() };
        rec.grad_probes.insert(
            "visual.patch_embed".into(),
            super::super::TensorProbe { mean_abs: 0.5, max_abs: 7.0, nonfinite: true },
        );
        rec.loss_scale = Some(65536.0);
        rec.skipped_step = true;
        rec.step_ms = Some(12.5);
        let back = StepRecord::from_json(&rec.to_json()).unwrap();
        let p = back.grad_probes.get("visual.patch_embed").unwrap();
        assert_eq!(p.max_abs, 7.0);
        assert!(p.nonfinite);
        assert_eq!(back.loss_scale, Some(65536.0));
        assert!(back.skipped_step);
        assert_eq!(back.step_ms, Some(12.5));
    }

    /// The spike-predictor field survives the JSONL round trip and stays
    /// absent (not `{}`) when no probes ran this step.
    #[test]
    fn under_estimation_ratio_roundtrip() {
        let mut rec = StepRecord { step: 3, ..Default::default() };
        rec.under_est.insert("visual.patch_embed".into(), 1.551);
        rec.under_est.insert("visual.block5".into(), 0.97);
        let line = rec.to_json();
        assert!(line.contains("\"under_estimation_ratio\""));
        let back = StepRecord::from_json(&line).unwrap();
        assert_eq!(back.under_est.len(), 2);
        assert!((back.under_est["visual.patch_embed"] - 1.551).abs() < 1e-6);
        assert!((back.under_est["visual.block5"] - 0.97).abs() < 1e-6);

        let bare = StepRecord::default().to_json();
        assert!(!bare.contains("under_estimation_ratio"));
    }

    #[test]
    fn scale_drop_counting() {
        let mut sink = MetricsSink::memory();
        for (i, s) in [65536.0, 65536.0, 32768.0, 32768.0, 16384.0]
            .iter()
            .enumerate()
        {
            sink.log(StepRecord {
                step: i as u64,
                loss_scale: Some(*s),
                ..Default::default()
            });
        }
        assert_eq!(sink.scale_drops(), 2);
    }

    #[test]
    fn traces() {
        let mut sink = MetricsSink::memory();
        let mut rms = BTreeMap::new();
        rms.insert("pe".to_string(), 3.0f32);
        sink.log(StepRecord { step: 0, loss: 1.0, rms, ..Default::default() });
        sink.log(StepRecord { step: 1, loss: 2.0, ..Default::default() });
        assert_eq!(sink.loss_trace(), vec![1.0, 2.0]);
        assert_eq!(sink.rms_trace("pe"), vec![3.0, 1.0]);
    }
}
