//! Stability telemetry (paper §3.3–3.4, Appendix D).
//!
//! * [`spikes`] — the paper's heuristics for detecting loss spikes
//!   (running mean + 3.2σ, multi-deviation confirmation, 10-iteration
//!   dedup) and RMS spikes (`RMS_t ≥ 2.3`).
//! * [`analyzer`] — the lead–lag analysis behind Fig 9 & 16–21: do loss
//!   spikes follow RMS spikes in the patch embedding by 1–8 iterations,
//!   and what is the probability of that by chance?
//! * [`sink`] — JSONL/CSV metrics output consumed by the experiment
//!   harnesses (every figure regenerates from these logs).
//! * [`histogram`] — lock-free log-bucketed latency histograms
//!   (p50/p95/p99) backing the serve engine's request/batch telemetry.

pub mod analyzer;
pub mod histogram;
pub mod sink;
pub mod spikes;

pub use analyzer::{lead_lag_analysis, lead_lag_from_events, LeadLagReport};
pub use histogram::Histogram;
pub use sink::{MetricsSink, StepRecord};
pub use spikes::{
    detect_loss_spikes, detect_rms_spikes, SpikeConfig, DEFAULT_LOSS_SIGMA,
    DEFAULT_RMS_THRESHOLD,
};

/// Summary statistics of a gradient tensor for probes (Fig 11, Fig 14).
#[derive(Debug, Clone, Default)]
pub struct TensorProbe {
    pub mean_abs: f32,
    pub max_abs: f32,
    pub nonfinite: bool,
}

impl TensorProbe {
    pub fn of(data: &[f32]) -> Self {
        if data.is_empty() {
            return Self::default();
        }
        let mut sum = 0.0f64;
        let mut max = 0.0f32;
        let mut nonfinite = false;
        for &v in data {
            if !v.is_finite() {
                nonfinite = true;
                continue;
            }
            sum += v.abs() as f64;
            max = max.max(v.abs());
        }
        Self { mean_abs: (sum / data.len() as f64) as f32, max_abs: max, nonfinite }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_stats() {
        let p = TensorProbe::of(&[1.0, -2.0, 3.0, -4.0]);
        assert!((p.mean_abs - 2.5).abs() < 1e-6);
        assert_eq!(p.max_abs, 4.0);
        assert!(!p.nonfinite);
        assert!(TensorProbe::of(&[f32::INFINITY]).nonfinite);
    }
}
