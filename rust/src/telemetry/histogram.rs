//! Lock-free latency histogram for the serving path (p50/p95/p99).
//!
//! Log-bucketed with 16 linear sub-buckets per power of two, the classic
//! HdrHistogram layout: worst-case quantile error is one sub-bucket width,
//! ≤ 1/16 ≈ 6% relative — plenty for serving dashboards, and recording is
//! a single relaxed atomic increment so worker threads never contend.
//!
//! Values are `u64` (the serve engine records nanoseconds); 0 is clamped
//! to 1 so everything lands in a bucket.

use std::sync::atomic::{AtomicU64, Ordering};

/// 16 sub-buckets per octave.
const SUB_BITS: u32 = 4;
const SUB: usize = 1 << SUB_BITS; // 16
/// Values < 16 get exact buckets; octaves above cover up to u64::MAX.
const OCTAVES: usize = 61; // (63 - SUB_BITS) octaves + the exact range
const BUCKETS: usize = SUB + OCTAVES * SUB;

/// A fixed-size, lock-free histogram of `u64` samples.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Bucket index for a value (see module docs for the layout).
fn index_of(v: u64) -> usize {
    let v = v.max(1);
    if v < SUB as u64 {
        return v as usize;
    }
    // highest set bit position; v >= 16 so msb >= 4
    let msb = 63 - v.leading_zeros();
    let sub = ((v >> (msb - SUB_BITS)) as usize) & (SUB - 1);
    let octave = (msb - SUB_BITS) as usize + 1; // v in [16,32) -> octave 1
    (octave * SUB + sub).min(BUCKETS - 1)
}

/// Representative (midpoint) value of a bucket.
fn value_of(idx: usize) -> u64 {
    if idx < SUB {
        return idx as u64;
    }
    let octave = (idx / SUB - 1) as u32 + SUB_BITS; // lower bound msb
    let sub = (idx % SUB) as u64;
    let lower = (1u64 << octave) + (sub << (octave - SUB_BITS));
    let width = 1u64 << (octave - SUB_BITS);
    lower + width / 2
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one sample (relaxed atomics — safe from any thread).
    pub fn record(&self, v: u64) {
        self.buckets[index_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of every recorded sample (as recorded, not bucket midpoints).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Fold every sample of `other` into `self` (bucket-wise addition).
    ///
    /// All loads/adds are relaxed, so merging is safe while either side is
    /// still being recorded into; samples landing mid-merge are either
    /// fully included or left for a later merge, never double-counted
    /// (each bucket is read exactly once).  The per-thread-histogram →
    /// merge pattern gives contention-free recording with one aggregate
    /// view at the end.
    pub fn merge(&self, other: &Histogram) {
        for (dst, src) in self.buckets.iter().zip(&other.buckets) {
            let n = src.load(Ordering::Relaxed);
            if n > 0 {
                dst.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum.fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max.fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Per-bucket counts (index-aligned across histograms) — lets a merge
    /// be verified bucket-for-bucket, not just through the summaries.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum.load(Ordering::Relaxed) as f64 / n as f64
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Quantile estimate, `q` in [0, 1].  Returns 0 on an empty histogram.
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                return value_of(i);
            }
        }
        self.max()
    }

    /// (p50, p95, p99) in one walk-friendly call.
    pub fn percentiles(&self) -> (u64, u64, u64) {
        (self.quantile(0.50), self.quantile(0.95), self.quantile(0.99))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values_are_exact() {
        let h = Histogram::new();
        for v in [0u64, 1, 5, 15] {
            h.record(v);
        }
        // 0 clamps to 1
        assert_eq!(h.quantile(0.01), 1);
        assert_eq!(h.max(), 15);
        assert_eq!(h.count(), 4);
    }

    #[test]
    fn quantiles_within_subbucket_error() {
        let h = Histogram::new();
        // 1..=10_000 uniformly: p50 ≈ 5000, p95 ≈ 9500, p99 ≈ 9900
        for v in 1..=10_000u64 {
            h.record(v);
        }
        let (p50, p95, p99) = h.percentiles();
        let close = |got: u64, want: f64| {
            let rel = (got as f64 - want).abs() / want;
            assert!(rel < 0.10, "got {got}, want ≈{want}");
        };
        close(p50, 5000.0);
        close(p95, 9500.0);
        close(p99, 9900.0);
        assert!((h.mean() - 5000.5).abs() < 1.0);
    }

    #[test]
    fn large_values_do_not_overflow_buckets() {
        let h = Histogram::new();
        h.record(u64::MAX);
        h.record(1 << 40);
        assert_eq!(h.count(), 2);
        assert!(h.quantile(0.5) >= 1 << 40);
    }

    /// Per-thread record → merge must equal one histogram recorded
    /// sequentially, bucket for bucket (the contention-free aggregation
    /// pattern the trace registry and serve metrics rely on).
    #[test]
    fn concurrent_record_then_merge_equals_sequential() {
        const THREADS: usize = 4;
        const PER: u64 = 5_000;
        let sample = |t: u64, i: u64| 1 + (t * 1_000_003 + i * 7_919) % 100_000;

        let merged = Histogram::new();
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..THREADS as u64)
                .map(|t| {
                    scope.spawn(move || {
                        let h = Histogram::new();
                        for i in 0..PER {
                            h.record(sample(t, i));
                        }
                        h
                    })
                })
                .collect();
            for h in handles {
                merged.merge(&h.join().expect("recorder thread"));
            }
        });

        let seq = Histogram::new();
        for t in 0..THREADS as u64 {
            for i in 0..PER {
                seq.record(sample(t, i));
            }
        }
        assert_eq!(merged.count(), seq.count());
        assert_eq!(merged.sum(), seq.sum());
        assert_eq!(merged.max(), seq.max());
        assert_eq!(merged.bucket_counts(), seq.bucket_counts());
        assert_eq!(merged.percentiles(), seq.percentiles());
    }

    /// Concurrent `record` into one shared histogram loses nothing: the
    /// totals equal the sequential recording of the same samples.
    #[test]
    fn concurrent_record_into_shared_histogram_loses_nothing() {
        const THREADS: u64 = 4;
        const PER: u64 = 5_000;
        let shared = Histogram::new();
        std::thread::scope(|scope| {
            for t in 0..THREADS {
                let h = &shared;
                scope.spawn(move || {
                    for i in 0..PER {
                        h.record(1 + (t * PER + i) % 4096);
                    }
                });
            }
        });
        let seq = Histogram::new();
        for v in 0..THREADS * PER {
            seq.record(1 + v % 4096);
        }
        assert_eq!(shared.count(), THREADS * PER);
        assert_eq!(shared.sum(), seq.sum());
        assert_eq!(shared.bucket_counts(), seq.bucket_counts());
    }

    /// Merging into an empty histogram is a copy; merging an empty one is
    /// a no-op.
    #[test]
    fn merge_identity_cases() {
        let a = Histogram::new();
        for v in [3u64, 40, 500_000] {
            a.record(v);
        }
        let copy = Histogram::new();
        copy.merge(&a);
        assert_eq!(copy.bucket_counts(), a.bucket_counts());
        assert_eq!((copy.count(), copy.sum(), copy.max()), (a.count(), a.sum(), a.max()));
        copy.merge(&Histogram::new());
        assert_eq!(copy.count(), a.count());
        assert_eq!(copy.bucket_counts(), a.bucket_counts());
    }

    #[test]
    fn index_value_roundtrip_is_monotone() {
        let mut last = 0usize;
        for shift in 4..40 {
            let v = 1u64 << shift;
            let i = index_of(v);
            assert!(i >= last, "index must be monotone in value");
            last = i;
            // representative value lands in the right octave
            let rep = value_of(i);
            assert!(rep >= v && rep < v * 2, "v={v} rep={rep}");
        }
    }
}
