//! Synthetic image–text corpus — the LAION-2B stand-in (DESIGN.md
//! §Substitutions).
//!
//! Generative model: `n_concepts` latent concepts.  Each concept `c` owns
//! * an image prototype: a deterministic pseudo-random patch pattern
//!   (per-concept RNG stream), and
//! * a caption template: a deterministic token sequence drawn from a
//!   concept-specific vocabulary band.
//!
//! A sample picks a concept, emits `prototype + σ·noise` as the patchified
//! image and a jittered caption.  The contrastive task is therefore
//! genuinely learnable (match image to its concept's caption against
//! in-batch negatives) but not trivial (noise, token jitter).
//!
//! **Distribution shift schedule**: at configured iterations the stream
//! rescales image intensity and/or remaps concepts.  An intensity rescale
//! abruptly changes the *patch-embedding gradient scale* — precisely the
//! "learning signal changes" precondition of the paper's stuck-in-the-past
//! scenario (§3.4) — giving the stability experiments a deterministic
//! spike trigger on a short schedule (the paper's runs are 20k iterations;
//! ours are hundreds).

use crate::tensor::{Matrix, Rng};

/// One scheduled distribution shift.
#[derive(Debug, Clone)]
pub struct Shift {
    /// iteration at which the shift takes effect (1-based, like steps)
    pub at_step: u64,
    /// multiply image intensities by this factor from then on
    pub image_gain: f32,
    /// if true, permute the concept→prototype mapping (semantic shift)
    pub remap_concepts: bool,
}

/// Dataset configuration.
#[derive(Debug, Clone)]
pub struct DataConfig {
    pub n_concepts: usize,
    pub patches: usize,
    pub patch_dim: usize,
    pub seq: usize,
    pub vocab: usize,
    /// image noise std relative to prototype std (1.0 = SNR 1)
    pub noise: f32,
    /// probability a caption token is replaced by a random one
    pub token_jitter: f32,
    pub seed: u64,
    pub shifts: Vec<Shift>,
}

impl DataConfig {
    pub fn for_model(
        patches: usize,
        patch_dim: usize,
        seq: usize,
        vocab: usize,
        seed: u64,
    ) -> Self {
        Self {
            n_concepts: 64,
            patches,
            patch_dim,
            seq,
            vocab,
            // Hard enough that 150-step runs do NOT saturate: precision /
            // optimizer quality shows up as accuracy differences (Fig 1).
            noise: 1.0,
            token_jitter: 0.2,
            seed,
            shifts: vec![],
        }
    }
}

/// A batch ready for the model: patchified images + token ids.
#[derive(Debug, Clone)]
pub struct Batch {
    /// `[batch, patches, patch_dim]` flattened row-major
    pub images: Vec<f32>,
    /// `[batch, seq]` flattened row-major
    pub tokens: Vec<i32>,
    /// concept id per example (for eval bookkeeping)
    pub concepts: Vec<usize>,
}

impl Batch {
    /// Number of examples in the batch.
    pub fn len(&self) -> usize {
        self.concepts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.concepts.is_empty()
    }

    /// The images as a `[len·patches, patch_dim]` matrix — exactly the
    /// layout the patch-embedding linear consumes (native training path).
    pub fn images_matrix(&self, patch_dim: usize) -> Matrix {
        assert!(patch_dim > 0, "patch_dim must be positive");
        assert_eq!(self.images.len() % patch_dim, 0, "patch_dim mismatch");
        Matrix::from_vec(self.images.len() / patch_dim, patch_dim, self.images.clone())
    }
}

/// A snapshot of [`SyntheticClip`]'s mutable state (checkpoint payload):
/// the live RNG words, the shift-schedule effects applied so far, and the
/// step counter that triggers future shifts.
#[derive(Debug, Clone, PartialEq)]
pub struct DataCursor {
    pub step: u64,
    pub gain: f32,
    pub mapping: Vec<usize>,
    pub rng: [u64; 4],
    pub rng_spare: Option<f32>,
}

/// The synthetic corpus stream.
pub struct SyntheticClip {
    cfg: DataConfig,
    prototypes: Vec<Vec<f32>>, // [concept][patches*patch_dim]
    /// concept -> prototype index (identity until a remap shift)
    mapping: Vec<usize>,
    rng: Rng,
    step: u64,
    gain: f32,
}

impl SyntheticClip {
    pub fn new(cfg: DataConfig) -> Self {
        let base = Rng::seed(cfg.seed);
        let dim = cfg.patches * cfg.patch_dim;
        let prototypes = (0..cfg.n_concepts)
            .map(|c| {
                let mut r = base.fork(1000 + c as u64);
                let mut p = vec![0.0f32; dim];
                r.fill_normal(&mut p, 1.0);
                p
            })
            .collect();
        let mapping = (0..cfg.n_concepts).collect();
        let rng = base.fork(1);
        Self { cfg, prototypes, mapping, rng, step: 0, gain: 1.0 }
    }

    pub fn config(&self) -> &DataConfig {
        &self.cfg
    }

    /// Canonical (jitter-free) caption for a concept — the "class prompt"
    /// used for zero-shot-style evaluation (the 80-template analogue).
    pub fn canonical_caption(&self, concept: usize) -> Vec<i32> {
        let c = concept as i32;
        let v = self.cfg.vocab as i32;
        (0..self.cfg.seq)
            .map(|i| {
                let i = i as i32;
                // concept-specific token band with positional variation
                (c * 7 + i * 3 + (c * i) % 5).rem_euclid(v)
            })
            .collect()
    }

    fn emit_example(
        &mut self,
        images: &mut Vec<f32>,
        tokens: &mut Vec<i32>,
        concept: usize,
    ) {
        let proto = &self.prototypes[self.mapping[concept]];
        let noise = self.cfg.noise;
        for &p in proto {
            images.push(self.gain * (p + noise * self.rng.normal()));
        }
        let caption = self.canonical_caption(concept);
        for tok in caption {
            if self.rng.uniform() < self.cfg.token_jitter {
                tokens.push(self.rng.below(self.cfg.vocab) as i32);
            } else {
                tokens.push(tok);
            }
        }
    }

    /// Advance the shift schedule to `step` (called by `next_batch`).
    fn apply_shifts(&mut self) {
        // collect triggered shifts first (borrow discipline)
        let triggered: Vec<Shift> = self
            .cfg
            .shifts
            .iter()
            .filter(|s| s.at_step == self.step)
            .cloned()
            .collect();
        for s in triggered {
            self.gain *= s.image_gain;
            if s.remap_concepts {
                // deterministic rotation of the concept mapping
                let n = self.mapping.len();
                self.mapping.rotate_right(n / 3 + 1);
            }
        }
    }

    /// Produce the next training batch.  Concepts are sampled without
    /// replacement while possible so in-batch negatives are distinct
    /// (contrastive training needs that at small batch sizes).
    pub fn next_batch(&mut self, batch: usize) -> Batch {
        self.step += 1;
        self.apply_shifts();
        let n = self.cfg.n_concepts;
        let mut images =
            Vec::with_capacity(batch * self.cfg.patches * self.cfg.patch_dim);
        let mut tokens = Vec::with_capacity(batch * self.cfg.seq);
        let mut concepts = Vec::with_capacity(batch);
        // shuffled concept deck, refilled as needed
        let mut deck: Vec<usize> = (0..n).collect();
        for i in 0..batch {
            if i % n == 0 {
                // Fisher–Yates reshuffle
                for j in (1..deck.len()).rev() {
                    let k = self.rng.below(j + 1);
                    deck.swap(j, k);
                }
            }
            let c = deck[i % n];
            concepts.push(c);
            self.emit_example(&mut images, &mut tokens, c);
        }
        Batch { images, tokens, concepts }
    }

    /// The stream's full mutable cursor — everything `next_batch` depends
    /// on besides the (reconstructable) config and prototypes.  Saved into
    /// checkpoints so a resumed run draws the exact same batches.
    pub fn cursor(&self) -> DataCursor {
        let (rng, spare) = self.rng.state();
        DataCursor {
            step: self.step,
            gain: self.gain,
            mapping: self.mapping.clone(),
            rng,
            rng_spare: spare,
        }
    }

    /// Restore a cursor captured by [`Self::cursor`].  The stream must
    /// have been built from the same `DataConfig` (prototypes are derived
    /// from the config seed, not part of the cursor).
    pub fn restore(&mut self, c: &DataCursor) -> Result<(), String> {
        if c.mapping.len() != self.mapping.len() {
            return Err(format!(
                "data cursor mapping has {} concepts, stream has {}",
                c.mapping.len(),
                self.mapping.len()
            ));
        }
        self.step = c.step;
        self.gain = c.gain;
        self.mapping = c.mapping.clone();
        self.rng = Rng::from_state(c.rng, c.rng_spare);
        Ok(())
    }

    /// Deterministic eval set: `per_concept` images per concept, fixed seed
    /// independent of training progress (but honouring the current gain /
    /// mapping so eval matches the live distribution).
    pub fn eval_set(&self, per_concept: usize) -> Batch {
        let mut rng = Rng::seed(self.cfg.seed ^ 0xEEAA);
        let mut images = vec![];
        let mut tokens = vec![];
        let mut concepts = vec![];
        for c in 0..self.cfg.n_concepts {
            let proto = &self.prototypes[self.mapping[c]];
            for _ in 0..per_concept {
                for &p in proto {
                    images.push(self.gain * (p + self.cfg.noise * rng.normal()));
                }
                tokens.extend(self.canonical_caption(c));
                concepts.push(c);
            }
        }
        Batch { images, tokens, concepts }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> DataConfig {
        DataConfig::for_model(16, 48, 16, 512, 7)
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = SyntheticClip::new(cfg());
        let mut b = SyntheticClip::new(cfg());
        let ba = a.next_batch(8);
        let bb = b.next_batch(8);
        assert_eq!(ba.images, bb.images);
        assert_eq!(ba.tokens, bb.tokens);
    }

    #[test]
    fn batch_shapes() {
        let mut d = SyntheticClip::new(cfg());
        let b = d.next_batch(5);
        assert_eq!(b.images.len(), 5 * 16 * 48);
        assert_eq!(b.tokens.len(), 5 * 16);
        assert_eq!(b.concepts.len(), 5);
        assert!(b.tokens.iter().all(|&t| (0..512).contains(&t)));
        assert_eq!(b.len(), 5);
        assert!(!b.is_empty());
        let m = b.images_matrix(48);
        assert_eq!((m.rows, m.cols), (5 * 16, 48));
        assert_eq!(m.data, b.images);
    }

    #[test]
    fn in_batch_negatives_distinct_for_small_batches() {
        let mut d = SyntheticClip::new(cfg());
        let b = d.next_batch(16); // ≤ n_concepts
        let mut seen = std::collections::HashSet::new();
        for &c in &b.concepts {
            assert!(seen.insert(c), "duplicate concept {c} in small batch");
        }
    }

    #[test]
    fn captions_identify_concepts() {
        let d = SyntheticClip::new(cfg());
        let c0 = d.canonical_caption(0);
        let c1 = d.canonical_caption(1);
        assert_ne!(c0, c1);
    }

    #[test]
    fn shift_changes_image_scale() {
        let mut c = cfg();
        c.shifts = vec![Shift { at_step: 3, image_gain: 8.0, remap_concepts: false }];
        c.noise = 0.0;
        let mut d = SyntheticClip::new(c);
        let b2 = d.next_batch(4);
        let b3 = d.next_batch(4); // shift has NOT fired yet at step 2
        let b_shift = d.next_batch(4); // step 3: fired
        let rms = |v: &Vec<f32>| {
            (v.iter().map(|x| (x * x) as f64).sum::<f64>() / v.len() as f64).sqrt()
        };
        assert!((rms(&b2.images) - rms(&b3.images)).abs() < 0.2);
        assert!(rms(&b_shift.images) > 4.0 * rms(&b3.images));
    }

    /// Capture mid-stream (after a shift fired), restore into a fresh
    /// stream: subsequent batches are bit-identical, including the shift
    /// state (gain, concept remap) and the un-fired tail of the schedule.
    #[test]
    fn cursor_roundtrip_resumes_exact_stream() {
        let mut c = cfg();
        c.shifts = vec![
            Shift { at_step: 2, image_gain: 4.0, remap_concepts: true },
            Shift { at_step: 5, image_gain: 0.25, remap_concepts: false },
        ];
        let mut a = SyntheticClip::new(c.clone());
        for _ in 0..3 {
            a.next_batch(6); // steps 1..3 — first shift fired, second pending
        }
        let cur = a.cursor();
        assert_eq!(cur.step, 3);
        assert_eq!(cur.gain, 4.0);
        let mut b = SyntheticClip::new(c);
        b.restore(&cur).unwrap();
        for _ in 0..4 {
            // crosses the pending at_step=5 shift on both streams
            let ba = a.next_batch(6);
            let bb = b.next_batch(6);
            assert_eq!(ba.images, bb.images);
            assert_eq!(ba.tokens, bb.tokens);
            assert_eq!(ba.concepts, bb.concepts);
        }
        // mismatched concept count fails closed
        let mut tiny = cfg();
        tiny.n_concepts = 3;
        let mut other = SyntheticClip::new(tiny);
        assert!(other.restore(&cur).is_err());
    }

    #[test]
    fn eval_set_is_labelled_and_stable() {
        let d = SyntheticClip::new(cfg());
        let e1 = d.eval_set(2);
        let e2 = d.eval_set(2);
        assert_eq!(e1.images, e2.images);
        assert_eq!(e1.concepts.len(), 64 * 2);
    }
}
