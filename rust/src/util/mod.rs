//! In-tree substrates that would normally be crates (the build environment
//! is offline, and the project mandate is to build every dependency):
//!
//! * [`json`]    — JSON parser + writer (manifests, JSONL metrics).
//! * [`threads`] — data-parallel helper over row chunks (the GEMM pool).
//! * [`float`]   — bf16 / fp16 rounding via bit manipulation.
//! * [`crc32`]   — CRC-32 integrity checks (checkpoint tensor blobs).
//! * [`bench`]   — a tiny criterion-style benchmark harness used by the
//!   `cargo bench` targets (median-of-samples timing + throughput).
//! * [`regression`] — BENCH_*.json baseline comparison (the
//!   `switchback benchdiff` CI gate).

pub mod bench;
pub mod crc32;
pub mod float;
pub mod json;
pub mod regression;
pub mod threads;
