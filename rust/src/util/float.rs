//! bf16 / fp16 rounding via bit manipulation (round-to-nearest-even),
//! replacing the `half` crate.  Used by the quantization library and the
//! §3.6 fp16 loss-scaler simulation.

/// fp16 largest finite value.
pub const F16_MAX: f32 = 65504.0;

/// Round an f32 to the nearest bfloat16 value (returned as f32).
/// bf16 is the top 16 bits of f32, so this is RNE on bit 16.
pub fn bf16_round(x: f32) -> f32 {
    if x.is_nan() {
        return x;
    }
    let bits = x.to_bits();
    // round half to even on the lower 16 bits
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x7FFF + lsb);
    f32::from_bits(rounded & 0xFFFF_0000)
}

/// Round an f32 to the nearest IEEE fp16 value (returned as f32), with
/// proper subnormals and overflow-to-infinity semantics.
pub fn fp16_round(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// f32 → fp16 bit pattern (RNE).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;
    if exp == 0xFF {
        // inf / nan
        return sign | 0x7C00 | if mant != 0 { 0x200 } else { 0 };
    }
    // unbias, rebias for fp16
    let e16 = exp - 127 + 15;
    if e16 >= 0x1F {
        return sign | 0x7C00; // overflow → inf
    }
    if e16 <= 0 {
        // subnormal fp16 (or underflow to zero)
        if e16 < -10 {
            return sign;
        }
        let full = mant | 0x0080_0000; // implicit bit
        let shift = (14 - e16) as u32; // amount to reach fp16 subnormal scale
        let sub = full >> shift;
        // RNE on the shifted-out bits
        let rem = full & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let rounded = if rem > half || (rem == half && (sub & 1) == 1) {
            sub + 1
        } else {
            sub
        };
        return sign | rounded as u16;
    }
    // normal: keep 10 mantissa bits, RNE on the lower 13
    let sub = mant >> 13;
    let rem = mant & 0x1FFF;
    let half = 0x1000;
    let mut out = ((e16 as u32) << 10) | sub;
    if rem > half || (rem == half && (out & 1) == 1) {
        out += 1; // may carry into the exponent — that is correct behaviour
    }
    if out >= 0x7C00 {
        return sign | 0x7C00;
    }
    sign | out as u16
}

/// fp16 bit pattern → f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x3FF) as u32;
    let bits = if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13) // inf/nan
    } else if exp == 0 {
        if mant == 0 {
            sign // ±0
        } else {
            // subnormal: value = ±mant * 2^-24
            let mag = (mant as f32) * 2.0f32.powi(-24);
            return if h & 0x8000 != 0 { -mag } else { mag };
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bf16_basics() {
        assert_eq!(bf16_round(1.0), 1.0);
        assert_eq!(bf16_round(0.0), 0.0);
        assert_eq!(bf16_round(-2.5), -2.5);
        // 8 mantissa bits: 1 + 2^-9 rounds to 1.0; 1 + 2^-7 is exact
        assert_eq!(bf16_round(1.0 + 2.0f32.powi(-9)), 1.0);
        assert_eq!(bf16_round(1.0 + 2.0f32.powi(-7)), 1.0 + 2.0f32.powi(-7));
        assert!(bf16_round(f32::NAN).is_nan());
        assert_eq!(bf16_round(f32::INFINITY), f32::INFINITY);
    }

    #[test]
    fn bf16_ties_to_even() {
        // exactly halfway between two bf16 values: 1 + 2^-8
        let half = 1.0 + 2.0f32.powi(-8);
        assert_eq!(bf16_round(half), 1.0, "ties to even (even mantissa is 1.0)");
    }

    #[test]
    fn fp16_roundtrip_exact_values() {
        for v in [0.0f32, 1.0, -1.0, 0.5, 65504.0, 6.1035156e-5, 2.0f32.powi(-24)] {
            assert_eq!(fp16_round(v), v, "fp16-exact {v} must round-trip");
        }
    }

    #[test]
    fn fp16_overflow_and_underflow() {
        assert_eq!(fp16_round(70000.0), f32::INFINITY);
        assert_eq!(fp16_round(-70000.0), f32::NEG_INFINITY);
        assert_eq!(fp16_round(1e-10), 0.0);
        assert!(fp16_round(f32::NAN).is_nan());
    }

    #[test]
    fn fp16_subnormals() {
        let min_sub = 2.0f32.powi(-24);
        assert_eq!(fp16_round(min_sub), min_sub);
        assert_eq!(fp16_round(min_sub * 0.4), 0.0);
        assert_eq!(fp16_round(min_sub * 0.6), min_sub);
        assert_eq!(fp16_round(-3.0 * min_sub), -3.0 * min_sub);
    }

    #[test]
    fn fp16_rne_on_normals() {
        // halfway between 2048 and 2050 (fp16 spacing at 2^11 is 2)
        assert_eq!(fp16_round(2049.0), 2048.0, "tie to even");
        assert_eq!(fp16_round(2051.0), 2052.0, "tie to even (upper)");
        assert_eq!(fp16_round(2049.5), 2050.0);
    }

    #[test]
    fn fp16_mantissa_carry_into_exponent() {
        // largest mantissa rounding up carries exponent: 1.9995117*2^k
        let v = 4095.8f32; // just below 4096
        assert_eq!(fp16_round(v), 4096.0);
    }
}
