//! CRC-32 (IEEE 802.3, the zlib/PNG polynomial) — integrity checks for
//! checkpoint tensor blobs ([`crate::ckpt`]).  Table-driven, table built
//! at compile time; no dependencies (offline build).

/// Reflected polynomial for CRC-32/ISO-HDLC (zlib, gzip, PNG).
const POLY: u32 = 0xEDB88320;

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { POLY ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// Streaming CRC-32 state (init → update… → finish).
#[derive(Debug, Clone)]
pub struct Crc32(u32);

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Self(0xFFFF_FFFF)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut c = self.0;
        for &b in bytes {
            c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.0 = c;
    }

    pub fn finish(&self) -> u32 {
        self.0 ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The standard check value: CRC-32("123456789") = 0xCBF43926.
    #[test]
    fn reference_vector() {
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7BE43);
    }

    #[test]
    fn streaming_matches_oneshot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let mut c = Crc32::new();
        for chunk in data.chunks(7) {
            c.update(chunk);
        }
        assert_eq!(c.finish(), crc32(&data));
    }

    #[test]
    fn sensitive_to_single_bit_flips() {
        let mut data = vec![0u8; 64];
        let base = crc32(&data);
        data[40] ^= 1;
        assert_ne!(crc32(&data), base);
    }
}
