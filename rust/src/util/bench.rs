//! A tiny criterion-style benchmark harness (the `cargo bench` targets are
//! `harness = false` binaries built on this).
//!
//! Methodology: warmup iterations, then `samples` timed batches; report
//! median, min, and mean — medians are robust to scheduler noise, which
//! matters because the figure benches compare *ratios* (SwitchBack vs
//! baseline) rather than absolute numbers.

use crate::trace;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub min_ns: f64,
    pub mean_ns: f64,
    pub samples: usize,
}

impl BenchResult {
    pub fn median_ms(&self) -> f64 {
        self.median_ns / 1e6
    }
}

/// Benchmark `f`, auto-calibrating the per-sample iteration count so one
/// sample takes ≳ `min_sample_ms`.
pub fn bench<F: FnMut()>(name: &str, samples: usize, mut f: F) -> BenchResult {
    // warmup + calibration
    let t0 = trace::clock();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((5e-3 / once).ceil() as usize).clamp(1, 1000);
    for _ in 0..2 {
        f();
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = trace::clock();
        for _ in 0..iters {
            f();
        }
        times.push(t.elapsed().as_secs_f64() * 1e9 / iters as f64);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let min = times[0];
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    BenchResult {
        name: name.to_string(),
        median_ns: median,
        min_ns: min,
        mean_ns: mean,
        samples,
    }
}

/// Print a result row (ms).
pub fn report(r: &BenchResult) {
    println!(
        "  {:<44} median {:>10.3} ms   min {:>10.3} ms",
        r.name,
        r.median_ns / 1e6,
        r.min_ns / 1e6
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut acc = 0u64;
        let r = bench("noop-ish", 5, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        assert!(r.median_ns > 0.0);
        assert!(r.min_ns <= r.median_ns);
        // keep the accumulator alive
        assert!(acc != 1);
    }
}
